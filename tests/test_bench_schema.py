"""Unit tests for the BENCH_*.json schema gate — one per schema.

The checker runs in CI between the smoke bench and the artifact upload;
these tests pin down what it accepts and what it must reject, per bench
family (generic rows, table3 telemetry, table5 scan rows, matrix cells).
"""
import json

import pytest

from benchmarks.check_bench_schema import PLAN_SOURCES, check_file, main


def _write(tmp_path, doc, name="BENCH_x.json"):
    path = tmp_path / name
    path.write_text(json.dumps(doc))
    return str(path)


def _rows(prefix="x.a", count=1):
    return [{"name": f"{prefix}{i}", "us_per_call": 1.5, "derived": "d"}
            for i in range(count)]


def _telemetry(hot=3):
    sources = {s: 0 for s in PLAN_SOURCES}
    sources["memory-hit"] = hot
    sources["host-build"] = 1
    return {"sources": sources, "build_seconds": {"host-build": 0.01},
            "total": hot + 1}


def _cell(**over):
    cell = {"workload": "spmv", "mesh": [8], "rung": "condensed",
            "dtype": "float32", "resolved": "condensed",
            "measured_us": 100.0, "predicted_us": 10.0, "model_error": 9.0,
            "budget": 120.0, "within_budget": True,
            "plan_source": "memory-hit", "plan_acquisitions": {}}
    cell.update(over)
    return cell


# -- generic rows schema --

def test_generic_valid(tmp_path):
    doc = {"bench": "fig2", "smoke": True, "rows": _rows()}
    assert check_file(_write(tmp_path, doc)) == []


def test_generic_rejects_bad_top_level(tmp_path):
    assert check_file(_write(tmp_path, {"bench": "x", "smoke": True}))
    assert check_file(_write(tmp_path, {"bench": "", "smoke": True,
                                        "rows": _rows()}))
    assert check_file(_write(tmp_path, {"bench": "x", "smoke": "yes",
                                        "rows": _rows()}))


def test_generic_rejects_bad_rows(tmp_path):
    bad = [{"name": "nodots", "us_per_call": 1, "derived": "d"},
           {"name": "a.b", "us_per_call": -1, "derived": "d"},
           {"name": "a.b", "us_per_call": 1, "derived": 3}]
    for row in bad:
        doc = {"bench": "x", "smoke": False, "rows": [row]}
        assert check_file(_write(tmp_path, doc))


def test_unreadable_file(tmp_path):
    path = tmp_path / "nope.json"
    assert check_file(str(path))
    path.write_text("{not json")
    assert check_file(str(path))


# -- table3: telemetry + dynamic rows + kernel rows --

def _kernel_rows(derived="predicted_us=9.1 accuracy=0.9 vs_jnp=0.98x"):
    return [{"name": "table3.kernel.gather.condensed", "us_per_call": 2.0,
             "derived": derived}]


def test_table3_valid(tmp_path):
    doc = {"bench": "table3", "smoke": True,
           "rows": _rows("table3.dynamic.r") + _kernel_rows(),
           "telemetry": _telemetry()}
    assert check_file(_write(tmp_path, doc)) == []


def test_table3_requires_telemetry_and_dynamic_rows(tmp_path):
    doc = {"bench": "table3", "smoke": True,
           "rows": _rows("table3.dynamic.r") + _kernel_rows()}
    assert any("telemetry" in e for e in check_file(_write(tmp_path, doc)))
    doc = {"bench": "table3", "smoke": True,
           "rows": _rows("table3.x") + _kernel_rows(),
           "telemetry": _telemetry()}
    assert any("dynamic" in e for e in check_file(_write(tmp_path, doc)))


def test_table3_requires_kernel_rows(tmp_path):
    doc = {"bench": "table3", "smoke": True,
           "rows": _rows("table3.dynamic.r"),
           "telemetry": _telemetry()}
    assert any("table3.kernel" in e
               for e in check_file(_write(tmp_path, doc)))


def test_table3_kernel_rows_need_prediction_columns(tmp_path):
    for derived in ("vs_jnp=1.00x", "predicted_us=9.1", "neither"):
        doc = {"bench": "table3", "smoke": True,
               "rows": _rows("table3.dynamic.r") + _kernel_rows(derived),
               "telemetry": _telemetry()}
        assert any("vs_jnp" in e for e in check_file(_write(tmp_path, doc)))


def test_table3_rejects_inconsistent_telemetry(tmp_path):
    tel = _telemetry()
    tel["total"] = 99
    doc = {"bench": "table3", "smoke": True,
           "rows": _rows("table3.dynamic.r"), "telemetry": tel}
    assert any("total" in e for e in check_file(_write(tmp_path, doc)))
    tel = _telemetry(hot=0)
    tel["sources"]["memory-hit"] = 0
    tel["total"] = 1
    doc["telemetry"] = tel
    assert any("hot-path" in e for e in check_file(_write(tmp_path, doc)))


# -- table5: scan rows --

def test_table5_requires_scan_rows(tmp_path):
    doc = {"bench": "table5", "smoke": True, "rows": _rows("table5.heat2d.")}
    assert any("scan" in e for e in check_file(_write(tmp_path, doc)))
    doc["rows"] += _rows("table5.scan.cg")
    assert check_file(_write(tmp_path, doc)) == []


# -- matrix: per-cell records --

def test_matrix_valid(tmp_path):
    doc = {"bench": "matrix", "smoke": True, "rows": _rows("matrix.a"),
           "cells": [_cell()]}
    assert check_file(_write(tmp_path, doc)) == []


def test_matrix_requires_cells(tmp_path):
    doc = {"bench": "matrix", "smoke": True, "rows": _rows("matrix.a")}
    assert any("cells" in e for e in check_file(_write(tmp_path, doc)))
    doc["cells"] = []
    assert any("cells" in e for e in check_file(_write(tmp_path, doc)))


@pytest.mark.parametrize("bad", [
    {"workload": ""}, {"rung": 3}, {"dtype": None}, {"resolved": ""},
    {"mesh": [0]}, {"mesh": "8"}, {"mesh": []},
    {"measured_us": -1}, {"predicted_us": "fast"}, {"model_error": -0.1},
    {"budget": 0}, {"within_budget": "yes"},
    {"plan_source": "magic"},
])
def test_matrix_rejects_bad_cell(tmp_path, bad):
    doc = {"bench": "matrix", "smoke": True, "rows": _rows("matrix.a"),
           "cells": [_cell(**bad)]}
    assert check_file(_write(tmp_path, doc))


def test_matrix_rejects_contradictory_verdict(tmp_path):
    # the gate's verdict may not contradict its own inputs
    doc = {"bench": "matrix", "smoke": True, "rows": _rows("matrix.a"),
           "cells": [_cell(model_error=999.0, within_budget=True)]}
    assert any("contradicts" in e for e in check_file(_write(tmp_path, doc)))
    doc["cells"] = [_cell(model_error=1.0, within_budget=False)]
    assert any("contradicts" in e for e in check_file(_write(tmp_path, doc)))


# -- serve rows schema --

def _serve_doc():
    return {"bench": "serve", "smoke": True, "rows": [
        {"name": "table_serve.engine.decode", "us_per_call": 9000.0,
         "derived": "tokens_per_s=900.0 p50_us=9000 p99_us=9300"},
        {"name": "table_serve.engine.prefill", "us_per_call": 6000.0,
         "derived": "ttft_p50_us=5800 requests=8 chunks=16"},
        {"name": "table_serve.decode_step.b1", "us_per_call": 1800.0,
         "derived": "predicted_us=4400.0 model_error=1.4 budget=360 "
                    "within_budget=True"},
    ]}


def test_serve_valid(tmp_path):
    assert check_file(_write(tmp_path, _serve_doc())) == []


def test_serve_rejects_missing_engine_metrics(tmp_path):
    doc = _serve_doc()
    doc["rows"][0]["derived"] = "p50_us=9000 p99_us=9300"   # no throughput
    assert any("tokens_per_s" in e for e in check_file(_write(tmp_path, doc)))
    doc = _serve_doc()
    doc["rows"][0]["derived"] = "tokens_per_s=900.0"        # no tail latency
    assert any("p99_us" in e for e in check_file(_write(tmp_path, doc)))


def test_serve_rejects_missing_decode_step_rows(tmp_path):
    doc = _serve_doc()
    doc["rows"] = doc["rows"][:2]
    assert any("decode_step" in e for e in check_file(_write(tmp_path, doc)))


@pytest.mark.parametrize("drop", ["predicted_us=", "model_error=",
                                  "within_budget="])
def test_serve_rejects_incomplete_decode_step_fields(tmp_path, drop):
    doc = _serve_doc()
    doc["rows"][2]["derived"] = doc["rows"][2]["derived"].replace(drop, "x_")
    errs = check_file(_write(tmp_path, doc))
    assert any(drop in e for e in errs)


# -- CLI exit codes --

def test_main_exit_codes(tmp_path, capsys):
    good = _write(tmp_path, {"bench": "fig2", "smoke": True,
                             "rows": _rows()}, "good.json")
    bad = _write(tmp_path, {"bench": "fig2", "smoke": True, "rows": []},
                 "bad.json")
    assert main([]) == 2
    assert main([good]) == 0
    assert capsys.readouterr().out.startswith("OK ")
    assert main([good, bad]) == 1
    assert "SCHEMA ERROR" in capsys.readouterr().err
