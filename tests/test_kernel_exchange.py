"""The fused Pallas exchange path (``use_kernel=True``) against the jnp
strategy ladder — the bit-identity contract, both directions.

Every kernelized rung must return the SAME BITS as its jnp sibling: the
kernels execute the identical op sequence (interpret mode lowers to the
same XLA ops), so any divergence is a routing bug, not rounding.  The
jaxpr regressions pin the kernel count per rung (the fused paths must not
silently fall back to jnp, nor grow extra passes).  Runs on whatever
devices the pytest process has (1 locally, 8 under the CI gate's
XLA_FLAGS).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (AccessPattern, IrregularGather, IrregularScatter,
                        STRATEGIES)
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _mesh():
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), ("data",)), ndev


def _gather_case(n, m, r, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(m, r)).astype(np.int32)
    return AccessPattern.from_indices(idx, n=n), idx


def _scatter_vals(rng, shape, dtype):
    # integer-valued floats: every combine is exact in f32 AND bf16, so
    # kernel-vs-jnp equality failures can only come from routing
    return rng.integers(-4, 5, size=shape).astype(np.float32).astype(dtype)


# --------------------------------------------------------------------------
# Kernel layer vs its jnp oracles (padding, feature dims, dtypes, edges)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("feat", [(), (3,)])
@pytest.mark.parametrize("block", [None, 16])
def test_pack_gather_matches_ref(feat, block):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((40,) + feat).astype(np.float32)
    idx = rng.integers(0, 40, size=37).astype(np.int32)   # 37 % 16 != 0
    got = kops.pack_gather(x, idx, block=block)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(kref.pack_gather_ref(x, idx)))


@pytest.mark.parametrize("feat", [(), (2,)])
@pytest.mark.parametrize("block", [None, 16])
def test_unpack_dest_matches_ref(feat, block):
    rng = np.random.default_rng(1)
    L, R, shard = 53, 21, 16
    recv = rng.standard_normal((R,) + feat).astype(np.float32)
    x = rng.standard_normal((shard,) + feat).astype(np.float32)
    src = rng.integers(0, R, size=L).astype(np.int32)
    own = rng.integers(0, shard, size=L).astype(np.int32)
    own_m = (rng.random(L) < 0.4).astype(np.int8)
    rem_m = ((rng.random(L) < 0.5) & (own_m == 0)).astype(np.int8)
    got = kops.unpack_dest(recv, x, src, own, own_m, rem_m, block=block)
    want = kref.unpack_dest_ref(recv, x, src, own, own_m, rem_m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("copy_own", [True, False])
def test_unpack_scatter_set_matches_ref(copy_own):
    rng = np.random.default_rng(2)
    recv = rng.standard_normal((19, 2)).astype(np.float32)
    idx = rng.integers(0, 33, size=19).astype(np.int32)
    x_own = rng.standard_normal((8, 2)).astype(np.float32)
    got = kops.unpack_scatter_set(recv, idx, x_own, 16, out_len=33,
                                  copy_own=copy_own)
    want = kref.unpack_scatter_set_ref(recv, idx, x_own, 16, out_len=33,
                                       copy_own=copy_own)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("reduce", ["add", "set", "max"])
def test_accumulate_kernels_match_ref(reduce):
    rng = np.random.default_rng(3)
    vals = _scatter_vals(rng, (29, 2), np.float32)
    idx = rng.integers(0, 11, size=29).astype(np.int32)
    got = kops.accumulate_segments(vals, idx, out_len=11, reduce=reduce)
    want = kref.accumulate_segments_ref(vals, idx, out_len=11, reduce=reduce)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    init = jnp.asarray(np.asarray(want))
    more = _scatter_vals(rng, (13, 2), np.float32)
    midx = rng.integers(0, 11, size=13).astype(np.int32)
    got2 = kops.accumulate_into(init, more, midx, reduce=reduce)
    want2 = kref.accumulate_into_ref(init, more, midx, reduce=reduce)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))


def test_pack_gather_empty_message_set():
    x = np.ones((8, 3), np.float32)
    out = kops.pack_gather(x, np.zeros((0,), np.int32))
    assert out.shape == (0, 3)


# --------------------------------------------------------------------------
# Gather direction: every rung, kernel vs jnp, bit-identical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("feat", [(), (3,)])
def test_gather_kernel_bit_identical(strategy, dtype, feat):
    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, _ = _gather_case(n, n, 4, seed=5)
    x = np.random.default_rng(5).standard_normal((n,) + feat)
    x = jnp.asarray(x).astype(dtype)
    outs = {}
    for uk in (False, True):
        g = IrregularGather(pattern, mesh, strategy=strategy, blocksize=8,
                            use_kernel=uk, use_plan_cache=False)
        outs[uk] = np.asarray(g(g.shard_vector(x)).astype(jnp.float32))
    np.testing.assert_array_equal(outs[True], outs[False])


# --------------------------------------------------------------------------
# Scatter direction: rungs x reduces x dtypes, kernel vs jnp, bit-identical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("reduce", ["add", "set", "max"])
def test_scatter_kernel_bit_identical(strategy, reduce):
    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, idx = _gather_case(n, n, 5, seed=6)
    vals = _scatter_vals(np.random.default_rng(6), idx.shape, np.float32)
    outs = {}
    for uk in (False, True):
        s = IrregularScatter(pattern, mesh, strategy=strategy, blocksize=8,
                             reduce=reduce, use_kernel=uk,
                             use_plan_cache=False)
        outs[uk] = np.asarray(s(s.shard_values(vals)))
    np.testing.assert_array_equal(outs[True], outs[False])


@pytest.mark.parametrize("strategy", ["condensed", "overlap"])
@pytest.mark.parametrize("dtype", [jnp.bfloat16])
@pytest.mark.parametrize("feat", [(), (2,)])
def test_scatter_kernel_bit_identical_bf16_feat(strategy, dtype, feat):
    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, idx = _gather_case(n, n, 4, seed=7)
    vals = _scatter_vals(np.random.default_rng(7), idx.shape + feat,
                         np.float32)
    vals = jnp.asarray(vals).astype(dtype)
    outs = {}
    for uk in (False, True):
        s = IrregularScatter(pattern, mesh, strategy=strategy, blocksize=8,
                             reduce="add", use_kernel=uk,
                             use_plan_cache=False)
        outs[uk] = np.asarray(s(s.shard_values(vals)).astype(jnp.float32))
    np.testing.assert_array_equal(outs[True], outs[False])


# --------------------------------------------------------------------------
# DistributedSpMV: transpose + use_kernel on every rung; dest + use_kernel
# (the formerly-rejected combination) routes to the dest-unpack kernel
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_spmv_transpose_kernel_all_rungs(strategy):
    from repro.core.matrix import make_mesh_like_matrix
    from repro.core.spmv import DistributedSpMV

    mesh, ndev = _mesh()
    n = 32 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 4, seed=8)
    x = np.random.default_rng(8).standard_normal(n).astype(np.float32)
    ys = {}
    for uk in (False, True):
        eng = DistributedSpMV(m, mesh, strategy=strategy, transpose=True,
                              use_kernel=uk, use_plan_cache=False)
        ys[uk] = np.asarray(eng(eng.shard_vector(x)))
    np.testing.assert_array_equal(ys[True], ys[False])


@pytest.mark.parametrize("strategy", ["replicate", "condensed", "overlap"])
def test_spmv_dest_kernel_routes_and_matches(strategy):
    """materialize="dest" + use_kernel=True used to raise; it now routes
    the exchange through the fused dest-unpack kernel, bit-identical to
    the jnp dest path (the local slot compute is shared)."""
    from repro.core.matrix import make_mesh_like_matrix
    from repro.core.spmv import DistributedSpMV

    mesh, ndev = _mesh()
    n = 32 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 4, seed=9)
    x = np.random.default_rng(9).standard_normal(n).astype(np.float32)
    ys = {}
    for uk in (False, True):
        eng = DistributedSpMV(m, mesh, strategy=strategy,
                              materialize="dest", use_kernel=uk,
                              use_plan_cache=False)
        assert eng.materialize == "dest"
        ys[uk] = np.asarray(eng(eng.shard_vector(x)))
    np.testing.assert_array_equal(ys[True], ys[False])


# --------------------------------------------------------------------------
# Jaxpr regression: the kernelized rungs run exactly the expected number
# of pallas_call equations (no silent jnp fallback, no extra passes)
# --------------------------------------------------------------------------

def _count_pallas(jaxpr) -> int:
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            count += 1
        for val in eqn.params.values():
            for sub in _jaxprs_of(val):
                count += _count_pallas(sub)
    return count


def _jaxprs_of(val):
    if hasattr(val, "jaxpr"):           # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):          # raw Jaxpr
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _jaxprs_of(v)


@pytest.mark.parametrize("use_kernel,expected", [(False, 0), (True, 2)])
def test_gather_condensed_pallas_count(use_kernel, expected):
    # kernelized condensed gather = pack + fused full unpack
    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, _ = _gather_case(n, n, 4, seed=10)
    g = IrregularGather(pattern, mesh, strategy="condensed", blocksize=8,
                        use_kernel=use_kernel, use_plan_cache=False)
    x = g.shard_vector(np.zeros(n, np.float32))
    jaxpr = jax.make_jaxpr(lambda xx: g._gather_all(xx, *g.plan_args))(x)
    assert _count_pallas(jaxpr.jaxpr) == expected


@pytest.mark.parametrize("use_kernel,expected", [(False, 0), (True, 3)])
def test_scatter_condensed_pallas_count(use_kernel, expected):
    # kernelized condensed scatter = pack-accumulate + own-accumulate
    # (issued while the collective flies) + landed-accumulate
    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, idx = _gather_case(n, n, 4, seed=11)
    s = IrregularScatter(pattern, mesh, strategy="condensed", blocksize=8,
                         reduce="add", use_kernel=use_kernel,
                         use_plan_cache=False)
    vals = s.shard_values(np.zeros(idx.shape, np.float32))
    jaxpr = jax.make_jaxpr(
        lambda vv: s._scatter_all(vv, *s.plan_args))(vals)
    assert _count_pallas(jaxpr.jaxpr) == expected


# --------------------------------------------------------------------------
# Schedule threading: schedule-wide default + per-stage override
# --------------------------------------------------------------------------

def test_schedule_use_kernel_default_and_override():
    from repro.comm.schedule import Schedule

    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, idx = _gather_case(n, n, 4, seed=12)
    rng = np.random.default_rng(12)
    vals = rng.standard_normal(idx.shape).astype(np.float32)
    x_host = rng.standard_normal(n).astype(np.float32)

    def build(**kw):
        sched = Schedule()
        x = sched.input("x")
        vl = sched.constant(vals, name="vals")
        cl = sched.constant(idx, name="cols")
        g = sched.gather(pattern, src=x, name="exchange",
                         use_kernel=kw.pop("stage_use_kernel", None))
        sched.compute(lambda xc, v_, c_: (v_ * xc[c_]).sum(-1), g, vl, cl,
                      name="spmv")
        return sched.compile(mesh, axis_name="data", strategy="condensed",
                             blocksize=8, **kw)

    base = build(use_kernel=False)
    kern = build(use_kernel=True)                     # schedule-wide default
    over = build(stage_use_kernel=True)               # per-stage override
    xs = base.shard_input(x_host)
    y0 = np.asarray(base(xs))
    np.testing.assert_array_equal(np.asarray(kern(kern.shard_input(x_host))),
                                  y0)
    np.testing.assert_array_equal(np.asarray(over(over.shard_input(x_host))),
                                  y0)
    # the kernel really engaged: the per-stage-override window holds
    # pallas_call equations, the jnp window none
    j_base = jax.make_jaxpr(base.mapped)(xs, *base.step_args)
    j_over = jax.make_jaxpr(over.mapped)(xs, *over.step_args)
    assert _count_pallas(j_base.jaxpr) == 0
    assert _count_pallas(j_over.jaxpr) == 2
