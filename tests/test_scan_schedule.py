"""Scan-level schedules: persistent exchange windows across time loops.

Covers the ``Schedule.scan`` / ``ScanSchedule`` tentpole:

* an n-step scan is bit-identical to re-dispatching the compiled one-shot
  window from a Python loop (single carry, multiple carries, and the
  double-buffered feed path);
* the whole loop is ONE ``shard_map`` for any ``n_steps``, and the
  scanned ``Heat2D.run`` resolves its plans exactly once (one plan-cache
  miss, one ``measure_hw`` memo entry — no per-step O(nnz) host work);
* the scanned double-buffered Heat2D overlap loop matches the sequential
  stencil reference, like every other rung;
* ``ConjugateGradient`` converges to the ``numpy.linalg`` reference on
  every rung including ``strategy="auto"``;
* the eq.-23′ steady-state model behaves (amortization, credit floor,
  ``rank_strategies(scan_steps=...)`` re-pricing);
* builder misuse fails loudly (``compile()`` on a double-buffered graph,
  ``feed`` on a non-db gather, double feed, exchange-tainted prime,
  carry/input mismatches).

Integer-valued data keeps float sums exact, so bit-identity tests the
scheduling machinery, not float associativity.  Runs on whatever devices
the pytest process has (1 locally, 8 under the CI gate's XLA_FLAGS).
"""
import jax
import numpy as np
import pytest

from repro.comm import AccessPattern, Schedule, plan_cache
from repro.comm import exchange as exchange_mod
from repro.comm import select
from repro.comm.exchange import clear_hw_memo
from repro.core import perfmodel as pm
from repro.core.heat2d import Heat2D
from repro.core.matrix import make_mesh_like_matrix
from repro.core.plan import Topology
from repro.core.solvers import ConjugateGradient


def _mesh():
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), ("data",)), ndev


def _case(n, r=3, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(n, r)).astype(np.int32)
    return AccessPattern.from_indices(idx, n=n), idx


def _inner_jaxprs(param_value):
    vals = param_value if isinstance(param_value, (list, tuple)) \
        else [param_value]
    return [getattr(v, "jaxpr", v) for v in vals if hasattr(v, "jaxpr")
            or hasattr(v, "eqns")]


def _count_shard_maps(jaxpr) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if "shard_map" in str(eqn.primitive):
            total += 1
        for v in eqn.params.values():
            for sub in _inner_jaxprs(v):
                total += _count_shard_maps(sub)
    return total


def _int_body(sched, pattern, idx):
    """x <- round-trip stage graph with exact integer arithmetic."""
    x = sched.input("x")
    rows = sched.constant(idx)
    g = sched.gather(pattern, src=x)
    y = sched.compute(lambda xc, r, xl: xc[r].sum(-1) - 2 * xl,
                      g, rows, x)
    return x, y


# --------------------------------------------------------------------------
# scan == python loop over the compiled one-shot window, bitwise
# --------------------------------------------------------------------------

def test_scan_matches_python_loop_bitwise():
    mesh, ndev = _mesh()
    n = 16 * ndev
    pattern, idx = _case(n)
    rng = np.random.default_rng(1)
    xv = rng.integers(-3, 4, size=n).astype(np.float32)

    sched = Schedule()
    _, y = _int_body(sched, pattern, idx)
    step = sched.compile(mesh, strategy="condensed", blocksize=8)
    ref = step.shard_input(xv)
    for _ in range(5):
        ref = step(ref)

    sched2 = Schedule()
    x2, y2 = _int_body(sched2, pattern, idx)
    loop = sched2.scan(mesh, carry=x2, output=y2,
                       strategy="condensed", blocksize=8)
    got = loop(loop.shard_input(xv), n_steps=5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # n_steps=0 is the identity
    np.testing.assert_array_equal(
        np.asarray(loop(loop.shard_input(xv), n_steps=0)), xv)


def test_multi_carry_scan_matches_numpy():
    mesh, ndev = _mesh()
    n = 16 * ndev
    pattern, idx = _case(n, seed=2)
    rng = np.random.default_rng(3)
    av = rng.integers(-3, 4, size=n).astype(np.float32)
    bv = rng.integers(-3, 4, size=n).astype(np.float32)

    sched = Schedule()
    a = sched.input("a")
    b = sched.input("b")
    rows = sched.constant(idx)
    g = sched.gather(pattern, src=a)
    a2 = sched.compute(lambda xc, r, bl: xc[r].sum(-1) + bl, g, rows, b)
    b2 = sched.compute(lambda bl: bl * 2.0, b)
    loop = sched.scan(mesh, carry=(a, b), output=(a2, b2),
                      strategy="condensed", blocksize=8)

    ra, rb = av.copy(), bv.copy()
    for _ in range(3):
        ra, rb = ra[idx].sum(-1) + rb, rb * 2.0
    fa, fb = loop(loop.shard_input(av, 0), loop.shard_input(bv, 1),
                  n_steps=3)
    np.testing.assert_array_equal(np.asarray(fa), ra)
    np.testing.assert_array_equal(np.asarray(fb), rb)


def test_double_buffer_feed_matches_in_body_gather():
    # feeding the refreshed carry is bit-identical to gathering it in-body
    # next iteration: the db value of iteration k IS gather(output k-1)
    mesh, ndev = _mesh()
    n = 16 * ndev
    pattern, idx = _case(n)
    rng = np.random.default_rng(1)
    xv = rng.integers(-3, 4, size=n).astype(np.float32)

    sched = Schedule()
    x, y = _int_body(sched, pattern, idx)
    loop = sched.scan(mesh, carry=x, output=y,
                      strategy="condensed", blocksize=8)
    want = np.asarray(loop(loop.shard_input(xv), n_steps=4))

    db = Schedule()
    xd = db.input("x")
    rows = db.constant(idx)
    gd = db.gather(pattern, double_buffer=True, prime=xd)
    yd = db.compute(lambda xc, r, xl: xc[r].sum(-1) - 2 * xl,
                    gd, rows, xd)
    db.feed(gd, yd)
    dloop = db.scan(mesh, carry=xd, output=yd,
                    strategy="condensed", blocksize=8)
    got = np.asarray(dloop(dloop.shard_input(xv), n_steps=4))
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# one window, one plan resolution — the no-per-step-host-work regression
# --------------------------------------------------------------------------

def test_scan_is_one_shard_map_for_any_n_steps():
    mesh, ndev = _mesh()
    n = 16 * ndev
    pattern, idx = _case(n)
    sched = Schedule()
    x, y = _int_body(sched, pattern, idx)
    loop = sched.scan(mesh, carry=x, output=y,
                      strategy="condensed", blocksize=8)
    v = loop.shard_input(np.zeros(n, np.float32))
    for steps in (1, 37):
        jaxpr = jax.make_jaxpr(lambda c: loop._run(steps, c))(v)
        assert _count_shard_maps(jaxpr.jaxpr) == 1, (
            f"{steps}-step scan must trace to ONE shard_map, got "
            f"{_count_shard_maps(jaxpr.jaxpr)}")


def test_heat2d_scan_resolves_plans_and_hw_once(monkeypatch, tmp_path):
    # isolate the persistent disk cache so the count below really is the
    # number of O(nnz) plan builds this construction pays
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "plans"))
    mesh2 = jax.make_mesh((1, len(jax.devices())), ("data", "model"))
    plan_cache.stats.reset()
    clear_hw_memo()
    h = Heat2D(mesh2, 8, 8 * len(jax.devices()), coef=0.1,
               strategy="auto", n_steps_hint=16)
    # TWO schedules were built (the one-shot window and the scan window)
    # over ONE O(nnz) plan build and ONE hardware calibration
    assert plan_cache.stats.misses == 1, plan_cache.stats
    assert len(exchange_mod._HW_MEMO) == 1
    phi = h.init_field(0)
    jaxpr = jax.make_jaxpr(lambda p_: h.run(p_, 16))(phi)
    assert _count_shard_maps(jaxpr.jaxpr) == 1
    # and the loop still computes the right thing on the resolved rung
    got = np.asarray(h.run(phi, 4))
    want = h.reference(np.asarray(phi), 4)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_heat2d_scan_overlap_matches_reference():
    ndev = len(jax.devices())
    shape = (2, ndev // 2) if ndev % 2 == 0 and ndev > 1 else (1, ndev)
    mesh2 = jax.make_mesh(shape, ("data", "model"))
    big_m, big_n = shape[0] * 16, shape[1] * 16
    h_ovl = Heat2D(mesh2, big_m, big_n, coef=0.07, overlap=True)
    h_cond = Heat2D(mesh2, big_m, big_n, coef=0.07, strategy="condensed")
    phi = h_ovl.init_field(3)
    want = h_ovl.reference(np.asarray(phi), 7, coef=0.07)
    np.testing.assert_allclose(np.asarray(h_ovl.run(phi, 7)), want,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_cond.run(phi, 7)), want,
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# the CG solver: convergence on every rung vs numpy.linalg
# --------------------------------------------------------------------------

def _dense(m):
    n = m.n
    a = np.zeros((n, n), np.float64)
    rows = np.repeat(np.arange(n), m.cols.shape[1]).reshape(m.cols.shape)
    np.add.at(a, (rows, m.cols), m.vals.astype(np.float64))
    a[np.arange(n), np.arange(n)] += m.diag.astype(np.float64)
    return a


@pytest.mark.parametrize("strategy", ["replicate", "blockwise", "condensed",
                                      "overlap", "auto"])
def test_cg_converges_to_linalg_reference(strategy):
    mesh, ndev = _mesh()
    m = make_mesh_like_matrix(16 * ndev, 4, seed=3)
    a = _dense(m)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(m.n).astype(np.float32)
    x_ref = np.linalg.solve(a.T @ a, b.astype(np.float64))

    cg = ConjugateGradient(m, mesh, strategy=strategy, blocksize=8,
                           n_steps_hint=50)
    x = np.asarray(cg.solve(b, n_steps=50))
    rel = np.abs(x - x_ref).max() / np.abs(x_ref).max()
    assert rel < 1e-3, (strategy, rel)
    # the iterate satisfies the normal equations, not just the ref
    resid = (a.T @ a) @ x.astype(np.float64) - b
    assert np.abs(resid).max() < 1e-3 * np.abs(b).max()


# --------------------------------------------------------------------------
# the eq.-23' steady-state model
# --------------------------------------------------------------------------

def test_scan_loop_cost_properties():
    setup, t_call = 5e-4, 2e-3
    # setup paid once: n-step loop beats n re-dispatches whenever setup > 0
    for n in (2, 10, 100):
        assert pm.scan_loop_cost(t_call, setup, n) < n * t_call
    # monotone in n, linear steady state
    t10 = pm.scan_loop_cost(t_call, setup, 10)
    t20 = pm.scan_loop_cost(t_call, setup, 20)
    assert abs((t20 - t10) - 10 * (t_call - setup)) < 1e-12
    # the credit floor: an iteration can never finish before the work the
    # in-flight exchange is hiding
    credit = 1.8e-3
    t = pm.scan_loop_cost(t_call, setup, 10, overlap_credit=credit)
    assert abs(t - (setup + 10 * credit)) < 1e-12
    # degenerate: per-iter never negative
    assert pm.scan_loop_cost(1e-5, 1e-3, 10) == 1e-3


def test_predict_scan_schedule_consistency():
    n, p = 1 << 10, 8
    rng = np.random.default_rng(0)
    cols = rng.integers(0, n, size=(n, 4)).astype(np.int32)
    from repro.comm.plan import build_comm_plan
    plan = build_comm_plan(cols, n, p, blocksize=32,
                           topology=Topology(p, 4))
    w = select.workload_from_plan(plan, 4)
    stages = [("g", "get", w, None), ("s", "put",
              select.workload_from_plan(plan.transpose(), 4), None)]
    loop = pm.predict_scan_schedule(stages, pm.ABEL, 50)
    from helpers.model_error import assert_model_error
    assert loop["total"] <= loop["sum_redispatch"]
    assert_model_error(loop["total"], loop["setup"] + 50 * loop["per_iter"],
                       budget=1e-9, label="scan total = setup + n*per_iter")
    assert loop["per_call"] == pm.predict_schedule(stages, pm.ABEL)["total"]

    # rank_strategies(scan_steps=...) is exactly the per-rung re-pricing
    base = dict(select.rank_strategies(plan, 4, pm.ABEL))
    setup = pm.window_setup_time(w.topology, pm.ABEL)
    looped = dict(select.rank_strategies(plan, 4, pm.ABEL, scan_steps=50))
    assert set(looped) == set(base)
    for name, t in base.items():
        assert_model_error(looped[name], pm.scan_loop_cost(t, setup, 50),
                           budget=1e-9, label=f"scan re-pricing [{name}]")


def test_predict_heat2d_scan_amortizes():
    w = pm.Heat2DWorkload(big_m=256, big_n=512, mprocs=2, nprocs=4,
                          topology=Topology(8, 1))
    hw = pm.ABEL.replace(tau=1e-4)
    scn = pm.predict_heat2d_scan(w, hw, 100)
    assert scn["condensed"] <= scn["redispatch"]["condensed"]
    assert scn["overlap"] <= scn["redispatch"]["overlap"]
    assert scn["setup"] > 0
    for rung, per in scn["per_iter"].items():
        assert per > 0, rung


# --------------------------------------------------------------------------
# builder misuse fails loudly
# --------------------------------------------------------------------------

def test_builder_misuse_errors():
    mesh, ndev = _mesh()
    n = 16 * ndev
    pattern, idx = _case(n)

    # compile() refuses a double-buffered graph
    sched = Schedule()
    x = sched.input("x")
    g = sched.gather(pattern, double_buffer=True, prime=x)
    sched.feed(g, x)
    with pytest.raises(ValueError, match="scan"):
        sched.compile(mesh, strategy="condensed", blocksize=8)

    # feed targets only db gathers; one feed per gather; prime required
    sched = Schedule()
    x = sched.input("x")
    g_plain = sched.gather(pattern, src=x)
    with pytest.raises(ValueError, match="double_buffer"):
        sched.feed(g_plain, x)
    with pytest.raises(ValueError, match="prime"):
        sched.gather(pattern, double_buffer=True)
    with pytest.raises(ValueError, match="src"):
        sched.gather(pattern, double_buffer=True, prime=x, src=x)

    sched = Schedule()
    x = sched.input("x")
    g = sched.gather(pattern, double_buffer=True, prime=x)
    sched.feed(g, x)
    with pytest.raises(ValueError, match="feed"):
        sched.feed(g, x)

    # a prime whose ancestry contains an exchange cannot seed the prologue
    sched = Schedule()
    x = sched.input("x")
    g0 = sched.gather(pattern, src=x)
    tainted = sched.compute(lambda xc: xc[:n], g0, name="tainted")
    g1 = sched.gather(pattern, double_buffer=True, prime=tainted)
    y = sched.compute(lambda xc: xc[:n], g1)
    sched.feed(g1, y)
    with pytest.raises(ValueError, match="exchange"):
        sched.scan(mesh, carry=x, output=y,
                   strategy="condensed", blocksize=8)

    # carries must cover every input exactly once
    sched = Schedule()
    a = sched.input("a")
    b = sched.input("b")
    ga = sched.gather(pattern, src=a)
    a2 = sched.compute(lambda xc, bl: xc[:n] + bl, ga, b)
    with pytest.raises(ValueError):
        sched.scan(mesh, carry=a, output=a2,
                   strategy="condensed", blocksize=8)
