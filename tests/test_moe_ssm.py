"""MoE dispatch and SSM correctness against brute-force references."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import moe as M
from repro.models import ssm as S

KEY = jax.random.PRNGKey(0)


def _moe_cfg(e=4, k=2, cf=8.0):
    # huge capacity factor -> no drops -> exact equality with brute force
    return ArchConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, num_experts=e,
        experts_per_token=k, capacity_factor=cf, act="swiglu",
    )


def _moe_brute_force(p, x, cfg):
    """Every token through its top-k experts, computed densely."""
    g, t, d = x.shape
    logits = jnp.einsum("gtd,de->gte", x, p["router"]["w"]).astype(
        jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        h = x @ p["w1"][e]
        h = jax.nn.silu(h) * (x @ p["w3"][e])
        y = h @ p["w2"][e]
        for kk in range(cfg.experts_per_token):
            w = jnp.where(top_e[..., kk] == e, top_p[..., kk], 0.0)
            out = out + y * w[..., None].astype(y.dtype)
    return out


@pytest.mark.parametrize("g,t,e,k", [(1, 32, 4, 2), (2, 16, 4, 1),
                                     (1, 64, 8, 2)])
def test_moe_condensed_dispatch_exact(g, t, e, k):
    cfg = _moe_cfg(e=e, k=k, cf=float(e))  # capacity >= t*k -> no drops
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (g, t, cfg.d_model))
    got = M.moe_fwd(p, x, cfg)
    want = _moe_brute_force(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(e=2, k=1, cf=0.25)  # tiny capacity forces drops
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.d_model))
    got = M.moe_fwd(p, x, cfg)
    want = _moe_brute_force(p, x, cfg)
    # dropped tokens produce zeros -> outputs differ, but finite and smaller
    assert bool(jnp.isfinite(got).all())
    assert float(jnp.abs(got).sum()) < float(jnp.abs(want).sum())


def test_random_router_seeded_and_skewed():
    """The shared bench/test router: deterministic per key, distinct
    experts per token, normalized weights, zipf-skewed expert popularity
    (expert 0 is routed to far more often than the last expert)."""
    n_tok, e, k = 512, 8, 2
    top_e, top_w = M.random_router(7, n_tok, e, k)
    te2, tw2 = M.random_router(7, n_tok, e, k)
    np.testing.assert_array_equal(top_e, te2)        # same key -> same route
    np.testing.assert_array_equal(top_w, tw2)
    te3, _ = M.random_router(8, n_tok, e, k)
    assert not np.array_equal(top_e, te3)            # different key differs
    assert top_e.dtype == np.int32 and top_w.dtype == np.float32
    assert top_e.shape == (n_tok, k) and top_w.shape == (n_tok, k)
    assert top_e.min() >= 0 and top_e.max() < e
    # top-k without replacement: a token never picks one expert twice
    assert all(len(set(row)) == k for row in top_e)
    np.testing.assert_allclose(top_w.sum(axis=1), 1.0, rtol=1e-6)
    assert top_w.min() > 0.0
    counts = np.bincount(top_e.ravel(), minlength=e)
    assert counts[0] > 2 * counts[e - 1]             # zipf-ish skew


def test_moe_aux_loss_balanced_router():
    cfg = _moe_cfg(e=4, k=2)
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 256, cfg.d_model))
    aux = {}
    M.moe_fwd(p, x, cfg, aux=aux)
    # Switch aux loss is ~1 for a balanced random router
    assert 0.5 < float(aux["moe_loss"]) < 2.5


def _ssm_cfg():
    return ArchConfig(
        name="s", family="ssm", num_layers=1, d_model=16, num_heads=0,
        num_kv_heads=0, d_ff=0, vocab_size=64, ssm_state=4, ssm_dt_rank=4,
    )


def _ssm_brute_force(p, u, cfg):
    """Sequential (per-step) recurrence — the definitional reference."""
    b, l, d = u.shape
    cache = S.init_ssm_cache(b, cfg)
    ys = []
    for i in range(l):
        y, cache = S.ssm_decode_step(p, u[:, i:i + 1], cache, cfg)
        ys.append(y[:, 0])
    return jnp.stack(ys, axis=1)


@pytest.mark.parametrize("l,chunk", [(8, 4), (16, 16), (12, 3)])
def test_ssm_chunked_scan_matches_sequential(l, chunk):
    cfg = _ssm_cfg()
    p = S.init_ssm(KEY, cfg)
    u = jax.random.normal(jax.random.PRNGKey(4), (2, l, cfg.d_model)) * 0.3
    got = S.ssm_fwd(p, u, cfg, chunk=chunk)
    want = _ssm_brute_force(p, u, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_state_carries_across_decode():
    cfg = _ssm_cfg()
    p = S.init_ssm(KEY, cfg)
    u = jax.random.normal(jax.random.PRNGKey(5), (1, 6, cfg.d_model)) * 0.3
    # decoding twice from a fresh cache == one pass
    full = _ssm_brute_force(p, u, cfg)
    cache = S.init_ssm_cache(1, cfg)
    for i in range(3):
        _, cache = S.ssm_decode_step(p, u[:, i:i + 1], cache, cfg)
    y4, _ = S.ssm_decode_step(p, u[:, 3:4], cache, cfg)
    np.testing.assert_allclose(np.asarray(y4[:, 0]), np.asarray(full[:, 3]),
                               rtol=2e-4, atol=2e-4)
