"""Persistent CommPlan cache: content-addressing, hit/miss accounting, and
engine-level reuse (second construction performs no O(nnz) rebuild)."""
import dataclasses

import numpy as np
import pytest

from repro.core.matrix import make_mesh_like_matrix
from repro.core.plan import Topology, build_comm_plan
from repro.comm import plan_cache


@pytest.fixture(autouse=True)
def isolated_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "plans"))
    monkeypatch.delenv("REPRO_PLAN_CACHE", raising=False)
    plan_cache.clear_memory_cache()
    plan_cache.stats.reset()
    yield
    plan_cache.clear_memory_cache()


def _case(seed=0, n=256, p=4, bs=16):
    m = make_mesh_like_matrix(n, 4, locality_window=n // 4,
                              long_range_frac=0.1, seed=seed)
    return m, n, p, bs, Topology(p, 2)


def _assert_plans_equal(a, b):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if f.name == "counts":
            for cf in dataclasses.fields(va):
                np.testing.assert_array_equal(getattr(va, cf.name),
                                              getattr(vb, cf.name))
        elif isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb)
        else:
            assert va == vb, f.name


def test_memory_and_disk_hits():
    m, n, p, bs, topo = _case()
    p1 = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    assert plan_cache.stats.misses == 1
    p2 = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    assert plan_cache.stats.memory_hits == 1 and plan_cache.stats.misses == 1
    _assert_plans_equal(p1, p2)

    plan_cache.clear_memory_cache()
    p3 = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    assert plan_cache.stats.disk_hits == 1 and plan_cache.stats.misses == 1
    _assert_plans_equal(p1, p3)
    # round-tripped plan is bit-identical to a fresh host-side build
    fresh = build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    _assert_plans_equal(p3, fresh)


def test_key_sensitivity():
    m, n, p, bs, topo = _case()
    base = plan_cache.plan_key(m.cols, n, p, bs, topo)
    assert base == plan_cache.plan_key(m.cols.copy(), n, p, bs, topo)
    assert base != plan_cache.plan_key(m.cols, n, p, bs * 2, topo)
    assert base != plan_cache.plan_key(m.cols, n, p, bs, Topology(p, p))
    cols2 = m.cols.copy()
    cols2[0, 0] = (cols2[0, 0] + 1) % n
    assert base != plan_cache.plan_key(cols2, n, p, bs, topo)


def test_different_matrices_do_not_collide():
    m1, n, p, bs, topo = _case(seed=1)
    m2 = make_mesh_like_matrix(n, 4, locality_window=n // 4,
                               long_range_frac=0.1, seed=2)
    p1 = plan_cache.get_comm_plan(m1.cols, n, p, blocksize=bs, topology=topo)
    p2 = plan_cache.get_comm_plan(m2.cols, n, p, blocksize=bs, topology=topo)
    assert plan_cache.stats.misses == 2
    assert not np.array_equal(p1.send_counts, p2.send_counts) or \
        not np.array_equal(p1.recv_global_idx, p2.recv_global_idx)


def test_memory_lru_eviction(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_MEM_ENTRIES", "2")
    n, p, bs = 256, 4, 16
    topo = Topology(p, 2)
    mats = [make_mesh_like_matrix(n, 4, locality_window=n // 4,
                                  long_range_frac=0.1, seed=s)
            for s in range(3)]
    for m in mats:
        plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    assert len(plan_cache._memory) == 2  # oldest evicted
    # evicted entry falls back to the disk tier, not a rebuild
    plan_cache.get_comm_plan(mats[0].cols, n, p, blocksize=bs, topology=topo)
    assert plan_cache.stats.misses == 3 and plan_cache.stats.disk_hits == 1


def test_disable_via_env(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", "0")
    m, n, p, bs, topo = _case()
    plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    assert plan_cache.stats.misses == 2 and plan_cache.stats.hits == 0


def test_corrupt_disk_entry_degrades_to_rebuild(tmp_path):
    m, n, p, bs, topo = _case()
    plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    key = plan_cache.plan_key(m.cols, n, p, bs, topo)
    path = plan_cache._disk_path(key)
    with open(path, "wb") as f:
        f.write(b"not an npz")
    plan_cache.clear_memory_cache()
    plan = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs,
                                    topology=topo)
    assert plan_cache.stats.misses == 2  # corrupt entry -> rebuild
    fresh = build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    _assert_plans_equal(plan, fresh)


def test_destination_plans_round_trip_and_reuse_base():
    """v3 entries carry the targeted-unpack arrays; attaching a Destination
    to an already-planned pattern reuses the cached base plan (no second
    O(nnz) build)."""
    from repro.comm.pattern import Destination

    m, n, p, bs, topo = _case()
    slots = m.cols[::4, :2].reshape(p, -1).astype(np.int64).copy()
    slots[:, -1] = Destination.ZERO
    dest = Destination.from_slots(s=slots)

    base = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    assert plan_cache.stats.misses == 1
    p1 = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo,
                                  destination=dest)
    # the destination entry was derived from the cached base, not rebuilt
    assert plan_cache.stats.misses == 1
    assert p1.dest_len == slots.shape[1] and base.dest_len == 0
    assert p1.dest_own_idx is not None

    # disk round trip is bit-identical, including the dest arrays
    plan_cache.clear_memory_cache()
    p2 = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo,
                                  destination=dest)
    assert plan_cache.stats.disk_hits >= 1
    _assert_plans_equal(p1, p2)
    # distinct destinations get distinct keys
    k0 = plan_cache.plan_key(m.cols, n, p, bs, topo)
    k1 = plan_cache.plan_key(m.cols, n, p, bs, topo, dest)
    slots2 = slots.copy()
    slots2[0, 0] = (slots2[0, 0] + 1) % n
    k2 = plan_cache.plan_key(m.cols, n, p, bs, topo,
                             Destination.from_slots(s=slots2))
    assert len({k0, k1, k2}) == 3


@pytest.mark.parametrize("legacy", [2, 3, 4])
def test_legacy_cache_entry_rejected_with_clear_message(legacy):
    """A genuine pre-v5 → v5 upgrade: the old build keyed its entries with
    its own version prefix, so a v5 lookup must probe those filenames too,
    surface the explicit migration warning, delete the stale-format orphan
    (it would otherwise count against the disk cap forever), count the
    eviction, and rebuild."""
    import os

    m, n, p, bs, topo = _case()
    plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    cur_path = plan_cache._disk_path(plan_cache.plan_key(m.cols, n, p, bs,
                                                         topo))
    # simulate the pre-upgrade cache: the entry lives under the legacy key
    old_path = plan_cache._disk_path(
        plan_cache._key_for_version(legacy, m.cols, n, p, bs, topo))
    os.rename(cur_path, old_path)

    plan_cache.clear_memory_cache()
    assert plan_cache.stats.evictions == 0
    with pytest.warns(UserWarning, match=f"v{legacy}.*v5"):
        plan = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs,
                                        topology=topo)
    assert not os.path.exists(old_path)  # orphan evicted, not left behind
    assert plan_cache.stats.misses == 2  # stale entry -> rebuild
    assert plan_cache.stats.evictions == 1  # ...and the unlink was counted
    fresh = build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    _assert_plans_equal(plan, fresh)


def test_stale_format_meta_rejected_by_deserialize():
    """Belt and braces: an entry whose meta says pre-v5 (however it got
    under the current key) is refused with the migration message and
    rebuilt — never reinterpreted as a current-format plan."""
    m, n, p, bs, topo = _case()
    plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    path = plan_cache._disk_path(plan_cache.plan_key(m.cols, n, p, bs, topo))
    with np.load(path) as data:
        entries = {k: data[k] for k in data.files}
    meta = entries["meta"].copy()
    meta[0] = 4  # a v4-era entry: same field set, older format stamp
    entries["meta"] = meta
    np.savez_compressed(path, **entries)

    plan_cache.clear_memory_cache()
    with pytest.warns(UserWarning, match="format v4.*v5"):
        plan = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs,
                                        topology=topo)
    assert plan_cache.stats.misses == 2  # stale entry -> rebuild
    fresh = build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    _assert_plans_equal(plan, fresh)


def test_cache_stats_snapshot_and_isolated():
    """CacheStats is capture-safe: snapshot() detaches, isolated() swaps a
    fresh module-global in and restores the old one (counts untouched)."""
    m, n, p, bs, topo = _case()
    plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    before = plan_cache.stats.snapshot()
    assert before["misses"] == 1 and before["evictions"] == 0
    with plan_cache.isolated() as inner:
        assert plan_cache.stats is inner
        assert inner.misses == 0  # fresh counters inside the context
        plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
        assert inner.memory_hits == 1 and inner.misses == 0
    assert plan_cache.stats.snapshot() == before  # outer stats untouched
    # snapshot is a detached copy, not a live view
    plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    assert before["memory_hits"] == 0


def _envelope_case(seed=0, n=256, p=4, m_rows=128, r=2):
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, n, size=(m_rows, r)).astype(np.int32)
    return cols, n, p


def test_envelope_plan_bucket_reuse_hits_and_misses():
    """Two routings whose quantized per-(reader, owner) stats round up to
    the same bucket boundaries share ONE envelope entry; a routing whose
    load crosses a bucket boundary founds a new one."""
    from repro.comm import telemetry

    cols, n, p = _envelope_case(seed=0)
    with telemetry.isolated() as tel:
        p1 = plan_cache.get_envelope_plan(cols, n, p, blocksize=16,
                                          s_max=n // p, bucket=n)
        assert plan_cache.stats.misses == 1
        assert tel.sources["host-build"] == 1
        # a different routing, same envelope: bucket=n quantizes every
        # per-pair count to the same ceiling -> reuse, no rebuild
        cols2, _, _ = _envelope_case(seed=1)
        p2 = plan_cache.get_envelope_plan(cols2, n, p, blocksize=16,
                                          s_max=n // p, bucket=n)
        assert plan_cache.stats.misses == 1
        assert plan_cache.stats.memory_hits == 1
        assert tel.sources["bucket-reuse"] == 1
        assert p2 is p1  # the founding entry, verbatim
        # the envelope geometry serves any routing it covers
        assert p2.s_max == n // p

        # fine buckets separate routings with different load envelopes
        plan_cache.get_envelope_plan(cols, n, p, blocksize=16,
                                     s_max=n // p, bucket=1)
        assert plan_cache.stats.misses == 2
        assert tel.sources["host-build"] == 2

        # disk tier: evicting memory still avoids the host rebuild
        plan_cache.clear_memory_cache()
        p3 = plan_cache.get_envelope_plan(cols2, n, p, blocksize=16,
                                          s_max=n // p, bucket=n)
        assert plan_cache.stats.misses == 2
        assert plan_cache.stats.disk_hits == 1
        _assert_plans_equal(p3, p1)


def test_envelope_plan_key_sensitivity():
    """The envelope key quantizes the routing stats — identical routings
    and bucket-equivalent routings collide (that is the point); different
    geometry, s_max, or bucket granularity never do."""
    cols, n, p = _envelope_case(seed=0)
    topo = Topology(p, 2)
    k0 = plan_cache.envelope_plan_key(cols, n, p, 16, topo, n // p, bucket=8)
    assert k0 == plan_cache.envelope_plan_key(cols.copy(), n, p, 16, topo,
                                              n // p, bucket=8)
    assert k0 != plan_cache.envelope_plan_key(cols, n, p, 32, topo, n // p,
                                              bucket=8)
    assert k0 != plan_cache.envelope_plan_key(cols, n, p, 16, topo,
                                              n // p // 2, bucket=8)
    assert k0 != plan_cache.envelope_plan_key(cols, n, p, 16, topo, n // p,
                                              bucket=4)
    assert k0 != plan_cache.envelope_plan_key(cols, n, p, 16,
                                              Topology(p, p), n // p,
                                              bucket=8)
    # a routing with a genuinely heavier per-pair load breaks the bucket
    heavy = cols.copy()
    heavy[: len(heavy) // 2] = 0  # pile half the reads onto owner 0
    assert k0 != plan_cache.envelope_plan_key(heavy, n, p, 16, topo, n // p,
                                              bucket=8)


def _assert_scatter_plans_equal(a, b):
    _assert_plans_equal(a.base, b.base)
    for name in ("tgt_global", "cond_msg_idx", "blk_msg_idx", "own_tgt_idx",
                 "win_mask", "touched"):
        np.testing.assert_array_equal(getattr(a, name), getattr(b, name))
    for cf in dataclasses.fields(a.counts):
        np.testing.assert_array_equal(getattr(a.counts, cf.name),
                                      getattr(b.counts, cf.name))


def test_scatter_plan_round_trip_and_reuse_base():
    """v4 scatter entries are O(m*r) deltas referencing the base plan: the
    gather and the scatter of one pattern share a single O(nnz) build, and
    the disk round trip reconstructs the transpose bit-identically."""
    from repro.comm.plan import derive_scatter_plan

    m, n, p, bs, topo = _case()
    base = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    s1 = plan_cache.get_scatter_plan(m.cols, n, p, blocksize=bs,
                                     topology=topo)
    assert plan_cache.stats.misses == 1 and plan_cache.stats.derives == 1
    _assert_scatter_plans_equal(s1, derive_scatter_plan(base))
    # transpose round-trips onto the cached base
    assert s1.transpose() is s1.base
    _assert_plans_equal(s1.transpose(), base)

    plan_cache.clear_memory_cache()
    s2 = plan_cache.get_scatter_plan(m.cols, n, p, blocksize=bs,
                                     topology=topo)
    assert plan_cache.stats.disk_hits >= 1
    assert plan_cache.stats.derives == 1  # no re-derivation
    _assert_scatter_plans_equal(s1, s2)
    # scatter and gather keys never collide
    assert plan_cache.plan_key(m.cols, n, p, bs, topo) != \
        plan_cache.plan_key(m.cols, n, p, bs, topo, scatter=True)


def test_concurrent_writers_no_torn_reads():
    """The write-to-temp + atomic-rename protocol must keep every reader
    seeing either a complete entry or a miss — never torn bytes — while
    several threads build/load the same plans concurrently."""
    import threading

    m, n, p, bs, topo = _case()
    fresh = build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    fresh_s = fresh.transpose()
    errors = []

    def worker():
        try:
            for _ in range(6):
                plan = plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs,
                                                topology=topo)
                _assert_plans_equal(plan, fresh)
                splan = plan_cache.get_scatter_plan(m.cols, n, p,
                                                    blocksize=bs,
                                                    topology=topo)
                _assert_scatter_plans_equal(splan, fresh_s)
                plan_cache.clear_memory_cache()  # force the disk tier
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    # the cache directory holds only complete entries (no leftover temps
    # visible under the entry names) and both load cleanly
    plan_cache.clear_memory_cache()
    _assert_plans_equal(
        plan_cache.get_comm_plan(m.cols, n, p, blocksize=bs, topology=topo),
        fresh)
    _assert_scatter_plans_equal(
        plan_cache.get_scatter_plan(m.cols, n, p, blocksize=bs,
                                    topology=topo),
        fresh_s)


def test_spmv_auto_dest_attaches_exactly_one_destination():
    """strategy="auto" with targeted unpack must not persist a throwaway
    destination entry: the strategy resolves against the base plan first,
    then exactly one Destination (the one the step actually runs) is
    attached and cached — one base entry + one dest entry on disk."""
    import glob
    import os

    import jax
    from repro.core import perfmodel as pm
    from repro.core.spmv import DistributedSpMV

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 4,
                              long_range_frac=0.1, seed=9)
    eng = DistributedSpMV(m, mesh, strategy="auto", blocksize=32, hw=pm.ABEL)
    assert eng.materialize == "dest" and eng.requested_strategy == "auto"
    files = glob.glob(os.path.join(plan_cache.cache_dir(), "*.npz"))
    assert len(files) == 2, files      # base plan + the one used Destination
    assert plan_cache.stats.misses == 1  # one O(nnz) build total


def test_engine_second_construction_hits_cache():
    import jax
    from repro.core.spmv import DistributedSpMV

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 4,
                              long_range_frac=0.1, seed=3)
    e1 = DistributedSpMV(m, mesh, strategy="condensed", blocksize=32)
    assert plan_cache.stats.misses == 1
    e2 = DistributedSpMV(m, mesh, strategy="condensed", blocksize=32)
    assert plan_cache.stats.misses == 1 and plan_cache.stats.hits >= 1
    _assert_plans_equal(e1.plan, e2.plan)
    # cached-plan engine still computes the right answer
    from repro.core.matrix import spmv_ref_np
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(e2(e2.shard_vector(x))),
                               spmv_ref_np(m, x), rtol=2e-4, atol=2e-4)
