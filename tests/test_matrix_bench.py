"""The config-driven benchmark matrix (benchmarks/matrix.py).

Covers the tentpole contract end to end:

* the YAML config loads and is structurally validated;
* ``iter_cells`` yields the full cartesian product, rungs innermost;
* a tiny in-process run produces a schema-valid ``BENCH_matrix.json``
  (validated by the SAME checker CI runs) with every cell within budget;
* a deliberately mispriced cell (the ``predict_scale`` testing hook)
  produces a budget violation — in-process, and (slow) as a non-zero
  ``benchmarks.run matrix`` exit code, which is the CI gate itself.
"""
import os
import subprocess
import sys

import jax
import pytest

from benchmarks import matrix
from benchmarks.check_bench_schema import check_file
from repro.comm import plan_cache

yaml = pytest.importorskip("yaml")


@pytest.fixture(autouse=True)
def isolated_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "plans"))
    monkeypatch.delenv("REPRO_PLAN_CACHE", raising=False)
    plan_cache.clear_memory_cache()
    plan_cache.stats.reset()
    yield
    plan_cache.clear_memory_cache()


def _tiny_cfg(ndev, *, workloads=("spmv", "moe_dispatch"),
              rungs=("condensed", "auto"), dtypes=("float32",),
              predict_scale=None):
    return {
        "matrix": {"mesh": [[ndev]], "rung": list(rungs),
                   "workload": list(workloads), "dtype": list(dtypes)},
        "run": {"iters": 2, "warmup": 1},
        "workloads": {
            "spmv": {"n": 64 * ndev, "r_nz": 4, "seed": 1},
            "spmv_skewed": {"n": 64 * ndev, "r_nz": 4, "alpha": 1.1,
                            "seed": 2},
            "moe_dispatch": {"n_tok": 32 * ndev, "d": 4, "k": 2,
                             "e_total": 8, "seed": 3},
            "gnn": {"n": 32 * ndev, "r": 4, "d": 4, "alpha": 1.1,
                    "seed": 4},
        },
        "predict_scale": dict(predict_scale or {}),
    }


# -- config loading / validation --

def test_checked_in_config_loads():
    cfg = matrix.load_matrix_config()
    assert set(cfg["matrix"]["workload"]) <= set(cfg["workloads"])
    # the checked-in config must not ship a tripped testing hook
    assert not cfg.get("predict_scale")


@pytest.mark.parametrize("mutate,msg", [
    (lambda c: c.pop("workloads"), "missing top-level"),
    (lambda c: c["matrix"].update(rung=[]), "non-empty list"),
    (lambda c: c["matrix"].update(dtype=["float16"]), "unknown dtype"),
    (lambda c: c["matrix"].update(workload=["nope"]), "nope"),
])
def test_config_validation_rejects(tmp_path, mutate, msg):
    cfg = _tiny_cfg(1)
    mutate(cfg)
    path = tmp_path / "bad.yaml"
    path.write_text(yaml.safe_dump(cfg))
    with pytest.raises(ValueError, match=msg):
        matrix.load_matrix_config(str(path))


def test_iter_cells_covers_product_rungs_innermost():
    cfg = _tiny_cfg(2, workloads=("spmv", "gnn"),
                    rungs=("replicate", "condensed"),
                    dtypes=("float32", "bfloat16"))
    cfg["matrix"]["mesh"] = [[2], [1, 2]]
    cells = list(matrix.iter_cells(cfg, smoke=False))
    assert len(cells) == 2 * 2 * 2 * 2
    combos = {(c["workload"], tuple(c["mesh"]), c["dtype"], c["rung"])
              for c in cells}
    assert len(combos) == len(cells)          # every cell distinct
    # rungs vary fastest, so consecutive pairs share everything else
    assert [c["rung"] for c in cells[:2]] == ["replicate", "condensed"]
    assert cells[0]["workload"] == cells[1]["workload"]
    assert cells[0]["mesh"] == cells[1]["mesh"]


def test_smoke_overrides_merge():
    cfg = _tiny_cfg(1)
    cfg["workloads"]["spmv"]["smoke"] = {"n": 32}
    cfg["run"]["smoke"] = {"iters": 1}
    cell = next(matrix.iter_cells(cfg, smoke=True))
    assert cell["params"]["n"] == 32
    assert cell["iters"] == 1
    cell = next(matrix.iter_cells(cfg, smoke=False))
    assert cell["params"]["n"] == 64 and "smoke" not in cell["params"]


# -- the runner + the gate --

def test_run_matrix_emits_schema_valid_artifact(tmp_path):
    # unit-test sizes are far below the calibrated smoke sizes, so budget
    # VERDICTS are not asserted here (the CI smoke run owns that claim) —
    # what must hold structurally: every cell record is complete,
    # self-consistent, and the artifact passes the CI gate's own checker
    ndev = len(jax.devices())
    cfg = _tiny_cfg(ndev)
    cells, violations = matrix.run_matrix(cfg)
    assert len(cells) == 2 * 2              # 2 workloads x 2 rungs
    assert len(violations) == sum(not c["within_budget"] for c in cells)
    for c in cells:
        assert c["measured_us"] > 0 and c["predicted_us"] > 0
        assert c["resolved"]
        assert c["within_budget"] == (c["model_error"] <= c["budget"])
    out = tmp_path / "BENCH_matrix.json"
    from benchmarks.common import drain_rows
    matrix.write_matrix_json(cells, drain_rows(), smoke=True,
                             path=str(out))
    assert check_file(str(out)) == []       # the CI gate's own checker


def test_mispriced_cell_trips_the_gate():
    ndev = len(jax.devices())
    # 1e7, not a tighter scale: at unit-test sizes the unscaled prediction
    # sits ~1e5 BELOW the dispatch-dominated measurement, and both ends
    # wobble with the per-mesh measured-hw memo — the hook must clear the
    # budget by orders of magnitude, not by a noise-sized margin
    cfg = _tiny_cfg(ndev, workloads=("spmv",), rungs=("condensed",),
                    predict_scale={"spmv": 1e7})
    cells, violations = matrix.run_matrix(cfg)
    from benchmarks.common import drain_rows
    drain_rows()
    assert len(violations) == 1
    assert "exceeds budget" in violations[0]
    assert not cells[0]["within_budget"]
    # and the artifact still validates — a tripped gate must not produce
    # a malformed trajectory record
    assert cells[0]["model_error"] > cells[0]["budget"]


@pytest.mark.slow
def test_run_cli_exits_nonzero_on_violation(tmp_path):
    cfg = _tiny_cfg(len(jax.devices()), workloads=("spmv",),
                    rungs=("condensed",), predict_scale={"spmv": 1e7})
    path = tmp_path / "mispriced.yaml"
    path.write_text(yaml.safe_dump(cfg))
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{repo}/src:{repo}"
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    env["REPRO_PLAN_CACHE_DIR"] = str(tmp_path / "plans")
    # cwd=tmp_path: the run writes its BENCH_matrix.json there, never
    # clobbering the repo's artifact
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "matrix", "--smoke",
         "--no-reexec", f"--config={path}"],
        capture_output=True, text=True, env=env, cwd=str(tmp_path))
    assert proc.returncode != 0, proc.stdout + proc.stderr
    assert "model-error budget" in proc.stderr
    assert (tmp_path / "BENCH_matrix.json").exists()


def test_ladder_volume_matches_table_convention():
    class Counts:
        def total_blockwise_volume(self):
            return 111

        def total_condensed_volume(self):
            return 42

    c = Counts()
    assert matrix.ladder_volume(c, "replicate", 8, 100) == 800
    assert matrix.ladder_volume(c, "blockwise", 8, 100) == 111
    assert matrix.ladder_volume(c, "condensed", 8, 100) == 42
    assert matrix.ladder_volume(c, "overlap", 8, 100) == 42
