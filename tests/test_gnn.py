"""The GNN neighbor-aggregation consumer (repro.models.gnn).

One fused gather → combine → scatter-update window must reproduce the
NumPy ground truth on every ladder rung, with the scatter stage riding
the gather stage's transposed base plan.  Runs on whatever devices the
pytest process has (1 locally, 8 under the CI gate's XLA_FLAGS).
"""
import jax
import numpy as np
import pytest

from repro.models.gnn import (GNNNeighborAggregate, gnn_ref_np,
                              random_neighbors)


def _mesh():
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), ("data",)), ndev


def _case(n, r, d, alpha=0.0, seed=0):
    nbrs = random_neighbors(n, r, alpha=alpha, seed=seed)
    h = np.random.default_rng(seed + 1).standard_normal(
        (n, d)).astype(np.float32)
    return nbrs, h, gnn_ref_np(h, nbrs)


def test_random_neighbors_shapes_and_bounds():
    nbrs = random_neighbors(64, 5, seed=3)
    assert nbrs.shape == (64, 5) and nbrs.dtype == np.int32
    assert nbrs.min() >= 0 and nbrs.max() < 64
    hub = random_neighbors(256, 8, alpha=1.1, seed=3)
    # the skewed law concentrates in-degree far above uniform
    top = np.sort(np.bincount(hub.ravel(), minlength=256))[-3:].sum()
    uni = np.sort(np.bincount(nbrs.ravel(), minlength=64))[-3:].sum()
    assert top / hub.size > 2 * uni / nbrs.size


def test_gnn_ref_self_edges_are_neutral():
    # a graph of only self-edges aggregates to the unchanged features
    n, d = 16, 3
    nbrs = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, 4))
    h = np.random.default_rng(0).standard_normal((n, d)).astype(np.float32)
    np.testing.assert_array_equal(gnn_ref_np(h, nbrs), h)


@pytest.mark.parametrize("strategy", ["replicate", "blockwise", "condensed",
                                      "overlap", "auto"])
def test_gnn_all_rungs_match_ref(strategy):
    mesh, ndev = _mesh()
    n, r, d = 32 * ndev, 4, 4
    nbrs, h, ref = _case(n, r, d, seed=1)
    layer = GNNNeighborAggregate(nbrs, n, mesh, strategy=strategy,
                                 use_plan_cache=False)
    out = np.asarray(layer(layer.shard_features(h)))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
    assert set(layer.strategies) == {"gather_nbrs", "scatter_upd"}
    if strategy != "auto":
        assert layer.strategies["gather_nbrs"] == strategy
    else:
        # auto pricing ran the §5 composition model for the fused window
        assert layer.predicted_window["total"] > 0.0


def test_gnn_skewed_neighbors_match_ref():
    mesh, ndev = _mesh()
    n, r, d = 32 * ndev, 6, 4
    nbrs, h, ref = _case(n, r, d, alpha=1.1, seed=2)
    layer = GNNNeighborAggregate(nbrs, n, mesh, strategy="condensed",
                                 use_plan_cache=False)
    out = np.asarray(layer(layer.shard_features(h)))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_gnn_bfloat16_accumulates_in_f32():
    # hub in-degree makes a bf16 scatter-accumulate drift unboundedly; the
    # layer upcasts messages, so the bf16 output stays within ONE final
    # rounding of the f32 ground truth even on a skewed graph
    import jax.numpy as jnp

    mesh, ndev = _mesh()
    n, r, d = 32 * ndev, 6, 4
    nbrs, h, ref = _case(n, r, d, alpha=1.1, seed=3)
    layer = GNNNeighborAggregate(nbrs, n, mesh, strategy="condensed",
                                 use_plan_cache=False)
    hb = jnp.asarray(h).astype(jnp.bfloat16)
    out = np.asarray(layer(layer.shard_features(np.asarray(hb)))
                     ).astype(np.float32)
    scale = np.maximum(np.abs(ref), 1.0)
    assert np.max(np.abs(out - ref) / scale) < 0.05


def test_gnn_rejects_bad_neighbor_shape():
    mesh, ndev = _mesh()
    with pytest.raises(AssertionError):
        GNNNeighborAggregate(np.zeros((8, 2), np.int32), 16 * ndev, mesh)
