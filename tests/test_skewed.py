"""Power-law-skewed pattern generator (repro.data.skewed) and the
eq.-11 BLOCKSIZE sweep it stresses (repro.comm.select.blocksize_sweep).

The uniform mesh-like generator flatters the blockwise model: every
block is roughly equally popular, so any blocksize looks fine.  The
zipf-hub generator concentrates remote traffic on a few columns, which
is where ``choose_blocksize`` has to actually earn its keep — and where
the sweep's curve stops being flat.
"""
import numpy as np
import pytest

from repro.comm.select import blocksize_sweep, choose_blocksize
from repro.core.perfmodel import ABEL
from repro.core.plan import Topology
from repro.data.skewed import (make_powerlaw_matrix, skew_summary,
                               zipf_column_weights)


def test_zipf_weights_normalized_and_skewed():
    w = zipf_column_weights(1024, alpha=1.1, seed=0)
    assert w.shape == (1024,)
    assert np.all(w > 0)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-12)
    # top 1% of columns carries far more than 1% of the mass
    assert np.sort(w)[-10:].sum() > 0.1


def test_powerlaw_matrix_is_valid_ellpack():
    n, r_nz = 512, 8
    m = make_powerlaw_matrix(n, r_nz, alpha=1.1, seed=1)
    assert m.n == n
    assert m.cols.shape == (n, r_nz) and m.cols.dtype == np.int32
    assert m.cols.min() >= 0 and m.cols.max() < n
    assert m.vals.shape == (n, r_nz)
    assert np.all(np.isfinite(m.diag)) and np.all(np.isfinite(m.vals))
    # diagonal dominance (the CG-friendly construction)
    assert np.all(np.abs(m.diag) >= np.abs(m.vals).sum(axis=1))


def test_powerlaw_matrix_concentrates_traffic():
    n, r_nz, p = 2048, 8, 8
    skewed = make_powerlaw_matrix(n, r_nz, alpha=1.1, seed=2)
    flat = make_powerlaw_matrix(n, r_nz, alpha=0.0, seed=2)
    s, f = skew_summary(skewed.cols, n, p), skew_summary(flat.cols, n, p)
    assert set(s) == {"top1pct_frac", "shard_imbalance"}
    assert s["top1pct_frac"] > 3 * f["top1pct_frac"]
    assert s["shard_imbalance"] >= f["shard_imbalance"] * 0.9


def test_blocksize_sweep_and_argmin():
    n, r_nz, p = 1024, 8, 8
    m = make_powerlaw_matrix(n, r_nz, alpha=1.1, seed=3)
    topo = Topology(p, 4)
    sweep = blocksize_sweep(m.cols, n, p, topology=topo, hw=ABEL)
    assert len(sweep) >= 2
    bss = [bs for bs, _ in sweep]
    assert bss == sorted(bss)                 # candidate order kept
    assert all(n // p % bs == 0 for bs in bss)
    assert all(t > 0 for _, t in sweep)
    best = choose_blocksize(m.cols, n, p, topology=topo, hw=ABEL)
    assert best == min(sweep, key=lambda kv: kv[1])[0]


def test_blocksize_sweep_skew_changes_the_curve():
    # the skewed pattern's sweep must differ from the uniform one — the
    # hub columns change which blocks are needed remotely
    n, r_nz, p = 2048, 8, 8
    topo = Topology(p, 4)
    sk = make_powerlaw_matrix(n, r_nz, alpha=1.3, seed=4)
    un = make_powerlaw_matrix(n, r_nz, alpha=0.0, seed=4)
    t_sk = dict(blocksize_sweep(sk.cols, n, p, topology=topo, hw=ABEL))
    t_un = dict(blocksize_sweep(un.cols, n, p, topology=topo, hw=ABEL))
    assert t_sk.keys() == t_un.keys()
    assert any(abs(t_sk[bs] - t_un[bs]) / t_un[bs] > 0.05 for bs in t_sk)


def test_blocksize_sweep_respects_candidates():
    n, p = 512, 8
    m = make_powerlaw_matrix(n, 4, alpha=1.1, seed=5)
    sweep = blocksize_sweep(m.cols, n, p, topology=Topology(p, 4), hw=ABEL,
                            candidates=[16, 30, 64])
    assert [bs for bs, _ in sweep] == [16, 64]   # 30 doesn't divide 64
