"""Dry-run smoke: one cheap (arch x shape x mesh) cell end-to-end in a
subprocess with the production 512-device host platform, plus unit checks of
the input_specs/skip machinery that don't need devices."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def test_dryrun_whisper_decode_cell(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # dryrun.py sets its own 512-device flag
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "decode_32k",
         "--mesh", "both", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "DRYRUN_ALL_OK" in proc.stdout
    for tag in ("pod_16x16", "multipod_2x16x16"):
        art = json.load(open(
            tmp_path / f"whisper-tiny__decode_32k__{tag}.json"))
        assert not art["skipped"]
        assert art["flops_total"] > 0
        assert art["memory_analysis"]["peak_bytes_per_device"] > 0
        assert art["dominant"] in ("compute", "memory", "collective")
        assert art["collective_ici_bytes"] >= 0
    # the multi-pod cell must exercise the pod axis (DCI traffic appears)
    mp = json.load(open(
        tmp_path / "whisper-tiny__decode_32k__multipod_2x16x16.json"))
    assert mp["num_devices"] == 512


def test_skip_rules():
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES, skip_reason
    # long_500k: runs only for sub-quadratic archs
    runs = {n for n in ("mixtral-8x22b", "hymba-1.5b", "falcon-mamba-7b")}
    from repro.configs.registry import ARCH_NAMES
    for name in ARCH_NAMES:
        cfg = get_config(name)
        r = skip_reason(cfg, SHAPES["long_500k"])
        assert (r is None) == (name in runs), name
        # every other shape always runs
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert skip_reason(cfg, SHAPES[s]) is None


def test_model_flops_convention():
    from repro.configs.registry import get_config
    from repro.runtime.steps import model_flops
    cfg = get_config("llama3-8b")
    n = cfg.flops_param_count()
    assert 6.5e9 < n < 8.5e9  # ~7B non-embedding params
    t = model_flops(cfg, mode="train", batch=256, seq=4096)
    assert t > 6 * n * 256 * 4096  # head term strictly adds
    d = model_flops(cfg, mode="decode", batch=128, seq=32768)
    assert d < t / 1000
