"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED same-family config and runs one forward/train
step and one decode step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, get_config
from repro.models.transformer import Model, RunCtx, lm_loss

KEY = jax.random.PRNGKey(0)


def _extra_for(cfg, b, key):
    if cfg.is_encdec:
        return {"frames": jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model))}
    if cfg.is_vlm:
        return {"image_embeds": jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.d_model))}
    return None


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    cfg = get_config(name)
    spec = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    }[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name):
    cfg = get_config(name, reduced=True)
    model = Model(cfg, RunCtx(remat="none", act_dtype=jnp.float32))
    params = model.init_params(KEY)
    b, s = 2, 32
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    extra = _extra_for(cfg, b, KEY)

    logits = model.forward(params, tokens, extra=extra)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, tokens, tokens, extra=extra, chunk=16))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_step(name):
    cfg = get_config(name, reduced=True)
    model = Model(cfg, RunCtx(remat="none", act_dtype=jnp.float32))
    params = model.init_params(KEY)
    b = 2
    cross_len = cfg.encoder_seq or cfg.num_image_tokens or 0
    cache = model.init_cache(b, 16, cross_len=cross_len, dtype=jnp.float32)
    extra = _extra_for(cfg, b, KEY)
    if extra is not None:
        context = next(iter(extra.values()))
        cache = model.prefill_cross(params, cache, context)
    tok = jax.random.randint(KEY, (b, 1), 0, cfg.vocab_size)
    logits, cache2 = model.decode_step(params, cache, tok)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["pos"]) == 1
    # second step advances
    logits2, cache3 = model.decode_step(params, cache2, tok)
    assert int(cache3["pos"]) == 2
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_matches_eval_shape(name):
    cfg = get_config(name, reduced=True)
    model = Model(cfg, RunCtx())
    shapes = jax.eval_shape(model.init_params, KEY)
    actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    analytic, active = cfg.param_count()
    assert active <= analytic
    # analytic count tracks the real tree within 2% (rope/minor buffers)
    assert abs(actual - analytic) / actual < 0.02, (actual, analytic)


def test_decode_matches_forward_dense():
    """Teacher-forced decode reproduces the training forward logits."""
    cfg = get_config("llama3-8b", reduced=True)
    model = Model(cfg, RunCtx(remat="none", act_dtype=jnp.float32))
    params = model.init_params(KEY)
    b, s = 2, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full = model.forward(params, tokens)
    cache = model.init_cache(b, s, dtype=jnp.float32)
    outs = []
    for i in range(s):
        logits, cache = model.decode_step(params, cache, tokens[:, i:i + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_ssm():
    cfg = get_config("falcon-mamba-7b", reduced=True)
    model = Model(cfg, RunCtx(remat="none", act_dtype=jnp.float32))
    params = model.init_params(KEY)
    b, s = 2, 8
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full = model.forward(params, tokens)
    cache = model.init_cache(b, s, dtype=jnp.float32)
    outs = []
    for i in range(s):
        logits, cache = model.decode_step(params, cache, tokens[:, i:i + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_cache_equals_full_window():
    """Ring-buffer SWA cache must agree with full attention as long as the
    context fits the window (mixtral long_500k mechanism)."""
    import dataclasses
    cfg = get_config("mixtral-8x22b", reduced=True)  # swa_window=16
    # huge capacity so train-path MoE drops cannot diverge from decode
    cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = Model(cfg, RunCtx(remat="none", act_dtype=jnp.float32))
    params = model.init_params(KEY)
    b, s = 1, 12  # < window
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full = model.forward(params, tokens)
    cache = model.init_cache(b, 64, dtype=jnp.float32)  # clamps to window 16
    assert cache["layers"]["k"].shape[2] == cfg.swa_window
    outs = []
    for i in range(s):
        logits, cache = model.decode_step(params, cache, tokens[:, i:i + 1])
        outs.append(logits[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), rtol=2e-3, atol=2e-3)
