"""Blocking-tier coverage for the ``overlap`` rung.

The end-to-end multi-device checks live in the slow subprocess tests; these
run in-process on whatever devices the pytest process has (1 locally, 8
under the CI gate's XLA_FLAGS) so a numerics regression in the own/foreign
split or the interior/edge split cannot pass the blocking job.
"""
import jax
import numpy as np

from repro.core.heat2d import Heat2D
from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
from repro.core.spmv import DistributedSpMV


def test_overlap_spmv_matches_reference():
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 8, locality_window=n // 8,
                              long_range_frac=0.1, seed=5)
    eng = DistributedSpMV(m, mesh, strategy="overlap", blocksize=32)
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(eng(eng.shard_vector(x))),
                               spmv_ref_np(m, x), rtol=2e-4, atol=2e-4)
    # the gather-only view (condensed exchange) still delivers every index
    xc = np.asarray(eng.gather_x_copy(eng.shard_vector(x)))
    ss = eng.plan.shard_size
    for q in range(ndev):
        needed = np.unique(m.cols[q * ss:(q + 1) * ss])
        np.testing.assert_array_equal(xc[q, needed], x[needed])


def test_overlap_heat2d_matches_reference():
    ndev = len(jax.devices())
    shape = (2, ndev // 2) if ndev % 2 == 0 and ndev > 1 else (1, ndev)
    mesh = jax.make_mesh(shape, ("data", "model"))
    h = Heat2D(mesh, shape[0] * 16, shape[1] * 16, coef=0.1, overlap=True)
    phi = h.init_field(1)
    got = np.asarray(h.run(phi, 5))
    want = h.reference(np.asarray(phi), 5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_heat2d_auto_enables_split_when_overlap_wins():
    """strategy="auto" resolving to overlap must actually run the
    interior/edge split — the §5 model's predicted win exists only if
    compute is scheduled inside the exchange window."""
    from repro.core import perfmodel as pm

    ndev = len(jax.devices())
    shape = (2, ndev // 2) if ndev % 2 == 0 and ndev > 1 else (1, ndev)
    mesh = jax.make_mesh(shape, ("data", "model"))
    h = Heat2D(mesh, shape[0] * 16, shape[1] * 16, strategy="auto",
               hw=pm.ABEL)
    assert h.overlap == (h.strategy == "overlap")
    phi = h.init_field(4)
    got = np.asarray(h.run(phi, 5))
    want = h.reference(np.asarray(phi), 5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_overlap_composes_with_kernel():
    """The ladder's fourth rung through the Pallas path: the split-kernel
    on-copy variant runs the own partial on x_local and the foreign partial
    on the condensed x_copy, both through the windowed kernel."""
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 8,
                              long_range_frac=0.1, seed=0)
    eng = DistributedSpMV(m, mesh, strategy="overlap", blocksize=32,
                          use_kernel=True)
    x = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(eng(eng.shard_vector(x))),
                               spmv_ref_np(m, x), rtol=2e-4, atol=2e-4)

    mesh2 = jax.make_mesh((1, ndev), ("data", "model"))
    h = Heat2D(mesh2, 16, 16 * ndev, coef=0.1, overlap=True, use_kernel=True)
    phi = h.init_field(2)
    got = np.asarray(h.run(phi, 4))
    want = h.reference(np.asarray(phi), 4)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
