"""Round-trip properties of the AccessPattern constructors and the plan
transpose involution.

The planner's whole edifice rests on two losslessness claims:

* every constructor (``from_indices`` / ``from_ellpack`` /
  ``from_stencil5``) captures EXACTLY the index set it was given —
  promotion, n-inference and padding included — and a built plan can
  reconstruct that set bit-for-bit (``pattern_cols``);
* ``CommPlan.transpose()`` is an involution: the push-direction plan's
  ``transpose()`` returns the original gather plan *object*, so the two
  directions can never drift apart.

Property-tested with hypothesis where the extra is installed; a seeded
grid sweep covers the same space otherwise (the repo's degraded-import
pattern).  Shapes deliberately include duplicate targets inside one row
and m != n accessor sets — the historical corner cases.
"""
import itertools

import numpy as np
import pytest

from repro.comm.pattern import AccessPattern
from repro.comm.plan import build_comm_plan, pattern_cols
from repro.core.matrix import make_mesh_like_matrix

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degraded: the seeded sweep below covers the grid
    HAVE_HYPOTHESIS = False


def _random_cols(n, m, r, seed, dup):
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, n, size=(m, r))
    if dup and r > 1:
        cols[:, -1] = cols[:, 0]   # duplicate target inside one row
    return cols


# --------------------------------------------------------------------------
# from_indices: promotion, inference, exact capture
# --------------------------------------------------------------------------

def _check_from_indices(n, m, r, seed, dup):
    cols = _random_cols(n, m, r, seed, dup)
    pat = AccessPattern.from_indices(cols, n=n)
    assert (pat.m, pat.r, pat.n) == (m, r, n)
    assert pat.indices.dtype == np.int32
    np.testing.assert_array_equal(pat.indices, cols)
    # inferred n is exactly max+1, never more
    inferred = AccessPattern.from_indices(cols)
    assert inferred.n == int(cols.max()) + 1


def test_from_indices_1d_promotion():
    pat = AccessPattern.from_indices(np.array([3, 0, 2]))
    assert pat.indices.shape == (3, 1)       # (m,) promotes to (m, 1)
    assert (pat.m, pat.r, pat.n) == (3, 1, 4)
    np.testing.assert_array_equal(pat.indices[:, 0], [3, 0, 2])


def test_from_indices_rejects_out_of_bounds():
    with pytest.raises(AssertionError):
        AccessPattern.from_indices(np.array([[0, 5]]), n=4)
    with pytest.raises(AssertionError):
        AccessPattern.from_indices(np.array([[-1, 0]]), n=4)


def test_from_ellpack_equals_from_indices():
    m = make_mesh_like_matrix(64, 4, locality_window=16, seed=0)
    a = AccessPattern.from_ellpack(m)
    b = AccessPattern.from_indices(m.cols, n=m.n)
    assert a.n == b.n == m.n
    np.testing.assert_array_equal(a.indices, b.indices)


# --------------------------------------------------------------------------
# from_stencil5: shape, bounds, boundary padding, edge symmetry
# --------------------------------------------------------------------------

def _check_stencil5(big_m, big_n, mprocs, nprocs):
    pat = AccessPattern.from_stencil5(big_m, big_n, mprocs, nprocs)
    n = big_m * big_n
    assert (pat.m, pat.r, pat.n) == (n, 4, n)
    idx = pat.indices
    assert idx.min() >= 0 and idx.max() < n
    # row g is the accessor of element g, so own-id padding shows up as
    # idx[g, s] == g; exactly one pad per out-of-domain neighbor
    pads = int((idx == np.arange(n)[:, None]).sum())
    assert pads == 2 * big_m + 2 * big_n
    # the 5-point neighborhood is symmetric: every real edge a->b has b->a
    a = np.repeat(np.arange(n), 4)
    b = idx.ravel()
    real = a != b
    edges = set(zip(a[real].tolist(), b[real].tolist()))
    assert all((y, x) in edges for x, y in edges)


STENCILS = [(4, 4, 2, 2), (4, 8, 2, 2), (8, 4, 2, 4), (6, 6, 3, 2),
            (8, 8, 1, 4), (4, 12, 2, 6)]


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.sampled_from(STENCILS))
    def test_stencil5_properties(case):
        _check_stencil5(*case)
else:
    @pytest.mark.parametrize("case", STENCILS)
    def test_stencil5_properties(case):
        _check_stencil5(*case)


# --------------------------------------------------------------------------
# CommPlan: lossless cols reconstruction + transpose involution
# --------------------------------------------------------------------------

def _check_plan_roundtrip(p, shard, rows, r, seed, dup):
    n, m = p * shard, p * rows
    cols = _random_cols(n, m, r, seed, dup)
    plan = build_comm_plan(cols, n, p)
    assert (plan.m, plan.n, plan.p) == (m, n, p)
    # the overlap-split arrays are a lossless compaction of cols
    np.testing.assert_array_equal(pattern_cols(plan), cols)
    sp = plan.transpose()
    assert sp.transpose() is plan            # involution, same object
    # a re-derived scatter plan prices the same put-direction volumes
    sp2 = plan.transpose()
    np.testing.assert_array_equal(np.asarray(sp2.counts.s_local_out),
                                  np.asarray(sp.counts.s_local_out))
    np.testing.assert_array_equal(np.asarray(sp2.counts.s_remote_out),
                                  np.asarray(sp.counts.s_remote_out))


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(p=st.sampled_from([2, 4]), shard=st.sampled_from([4, 8]),
           rows=st.sampled_from([2, 4, 8]), r=st.integers(1, 4),
           seed=st.integers(0, 999), dup=st.booleans())
    def test_plan_roundtrip(p, shard, rows, r, seed, dup):
        _check_plan_roundtrip(p, shard, rows, r, seed, dup)

    @settings(max_examples=30, deadline=None)
    @given(p=st.sampled_from([2, 4]), shard=st.sampled_from([4, 8, 16]),
           rows=st.sampled_from([2, 4]), r=st.integers(1, 4),
           seed=st.integers(0, 999), dup=st.booleans())
    def test_from_indices_roundtrip(p, shard, rows, r, seed, dup):
        _check_from_indices(p * shard, p * rows, r, seed, dup)
else:
    GRID = list(itertools.product([2, 4], [4, 8], [2, 4, 8], [1, 2, 4],
                                  [0, 7], [False, True]))[::3]

    @pytest.mark.parametrize("p,shard,rows,r,seed,dup", GRID)
    def test_plan_roundtrip(p, shard, rows, r, seed, dup):
        _check_plan_roundtrip(p, shard, rows, r, seed, dup)

    @pytest.mark.parametrize("p,shard,rows,r,seed,dup", GRID)
    def test_from_indices_roundtrip(p, shard, rows, r, seed, dup):
        _check_from_indices(p * shard, p * rows, r, seed, dup)
