"""The workload-agnostic ``repro.comm`` front door: ``AccessPattern`` /
``SharedVector`` / ``IrregularGather`` / ``OverlapHandle``.

Every gather is checked against the NumPy ground truth (x_copy must equal x
at every index the pattern's shard accesses), for every ladder rung, for
m != n accessor patterns, and for vectors with trailing feature dims.  Runs
on whatever devices the pytest process has (1 locally, 8 under the CI
gate's XLA_FLAGS).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.comm import (AccessPattern, IrregularGather, SharedVector,
                        STRATEGIES, Topology, select)
from repro.core import perfmodel as pm


def _mesh():
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), ("data",)), ndev


def _check_gather(g, pattern, x, ndev):
    """Every index accessed by shard q's pattern rows must be delivered."""
    xc = np.asarray(g(g.shard_vector(x)))
    rows = pattern.m // ndev
    for q in range(ndev):
        needed = np.unique(pattern.indices[q * rows:(q + 1) * rows])
        np.testing.assert_array_equal(xc[q][needed], np.asarray(x)[needed])


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_gather_matches_numpy_reference(strategy):
    mesh, ndev = _mesh()
    n = 64 * ndev
    rng = np.random.default_rng(0)
    idx = rng.integers(0, n, size=(n, 5)).astype(np.int32)
    pattern = AccessPattern.from_indices(idx, n=n)
    g = IrregularGather(pattern, mesh, strategy=strategy, blocksize=16)
    x = rng.standard_normal(n).astype(np.float32)
    _check_gather(g, pattern, x, ndev)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_gather_with_feature_dims(strategy):
    mesh, ndev = _mesh()
    n, d = 32 * ndev, 7
    rng = np.random.default_rng(1)
    idx = rng.integers(0, n, size=(n, 3)).astype(np.int32)
    pattern = AccessPattern.from_indices(idx, n=n)
    g = IrregularGather(pattern, mesh, strategy=strategy, blocksize=8)
    x = rng.standard_normal((n, d)).astype(np.float32)
    _check_gather(g, pattern, x, ndev)


def test_gather_m_not_equal_n():
    """Accessor count decoupled from vector length (the MoE-dispatch shape)."""
    mesh, ndev = _mesh()
    n, m = 64 * ndev, 16 * ndev
    rng = np.random.default_rng(2)
    idx = rng.integers(0, n, size=(m, 2)).astype(np.int32)
    pattern = AccessPattern.from_indices(idx, n=n)
    assert pattern.m == m and pattern.n == n
    for strategy in STRATEGIES:
        g = IrregularGather(pattern, mesh, strategy=strategy, blocksize=16)
        assert g.plan.m == m and g.plan.rows_per_shard == m // ndev
        x = rng.standard_normal(n).astype(np.float32)
        _check_gather(g, pattern, x, ndev)


def test_auto_strategy_resolves_and_delivers():
    mesh, ndev = _mesh()
    n = 64 * ndev
    rng = np.random.default_rng(3)
    idx = rng.integers(0, n, size=(n, 4)).astype(np.int32)
    pattern = AccessPattern.from_indices(idx, n=n)
    g = IrregularGather(pattern, mesh, strategy="auto", blocksize=16,
                        hw=pm.ABEL)
    assert g.requested_strategy == "auto"
    assert g.strategy in STRATEGIES
    assert set(g.predicted_times) == set(STRATEGIES)
    x = rng.standard_normal(n).astype(np.float32)
    _check_gather(g, pattern, x, ndev)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_overlap_handle_zero_slots(strategy):
    """finish(extra_slots=k) must guarantee x_copy[n+1 .. n+k] == 0 for
    every strategy — consumers point their padding indices there."""
    mesh, ndev = _mesh()
    n = 32 * ndev
    rng = np.random.default_rng(4)
    idx = rng.integers(0, n, size=(n, 3)).astype(np.int32)
    pattern = AccessPattern.from_indices(idx, n=n)
    g = IrregularGather(pattern, mesh, strategy=strategy, blocksize=8)
    from jax.sharding import PartitionSpec as P

    def local(x_local, *args):
        h = g.start_local(x_local, *args)
        return h.finish(extra_slots=2)[None]

    f = jax.jit(compat.shard_map(
        local, mesh=mesh, in_specs=(P("data"),) + g.in_specs,
        out_specs=P("data"), check_vma=False))
    x = rng.standard_normal(n).astype(np.float32) + 10.0  # no accidental 0s
    xc = np.asarray(f(g.shard_vector(x), *g.plan_args))
    rows = pattern.m // ndev
    for q in range(ndev):
        assert xc[q].shape[0] >= n + 3
        np.testing.assert_array_equal(xc[q][n + 1:n + 3], 0.0)
        needed = np.unique(pattern.indices[q * rows:(q + 1) * rows])
        np.testing.assert_array_equal(xc[q][needed], x[needed])


def test_shared_vector_ownership():
    mesh, ndev = _mesh()
    sv = SharedVector(mesh, n=16 * ndev)
    assert sv.p == ndev and sv.shard_size == 16
    assert sv.owner_of(0) == 0
    assert sv.owner_of(16 * ndev - 1) == ndev - 1
    x = np.arange(16 * ndev, dtype=np.float32)
    xs = sv.put(x)
    np.testing.assert_array_equal(np.asarray(xs), x)
    # IrregularGather accepts the SharedVector as the placement spec
    idx = np.arange(16 * ndev, dtype=np.int32)[:, None]
    g = IrregularGather(AccessPattern.from_indices(idx, n=sv.n), sv,
                        strategy="condensed")
    _check_gather(g, g.pattern, x, ndev)


def test_pattern_validation():
    with pytest.raises(AssertionError):
        AccessPattern.from_indices(np.array([[0, 5]]), n=4)  # out of range
    pat = AccessPattern.from_indices(np.array([3, 1, 2, 0]))  # 1-D ok
    assert pat.indices.shape == (4, 1) and pat.n == 4


def test_choose_blocksize_minimizes_eq11():
    from repro.comm.plan import blockwise_block_counts
    from repro.core.matrix import make_mesh_like_matrix

    n, p = 1 << 12, 8
    topo = Topology(p, 4)
    m = make_mesh_like_matrix(n, 8, locality_window=n // 16,
                              long_range_frac=0.05, seed=7)
    bs = select.choose_blocksize(m.cols, n, p, topology=topo, hw=pm.ABEL)
    shard = n // p
    assert shard % bs == 0
    # exhaustively verify the sweep's argmin against direct eq.-11 evals
    preds = {}
    for cand in select.blocksize_candidates(shard):
        bl, br = blockwise_block_counts(m.cols, n, p, cand, topo)
        zeros = np.zeros(p, np.int64)
        counts = pm.GatherCounts(
            c_local_indv=zeros, c_remote_indv=zeros, b_local=bl, b_remote=br,
            blocksize=cand, s_local_out=zeros, s_remote_out=zeros,
            s_local_in=zeros, s_remote_in=zeros, c_remote_out=zeros,
            padded_condensed_per_shard=0, padded_blockwise_per_shard=0)
        w = pm.SpmvWorkload(n=n, r_nz=8, p=p, blocksize=cand, topology=topo,
                            counts=counts)
        preds[cand] = pm.predict_v2(w, pm.ABEL)
    assert bs == min(preds, key=preds.get)


def test_blocksize_auto_on_engine():
    from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
    from repro.core.spmv import DistributedSpMV

    mesh, ndev = _mesh()
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 8,
                              long_range_frac=0.1, seed=8)
    eng = DistributedSpMV(m, mesh, strategy="blockwise", blocksize="auto",
                          hw=pm.ABEL)
    assert (n // ndev) % eng.blocksize == 0
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(eng(eng.shard_vector(x))),
                               spmv_ref_np(m, x), rtol=2e-4, atol=2e-4)


def test_moe_dispatch_matches_reference_all_rungs():
    from repro.models.moe import (MoEDispatchGather, moe_dispatch_pattern,
                                  moe_dispatch_ref)

    mesh, ndev = _mesh()
    n_tok, k, d = 64 * ndev, 2, 6
    e_total, cap = 2 * ndev, 12
    rng = np.random.default_rng(5)
    top_e = rng.integers(0, e_total, size=(n_tok, k))
    x = rng.standard_normal((n_tok, d)).astype(np.float32)
    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, ndev)
    ref = moe_dispatch_ref(x, idx, valid, e_total, cap)
    for strategy in STRATEGIES + ("auto",):
        g = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh,
                              strategy=strategy, blocksize=16, hw=pm.ABEL)
        buf = np.asarray(g(g.shard_tokens(x)))
        np.testing.assert_array_equal(buf, ref)


def test_moe_dispatch_pattern_capacity_truncation():
    from repro.models.moe import moe_dispatch_pattern

    # all tokens route to expert 0 -> capacity keeps the first C tokens
    top_e = np.zeros((16, 1), np.int64)
    idx, valid = moe_dispatch_pattern(top_e, 16, 2, 4, p=1)
    idx = idx.reshape(2, 4)
    valid = valid.reshape(2, 4)
    np.testing.assert_array_equal(idx[0], [0, 1, 2, 3])
    assert valid[0].all() and not valid[1].any()
