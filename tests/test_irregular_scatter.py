"""The push-direction front door: ``IrregularScatter`` / ``ScatterHandle``
over transpose-derived plans, plus the two scatter consumers.

Every rung is checked bit-identically against the NumPy ground truth.
Contributions are integer-valued floats (and combine weights powers of
two), so every float sum is exact and bit-identical regardless of the
accumulation order each rung/backend picks — the duplicate handling itself,
not float associativity, is what is under test.  Runs on whatever devices
the pytest process has (1 locally, 8 under the CI gate's XLA_FLAGS).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.comm import (AccessPattern, IrregularScatter, STRATEGIES,
                        plan_cache)
from repro.core import perfmodel as pm


def _mesh():
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), ("data",)), ndev


def _case(n, m, r, seed=0, lo=-4, hi=5):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(m, r)).astype(np.int32)
    vals = rng.integers(lo, hi, size=(m, r)).astype(np.float32)
    return AccessPattern.from_indices(idx, n=n), idx, vals


def _ref(idx, vals, n, reduce):
    feat = vals.shape[2:]
    if reduce == "add":
        y = np.zeros((n,) + feat, vals.dtype)
        np.add.at(y, idx.ravel(), vals.reshape((-1,) + feat))
        return y
    if reduce == "max":
        y = np.full((n,) + feat, -np.inf, vals.dtype)
        np.maximum.at(y, idx.ravel(), vals.reshape((-1,) + feat))
        return np.where(np.isneginf(y), 0.0, y).astype(vals.dtype)
    y = np.zeros((n,) + feat, vals.dtype)   # "set": last writer wins
    for i, v in zip(idx.ravel(), vals.reshape((-1,) + feat)):
        y[i] = v
    return y


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("reduce", ("add", "set", "max"))
def test_scatter_matches_numpy_reference(strategy, reduce):
    """All four rungs, all three reduce semantics, duplicate targets
    included (r random draws per row collide constantly)."""
    mesh, ndev = _mesh()
    n = 64 * ndev
    pattern, idx, vals = _case(n, n, 5)
    s = IrregularScatter(pattern, mesh, strategy=strategy, blocksize=16,
                         reduce=reduce)
    y = np.asarray(s(s.shard_values(vals)))
    np.testing.assert_array_equal(y, _ref(idx, vals, n, reduce))


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_scatter_with_feature_dims(strategy):
    mesh, ndev = _mesh()
    n, d = 32 * ndev, 7
    rng = np.random.default_rng(1)
    idx = rng.integers(0, n, size=(n, 3)).astype(np.int32)
    vals = rng.integers(-3, 4, size=(n, 3, d)).astype(np.float32)
    pattern = AccessPattern.from_indices(idx, n=n)
    s = IrregularScatter(pattern, mesh, strategy=strategy, blocksize=8)
    y = np.asarray(s(s.shard_values(vals)))
    np.testing.assert_array_equal(y, _ref(idx, vals, n, "add"))


def test_scatter_m_not_equal_n():
    """Accessor count decoupled from vector length (the MoE-combine
    shape: expert-capacity slots push into the token vector)."""
    mesh, ndev = _mesh()
    n, m = 64 * ndev, 16 * ndev
    pattern, idx, vals = _case(n, m, 2, seed=2)
    for strategy in STRATEGIES:
        s = IrregularScatter(pattern, mesh, strategy=strategy, blocksize=16)
        assert s.plan.m == m and s.splan.m == m
        y = np.asarray(s(s.shard_values(vals)))
        np.testing.assert_array_equal(y, _ref(idx, vals, n, "add"))


def test_scatter_handle_overlap_protocol():
    """start_local issues the exchange; finish combines own + landed —
    composable inside a consumer's own shard_map."""
    from jax.sharding import PartitionSpec as P

    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, idx, vals = _case(n, n, 3, seed=3)
    s = IrregularScatter(pattern, mesh, strategy="overlap", blocksize=8)

    def step(vals_local, *args):
        h = s.start_local(vals_local, *args)
        own_window = vals_local.sum() * 0.0  # any x_local-only compute
        return h.finish() + own_window

    f = jax.jit(compat.shard_map(
        step, mesh=mesh, in_specs=(P("data"),) + s.in_specs,
        out_specs=P("data"), check_vma=False))
    y = np.asarray(f(s.shard_values(vals), *s.plan_args))
    np.testing.assert_array_equal(y, _ref(idx, vals, n, "add"))


def test_transpose_round_trips():
    """transpose() is an involution onto the shared base plan, and the
    derived tables are exactly reconstructible from the plan alone."""
    from repro.comm.plan import build_comm_plan, pattern_cols

    n, p, r = 256, 4, 5
    pattern, idx, _ = _case(n, n, r, seed=4)
    plan = build_comm_plan(idx, n, p, blocksize=16)
    splan = plan.transpose()
    assert splan.transpose() is plan
    np.testing.assert_array_equal(pattern_cols(plan), idx)
    np.testing.assert_array_equal(splan.tgt_global, idx)
    # put-direction counts: outgoing <-> incoming volumes swap
    np.testing.assert_array_equal(
        splan.counts.s_local_out + splan.counts.s_remote_out,
        plan.counts.s_local_in + plan.counts.s_remote_in)
    np.testing.assert_array_equal(
        splan.counts.s_local_in + splan.counts.s_remote_in,
        plan.counts.s_local_out + plan.counts.s_remote_out)


def test_auto_strategy_uses_put_models():
    mesh, ndev = _mesh()
    n = 64 * ndev
    pattern, idx, vals = _case(n, n, 4, seed=5)
    s = IrregularScatter(pattern, mesh, strategy="auto", blocksize=16,
                         hw=pm.ABEL)
    assert s.requested_strategy == "auto"
    assert s.strategy in STRATEGIES
    assert set(s.predicted_times) == set(STRATEGIES)
    # the resolved pick is the put-model argmin (acceptance criterion)
    assert s.strategy == min(s.predicted_times, key=s.predicted_times.get)
    # and it matches an explicit put-direction ranking of the same plan
    from repro.comm import select
    ranked = select.rank_strategies(s.splan, pattern.r, pm.ABEL,
                                    direction="put")
    assert s.strategy == ranked[0][0]
    y = np.asarray(s(s.shard_values(vals)))
    np.testing.assert_array_equal(y, _ref(idx, vals, n, "add"))


def test_scatter_invalid_args_rejected():
    mesh, ndev = _mesh()
    pattern, _, _ = _case(16 * ndev, 16 * ndev, 2, seed=6)
    with pytest.raises(ValueError, match="reduce"):
        IrregularScatter(pattern, mesh, reduce="mean")
    with pytest.raises(ValueError, match="strategy"):
        IrregularScatter(pattern, mesh, strategy="bogus")


def test_hw_measurement_memoized_per_mesh(monkeypatch):
    """Constructing several exchanges on one mesh must run the §5.4
    microbenchmark at most once (module-level memo in comm.exchange)."""
    from repro.comm import exchange
    from repro.core import tune

    calls = []

    def fake_measure(mesh=None, axis_name=None, **kw):
        calls.append((axis_name,))
        return pm.ABEL

    monkeypatch.setattr(tune, "measure_hardware", fake_measure)
    exchange.clear_hw_memo()
    mesh, ndev = _mesh()
    n = 16 * ndev
    pattern, idx, vals = _case(n, n, 2, seed=7)
    g1 = IrregularScatter(pattern, mesh, strategy="auto", blocksize=8)
    from repro.comm import IrregularGather
    g2 = IrregularGather(pattern, mesh, strategy="auto", blocksize=8)
    g3 = IrregularScatter(pattern, mesh, strategy="auto", blocksize=8)
    assert len(calls) == 1, calls
    exchange.clear_hw_memo()
    assert g1.hw is g2.hw is g3.hw


def test_moe_combine_matches_reference_all_rungs():
    from repro.models.moe import (MoECombineScatter, moe_combine_ref,
                                  moe_combine_weights, moe_dispatch_pattern)

    mesh, ndev = _mesh()
    n_tok, k, d = 64 * ndev, 2, 6
    e_total, cap = 2 * ndev, 12
    rng = np.random.default_rng(8)
    top_e = rng.integers(0, e_total, size=(n_tok, k))
    # power-of-two weights keep every product/sum exact in float32
    top_w = np.where(rng.random((n_tok, k)) < 0.5, 0.5, 0.25).astype(
        np.float32)
    buf = rng.integers(-3, 4, (e_total, cap, d)).astype(np.float32)
    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, ndev)
    w_slot = moe_combine_weights(top_e, top_w, n_tok, e_total, cap)
    ref = moe_combine_ref(buf, idx, valid, w_slot, n_tok)
    for strategy in STRATEGIES + ("auto",):
        g = MoECombineScatter(top_e, top_w, n_tok, e_total, cap, mesh,
                              strategy=strategy, blocksize=16, hw=pm.ABEL)
        y = np.asarray(g(g.shard_expert_buf(buf)))
        np.testing.assert_array_equal(y, ref)


def test_moe_dispatch_combine_round_trip():
    """Dispatch → (identity experts) → combine equals the local-only
    combine_one reference: each token recovers the weighted sum of its
    kept expert copies."""
    from repro.models.moe import (MoECombineScatter, MoEDispatchGather,
                                  moe_combine_ref, moe_combine_weights,
                                  moe_dispatch_pattern)

    mesh, ndev = _mesh()
    n_tok, k, d = 32 * ndev, 2, 4
    e_total, cap = 2 * ndev, 8
    rng = np.random.default_rng(9)
    top_e = rng.integers(0, e_total, size=(n_tok, k))
    top_w = np.where(rng.random((n_tok, k)) < 0.5, 0.5, 0.25).astype(
        np.float32)
    x = rng.integers(-3, 4, (n_tok, d)).astype(np.float32)

    disp = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh,
                             strategy="condensed", blocksize=8)
    comb = MoECombineScatter(top_e, top_w, n_tok, e_total, cap, mesh,
                             strategy="condensed", blocksize=8)
    ebuf = np.asarray(disp(disp.shard_tokens(x)))
    y = np.asarray(comb(comb.shard_expert_buf(ebuf)))

    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, ndev)
    w_slot = moe_combine_weights(top_e, top_w, n_tok, e_total, cap)
    np.testing.assert_array_equal(
        y, moe_combine_ref(ebuf, idx, valid, w_slot, n_tok))


def test_spmv_transpose_matches_reference_all_rungs():
    from repro.core.matrix import (EllpackMatrix, make_mesh_like_matrix,
                                   spmv_t_ref_np)
    from repro.core.spmv import DistributedSpMV

    mesh, ndev = _mesh()
    n = 64 * ndev
    m0 = make_mesh_like_matrix(n, 4, locality_window=n // 8,
                               long_range_frac=0.1, seed=10)
    rng = np.random.default_rng(10)
    m = EllpackMatrix(
        n=n, r_nz=m0.r_nz,
        diag=rng.integers(-3, 4, n).astype(np.float32),
        vals=rng.integers(-3, 4, (n, m0.r_nz)).astype(np.float32),
        cols=m0.cols)
    x = rng.integers(-3, 4, n).astype(np.float32)
    ref = spmv_t_ref_np(m, x)
    for strategy in STRATEGIES + ("auto",):
        eng = DistributedSpMV(m, mesh, strategy=strategy, blocksize=16,
                              transpose=True, hw=pm.ABEL)
        assert eng.transpose and eng.gather is None
        y = np.asarray(eng(eng.shard_vector(x)))
        np.testing.assert_array_equal(y, ref)


def test_spmv_forward_and_transpose_share_base_plan(tmp_path, monkeypatch):
    """The transpose is a cached O(m*r) delta of the forward plan: one
    O(nnz) preparation step covers both directions."""
    from repro.core.matrix import make_mesh_like_matrix
    from repro.core.spmv import DistributedSpMV

    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "plans"))
    plan_cache.clear_memory_cache()
    plan_cache.stats.reset()
    mesh, ndev = _mesh()
    n = 64 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 8,
                              long_range_frac=0.1, seed=11)
    fwd = DistributedSpMV(m, mesh, strategy="condensed", blocksize=16,
                          materialize="full")
    t = DistributedSpMV(m, mesh, strategy="condensed", blocksize=16,
                        transpose=True)
    assert plan_cache.stats.misses == 1      # one O(nnz) build total
    assert plan_cache.stats.derives == 1     # one O(m*r) transpose delta
    assert t.splan.transpose() is t.plan

    # the transposed engine's counts are the put-direction volumes
    np.testing.assert_array_equal(
        t.counts.s_local_out + t.counts.s_remote_out,
        fwd.counts.s_local_in + fwd.counts.s_remote_in)
