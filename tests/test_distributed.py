"""Multi-device integration tests.  Each runs a helper script in a
subprocess with XLA_FLAGS forcing 8 host devices (the main pytest process
must keep seeing 1 device, per the assignment)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess e2e: non-blocking CI job

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _run(script, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"{script} failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
        f"STDERR:{proc.stderr[-3000:]}")
    return proc.stdout


def test_gather_strategies_equivalence_8dev():
    out = _run("check_strategies.py")
    assert "ALL_STRATEGIES_OK" in out


def test_heat2d_distributed_8dev():
    out = _run("check_heat2d.py")
    assert "HEAT2D_OK" in out


def test_moe_dispatch_gather_8dev():
    out = _run("check_moe_dispatch.py")
    assert "MOE_DISPATCH_OK" in out


def test_elastic_checkpoint_restore_8dev():
    out = _run("check_elastic_ckpt.py")
    assert "ELASTIC_CKPT_OK" in out


def test_sharded_model_matches_single_device_8dev():
    out = _run("check_sharded_model.py")
    assert "SHARDED_MODEL_OK" in out
