"""Consumer-targeted unpack (``Destination``): every strategy rung must
deliver values straight into named consumer slots, bit-identically to the
assembled-x_copy path, and the Heat2D step must do O(halo) unpack work —
no full-length intermediate (the regression the ROADMAP asked for).

Runs on whatever devices the pytest process has (1 locally, 8 under the CI
gate's XLA_FLAGS).
"""
import jax
import numpy as np
import pytest

from repro import compat
from repro.comm import (AccessPattern, Destination, IrregularGather,
                        STRATEGIES, Topology)
from repro.core import perfmodel as pm
from jax.sharding import PartitionSpec as P


def _mesh():
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), ("data",)), ndev


# ---------------------------------------------------------------------------
# Destination descriptor basics
# ---------------------------------------------------------------------------

def test_destination_from_slots_and_split():
    d = Destination.from_slots(
        up=np.array([[4, 5], [0, 1]]),
        left=np.array([[6], [-1]]))
    assert d.names == ("up", "left")
    assert d.p == 2 and d.num_slots == 3
    out = d.split_local(np.array([10.0, 11.0, 12.0]))
    np.testing.assert_array_equal(out["up"], [10.0, 11.0])
    np.testing.assert_array_equal(out["left"], [12.0])
    # feature dims flow through the split
    out = d.split_local(np.zeros((3, 5)))
    assert out["up"].shape == (2, 5) and out["left"].shape == (1, 5)


def test_destination_rejects_unplanned_foreign_index():
    """A foreign destination id outside the AccessPattern never arrives —
    the planner must refuse instead of delivering garbage."""
    mesh, ndev = _mesh()
    if ndev == 1:
        pytest.skip("needs a foreign shard")
    n = 16 * ndev
    idx = np.zeros((n, 1), np.int32)        # pattern only gathers element 0
    pattern = AccessPattern.from_indices(idx, n=n)
    # shard 0 asks for element n-1 (owned by the last shard, never exchanged)
    slots = np.zeros((ndev, 1), np.int64)
    slots[0, 0] = n - 1
    with pytest.raises(ValueError, match="never"):
        IrregularGather(pattern, mesh, strategy="condensed", blocksize=8,
                        destination=Destination.from_slots(s=slots),
                        use_plan_cache=False)


# ---------------------------------------------------------------------------
# gather-level: targeted delivery equals the reference for every rung
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_targeted_unpack_matches_reference(strategy):
    mesh, ndev = _mesh()
    n, d = 64 * ndev, 3
    rng = np.random.default_rng(0)
    idx = rng.integers(0, n, size=(n, 5)).astype(np.int32)
    pattern = AccessPattern.from_indices(idx, n=n)
    # slots: a mix of pattern reads and forced-zero sentinels
    slots = idx.reshape(ndev, -1, 5)[:, :16].reshape(ndev, -1).astype(
        np.int64).copy()
    slots[:, -3:] = Destination.ZERO
    dest = Destination.from_slots(rows=slots)
    g = IrregularGather(pattern, mesh, strategy=strategy, blocksize=16,
                        destination=dest)
    x = rng.standard_normal((n, d)).astype(np.float32)

    def local(x_local, *args):
        return g.local(x_local, *args)["rows"][None]

    f = jax.jit(compat.shard_map(
        local, mesh=mesh, in_specs=(P("data"),) + g.in_specs,
        out_specs=P("data"), check_vma=False))
    out = np.asarray(f(g.shard_vector(x), *g.plan_args))
    want = np.where((slots >= 0)[..., None], x[np.clip(slots, 0, None)], 0.0)
    np.testing.assert_array_equal(out, want)
    # the full materialization stays available on the same gather
    xc = np.asarray(g(g.shard_vector(x)))
    rows = pattern.m // ndev
    for q in range(ndev):
        needed = np.unique(pattern.indices[q * rows:(q + 1) * rows])
        np.testing.assert_array_equal(xc[q][needed], x[needed])


# ---------------------------------------------------------------------------
# consumer equivalence: materialize="dest" == materialize="full", bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_spmv_dest_equals_full_bitwise(strategy):
    from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
    from repro.core.spmv import DistributedSpMV

    mesh, ndev = _mesh()
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 8, locality_window=n // 8,
                              long_range_frac=0.1, seed=11)
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    ed = DistributedSpMV(m, mesh, strategy=strategy, blocksize=32)
    assert ed.materialize == "dest"
    ef = DistributedSpMV(m, mesh, strategy=strategy, blocksize=32,
                         materialize="full")
    yd = np.asarray(ed(ed.shard_vector(x)))
    np.testing.assert_array_equal(
        yd, np.asarray(ef(ef.shard_vector(x))),
        err_msg=f"strategy={strategy}: targeted unpack changed the result")
    np.testing.assert_allclose(yd, spmv_ref_np(m, x), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_heat2d_dest_equals_full_bitwise(strategy):
    from repro.core.heat2d import Heat2D

    mesh, ndev = _mesh()
    shape = (2, ndev // 2) if ndev % 2 == 0 and ndev > 1 else (1, ndev)
    mesh = jax.make_mesh(shape, ("data", "model"))
    kw = dict(coef=0.1, strategy=strategy)
    if strategy == "blockwise":
        kw["blocksize"] = 8
    hd = Heat2D(mesh, shape[0] * 16, shape[1] * 16, **kw)
    hf = Heat2D(mesh, shape[0] * 16, shape[1] * 16, materialize="full", **kw)
    phi = hd.init_field(3)
    got = np.asarray(hd.run(phi, 5))
    np.testing.assert_array_equal(
        got, np.asarray(hf.run(phi, 5)),
        err_msg=f"strategy={strategy}: targeted unpack changed the result")
    np.testing.assert_allclose(got, hd.reference(np.asarray(phi), 5),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_moe_dispatch_dest_equals_full_bitwise(strategy):
    from repro.models.moe import (MoEDispatchGather, moe_dispatch_pattern,
                                  moe_dispatch_ref)

    mesh, ndev = _mesh()
    n_tok, k, d = 64 * ndev, 2, 6
    e_total, cap = 2 * ndev, 12
    rng = np.random.default_rng(5)
    top_e = rng.integers(0, e_total, size=(n_tok, k))
    x = rng.standard_normal((n_tok, d)).astype(np.float32)
    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, ndev)
    ref = moe_dispatch_ref(x, idx, valid, e_total, cap)
    gd = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh,
                           strategy=strategy, blocksize=16, hw=pm.ABEL)
    gf = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh,
                           strategy=strategy, blocksize=16, hw=pm.ABEL,
                           materialize="full")
    bd = np.asarray(gd(gd.shard_tokens(x)))
    np.testing.assert_array_equal(bd, np.asarray(gf(gf.shard_tokens(x))))
    np.testing.assert_array_equal(bd, ref)


# ---------------------------------------------------------------------------
# the regression the ROADMAP asked for: Heat2D unpack work is O(halo)
# ---------------------------------------------------------------------------

def _max_rank1_intermediate(jaxpr) -> int:
    """Largest rank-1 array produced by any equation, recursing into
    sub-jaxprs (pjit / scan / shard_map bodies)."""
    try:
        from jax.extend import core as jcore  # noqa: F401
    except ImportError:
        pass
    best = 0
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape is not None and len(shape) == 1:
                best = max(best, int(shape[0]))
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                best = max(best, _max_rank1_intermediate(sub))
    return best


def _sub_jaxprs(val):
    if hasattr(val, "jaxpr") and hasattr(val, "eqns") is False:
        # ClosedJaxpr wraps a Jaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _sub_jaxprs(v)


def _shard_map_bodies(jaxpr):
    """Inner jaxprs of every shard_map equation (the per-device programs)."""
    for eqn in jaxpr.eqns:
        is_shmap = "shard_map" in str(eqn.primitive)
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                if is_shmap:
                    yield sub
                yield from _shard_map_bodies(sub)


def test_heat2d_step_has_no_full_length_intermediate():
    """The targeted unpack must not materialize any O(n)=O(big_m*big_n)
    buffer: every rank-1 intermediate in the step (x_local, recv buffers,
    halo strips) is O(shard + halo).  The full materialization, by
    construction, assembles the (n+2,) x_copy — the detector must see it."""
    from repro.core.heat2d import Heat2D

    ndev = len(jax.devices())
    shape = (2, ndev // 2) if ndev % 2 == 0 and ndev > 1 else (1, ndev)
    mesh = jax.make_mesh(shape, ("data", "model"))
    big_m, big_n = shape[0] * 16, shape[1] * 16
    n = big_m * big_n
    shard = n // (shape[0] * shape[1])

    hd = Heat2D(mesh, big_m, big_n, coef=0.1)
    jaxpr_dest = jax.make_jaxpr(lambda p: hd.run(p, 1))(hd.init_field(0))
    dest_max = _max_rank1_intermediate(jaxpr_dest.jaxpr)
    # O(shard + halo): the biggest 1-D buffer is the flattened local tile
    # (shard elements) plus at most the padded recv buffer — far below n
    halo = 2 * (big_m // shape[0] + big_n // shape[1])
    assert dest_max <= shard + hd.gather.plan.p * hd.gather.plan.s_max, (
        f"targeted unpack materialized a {dest_max}-element 1-D buffer "
        f"(shard={shard}, halo={halo}, n={n})")
    # on a single device shard == n, so the O(n)-vs-O(shard) distinction
    # only exists multi-device (the CI gate runs with 8)
    assert dest_max < n or hd.gather.p == 1

    # sanity: the detector is not blind — the full path DOES build x_copy
    hf = Heat2D(mesh, big_m, big_n, coef=0.1, materialize="full")
    jaxpr_full = jax.make_jaxpr(lambda p: hf.run(p, 1))(hf.init_field(0))
    assert _max_rank1_intermediate(jaxpr_full.jaxpr) >= n


def test_spmv_dest_scatter_operands_are_o_slots():
    """SpMV targeted unpack: inside the per-device program, no rank-1
    intermediate beyond shard + recv + slots (the sharded global output y
    is legitimately n-sized, so only the shard_map body is inspected)."""
    from repro.core.matrix import make_mesh_like_matrix
    from repro.core.spmv import DistributedSpMV

    mesh, ndev = _mesh()
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 8,
                              long_range_frac=0.1, seed=3)
    x_host = np.random.default_rng(0).standard_normal(n).astype(np.float32)

    def body_max(eng):
        jaxpr = jax.make_jaxpr(eng._step)(eng.shard_vector(x_host))
        bodies = list(_shard_map_bodies(jaxpr.jaxpr))
        assert bodies, "step contains no shard_map body"
        return max(_max_rank1_intermediate(b) for b in bodies)

    eng = DistributedSpMV(m, mesh, strategy="condensed", blocksize=32)
    mx = body_max(eng)
    shard = n // ndev
    recv = eng.plan.p * eng.plan.s_max
    assert mx <= max(shard + 1, recv, eng.plan.dest_len), (mx, shard, recv)
    assert mx < n or ndev == 1
    # sanity: the full path's per-device program does build the (>=n) copy
    engf = DistributedSpMV(m, mesh, strategy="condensed", blocksize=32,
                           materialize="full")
    assert body_max(engf) >= n


# ---------------------------------------------------------------------------
# §5 pricing of the two unpack modes
# ---------------------------------------------------------------------------

def test_model_prices_dest_unpack_below_full_assembly():
    """For a sparse-access consumer (halo-sized destination, big n) the
    targeted unpack must be predicted cheaper than full assembly, for every
    runnable rung — that's what lets strategy="auto" pick per consumer."""
    from repro.comm import select
    from repro.comm.plan import build_comm_plan

    n, p = 1 << 14, 8
    rng = np.random.default_rng(0)
    idx = rng.integers(0, n, size=(n, 4)).astype(np.int32)
    slots = idx[::64, :2].reshape(p, -1).astype(np.int64)  # sparse consumer
    dest = Destination.from_slots(s=slots)
    plan = build_comm_plan(idx, n, p, blocksize=64, topology=Topology(p, 4),
                           destination=dest)
    full = dict(select.rank_strategies(plan, 4, pm.ABEL, materialize="full"))
    tgt = dict(select.rank_strategies(plan, 4, pm.ABEL, materialize="dest"))
    for name in ("condensed", "blockwise", "overlap"):
        assert tgt[name] < full[name], name
    # paper-mode pricing (materialize=None) is untouched by the extension
    base = dict(select.rank_strategies(plan, 4, pm.ABEL))
    w = select.workload_from_plan(plan, 4)
    assert base["condensed"] == pytest.approx(pm.predict_v3(w, pm.ABEL))
