"""Per-kernel validation against the pure-jnp oracles (interpret mode),
sweeping shapes and dtypes per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@pytest.mark.parametrize("n,r_nz,seed", [
    (512, 4, 0), (1024, 8, 1), (2048, 16, 2), (768, 3, 3),
])
@pytest.mark.parametrize("dtype", [np.float32])
def test_ellpack_spmv_kernel(n, r_nz, seed, dtype):
    m = make_mesh_like_matrix(n, r_nz, locality_window=max(32, n // 16),
                              seed=seed, dtype=dtype)
    x = np.random.default_rng(seed).standard_normal(n).astype(dtype)
    y = np.asarray(kops.ellpack_spmv(
        jnp.asarray(m.diag), jnp.asarray(m.vals), m.cols, jnp.asarray(x),
        rows_per_block=128))
    np.testing.assert_allclose(y, spmv_ref_np(m, x), rtol=3e-5, atol=3e-5)


def test_ellpack_spmv_bf16_vals():
    n, r_nz = 512, 8
    m = make_mesh_like_matrix(n, r_nz, locality_window=64, seed=5)
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    y = np.asarray(kops.ellpack_spmv(
        jnp.asarray(m.diag, jnp.bfloat16).astype(jnp.float32),
        jnp.asarray(m.vals), m.cols, jnp.asarray(x), rows_per_block=64))
    np.testing.assert_allclose(y, spmv_ref_np(m, x), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("nx,m_idx,block", [
    (1000, 333, 128), (4096, 4096, 1024), (257, 7, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_pack_gather(nx, m_idx, block, dtype):
    x = jnp.arange(nx).astype(dtype)
    idx = jnp.asarray(
        np.random.default_rng(1).integers(0, nx, m_idx), jnp.int32)
    out = kops.pack_gather(x, idx, block=block)
    np.testing.assert_array_equal(
        np.asarray(out).astype(np.float64),
        np.asarray(kref.pack_gather_ref(x, idx)).astype(np.float64))


@pytest.mark.parametrize("m,n,tile", [
    (64, 128, 8), (40, 56, 8), (16, 16, 4), (129, 65, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_stencil2d(m, n, tile, dtype):
    x = jnp.asarray(
        np.random.default_rng(2).standard_normal((m, n)), dtype)
    got = kops.stencil2d(x, coef=0.13, tile_rows=tile)
    want = kref.stencil2d_ref(x, 0.13)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_spmv_window_plan_covers_all_columns():
    m = make_mesh_like_matrix(2048, 16, locality_window=100, seed=9)
    window, win_blk, cols_rel, own_rel = kops.plan_spmv_windows(
        m.cols, rows_per_block=256)
    assert window % 128 == 0
    assert cols_rel.min() >= 0 and cols_rel.max() < 2 * window
    assert own_rel.min() >= 0 and own_rel.max() < 2 * window
    # reconstruct globals
    base = np.repeat(win_blk.astype(np.int64) * window, 256)
    np.testing.assert_array_equal(cols_rel + base[:, None], m.cols)


@pytest.mark.parametrize("b,h,hkv,d,s,chunk", [
    (2, 8, 4, 32, 1024, 256), (1, 4, 4, 64, 512, 512), (3, 6, 2, 16, 768, 128),
])
def test_decode_attention_kernel(b, h, hkv, d, s, chunk):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    lengths = jnp.asarray(
        np.random.default_rng(3).integers(1, s + 1, b), jnp.int32)
    got = kops.decode_attention(q, k, v, lengths, kv_chunk=chunk)
    # oracle: per-batch slice to the valid length, dense attention
    outs = []
    for i in range(b):
        L = int(lengths[i])
        outs.append(kref.decode_attention_ref(
            q[i:i+1], k[i:i+1, :L], v[i:i+1, :L])[0])
    want = jnp.stack(outs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,l,di,st,tile,chunk", [
    (2, 128, 16, 4, 8, 64), (1, 256, 32, 8, 32, 256), (2, 64, 8, 16, 8, 32),
])
def test_selective_scan_kernel(b, l, di, st, tile, chunk):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, l, di)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, l, di)))
    bm = jax.random.normal(jax.random.PRNGKey(2), (b, l, st)) * 0.5
    cm = jax.random.normal(jax.random.PRNGKey(3), (b, l, st)) * 0.5
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (di, st)) * 0.3)
    got = kops.selective_scan(x, dt, bm, cm, a, tile_di=tile, chunk_l=chunk)
    want = kref.selective_scan_ref(x, dt, bm, cm, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
