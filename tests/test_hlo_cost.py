"""Trip-count-aware HLO cost walker vs known programs, and the collective
byte conventions on synthetic HLO text."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hlo_cost import analyze_hlo
from repro.core.roofline import parse_collectives


def _flops(fn, *sds):
    c = jax.jit(fn).lower(*sds).compile()
    return analyze_hlo(c.as_text()).flops


def test_plain_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    assert _flops(lambda x, y: x @ y, a, b) == 2 * 64 * 32 * 16


def test_scan_flops_multiplied_by_trip_count():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    assert _flops(f, x, w) == 2 * 128 * 256 * 256 * 10


def test_nested_scan_flops():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)

    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return c2 @ wi, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    assert _flops(f, x, w) == 2 * 128 * 256 * 256 * 30


def test_xla_cost_analysis_undercounts_scans():
    """Documents WHY the walker exists: XLA's visitor counts the body once."""
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    c = jax.jit(f).lower(x, w).compile()
    cost = c.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    # one iteration only (within 1%: some XLA versions add a few bookkeeping
    # flops), i.e. 10x below the true trip-count cost
    one_iter = 2 * 128 * 256 * 256
    assert one_iter <= cost["flops"] <= 1.01 * one_iter


def test_bytes_proxy_counts_dot_operands():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    hc = analyze_hlo(c.as_text())
    expect = 4 * (64 * 32 + 32 * 16 + 64 * 16)
    assert hc.hbm_bytes == expect


SYNTH = """
HloModule synth

ENTRY %main.1 (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ag = f32[4096]{0} all-gather(%p0), replica_groups=[2,4]<=[8], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %cp = f32[1024]{0} collective-permute(%p0), source_target_pairs={{0,4},{4,0}}
  ROOT %out = f32[1024]{0} add(%ar, %cp)
}
"""


def test_collective_conventions_on_synthetic_text():
    st = parse_collectives(SYNTH, num_devices=8, devices_per_pod=4)
    # all-gather: out 4096*4 bytes * (4-1)/4
    ag = 4096 * 4 * 3 / 4
    # all-reduce: 2 * 1024*4 * 3/4
    ar = 2 * 1024 * 4 * 3 / 4
    # collective-permute crosses pods (0->4): DCI
    cp = 1024 * 4
    assert abs(st.by_kind["all-gather"] - ag) < 1e-6
    assert abs(st.by_kind["all-reduce"] - ar) < 1e-6
    assert abs(st.dci_bytes - cp) < 1e-6
    assert st.op_count == 3


def test_iota_group_parse_with_transpose():
    txt = """
ENTRY %m (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  ROOT %ar = f32[16]{0} all-reduce(%p), replica_groups=[2,4]<=[4,2]T(1,0), to_apply=%a
}
"""
    st = parse_collectives(txt, num_devices=8, devices_per_pod=4)
    # groups = arange(8).reshape(4,2).T.reshape(2,4) = [[0,2,4,6],[1,3,5,7]]
    # -> crosses the pod boundary (0 and 4 in one group)
    assert st.dci_bytes > 0 and st.ici_bytes == 0
