"""repro.serve: queue admission, slot lifecycle, the fused-prefill oracle,
and the engine's token-for-token identity with the naive batch-loop.

The greedy-decode comparisons are EXACT (assert_array_equal / ``==`` on
token lists): the engine and the baseline run the same jitted prefill /
insert / decode functions, so any drift is a real scheduling bug, not
float noise.  MoE configs use no-drop capacity (``capacity_factor =
E / k`` ⇒ capacity == tokens) — with drops enabled, fused prefill routes
B·S tokens per call while the sequential oracle routes B per step, and
different tokens lose the capacity race.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models.transformer import Model, RunCtx
from repro.serve import (Request, RequestQueue, ServeEngine, SlotManager,
                         generate_batch_loop)

KEY = jax.random.PRNGKey(0)


def _cfg(family="moe", e=4, k=2):
    kw = dict(name="t", family=family, num_layers=2, d_model=16,
              num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64)
    if family == "moe":
        kw.update(num_experts=e, experts_per_token=k,
                  capacity_factor=float(e) / k, act="swiglu")
    return ArchConfig(**kw)


def _model(cfg):
    model = Model(cfg, RunCtx(remat="none", act_dtype=jnp.float32))
    return model, model.init_params(KEY)


# -- queue: FIFO within arrival, arrival-time gating --

def test_queue_fifo_and_arrival_gating():
    q = RequestQueue()
    q.submit(Request(id="late", prompt=[1], max_new_tokens=1,
                     arrival_time=5.0))
    q.submit(Request(id="a", prompt=[1], max_new_tokens=1, arrival_time=0.0))
    q.submit(Request(id="b", prompt=[1], max_new_tokens=1, arrival_time=0.0))
    # nothing has arrived before t=0 ... and same-arrival pops are FIFO
    assert q.pop_ready(-1.0) is None
    assert q.pop_ready(0.0).id == "a"
    assert q.pop_ready(0.0).id == "b"
    # "late" is submitted but not yet arrived
    assert len(q) == 1 and q.pop_ready(4.9) is None
    assert q.next_arrival() == 5.0
    assert q.pop_ready(5.0).id == "late"
    assert not q


# -- slots: exhaustion, release, lowest-free reuse --

def test_slot_manager_lifecycle():
    sm = SlotManager(2)
    s0 = sm.allocate("r0", max_new_tokens=4)
    s1 = sm.allocate("r1", max_new_tokens=4)
    assert (s0, s1) == (0, 1)
    assert sm.allocate("r2") is None          # exhausted
    assert [s.index for s in sm.active()] == [0, 1]
    sm.release(0)
    assert sm.num_free == 1 and sm[0].free
    # reuse hands out the lowest free lane
    assert sm.allocate("r2", max_new_tokens=1) == 0
    assert sm[0].request_id == "r2" and sm[0].generated == 0


# -- fused prefill == sequential decode oracle, bitwise --

@pytest.mark.parametrize("family", ["dense", "moe"])
def test_fused_prefill_matches_sequential_oracle(family):
    from repro.launch.serve import prefill_into_cache

    cfg = _cfg(family)
    model, params = _model(cfg)
    B, S, L = 2, 6, 12
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, S)), jnp.int32)
    c_seq, logits_seq = prefill_into_cache(
        model, params, model.init_cache(B, L, dtype=jnp.float32), toks)
    logits_fused, c_fused = model.prefill(
        params, model.init_cache(B, L, dtype=jnp.float32), toks)
    np.testing.assert_array_equal(np.asarray(logits_fused),
                                  np.asarray(logits_seq))
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(c_fused["layers"][leaf]),
            np.asarray(c_seq["layers"][leaf]))


# -- the engine vs the naive batch-loop: token-for-token --

def test_engine_matches_batch_loop_with_slot_reuse():
    cfg = _cfg("moe")
    model, params = _model(cfg)
    rng = np.random.default_rng(1)
    reqs = [Request(id=f"r{i}",
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(3, 7)),)).tolist(),
                    max_new_tokens=3,
                    arrival_time=float(i // 2))
            for i in range(5)]

    engine = ServeEngine(model, params, num_slots=2, cache_len=12,
                         prefill_chunk=3, cache_dtype=jnp.float32)
    for r in reqs:
        engine.submit(r)
    rep = engine.run()
    base = generate_batch_loop(model, params, reqs, cache_len=12,
                               prefill_chunk=3, cache_dtype=jnp.float32)
    assert rep.outputs == base                # greedy tokens, bit-identical
    # 5 requests over 2 lanes: admission must have reused released slots
    assert set(rep.slot_of.values()) == {0, 1}
    assert len(rep.slot_of) == 5
    # equal budgets + staggered arrivals => completions in admission order
    assert rep.completed == [r.id for r in reqs]
    # every decode tick and prefill chunk was counted
    assert rep.telemetry["decode_steps"] == len(rep.tick_seconds) > 0
    assert rep.telemetry["prefill_chunks"] >= len(reqs)
    assert rep.total_tokens == sum(r.max_new_tokens for r in reqs)
    assert set(rep.ttft_seconds) == {r.id for r in reqs}


def test_engine_submit_validation():
    model, params = _model(_cfg("dense"))
    engine = ServeEngine(model, params, num_slots=1, cache_len=4,
                         cache_dtype=jnp.float32)
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(Request(id="x", prompt=[1], max_new_tokens=0))
    with pytest.raises(ValueError, match="prompt length"):
        engine.submit(Request(id="x", prompt=[1] * 5, max_new_tokens=1))
    with pytest.raises(ValueError, match="prompt length"):
        engine.submit(Request(id="x", prompt=[], max_new_tokens=1))


def test_engine_rejects_mismatched_moe_layer():
    class FakeLayer:
        num_tokens = 4

    model, params = _model(_cfg("moe"))
    with pytest.raises(ValueError, match="num_tokens"):
        ServeEngine(model, params, num_slots=2, cache_len=8,
                    moe_layer=FakeLayer())


# -- 8-device sharded MoE decode path (CI: non-blocking slow job) --

@pytest.mark.slow
def test_engine_moe_comm_bit_identity_and_host_free():
    """The ISSUE's acceptance smoke: on 8 devices, the engine with the
    §5-priced DynamicMoELayer decode hook emits bit-identical greedy
    tokens to the naive batch-loop running the SAME hook, and the
    steady-state interval performs zero host plan builds."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (XLA_FLAGS host device count)")
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import build_moe_layer

    cfg = get_config("mixtral-8x22b", reduced=True)
    # experts divide the mesh, full attention, no-drop capacity
    cfg = dataclasses.replace(cfg, num_experts=8, swa_window=0,
                              capacity_factor=8.0 / cfg.experts_per_token)
    model, params = _model(cfg)
    mesh = make_local_mesh((8,), ("data",))
    layer = build_moe_layer(model, params, 8, mesh)
    assert layer.decode and layer.gather.decode and layer.scatter.decode

    engine = ServeEngine(model, params, num_slots=8, cache_len=16,
                         prefill_chunk=4, moe_layer=layer,
                         cache_dtype=jnp.float32)
    rng = np.random.default_rng(2)

    def batch(tag, gen):
        return [Request(id=f"{tag}{i}",
                        prompt=rng.integers(0, cfg.vocab_size,
                                            (8,)).tolist(),
                        max_new_tokens=gen, arrival_time=float(i // 4))
                for i in range(8)]

    for r in batch("warm", 2):                # warmup: traces + compiles
        engine.submit(r)
    engine.run()
    snap = engine.snapshot()

    reqs = batch("req", 4)
    for r in reqs:
        engine.submit(r)
    rep = engine.run()
    delta = engine.assert_steady_state(snap)  # raises on any host-build
    assert delta["host-build"] == 0 and delta["decode_steps"] > 0
    # one in-jit derivation per MoE layer per executed decode tick
    assert delta["device-derive"] == cfg.num_layers * delta["decode_steps"]

    base = generate_batch_loop(model, params, reqs, cache_len=16,
                               prefill_chunk=4, moe_layer=layer,
                               cache_dtype=jnp.float32)
    assert {r.id: rep.outputs[r.id] for r in reqs} == base
