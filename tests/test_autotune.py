"""Model-driven strategy autotuner (core.tune): ranking is a faithful sort of
the §5 predictions, ``strategy="auto"`` resolves to a runnable rung that
matches the reference, and (subprocess, 8 devices) the predicted ranking
tracks the measured one."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import perfmodel as pm
from repro.core import tune
from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
from repro.core.plan import Topology, build_comm_plan
from repro.core.strategies import STRATEGIES

ABEL = pm.ABEL


def _plan(p=16, shard=4096, r_nz=16, nodes=4, long_frac=0.05, bs=256,
          window_div=64):
    n = p * shard
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // window_div,
                              long_range_frac=long_frac, seed=1)
    topo = Topology(p, p // nodes)
    return build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo), r_nz


def test_rank_is_sorted_and_complete():
    plan, r_nz = _plan()
    ranked = tune.rank_strategies(plan, r_nz, ABEL)
    names = [s for s, _ in ranked]
    times = [t for _, t in ranked]
    assert sorted(names) == sorted(STRATEGIES)
    assert times == sorted(times)
    assert all(t > 0 and np.isfinite(t) for t in times)


def test_rank_matches_predictors_exactly():
    plan, r_nz = _plan()
    w = tune.workload_from_plan(plan, r_nz)
    ranked = dict(tune.rank_strategies(plan, r_nz, ABEL))
    from helpers.model_error import assert_model_error
    for rung, direct in (("condensed", pm.predict_v3(w, ABEL)),
                         ("blockwise", pm.predict_v2(w, ABEL)),
                         ("replicate", pm.predict_replicate(w, ABEL)),
                         ("overlap", pm.predict_overlap(w, ABEL))):
        assert_model_error(ranked[rung], direct, budget=1e-6,
                           label=f"rank_strategies vs predictor [{rung}]")


def test_overlap_never_predicted_slower_than_condensed():
    """Overlap hides the memput phase behind own compute and drops the
    eq.-14 copy, so the model must never rank it behind condensed."""
    for kwargs in (dict(), dict(long_frac=0.3), dict(nodes=1),
                   dict(p=8, shard=512, nodes=2, bs=64)):
        plan, r_nz = _plan(**kwargs)
        w = tune.workload_from_plan(plan, r_nz)
        assert pm.predict_overlap(w, ABEL) <= pm.predict_v3(w, ABEL) * (1 + 1e-9)


def test_condensed_family_wins_at_paper_scale():
    """Paper Table 3 regime: multi-node, large shards, mostly-local pattern
    -> the condensed family (condensed/overlap) must be the model's pick,
    and blockwise must rank last (whole-block volume tax)."""
    plan, r_nz = _plan(p=16, shard=16384, long_frac=0.002, bs=256,
                       window_div=256)
    ranked = tune.rank_strategies(plan, r_nz, ABEL)
    assert ranked[0][0] in ("condensed", "overlap")
    assert ranked[-1][0] == "blockwise"


def test_choose_respects_candidates():
    plan, r_nz = _plan()
    assert tune.choose_strategy(
        plan, r_nz, hw=ABEL,
        candidates=("replicate", "blockwise")) in ("replicate", "blockwise")


def test_measure_hardware_memoized_and_sane():
    hw1 = tune.measure_hardware()
    hw2 = tune.measure_hardware()
    assert hw1 is hw2  # per-process memoization: one calibration per mesh
    assert hw1.w_private > 0 and hw1.w_remote > 0 and hw1.tau > 0
    assert 16 <= hw1.cacheline <= 4096


def test_auto_engine_matches_reference():
    import jax
    from repro.core.spmv import DistributedSpMV

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 8, locality_window=n // 8,
                              long_range_frac=0.05, seed=2)
    eng = DistributedSpMV(m, mesh, strategy="auto", blocksize=32)
    assert eng.requested_strategy == "auto"
    assert eng.strategy in STRATEGIES
    assert set(eng.predicted_times) == set(STRATEGIES)
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(eng(eng.shard_vector(x))),
                               spmv_ref_np(m, x), rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_predicted_ranking_tracks_measured_8dev():
    helpers = os.path.join(os.path.dirname(__file__), "helpers")
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(helpers, "check_autotune.py")],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, (
        f"check_autotune failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
        f"STDERR:{proc.stderr[-3000:]}")
    assert "AUTOTUNE_OK" in proc.stdout
