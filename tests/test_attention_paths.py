"""Attention path equivalence: banded SWA and flash vs the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (_dense_attention, _flash_attention,
                                 _swa_banded_attention, attention)

KEY = jax.random.PRNGKey(0)


def _qkv(b, s, h, hkv, d, skv=None):
    skv = skv or s
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, hkv, d))
    return q, k, v


@pytest.mark.parametrize("s,window,qc", [(4096, 512, 2048), (2048, 256, 512),
                                         (1024, 128, 1024)])
def test_banded_swa_matches_dense(s, window, qc):
    b, h, hkv, d = 2, 4, 2, 32
    q, k, v = _qkv(b, s, h, hkv, d)
    qg = q.reshape(b, s, hkv, h // hkv, d)
    got = _swa_banded_attention(qg, k, v, window=window, q_chunk=qc)
    want = _dense_attention(qg, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got).reshape(b, s, h, d),
                               np.asarray(want).reshape(b, s, h, d),
                               rtol=2e-4, atol=2e-4)


def test_flash_matches_dense_causal():
    b, s, h, hkv, d = 1, 2048, 4, 2, 32
    q, k, v = _qkv(b, s, h, hkv, d)
    qg = q.reshape(b, s, hkv, h // hkv, d)
    got = _flash_attention(qg, k, v, causal=True, window=0,
                           q_chunk=512, kv_chunk=512)
    want = _dense_attention(qg, k, v, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_attention_dispatcher_picks_banded():
    """attention() must route large SWA self-attention through the banded
    path and still agree with the dense oracle."""
    b, s, h, hkv, d, window = 1, 4096, 2, 1, 16, 256
    q, k, v = _qkv(b, s, h, hkv, d)
    got = attention(q, k, v, causal=True, window=window)
    qg = q.reshape(b, s, hkv, h // hkv, d)
    want = _dense_attention(qg, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want).reshape(b, s, h, d),
                               rtol=2e-4, atol=2e-4)
