"""Optimizer, data pipeline, checkpointing, fused loss, fault utilities."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataState, SyntheticLM
from repro.models.transformer import fused_ce_loss, lm_loss
from repro.optim.adamw import (AdamW, clip_by_global_norm, cosine_schedule,
                               global_norm)
from repro.runtime.fault import StragglerWatch, retrying

from repro import compat


# ---------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, _ = opt.apply(params, grads, state)
    assert float(jnp.abs(params["x"]).max()) < 1e-2
    assert int(state["step"]) == 200


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((2, 2)) * 4.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(norm) > 1.0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(lr(0)) < 0.2
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=0.1)
    assert float(lr(99)) < 0.2


def test_weight_decay_pulls_to_zero():
    opt = AdamW(lr=0.05, weight_decay=0.5, clip_norm=0.0)
    params = {"x": jnp.array([5.0])}
    state = opt.init(params)
    for _ in range(100):
        params, state, _ = opt.apply(params, {"x": jnp.zeros(1)}, state)
    assert float(jnp.abs(params["x"])[0]) < 1.0


# ------------------------------------------------------------------- data
def test_data_deterministic_and_resumable():
    d = SyntheticLM(1000, 64, 4, seed=7)
    t1, l1 = d.batch_at(5)
    t2, l2 = d.batch_at(5)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(t1[:, 1:], l1[:, :-1])
    assert t1.shape == (4, 64) and t1.dtype == np.int32
    assert t1.min() >= 0 and t1.max() < 1000
    # iterating from a restored state replays the exact stream
    it = d.iterate(DataState(step=5))
    t3, _ = next(it)
    np.testing.assert_array_equal(t1, t3)


def test_data_batches_differ_across_steps():
    d = SyntheticLM(1000, 64, 4, seed=7)
    a, _ = d.batch_at(0)
    b, _ = d.batch_at(1)
    assert (a != b).any()


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    ckpt.save(str(tmp_path), 7, tree, extra={"data": {"step": 7}})
    assert ckpt.latest_step(str(tmp_path)) == 7
    target = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, extra = ckpt.restore(str(tmp_path), 7, target)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), restored, tree)
    assert extra == {"data": {"step": 7}}


def test_checkpoint_manager_gc_and_async(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), keep_last=2, save_every=1)
    tree = {"w": jnp.ones((8,))}
    for step in (1, 2, 3, 4):
        assert mgr.maybe_save(step, tree)
    mgr.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    got, _, _ = mgr.restore_latest(tree)
    assert got == 4


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, {"w": jnp.ones((5,))})


# --------------------------------------------------------------- fused loss
def test_fused_ce_matches_full_logits_loss():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 32, 16, 50
    x = jax.random.normal(key, (b, s, d))
    head = jax.random.normal(jax.random.PRNGKey(1), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    full = lm_loss(x @ head, labels)
    fused = fused_ce_loss(x, head, labels, chunk=8)
    np.testing.assert_allclose(float(fused), float(full), rtol=1e-5)
    # gradients agree too
    g1 = jax.grad(lambda xx: lm_loss(xx @ head, labels))(x)
    g2 = jax.grad(lambda xx: fused_ce_loss(xx, head, labels, chunk=8))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-6)


# -------------------------------------------------------------------- fault
def test_straggler_watch_flags_outlier():
    w = StragglerWatch(window=50, z_thresh=4.0, patience=2)
    for _ in range(30):
        assert not w.observe(0.1 + np.random.default_rng(0).normal() * 1e-4)
    assert w.observe(10.0)
    assert not w.persistent
    assert w.observe(10.0)
    assert w.persistent


def test_retrying_recovers_then_raises():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 2:
            raise RuntimeError("transient")
        return "ok"

    assert retrying(flaky, retries=2)() == "ok"

    def always_fails():
        raise RuntimeError("hard")

    with pytest.raises(RuntimeError):
        retrying(always_fails, retries=1)()


# -------------------------------------------------------- int8 compression
def test_compressed_psum_error_feedback_single_device():
    """Error feedback: quantization residual is re-injected, so the running
    sum of dequantized values tracks the true sum (unbiased over steps)."""
    from repro.optim.compress import compressed_psum
    mesh = compat.make_mesh((1,), ("data",),
                            axis_types=compat.auto_axis_types(1))
    from jax.sharding import PartitionSpec as P

    g = jnp.asarray(np.random.default_rng(0).standard_normal(128) * 1e-3,
                    jnp.float32)
    r = jnp.zeros_like(g)
    total_true, total_deq = jnp.zeros_like(g), jnp.zeros_like(g)
    f = jax.jit(compat.shard_map(
        lambda gg, rr: compressed_psum(gg, rr, "data"), mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False))
    for _ in range(50):
        out, r = f(g, r)
        total_deq = total_deq + out
        total_true = total_true + g
    # cumulative relative error shrinks thanks to error feedback
    rel = float(jnp.abs(total_deq - total_true).max()
                / jnp.abs(total_true).max())
    assert rel < 0.02, rel
