"""DynamicPattern: device-derived executor tables vs the host ``CommPlan``.

The dynamic tier's whole contract is bit-identity: the in-jit derivation
(``repro.comm.dynamic``) must reproduce the host planner's tables exactly —
same sort order, same dump slots, same envelope padding — across routing
shapes, in BOTH directions.  Property-tested with hypothesis where the
extra is installed; a seeded grid sweep covers the same space otherwise
(the repo's degraded-import pattern).
"""
import numpy as np
import pytest

from repro.comm import dynamic as dyn
from repro.comm import plan_cache
from repro.comm.pattern import AccessPattern
from repro.comm.plan import build_comm_plan, derive_scatter_plan
from repro.models.moe import moe_dispatch_pattern, random_router

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degraded: the seeded sweep below covers the grid
    HAVE_HYPOTHESIS = False


@pytest.fixture(autouse=True)
def isolated_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "plans"))
    monkeypatch.delenv("REPRO_PLAN_CACHE", raising=False)
    plan_cache.clear_memory_cache()
    plan_cache.stats.reset()
    yield
    plan_cache.clear_memory_cache()


def _routing_cols(num_experts, capacity, k, seed, n_tok=128, p=4):
    """A realistic irregular index set: the MoE slot→token table."""
    top_e, _ = random_router(seed, n_tok, num_experts, k)
    idx, _ = moe_dispatch_pattern(top_e, n_tok, num_experts, capacity, p)
    return idx.reshape(-1, 1), n_tok, p


def _assert_tables_match(cols, n, p, s_max):
    """Both directions, all seven executor tables, bit-exact."""
    plan = build_comm_plan(cols, n, p, s_max=s_max)
    assert plan.s_max == s_max
    g = dyn.derive_gather_tables(cols, n, p, s_max)
    np.testing.assert_array_equal(np.asarray(g.send_local_idx),
                                  plan.send_local_idx)
    np.testing.assert_array_equal(np.asarray(g.recv_global_idx),
                                  plan.recv_global_idx)
    np.testing.assert_array_equal(np.asarray(g.send_counts),
                                  plan.send_counts)
    splan = derive_scatter_plan(plan)
    s = dyn.derive_scatter_tables(cols, n, p, s_max, gather=g)
    np.testing.assert_array_equal(np.asarray(s.cond_msg_idx),
                                  splan.cond_msg_idx)
    np.testing.assert_array_equal(np.asarray(s.own_tgt_idx),
                                  splan.own_tgt_idx)
    np.testing.assert_array_equal(np.asarray(s.win_mask), splan.win_mask)
    np.testing.assert_array_equal(np.asarray(s.touched), splan.touched)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(num_experts=st.sampled_from([8, 16, 32]),
           capacity=st.sampled_from([4, 8, 16]),
           k=st.integers(1, 4),
           seed=st.integers(0, 2 ** 16),
           widen=st.integers(0, 3))
    def test_dynamic_tables_bit_identical(num_experts, capacity, k, seed,
                                          widen):
        cols, n, p = _routing_cols(num_experts, capacity, k, seed)
        s_max = dyn.envelope_s_max(cols.shape[0], 1, n, p)
        _assert_tables_match(cols, n, p, s_max + widen)

else:

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("num_experts,capacity,k",
                             [(e, c, k) for e in (8, 16, 32)
                              for c in (4, 16) for k in (1, 2, 4)])
    def test_dynamic_tables_bit_identical(num_experts, capacity, k, seed):
        cols, n, p = _routing_cols(num_experts, capacity, k, seed)
        s_max = dyn.envelope_s_max(cols.shape[0], 1, n, p)
        _assert_tables_match(cols, n, p, s_max + seed)


def test_envelope_padding_is_widening_only():
    """The natural s_max, the envelope bound, and anything wider all give
    bit-identical tables (extra slots are pure dump padding); narrowing
    below the natural maximum is refused by the host build."""
    cols, n, p = _routing_cols(8, 8, 2, 0)
    natural = build_comm_plan(cols, n, p).s_max
    env = dyn.envelope_s_max(cols.shape[0], 1, n, p)
    assert natural <= env
    for s_max in (natural, env, env + 5):
        _assert_tables_match(cols, n, p, s_max)
    if natural > 1:
        with pytest.raises(AssertionError, match="widening-only"):
            build_comm_plan(cols, n, p, s_max=natural - 1)


def test_multi_r_patterns_match():
    """r > 1 rows (SpMV-like) derive identically too — the tier is not
    MoE-specific."""
    rng = np.random.default_rng(5)
    n, p = 256, 4
    for r in (2, 3):
        cols = rng.integers(0, n, size=(64, r)).astype(np.int32)
        s_max = dyn.envelope_s_max(64, r, n, p)
        _assert_tables_match(cols, n, p, s_max)


# ---------------------------------------------------------------------------
# Front-door surface: the DynamicPattern duck-type through the real doors
# ---------------------------------------------------------------------------


def _mesh():
    import jax
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), ("data",)), ndev


def _dyn_case(p, seed=0, rows_per_shard=32, r=2, shard=64):
    rng = np.random.default_rng(seed)
    n = shard * p
    cols = rng.integers(0, n, size=(rows_per_shard * p, r)).astype(np.int32)
    template = AccessPattern.from_indices(cols, n=n)
    return template, dyn.DynamicPattern.from_template(template, p), n


def test_front_doors_accept_dynamic_pattern():
    """Gather and scatter take a DynamicPattern wherever they take an
    AccessPattern; auto restricts candidates to the dynamic rungs; results
    match a statically host-planned exchange of the same pattern."""
    from repro.comm.gather import IrregularGather
    from repro.comm.scatter import IrregularScatter
    from repro.core import perfmodel as pm

    mesh, p = _mesh()
    template, dp, n = _dyn_case(p)
    rng = np.random.default_rng(1)

    gather = IrregularGather(dp, mesh, strategy="auto", hw=pm.ABEL)
    assert gather.strategy in dyn.DYNAMIC_STRATEGIES
    assert set(gather.predicted_times) == set(dyn.DYNAMIC_STRATEGIES)
    static_g = IrregularGather(template, mesh, strategy=gather.strategy,
                               hw=pm.ABEL)
    x = rng.standard_normal(n).astype(np.float32)
    # compare the n real entries only: the trailing dump slot collects
    # padded sends and legitimately differs between the natural-s_max
    # static plan and the envelope-s_max dynamic one
    np.testing.assert_array_equal(
        np.asarray(gather(gather.shard_vector(x)))[:, :n],
        np.asarray(static_g(static_g.shard_vector(x)))[:, :n])

    scatter = IrregularScatter(dp, mesh, strategy="auto", reduce="add",
                               hw=pm.ABEL)
    assert scatter.strategy in dyn.DYNAMIC_STRATEGIES
    static_s = IrregularScatter(template, mesh, strategy=scatter.strategy,
                                reduce="add", hw=pm.ABEL)
    vals = rng.standard_normal(template.indices.shape).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(scatter(scatter.shard_values(vals))),
        np.asarray(static_s(static_s.shard_values(vals))))


def test_dynamic_pattern_rejects_underivable_configs():
    """Rungs outside DYNAMIC_STRATEGIES, auto candidates naming them, and
    host-precomputed Destination descriptors are all refused loudly."""
    from repro.comm.gather import IrregularGather
    from repro.comm.pattern import Destination
    from repro.comm.scatter import IrregularScatter
    from repro.core import perfmodel as pm

    mesh, p = _mesh()
    _, dp, n = _dyn_case(p)
    with pytest.raises(ValueError, match="DynamicPattern"):
        IrregularGather(dp, mesh, strategy="blockwise", hw=pm.ABEL)
    with pytest.raises(ValueError, match="DynamicPattern"):
        IrregularScatter(dp, mesh, strategy="replicate", reduce="add",
                         hw=pm.ABEL)
    with pytest.raises(ValueError, match="candidates"):
        IrregularGather(dp, mesh, strategy="auto",
                        candidates=("blockwise", "condensed"), hw=pm.ABEL)
    slots = np.zeros((p, 4), np.int64)
    with pytest.raises(ValueError, match="Destination"):
        IrregularGather(dp, mesh, strategy="condensed",
                        destination=Destination.from_slots(s=slots),
                        hw=pm.ABEL)


def test_derive_plan_args_guard_rails():
    """derive_plan_args serves only the dynamic rungs."""
    from repro.comm.gather import IrregularGather
    from repro.core import perfmodel as pm

    mesh, p = _mesh()
    template, dp, n = _dyn_case(p)
    g = IrregularGather(template, mesh, strategy="blockwise", hw=pm.ABEL)
    with pytest.raises(ValueError, match="derive_plan_args"):
        g.derive_plan_args(template.indices)


def test_envelope_s_max_bounds():
    """The envelope is the tight worst case: no per-(reader, owner) pair
    can need more slots than its shard holds or than the reader reads."""
    assert dyn.envelope_s_max(64, 1, 1024, 8) == 8        # rows bound
    assert dyn.envelope_s_max(4096, 2, 64, 8) == 8        # shard bound
    assert dyn.envelope_s_max(8, 1, 8, 8) == 1            # floor
    cols, n, p = _routing_cols(16, 8, 2, 3)
    natural = build_comm_plan(cols, n, p).s_max
    assert natural <= dyn.envelope_s_max(cols.shape[0], 1, n, p)


def test_dynamic_moe_layer_matches_static_layer():
    """The proving consumer: one routed step through DynamicMoELayer ==
    the statically host-planned MoELayer for the same routing."""
    import jax
    from repro.core import perfmodel as pm
    from repro.models.moe import DynamicMoELayer, MoELayer

    mesh, p = _mesh()
    n_tok, d, f, k, e_total, cap = 128, 4, 8, 2, 8, 16
    rng = np.random.default_rng(0)
    params = {
        "w1": (rng.standard_normal((e_total, d, f)) * 0.1).astype(np.float32),
        "w2": (rng.standard_normal((e_total, f, d)) * 0.1).astype(np.float32),
    }
    te, tw = random_router(1, n_tok, e_total, k)
    x_host = rng.standard_normal((n_tok, d)).astype(np.float32)

    layer = DynamicMoELayer(params, te, n_tok, e_total, cap, mesh,
                            strategy="auto", hw=pm.ABEL)
    y_dyn = np.asarray(layer(layer.shard_tokens(x_host), te, tw))
    base = MoELayer(params, te, tw, n_tok, e_total, cap, mesh,
                    strategy="condensed", hw=pm.ABEL)
    y_ref = np.asarray(base(base.shard_tokens(x_host)))
    np.testing.assert_allclose(y_dyn, y_ref, rtol=2e-5, atol=2e-5)
    # a second, different routing through the SAME layer still matches
    te2, tw2 = random_router(2, n_tok, e_total, k)
    y_dyn2 = np.asarray(layer(layer.shard_tokens(x_host), te2, tw2))
    base2 = MoELayer(params, te2, tw2, n_tok, e_total, cap, mesh,
                     strategy="condensed", hw=pm.ABEL)
    y_ref2 = np.asarray(base2(base2.shard_tokens(x_host)))
    np.testing.assert_allclose(y_dyn2, y_ref2, rtol=2e-5, atol=2e-5)
