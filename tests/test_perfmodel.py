"""Unit tests for the paper's performance models (§5, §8)."""
import numpy as np
import pytest

from repro.core import perfmodel as pm
from repro.core.matrix import make_mesh_like_matrix
from repro.core.plan import Topology, build_comm_plan


def _workload(p=8, shard=64, r_nz=4, nodes=2, long_frac=0.2, bs=16, seed=0):
    n = p * shard
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 4,
                              long_range_frac=long_frac, seed=seed)
    topo = Topology(p, p // nodes)
    plan = build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    return pm.SpmvWorkload(n=n, r_nz=r_nz, p=p, blocksize=bs, topology=topo,
                           counts=plan.counts)


def test_d_min_comp_matches_paper_eq6():
    # r_nz=16, double + int: 16*(8+4) + 3*8 = 216 bytes per row
    hw = pm.ABEL
    assert pm._d_min_comp(hw, 16) == 216


def test_compute_time_hand_computed():
    w = _workload()
    hw = pm.HardwareParams(w_private=1e9, w_remote=1e8, tau=1e-6,
                           cacheline=64)
    t = pm.t_comp_per_thread(w, hw)
    expect = 64 * (4 * 12 + 24) / 1e9
    np.testing.assert_allclose(t, expect)


def test_v1_hand_computed():
    w = _workload()
    hw = pm.HardwareParams(w_private=1e9, w_remote=1e8, tau=1e-5,
                           cacheline=64)
    c = w.counts
    expect = np.max(
        pm.t_comp_per_thread(w, hw)
        + c.c_local_indv * 64 / 1e9 + c.c_remote_indv * 1e-5)
    np.testing.assert_allclose(pm.predict_v1(w, hw), expect)


def test_strategy_ordering_at_scale():
    """Paper Table 3: at multi-node scale, v3 < v2 and v3 < v1."""
    w = _workload(p=16, shard=4096, r_nz=16, nodes=4, long_frac=0.05)
    hw = pm.ABEL
    t = pm.predict_all(w, hw)
    assert t["v3_condensed"] < t["v2_blockwise"]
    assert t["v3_condensed"] < t["v1_finegrained"]


def test_single_node_v1_can_beat_v2():
    """Paper's observed exception (Table 3, one node): with no tau penalty,
    v1's few individual accesses beat v2's whole-block transfers when the
    access pattern is local (small window) and blocks are large."""
    p, shard = 16, 4096
    n = p * shard
    m = make_mesh_like_matrix(n, 16, locality_window=256,
                              long_range_frac=0.0, seed=3)
    topo = Topology(p, p)  # one node
    plan = build_comm_plan(m.cols, n, p, blocksize=shard, topology=topo)
    w = pm.SpmvWorkload(n=n, r_nz=16, p=p, blocksize=shard, topology=topo,
                        counts=plan.counts)
    t = pm.predict_all(w, pm.ABEL)
    assert t["v1_finegrained"] < t["v2_blockwise"], t


def test_tau_dominates_v1_across_nodes():
    w = _workload(p=8, shard=2048, r_nz=16, nodes=4, long_frac=0.3)
    slow = pm.ABEL.replace(tau=1e-3)
    fast = pm.ABEL.replace(tau=1e-7)
    assert pm.predict_v1(w, slow) > 100 * pm.predict_v1(w, fast) * 0.01


def test_blocksize_affects_v2_volume():
    """Paper Fig. 2 bottom: BLOCKSIZE dials the blockwise volume."""
    vols = []
    for bs in (8, 16, 32, 64):
        w = _workload(bs=bs)
        vols.append(w.counts.total_blockwise_volume())
    assert vols[0] <= vols[-1]  # bigger blocks move at least as much data


def test_heat2d_volumes_and_prediction():
    topo = Topology(8, 4)
    w = pm.Heat2DWorkload(big_m=512, big_n=1024, mprocs=2, nprocs=4,
                          topology=topo)
    s_horiz, s_local, s_remote, c_remote = pm._heat2d_volumes(w)
    # interior thread count halo sides: corner threads have 2 nbrs
    assert s_horiz.sum() > 0
    # total exchanged volume is symmetric
    assert s_local.sum() % 2 == 0
    pred = pm.predict_heat2d(w, pm.ABEL, steps=1000)
    assert pred["comp"] > 0 and pred["halo"] > 0
    # compute term matches eq. 22 by hand
    m_loc, n_loc = 512 // 2 + 2, 1024 // 4 + 2
    expect = 1000 * 3 * (m_loc - 2) * (n_loc - 2) * 8 / pm.ABEL.w_private
    np.testing.assert_allclose(pred["comp"], expect)


def test_decode_exchange_is_max_of_model_and_floor():
    """Eqs. 12δ–15δ: the decode price of a rung is max(β throughput model,
    α/latency floor), and the floor never drops below the window setup."""
    w = _workload(nodes=4)
    hw = pm.ABEL
    setup = pm.window_setup_time(w.topology, hw)
    for strat, base_fn in pm.STRATEGY_PREDICTORS.items():
        floor = pm.decode_floor(w, hw, strategy=strat, direction="get")
        assert floor >= setup
        t = pm.predict_decode_exchange(w, hw, strategy=strat,
                                       direction="get")
        np.testing.assert_allclose(t, max(float(base_fn(w, hw)), floor))


def test_decode_floor_dominates_at_tiny_m():
    """A serving-sized workload (few accessed elements) must be
    latency-bound: the α floor exceeds the β model, which under-charges
    transfers too small to amortize its bandwidth terms."""
    tiny = _workload(shard=16, r_nz=1, nodes=4, bs=8)
    hw = pm.ABEL
    for strat in pm.STRATEGY_PREDICTORS:
        floor = pm.decode_floor(tiny, hw, strategy=strat, direction="get")
        assert (pm.predict_decode_exchange(tiny, hw, strategy=strat,
                                           direction="get") == floor)


def test_predict_decode_step_composition():
    w = _workload(nodes=2)
    hw = pm.ABEL
    out = pm.predict_decode_step(
        [("dispatch", "get", w, "condensed"),
         ("combine", "put", w, "condensed")], hw)
    times = [t for (_, _, _, t) in out["stages"]]
    np.testing.assert_allclose(out["sum_standalone"], sum(times))
    # the fused window consolidates exactly K-1 redundant setups (eq. 23)
    np.testing.assert_allclose(out["setup_saved"],
                               pm.window_setup_time(w.topology, hw))
    assert max(times) <= out["total"] <= out["sum_standalone"]
    # strategy=None resolves each stage to its argmin decode-priced rung
    auto = pm.predict_decode_step([("dispatch", "get", w, None)], hw)
    _, _, picked, t = auto["stages"][0]
    best = min((pm.predict_decode_exchange(w, hw, strategy=s,
                                           direction="get"), s)
               for s in pm.STRATEGY_PREDICTORS)
    np.testing.assert_allclose(t, best[0])
    assert picked == best[1]


def test_rank_strategies_decode_reprices():
    """select.rank_strategies(decode=True) is what keeps strategy="auto"
    honest for serving: every rung's time is re-priced through
    predict_decode_exchange, which can only raise it."""
    from repro.comm import select
    n, p = 512, 8
    m = make_mesh_like_matrix(n, 4, locality_window=n // 4, seed=0)
    topo = Topology(p, 4)
    plan = build_comm_plan(m.cols, n, p, blocksize=16, topology=topo)
    hw = pm.ABEL
    plain = dict(select.rank_strategies(plan, 4, hw, direction="get"))
    dec = dict(select.rank_strategies(plan, 4, hw, direction="get",
                                      decode=True))
    w = select.workload_from_plan(plan, 4)
    assert set(dec) == set(plain)
    for s, t in dec.items():
        assert t >= plain[s]
        np.testing.assert_allclose(
            t, pm.predict_decode_exchange(w, hw, strategy=s,
                                          direction="get"))


def test_error_budget_decode_workload():
    """moe_decode carries a 3x budget over the base rung budget: the
    decode regime's wall clocks sit in dispatch-overhead territory on
    interpret-mode hosts."""
    key = {"rung": "condensed", "dtype": "float32", "mesh": [8]}
    base = pm.error_budget(dict(key, workload="spmv"))
    dec = pm.error_budget(dict(key, workload="moe_decode"))
    np.testing.assert_allclose(dec, 3.0 * base)


def test_paper_table5_comp_prediction():
    """Reproduce the paper's Table 5 T_comp predictions with Abel params:
    20000x20000 mesh, 16 threads (4x4): paper predicts 122.07 s / 1000
    steps.  Our eq.(22) with the stated constants gives 128 s; the ~5%
    offset is a GB/GiB rounding in the paper's bandwidth constant, so we
    assert agreement within 6% (and exact proportionality across rows)."""
    topo = Topology(16, 16)
    w = pm.Heat2DWorkload(big_m=20000, big_n=20000, mprocs=4, nprocs=4,
                          topology=topo)
    pred16 = pm.predict_heat2d(w, pm.ABEL, steps=1000)
    from helpers.model_error import assert_model_error
    assert_model_error(122.07, pred16["comp"], budget=0.06,
                       label="paper table5 comp, 16 threads")
    # and the 512-thread (16x32) row: 3.81 s
    topo = Topology(512, 16)
    w = pm.Heat2DWorkload(big_m=20000, big_n=20000, mprocs=16, nprocs=32,
                          topology=topo)
    pred512 = pm.predict_heat2d(w, pm.ABEL, steps=1000)
    assert_model_error(3.81, pred512["comp"], budget=0.06,
                       label="paper table5 comp, 512 threads")
    # scaling across rows is exact (32x fewer points per thread)
    assert_model_error(pred16["comp"] / pred512["comp"], 32.0, budget=1e-6,
                       label="row-to-row proportionality")
