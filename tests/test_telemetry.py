"""Plan-source telemetry, and the dynamic-MoE acceptance criterion:
N distinct routings through one ``DynamicMoELayer``, zero host plan
builds after warmup — every hot-path acquisition is a device derivation.
"""
import numpy as np
import pytest

from repro.comm import plan_cache, telemetry


@pytest.fixture(autouse=True)
def isolated_everything(tmp_path, monkeypatch):
    """Fresh telemetry AND a private plan cache per test — module-global
    counters never leak across tests (or from other test files)."""
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "plans"))
    monkeypatch.delenv("REPRO_PLAN_CACHE", raising=False)
    plan_cache.clear_memory_cache()
    plan_cache.stats.reset()
    with telemetry.isolated() as tel:
        yield tel
    plan_cache.clear_memory_cache()


def test_record_counts_and_latency(isolated_everything):
    tel = isolated_everything
    telemetry.record("host-build", seconds=0.5)
    telemetry.record("host-build", seconds=0.25)
    telemetry.record("memory-hit")
    snap = tel.snapshot()
    assert snap["sources"]["host-build"] == 2
    assert snap["sources"]["memory-hit"] == 1
    assert snap["build_seconds"]["host-build"] == pytest.approx(0.75)
    assert snap["total"] == 3
    assert tel.total == 3


def test_unknown_source_rejected(isolated_everything):
    with pytest.raises(ValueError, match="unknown plan source"):
        telemetry.record("clairvoyance")
    assert isolated_everything.total == 0


def test_snapshot_is_detached_and_since_is_flat(isolated_everything):
    tel = isolated_everything
    telemetry.record("disk-hit")
    snap = tel.snapshot()
    telemetry.record("device-derive")
    telemetry.record("device-derive")
    telemetry.record("bucket-reuse")
    telemetry.record_tick("decode_steps", 3)
    assert snap["sources"]["device-derive"] == 0      # detached
    delta = tel.since(snap)
    assert delta == {"memory-hit": 0, "disk-hit": 0, "bucket-reuse": 1,
                     "device-derive": 2, "host-build": 0,
                     "decode_steps": 3, "prefill_chunks": 0}


def test_decode_host_free_interval(isolated_everything):
    """The serving steady-state predicate: decode ticks happened and no
    host build landed inside the interval."""
    tel = isolated_everything
    telemetry.record("host-build", seconds=0.1)       # warmup build
    snap = tel.snapshot()
    assert not tel.decode_host_free(snap)             # no ticks yet
    telemetry.record_tick("decode_steps")
    telemetry.record("device-derive")
    assert tel.decode_host_free(snap)                 # warm + host-free
    telemetry.record("host-build")                    # steady-state bug
    assert not tel.decode_host_free(snap)


def test_host_free_warmup_boundary(isolated_everything):
    tel = isolated_everything
    telemetry.record("host-build", seconds=0.1)
    telemetry.record("device-derive")
    telemetry.record("device-derive")
    assert not tel.host_free()            # the warmup build counts
    assert tel.host_free(warmup=1)        # ... until it is excused
    telemetry.record("host-build")        # a post-warmup build is a bug
    assert not tel.host_free(warmup=1)


def test_isolated_restores_previous_stats():
    outer = telemetry.stats
    with telemetry.isolated() as inner:
        assert telemetry.stats is inner and inner is not outer
        telemetry.record("memory-hit")
        assert inner.total == 1
    assert telemetry.stats is outer


def test_plan_cache_feeds_telemetry(isolated_everything, tmp_path):
    """The three static-cache tiers each land in the right counter, with
    host builds carrying a positive measured latency."""
    tel = isolated_everything
    rng = np.random.default_rng(0)
    n, p = 256, 4
    cols = rng.integers(0, n, size=(64, 2)).astype(np.int32)

    plan_cache.get_comm_plan(cols, n, p)                 # cold: host build
    snap = tel.snapshot()
    assert snap["sources"]["host-build"] == 1
    assert snap["build_seconds"]["host-build"] > 0.0

    plan_cache.get_comm_plan(cols, n, p)                 # warm: memory LRU
    assert tel.since(snap)["memory-hit"] == 1

    plan_cache.clear_memory_cache()
    snap = tel.snapshot()
    plan_cache.get_comm_plan(cols, n, p)                 # persistent tier
    assert tel.since(snap)["disk-hit"] == 1

    snap = tel.snapshot()
    plan_cache.get_envelope_plan(cols, n, p, bucket=n)   # new envelope tier
    d = tel.since(snap)
    assert d["host-build"] == 1                          # founding build
    snap = tel.snapshot()
    other = rng.integers(0, n, size=(64, 2)).astype(np.int32)
    plan_cache.get_envelope_plan(other, n, p, bucket=n)  # coarse bucket
    assert tel.since(snap)["bucket-reuse"] == 1


def test_dynamic_moe_layer_runs_host_free(isolated_everything):
    """The tentpole acceptance test: one DynamicMoELayer, N distinct
    routings — after the construction/compile warmup, every routing is a
    single device-derive and host-build stays exactly zero."""
    import jax

    from repro.core import perfmodel as pm
    from repro.models.moe import DynamicMoELayer, random_router

    tel = isolated_everything
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    n_tok, d, f, k, e_total, cap = 128, 4, 8, 2, 8, 16
    rng = np.random.default_rng(0)
    params = {
        "w1": (rng.standard_normal((e_total, d, f)) * 0.1).astype(np.float32),
        "w2": (rng.standard_normal((e_total, f, d)) * 0.1).astype(np.float32),
    }
    te0, tw0 = random_router(0, n_tok, e_total, k)
    layer = DynamicMoELayer(params, te0, n_tok, e_total, cap, mesh,
                            strategy="auto", hw=pm.ABEL)
    assert layer.plan_time > 0.0          # T_plan priced into the ranking
    x = layer.shard_tokens(rng.standard_normal((n_tok, d)).astype(np.float32))
    jax.block_until_ready(layer(x, te0, tw0))            # warmup: traces
    warmup = tel.snapshot()["total"]

    n_routings = 4
    snap = tel.snapshot()
    for s in range(1, 1 + n_routings):
        te, tw = random_router(s, n_tok, e_total, k)
        jax.block_until_ready(layer(x, te, tw))
    delta = tel.since(snap)
    assert delta["device-derive"] == n_routings
    assert delta["host-build"] == 0
    assert sum(delta.values()) == n_routings             # nothing else fired
    assert tel.host_free(warmup=warmup)
