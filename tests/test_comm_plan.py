"""Property-based tests for the communication planner (paper §4.3.1).

Invariants:
  * delivery: after the condensed exchange, every index a shard's rows access
    is present in its x_copy (verified numerically in the multi-device test;
    here structurally);
  * conservation: Σ send == Σ recv, per pair;
  * condensing: per-pair message contents are unique and sorted;
  * volume ordering (paper Fig. 2): condensed <= blockwise <= replicate;
  * counts consistency between the plan arrays and the perf-model counts.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based planner tests need the 'hypothesis' extra")
from hypothesis import given, settings, strategies as st

from repro.core.matrix import make_mesh_like_matrix
from repro.core.plan import Topology, build_comm_plan


@st.composite
def plan_case(draw):
    p = draw(st.sampled_from([2, 4, 8]))
    shard = draw(st.sampled_from([16, 32, 64]))
    r_nz = draw(st.integers(2, 8))
    n = p * shard
    seed = draw(st.integers(0, 2**16))
    window = draw(st.integers(4, n))
    long_frac = draw(st.sampled_from([0.0, 0.05, 0.3]))
    spn = draw(st.sampled_from([1, 2]))
    if p % spn:
        spn = 1
    m = make_mesh_like_matrix(n, r_nz, locality_window=window,
                              long_range_frac=long_frac, seed=seed)
    bs = draw(st.sampled_from([s for s in (4, 8, 16, shard)
                               if shard % s == 0]))
    return m, n, p, bs, Topology(p, p // spn if p % (p // spn) == 0 else p)


@settings(max_examples=25, deadline=None)
@given(plan_case())
def test_plan_invariants(case):
    m, n, p, bs, topo = case
    plan = build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    shard = n // p

    # conservation + condensing + correct ownership
    for s in range(p):
        for q in range(p):
            k = int(plan.send_counts[s, q])
            if s == q:
                assert k == 0
                continue
            sent_local = plan.send_local_idx[s, q, :k]
            recv_glob = plan.recv_global_idx[q, s, :k]
            # sender's local indices + shard offset == receiver's globals
            np.testing.assert_array_equal(sent_local + s * shard, recv_glob)
            # condensed: unique and sorted
            assert len(np.unique(recv_glob)) == k
            assert (np.diff(recv_glob) > 0).all()
            # padding is the dump slot
            assert (plan.recv_global_idx[q, s, k:] == n).all()

    # delivery: every foreign index needed by q appears in some message to q
    for q in range(p):
        rows = slice(q * shard, (q + 1) * shard)
        needed = np.unique(m.cols[rows])
        foreign = needed[(needed // shard) != q]
        got = np.concatenate([
            plan.recv_global_idx[q, s, :plan.send_counts[s, q]]
            for s in range(p)]) if p > 1 else np.zeros(0, int)
        assert np.isin(foreign, got).all()

    # volume ordering (paper Fig. 2): condensed <= blockwise-foreign <= n-shard
    c = plan.counts
    cond = c.total_condensed_volume()
    blockw_foreign = (c.total_blockwise_volume()
                      - p * shard)  # minus own-shard copies
    assert cond <= blockw_foreign <= p * (n - shard)

    # counts consistency
    assert cond == int(plan.send_counts.sum())
    assert (c.s_local_out + c.s_remote_out).sum() == cond


@settings(max_examples=10, deadline=None)
@given(plan_case())
def test_blockwise_covers_condensed(case):
    """Every condensed index must live inside some transferred block."""
    m, n, p, bs, topo = case
    plan = build_comm_plan(m.cols, n, p, blocksize=bs, topology=topo)
    for q in range(p):
        for s in range(p):
            k = int(plan.send_counts[s, q])
            if not k:
                continue
            vals = plan.recv_global_idx[q, s, :k]
            kb = int(plan.send_block_counts[s, q])
            blocks = plan.recv_global_blk[q, s, :kb]
            assert np.isin(vals // bs, blocks).all()


def test_tau_counts_split_by_node():
    m = make_mesh_like_matrix(256, 4, locality_window=256,
                              long_range_frac=0.5, seed=1)
    topo = Topology(8, 4)  # 2 nodes
    plan = build_comm_plan(m.cols, 256, 8, blocksize=8, topology=topo)
    c = plan.counts
    # with heavy long-range traffic both intra and inter node occur
    assert c.c_local_indv.sum() > 0 and c.c_remote_indv.sum() > 0
    # every occurrence classified exactly once
    total_foreign = sum(
        ((m.cols[q * 32:(q + 1) * 32] // 32) != q).sum() for q in range(8))
    assert c.c_local_indv.sum() + c.c_remote_indv.sum() == total_foreign
