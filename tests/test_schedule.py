"""The ExchangeSchedule front door: fused multi-exchange windows.

Covers the tentpole guarantees of ``repro.comm.schedule``:

* a single-stage schedule is bit-identical to the one-shot front door it
  wraps (``IrregularGather`` / ``IrregularScatter`` stay the stage
  executors — the shim tests);
* the fused MoE dispatch → expert → combine layer is bit-identical to the
  composed three-window path on every ladder rung, and issues its stages
  inside ONE ``shard_map``;
* ``normal_equations_step`` (z = MᵀM x) matches the NumPy ground truth on
  every rung and shares one base plan between its two directions;
* the §5 composition model (``perfmodel.predict_schedule``) and the
  Heat2D full-window refinement (edge-ring term) behave;
* the ``measure_hw`` memo keys (tuple axes, factorization, clearing).

Integer-valued data keeps every float sum exact, so bit-identity tests
the scheduling/unpacking machinery, not float associativity.  Runs on
whatever devices the pytest process has (1 locally, 8 under the CI
gate's XLA_FLAGS).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm import (AccessPattern, IrregularGather, IrregularScatter,
                        Schedule, STRATEGIES, plan_cache)
from repro.core import perfmodel as pm
from repro.core.plan import Topology


def _mesh():
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), ("data",)), ndev


def _case(n, m, r, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(m, r)).astype(np.int32)
    vals = rng.integers(-4, 5, size=(m, r)).astype(np.float32)
    return AccessPattern.from_indices(idx, n=n), idx, vals


def _inner_jaxprs(param_value):
    vals = param_value if isinstance(param_value, (list, tuple)) \
        else [param_value]
    out = []
    for v in vals:
        if hasattr(v, "jaxpr"):       # ClosedJaxpr
            out.append(v.jaxpr)
        elif hasattr(v, "eqns"):      # Jaxpr
            out.append(v)
    return out


def _count_shard_maps(jaxpr) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if "shard_map" in str(eqn.primitive):
            total += 1
        for v in eqn.params.values():
            for sub in _inner_jaxprs(v):
                total += _count_shard_maps(sub)
    return total


# --------------------------------------------------------------------------
# shim tests: one-stage schedules == the one-shot front doors, bitwise
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_single_stage_gather_schedule_is_the_front_door(strategy):
    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, idx, _ = _case(n, n, 3, seed=0)
    rng = np.random.default_rng(0)
    x = rng.integers(-4, 5, size=n).astype(np.float32)

    g = IrregularGather(pattern, mesh, strategy=strategy, blocksize=8)
    sched = Schedule()
    x_ref = sched.input("x")
    gr = sched.gather(pattern, src=x_ref, strategy=strategy)
    sched.compute(lambda xc: xc[None], gr, name="stack")
    step = sched.compile(mesh, strategy=strategy, blocksize=8)
    np.testing.assert_array_equal(
        np.asarray(step(step.shard_input(x))),
        np.asarray(g(g.shard_vector(x))),
        err_msg=f"strategy={strategy}: schedule shim diverged")


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_single_stage_scatter_schedule_is_the_front_door(strategy):
    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, idx, vals = _case(n, n, 3, seed=1)
    s = IrregularScatter(pattern, mesh, strategy=strategy, blocksize=8)
    sched = Schedule()
    v_ref = sched.input("vals")
    sched.scatter(pattern, v_ref, reduce="add", strategy=strategy)
    step = sched.compile(mesh, blocksize=8)
    np.testing.assert_array_equal(
        np.asarray(step(step.shard_input(vals))),
        np.asarray(s(s.shard_values(vals))),
        err_msg=f"strategy={strategy}: schedule shim diverged")


# --------------------------------------------------------------------------
# the fused MoE layer (acceptance criterion): bit-identical to the
# composed dispatch + expert MLP + combine path on every rung, one
# shard_map for the whole chain
# --------------------------------------------------------------------------

def _moe_case(ndev, seed=2):
    n_tok, k, d, f = 32 * ndev, 2, 4, 8
    e_total, cap = 2 * ndev, 12
    rng = np.random.default_rng(seed)
    top_e = rng.integers(0, e_total, size=(n_tok, k))
    # power-of-two weights keep every product/sum exact in float32
    top_w = np.where(rng.random((n_tok, k)) < 0.5, 0.5, 0.25).astype(
        np.float32)
    x = rng.integers(-3, 4, (n_tok, d)).astype(np.float32)
    params = {
        "w1": rng.integers(-2, 3, (e_total, d, f)).astype(np.float32) * 0.25,
        "w2": rng.integers(-2, 3, (e_total, f, d)).astype(np.float32) * 0.25,
    }
    return n_tok, d, e_total, cap, top_e, top_w, x, params


def _composed_moe(params, top_e, top_w, n_tok, e_total, cap, mesh,
                  strategies, blocksize):
    """The back-to-back baseline: three windows, same rungs, the same
    local expert math (``moe_expert_local`` on both paths)."""
    from repro.models.moe import (MoECombineScatter, MoEDispatchGather,
                                  moe_expert_local)

    disp = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh,
                             strategy=strategies["dispatch"],
                             blocksize=blocksize, hw=pm.ABEL)
    comb = MoECombineScatter(top_e, top_w, n_tok, e_total, cap, mesh,
                             strategy=strategies["combine"],
                             blocksize=blocksize, hw=pm.ABEL)
    shard = NamedSharding(mesh, P("data"))
    w1 = jax.device_put(params["w1"], shard)
    w2 = jax.device_put(params["w2"], shard)
    expert = jax.jit(compat.shard_map(
        lambda b, a, c: moe_expert_local(b, a, c),
        mesh=mesh, in_specs=(P("data"),) * 3, out_specs=P("data"),
        check_vma=False))
    return disp, lambda x: comb(expert(disp(x), w1, w2))


@pytest.mark.parametrize("strategy", STRATEGIES + ("auto",))
def test_moe_layer_bit_identical_to_composed_path(strategy):
    from repro.models.moe import MoELayer

    mesh, ndev = _mesh()
    n_tok, d, e_total, cap, top_e, top_w, x, params = _moe_case(ndev)
    layer = MoELayer(params, top_e, top_w, n_tok, e_total, cap, mesh,
                     strategy=strategy, blocksize=8, hw=pm.ABEL)
    assert set(layer.strategies) == {"dispatch", "combine"}
    disp, baseline = _composed_moe(params, top_e, top_w, n_tok, e_total,
                                   cap, mesh, layer.strategies, blocksize=8)
    xs = layer.shard_tokens(x)
    np.testing.assert_array_equal(
        np.asarray(layer(xs)), np.asarray(baseline(xs)),
        err_msg=f"strategy={strategy}: fused layer diverged from the "
                "composed dispatch+expert+combine path")


def test_moe_layer_single_shard_map_and_shared_plan(tmp_path, monkeypatch):
    """The fused step is ONE shard_map; the combine's executor tables are
    a transpose-derived delta of the dispatch's base plan (one O(nnz)
    preparation step for the whole chain); the fused window is priced."""
    from repro.models.moe import MoELayer

    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "plans"))
    plan_cache.clear_memory_cache()
    plan_cache.stats.reset()
    mesh, ndev = _mesh()
    n_tok, d, e_total, cap, top_e, top_w, x, params = _moe_case(ndev, seed=3)
    layer = MoELayer(params, top_e, top_w, n_tok, e_total, cap, mesh,
                     strategy="condensed", blocksize=8, hw=pm.ABEL)
    assert plan_cache.stats.misses == 1      # one O(nnz) build total
    assert plan_cache.stats.derives == 1     # one O(m*r) transpose delta
    assert layer.scatter.splan.transpose() is layer.scatter.plan

    jaxpr = jax.make_jaxpr(lambda v: layer.schedule(v))(
        layer.shard_tokens(x))
    assert _count_shard_maps(jaxpr.jaxpr) == 1, (
        "the fused step must issue all stages inside one shard_map")

    win = layer.predicted_window
    assert win is not None and win["total"] > 0
    assert win["total"] <= win["sum_standalone"]
    assert len(win["stages"]) == 2
    assert {s[1] for s in win["stages"]} == {"get", "put"}


# --------------------------------------------------------------------------
# normal equations: z = MᵀM x through one schedule
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES + ("auto",))
def test_normal_equations_step_matches_reference(strategy):
    from repro.core.matrix import (EllpackMatrix, make_mesh_like_matrix,
                                   spmv_ref_np, spmv_t_ref_np)
    from repro.core.spmv import normal_equations_step

    mesh, ndev = _mesh()
    n = 64 * ndev
    m0 = make_mesh_like_matrix(n, 4, locality_window=n // 8,
                               long_range_frac=0.1, seed=4)
    rng = np.random.default_rng(4)
    m = EllpackMatrix(
        n=n, r_nz=m0.r_nz,
        diag=rng.integers(-3, 4, n).astype(np.float32),
        vals=rng.integers(-3, 4, (n, m0.r_nz)).astype(np.float32),
        cols=m0.cols)
    x = rng.integers(-3, 4, n).astype(np.float32)
    ref = spmv_t_ref_np(m, spmv_ref_np(m, x))
    step = normal_equations_step(m, mesh, strategy=strategy, blocksize=16,
                                 hw=pm.ABEL)
    z = np.asarray(step(step.shard_vector(x)))
    np.testing.assert_array_equal(z, ref)
    assert set(step.strategies) == {"gather_x", "scatter_t"}


def test_normal_equations_shares_one_base_plan(tmp_path, monkeypatch):
    from repro.core.matrix import make_mesh_like_matrix
    from repro.core.spmv import normal_equations_step

    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "plans"))
    plan_cache.clear_memory_cache()
    plan_cache.stats.reset()
    mesh, ndev = _mesh()
    n = 64 * ndev
    m = make_mesh_like_matrix(n, 4, locality_window=n // 8,
                              long_range_frac=0.1, seed=5)
    step = normal_equations_step(m, mesh, strategy="condensed",
                                 blocksize=16)
    assert plan_cache.stats.misses == 1
    assert plan_cache.stats.derives == 1
    assert step.predicted_window is None  # no hw in scope, fixed rungs


# --------------------------------------------------------------------------
# builder semantics
# --------------------------------------------------------------------------

def test_schedule_per_stage_strategy_override_and_pipelined_chain():
    """gather → compute → scatter in one window, with a per-stage rung
    override beating the schedule default, against the NumPy reference."""
    mesh, ndev = _mesh()
    n = 32 * ndev
    pattern, idx, vals = _case(n, n, 3, seed=6)
    rng = np.random.default_rng(6)
    x = rng.integers(-3, 4, n).astype(np.float32)

    sched = Schedule()
    x_ref = sched.input("x")
    rows = sched.constant(idx)
    v = sched.constant(vals)
    g = sched.gather(pattern, src=x_ref, strategy="replicate", name="g")
    c = sched.compute(lambda xc, r, vv: vv * xc[r], g, rows, v)
    s = sched.scatter(pattern, c, reduce="add", name="s")
    # the schedule default applies to stages without an override
    step = sched.compile(mesh, strategy="condensed", blocksize=8, output=s)
    assert step.strategies == {"g": "replicate", "s": "condensed"}
    out = np.asarray(step(step.shard_input(x)))
    ref = np.zeros(n, np.float32)
    np.add.at(ref, idx.ravel(), (vals * x[idx]).ravel())
    np.testing.assert_array_equal(out, ref)


def test_schedule_validation_errors():
    mesh, ndev = _mesh()
    n = 16 * ndev
    pattern, idx, _ = _case(n, n, 2, seed=7)
    from repro.comm import Destination
    slots = idx[:, :1].reshape(ndev, -1).astype(np.int64)
    dest = Destination.from_slots(rows=slots)

    sched = Schedule()
    x = sched.input("x")
    g = sched.gather(pattern, src=x, destination=dest)
    with pytest.raises(ValueError, match="Destination"):
        sched.scatter(pattern, g)          # dict-valued src rejected
    with pytest.raises(ValueError, match="Destination"):
        sched.compile(mesh, strategy="condensed", output=g)

    s2 = Schedule()
    vin = s2.input("v")
    with pytest.raises(ValueError, match="reduce"):
        s2.scatter(pattern, vin, reduce="mean")

    empty = Schedule()
    empty.input("x")
    with pytest.raises(AssertionError, match="at least one exchange"):
        empty.compile(mesh)


# --------------------------------------------------------------------------
# the §5 composition model (eq. 23)
# --------------------------------------------------------------------------

def test_predict_schedule_composition():
    n, p = 1 << 12, 8
    rng = np.random.default_rng(8)
    cols = rng.integers(0, n, size=(n, 4)).astype(np.int32)
    from repro.comm.plan import build_comm_plan
    from repro.comm import select
    plan = build_comm_plan(cols, n, p, blocksize=64,
                           topology=Topology(p, 4))
    wg = select.workload_from_plan(plan, 4)
    wp = select.workload_from_plan(plan.transpose(), 4)

    out = pm.predict_schedule(
        [("g", "get", wg, None), ("s", "put", wp, None)], pm.ABEL)
    times = [t for (_, _, _, t) in out["stages"]]
    # the fused window saves setup but can never beat its slowest stage
    assert out["total"] <= out["sum_standalone"]
    assert out["total"] >= max(times)
    assert out["setup_saved"] == pm.window_setup_time(wg.topology, pm.ABEL)
    # per-stage auto picks match the per-direction §5 argmins
    get_pick = min(pm.STRATEGY_PREDICTORS,
                   key=lambda s: pm.STRATEGY_PREDICTORS[s](wg, pm.ABEL))
    put_pick = min(pm.PUT_STRATEGY_PREDICTORS,
                   key=lambda s: pm.PUT_STRATEGY_PREDICTORS[s](wp, pm.ABEL))
    assert out["stages"][0][2] == get_pick
    assert out["stages"][1][2] == put_pick
    # pinning a rung prices exactly that rung
    pinned = pm.predict_schedule([("g", "get", wg, "condensed")], pm.ABEL)
    assert pinned["stages"][0][3] == pm.predict_v3(wg, pm.ABEL)
    assert pinned["setup_saved"] == 0.0   # K=1: nothing to consolidate


# --------------------------------------------------------------------------
# Heat2D full-window refinement (the ROADMAP edge-ring term), table5-style
# --------------------------------------------------------------------------

def test_heat2d_window_model_edge_ring_term():
    topo = Topology(8, 8)
    hw = pm.ABEL.replace(tau=0.0)     # isolate the compute terms
    small = pm.Heat2DWorkload(big_m=8, big_n=16, mprocs=2, nprocs=4,
                              topology=topo)
    big = pm.Heat2DWorkload(big_m=512, big_n=1024, mprocs=2, nprocs=4,
                            topology=topo)
    # skinny tiles: the four 3-wide strips recompute more than the whole
    # tile costs — overlap must NOT be predicted cheaper (the mispick the
    # ring term fixes)
    ws = pm.predict_heat2d_window(small, hw)
    assert ws["overlap"] > ws["condensed"]
    # big tiles + expensive communication: hiding the exchange behind the
    # interior wins despite the ring overhead
    wb = pm.predict_heat2d_window(big, pm.ABEL.replace(tau=1e-3))
    assert wb["overlap"] < wb["condensed"]
    # the ring term is exactly the overlap surcharge at zero comm cost
    free = pm.ABEL.replace(tau=0.0, w_remote=1e30, w_private=1e30)
    wf = pm.predict_heat2d_window(big, free)
    assert wf["overlap"] == pytest.approx(0.0, abs=1e-18)


def test_heat2d_auto_ranks_on_full_window_cost():
    """table5-style predicted-vs-measured smoke: strategy="auto" must
    carry the window-refined overlap/condensed entries, pick their argmin,
    and still match the sequential reference."""
    from repro.core.heat2d import Heat2D

    ndev = len(jax.devices())
    shape = (2, ndev // 2) if ndev % 2 == 0 and ndev > 1 else (1, ndev)
    mesh = jax.make_mesh(shape, ("data", "model"))
    big_m, big_n = shape[0] * 16, shape[1] * 16
    h = Heat2D(mesh, big_m, big_n, strategy="auto", hw=pm.ABEL)

    w2d = pm.Heat2DWorkload(big_m=big_m, big_n=big_n, mprocs=shape[0],
                            nprocs=shape[1],
                            topology=Topology(ndev, ndev))
    win = pm.predict_heat2d_window(w2d, pm.ABEL)
    assert h.predicted_times["condensed"] == win["condensed"]
    assert h.predicted_times["overlap"] == win["overlap"]
    assert h.strategy == min(h.predicted_times, key=h.predicted_times.get)
    assert h.overlap == (h.strategy == "overlap")
    assert all(np.isfinite(t) and t > 0
               for t in h.predicted_times.values())

    phi = h.init_field(6)
    got = np.asarray(h.run(phi, 3))
    np.testing.assert_allclose(got, h.reference(np.asarray(phi), 3),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# measure_hw memo keys (exchange core)
# --------------------------------------------------------------------------

class _Dev:
    def __init__(self, i):
        self.id = i


def _fake_mesh(shape, names):
    """A mesh-shaped stub: enough surface for the memo key (devices,
    axis_names, shape) without needing that many real devices."""
    import types
    m = types.SimpleNamespace()
    n = int(np.prod(shape))
    m.devices = np.array([_Dev(i) for i in range(n)],
                         dtype=object).reshape(shape)
    m.axis_names = tuple(names)
    m.shape = dict(zip(names, shape))
    return m


def test_hw_memo_keys_and_clearing(monkeypatch):
    from repro.comm import exchange
    from repro.core import tune

    calls = []
    monkeypatch.setattr(
        tune, "measure_hardware",
        lambda *a, **k: (calls.append(a), pm.ABEL)[1])
    exchange.clear_hw_memo()
    m24 = _fake_mesh((2, 4), ("a", "b"))
    m42 = _fake_mesh((4, 2), ("a", "b"))

    # multi-axis tuple key: calibrates once, then memo-hits
    h1 = exchange.measure_hw(m24, ("a", "b"))
    h2 = exchange.measure_hw(m24, ("a", "b"))
    assert len(calls) == 1 and h1 is h2
    # tuple-axis calibration describes the whole device set, so the two
    # factorizations of the SAME 8 devices share one entry
    h3 = exchange.measure_hw(m42, ("a", "b"))
    assert len(calls) == 1 and h3 is h1

    # single-axis keys: (2,4) vs (4,2) give axis "a" different ring
    # lengths on the same devices — distinct entries, one probe each
    exchange.measure_hw(m24, "a")
    exchange.measure_hw(m42, "a")
    assert len(calls) == 3
    exchange.measure_hw(m24, "a")     # memo hit
    exchange.measure_hw(m42, "a")     # memo hit
    assert len(calls) == 3

    # clear_hw_memo forces recalibration
    exchange.clear_hw_memo()
    exchange.measure_hw(m24, ("a", "b"))
    assert len(calls) == 4
    exchange.clear_hw_memo()


# --------------------------------------------------------------------------
# transpose + use_kernel: the push-side split kernels (formerly rejected)
# --------------------------------------------------------------------------

def test_spmv_transpose_kernel_matches_jnp():
    from repro.core.matrix import make_mesh_like_matrix
    from repro.core.spmv import DistributedSpMV

    mesh, ndev = _mesh()
    n = 16 * ndev
    m = make_mesh_like_matrix(n, 2, locality_window=n // 4, seed=9)
    x = np.random.default_rng(9).standard_normal(n).astype(np.float32)
    ys = {}
    for uk in (False, True):
        eng = DistributedSpMV(m, mesh, transpose=True, use_kernel=uk,
                              use_plan_cache=False)
        ys[uk] = np.asarray(eng(eng.shard_vector(x)))
    np.testing.assert_array_equal(ys[True], ys[False])
