"""End-to-end driver tests on CPU: loss goes down, checkpoint/resume is
exact, serve driver generates."""
import json

import numpy as np
import pytest

from repro.launch import train as T
from repro.launch import serve as S

pytestmark = pytest.mark.slow  # full train/serve loops: non-blocking CI job


def test_train_loss_decreases(tmp_path):
    # small reduced dense arch, enough steps to see learning
    hist = T.main([
        "--arch", "granite-20b", "--reduced", "--steps", "30",
        "--batch", "8", "--seq", "64", "--lr", "3e-3", "--warmup", "5",
        "--log-every", "50",
        "--metrics-out", str(tmp_path / "m.json"),
    ])
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert np.isfinite(last)
    assert last < first - 0.3, (first, last)
    assert (tmp_path / "m.json").exists()


def test_train_resume_is_seamless(tmp_path):
    common = ["--arch", "llama3-8b", "--reduced", "--batch", "4",
              "--seq", "32", "--save-every", "5",
              "--ckpt-dir", str(tmp_path / "ck")]
    T.main(common + ["--steps", "5"])
    hist2 = T.main(common + ["--steps", "8"])
    # resumed exactly at step 5
    assert hist2[0]["step"] == 5
    assert len(hist2) == 3


def test_train_with_accumulation_matches_plain():
    h1 = T.main(["--arch", "minitron-4b", "--reduced", "--steps", "3",
                 "--batch", "8", "--seq", "32", "--accum", "1",
                 "--lr", "0"])
    h2 = T.main(["--arch", "minitron-4b", "--reduced", "--steps", "3",
                 "--batch", "8", "--seq", "32", "--accum", "4",
                 "--lr", "0"])
    # with lr=0 params never change; losses must agree exactly per step
    for a, b in zip(h1, h2):
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)


def test_serve_generates():
    seq = S.main(["--arch", "qwen2.5-32b", "--reduced", "--batch", "2",
                  "--prompt-len", "8", "--gen", "4"])
    assert seq.shape == (2, 4)
