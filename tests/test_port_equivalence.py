"""Port equivalence: the IrregularGather-based consumers must produce
BIT-IDENTICAL outputs to the pre-refactor implementations.

The pre-refactor paths are reconstructed here verbatim: SpMV as the direct
composition of the strategy-local gather with the local EllPack compute
(what ``DistributedSpMV.step_local`` used to inline), Heat2D as the
ppermute-based halo exchange (``_shift`` + padded-tile update).  Both moved
pure float values with no arithmetic on the wire, so the ported versions
must agree to the last bit — any nonzero difference means the refactor
changed semantics, not just structure.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.comm import strategies as strat
from repro.core.heat2d import Heat2D
from repro.core.matrix import make_mesh_like_matrix
from repro.core.spmv import DistributedSpMV
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# pre-refactor SpMV step (direct strategy-local composition)
# ---------------------------------------------------------------------------

def _legacy_spmv(matrix, mesh, strategy, plan, axis_name="data"):
    p = mesh.shape[axis_name]
    shard_size = plan.shard_size
    n = plan.n
    gather_local = strat.make_gather_local(plan, strategy, axis_name)
    shard = NamedSharding(mesh, P(axis_name))
    shard2 = NamedSharding(mesh, P(axis_name, None))
    diag = jax.device_put(matrix.diag, shard)

    if strategy == "overlap":
        loc_vals = np.take_along_axis(matrix.vals, plan.loc_src, axis=1)
        rem_vals = np.take_along_axis(matrix.vals, plan.rem_src, axis=1)
        args = tuple(
            jax.device_put(a, shard)
            for a in strat.plan_device_args(plan, strategy)
        ) + tuple(
            jax.device_put(a, shard2)
            for a in (plan.loc_cols, loc_vals, plan.rem_cols, rem_vals))

        def step_local(x_local, diag_l, send_idx, recv_idx, loc_cols_l,
                       loc_vals_l, rem_cols_l, rem_vals_l):
            buf = x_local[send_idx[0]]
            recv = jax.lax.all_to_all(
                buf, axis_name, split_axis=0, concat_axis=0, tiled=True)
            x_ext = jnp.concatenate([x_local, jnp.zeros((1,), x_local.dtype)])
            y_own = diag_l * x_local + (
                loc_vals_l * x_ext[loc_cols_l]).sum(axis=-1)
            x_copy = jnp.zeros((n + 2,), x_local.dtype)
            x_copy = x_copy.at[recv_idx[0].ravel()].set(recv.ravel())
            y_rem = (rem_vals_l * x_copy[rem_cols_l]).sum(axis=-1)
            return y_own + y_rem

        in_specs = (P(axis_name), P(axis_name),
                    P(axis_name), P(axis_name)) + (P(axis_name, None),) * 4
        base = (diag,)
    else:
        vals = jax.device_put(matrix.vals, shard2)
        cols = jax.device_put(matrix.cols, shard2)
        args = tuple(jax.device_put(a, shard)
                     for a in strat.plan_device_args(plan, strategy))

        def step_local(x_local, diag_l, vals_l, cols_l, *plan_args):
            x_copy = gather_local(x_local, *plan_args)
            me = jax.lax.axis_index(axis_name)
            own = jax.lax.dynamic_slice(
                x_copy, (me * shard_size,), (shard_size,))
            return diag_l * own + (vals_l * x_copy[cols_l]).sum(axis=-1)

        in_specs = ((P(axis_name), P(axis_name), P(axis_name, None),
                     P(axis_name, None))
                    + strat.gather_in_specs(strategy, axis_name))
        base = (diag, vals, cols)

    mapped = compat.shard_map(step_local, mesh=mesh, in_specs=in_specs,
                              out_specs=P(axis_name), check_vma=False)
    return jax.jit(lambda x: mapped(x, *base, *args))


def test_spmv_port_is_bit_identical():
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    n = 128 * ndev
    m = make_mesh_like_matrix(n, 8, locality_window=n // 8,
                              long_range_frac=0.1, seed=11)
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    for strategy in strat.STRATEGIES:
        eng = DistributedSpMV(m, mesh, strategy=strategy, blocksize=32)
        legacy = _legacy_spmv(m, mesh, strategy, eng.plan)
        xs = eng.shard_vector(x)
        np.testing.assert_array_equal(
            np.asarray(eng(xs)), np.asarray(legacy(xs)),
            err_msg=f"strategy={strategy} diverged from pre-refactor step")


# ---------------------------------------------------------------------------
# pre-refactor Heat2D step (ppermute halo exchange)
# ---------------------------------------------------------------------------

def _shift(x, axis_name, direction, size):
    perm = [(i, i + direction) for i in range(size)
            if 0 <= i + direction < size]
    return jax.lax.ppermute(x, axis_name, perm)


def _legacy_heat2d_step(phi, *, row_axis, col_axis, mprocs, nprocs, coef,
                        overlap):
    m_loc, n_loc = phi.shape
    ip = jax.lax.axis_index(row_axis)
    kp = jax.lax.axis_index(col_axis)

    up_halo = _shift(phi[-1:, :], row_axis, +1, mprocs)
    down_halo = _shift(phi[:1, :], row_axis, -1, mprocs)
    left_halo = _shift(phi[:, -1:], col_axis, +1, nprocs)
    right_halo = _shift(phi[:, :1], col_axis, -1, nprocs)

    padded = jnp.zeros((m_loc + 2, n_loc + 2), phi.dtype)
    padded = padded.at[1:-1, 1:-1].set(phi)
    padded = padded.at[0, 1:-1].set(up_halo[0])
    padded = padded.at[-1, 1:-1].set(down_halo[0])
    padded = padded.at[1:-1, 0].set(left_halo[:, 0])
    padded = padded.at[1:-1, -1].set(right_halo[:, 0])

    from repro.kernels import ref as kref
    if overlap:
        inner = kref.stencil2d_ref(phi, coef)
        top = kref.stencil2d_ref(padded[0:3, :], coef)[1, 1:-1]
        bottom = kref.stencil2d_ref(padded[-3:, :], coef)[1, 1:-1]
        left = kref.stencil2d_ref(padded[:, 0:3], coef)[1:-1, 1]
        right = kref.stencil2d_ref(padded[:, -3:], coef)[1:-1, 1]
        upd = inner.at[0, :].set(top).at[-1, :].set(bottom)
        upd = upd.at[:, 0].set(left).at[:, -1].set(right)
    else:
        upd = kref.stencil2d_ref(padded, coef)[1:-1, 1:-1]

    grow = ip * m_loc + jax.lax.broadcasted_iota(jnp.int32, phi.shape, 0)
    gcol = kp * n_loc + jax.lax.broadcasted_iota(jnp.int32, phi.shape, 1)
    big_m, big_n = mprocs * m_loc, nprocs * n_loc
    interior = ((grow > 0) & (grow < big_m - 1)
                & (gcol > 0) & (gcol < big_n - 1))
    return jnp.where(interior, upd, phi)


def _legacy_heat2d(mesh, big_m, big_n, coef, overlap,
                   row_axis="data", col_axis="model"):
    mprocs, nprocs = mesh.shape[row_axis], mesh.shape[col_axis]
    spec = P(row_axis, col_axis)
    local = functools.partial(
        _legacy_heat2d_step, row_axis=row_axis, col_axis=col_axis,
        mprocs=mprocs, nprocs=nprocs, coef=coef, overlap=overlap)
    mapped = compat.shard_map(local, mesh=mesh, in_specs=spec,
                              out_specs=spec, check_vma=False)

    @functools.partial(jax.jit, static_argnames=("steps",))
    def run(phi, steps):
        def body(x, _):
            return mapped(x), None
        out, _ = jax.lax.scan(body, phi, None, length=steps)
        return out

    return run


def test_heat2d_port_is_bit_identical():
    ndev = len(jax.devices())
    shape = (2, ndev // 2) if ndev % 2 == 0 and ndev > 1 else (1, ndev)
    mesh = jax.make_mesh(shape, ("data", "model"))
    big_m, big_n = shape[0] * 12, shape[1] * 20
    for overlap in (False, True):
        h = Heat2D(mesh, big_m, big_n, coef=0.13, overlap=overlap)
        legacy = _legacy_heat2d(mesh, big_m, big_n, 0.13, overlap)
        phi = h.init_field(9)
        np.testing.assert_array_equal(
            np.asarray(h.run(phi, 6)), np.asarray(legacy(phi, 6)),
            err_msg=f"overlap={overlap} diverged from ppermute halo path")
