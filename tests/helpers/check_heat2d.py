"""Subprocess helper: distributed heat2d vs sequential reference (8 dev)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core.heat2d import Heat2D

from repro import compat


def main():
    mesh = compat.make_mesh((2, 4), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    for use_kernel, overlap in ((False, False), (True, False), (False, True)):
        h = Heat2D(mesh, 32, 64, coef=0.07, use_kernel=use_kernel,
                   overlap=overlap)
        phi0 = h.init_field(3)
        got = np.asarray(h.run(phi0, 7))
        want = h.reference(np.asarray(phi0), 7, coef=0.07)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    print("HEAT2D_OK")


if __name__ == "__main__":
    main()
