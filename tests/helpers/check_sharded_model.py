"""Subprocess helper (8 dev): sharded train step == single-device train step,
and MoE ep_a2a sharding preserves outputs.  This is the distributed-equals-
local contract for the whole model stack."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.transformer import Model, RunCtx
from repro.optim.adamw import AdamW
from repro.runtime import sharding as sh
from repro.runtime.steps import build_train_step

from repro import compat


def run(name, ep_expected):
    cfg = get_config(name, reduced=True)
    mesh = compat.make_mesh((4, 2), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    rules = sh.ShardingRules(
        mesh=mesh, fsdp_axes="data",
        ep_mode=cfg.is_moe and cfg.num_experts >= 2)
    assert rules.ep_mode == ep_expected

    b, s = 8, 32
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    # single device reference
    model0 = Model(cfg, RunCtx(remat="none", act_dtype=jnp.float32))
    params0 = model0.init_params(key)
    opt = AdamW(lr=1e-2)
    step0 = jax.jit(build_train_step(model0, opt))
    p0, _, m0 = step0(params0, opt.init(params0), (tokens, tokens), None)

    # sharded (moe_groups=1 so capacity semantics match the reference run;
    # grouped dispatch is exercised in test_moe_ssm + the dry-run)
    ctx = RunCtx(remat="none", act_dtype=jnp.float32, moe_groups=1,
                 constrain=sh.make_constrain(rules),
                 vocab_shards=2)
    model1 = Model(cfg, ctx)
    params1 = model1.init_params(key)
    pshard = sh.param_shardings(rules, jax.eval_shape(lambda: params1))
    params1 = jax.tree.map(jax.device_put, params1, pshard)
    ostate = opt.init(params1)
    step1 = jax.jit(build_train_step(model1, opt, grad_shardings=pshard))
    bshard = sh.batch_sharding(rules, (b, s))
    tok_s = jax.device_put(tokens, bshard)
    p1, _, m1 = step1(params1, ostate, (tok_s, tok_s), None)

    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=2e-3, atol=2e-3)
    # parameters after one step agree (spot-check a couple of leaves)
    l0 = jax.tree.leaves(p0)
    l1 = jax.tree.leaves(p1)
    for a, b_ in list(zip(l0, l1))[:6]:
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-3, atol=5e-3)
    print(f"OK {name} loss={float(m1['loss']):.4f}")


def main():
    run("llama3-8b", False)       # dense GQA
    run("arctic-480b", True)      # MoE expert-parallel (condensed a2a)
    run("falcon-mamba-7b", False)  # SSM
    print("SHARDED_MODEL_OK")


if __name__ == "__main__":
    main()
