"""Subprocess helper: autotuner predictions vs measurements at P=8.

Validates the ISSUE-1 closing-the-loop claim on the paper's mesh-like matrix:
the §5 models, fed with the measured hardware parameters of THIS host, must
rank the strategies well enough that either (a) the predicted winner measures
within 2x of the measured winner, or (b) the model itself calls the two a
near-tie (predicted times within 25%) — on CPU host devices tau dominates
every strategy's prediction, so the model legitimately reports "these rungs
are equivalent here" and measurement noise picks the winner.  A strict
total-order comparison is not meaningful in that regime.  The structurally
robust part of the ranking — blockwise pays the whole-block volume tax and
comes last — is asserted unconditionally.

Also asserts ``strategy="auto"`` resolves to a concrete rung and matches the
reference SpMV bit-for-tolerance.
"""
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core import tune
from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
from repro.core.spmv import DistributedSpMV
from repro.core.strategies import STRATEGIES


def _measure(eng, x, iters=20):
    jax.block_until_ready(eng(x))
    jax.block_until_ready(eng(x))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(eng(x))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("data",))
    n, r_nz = 1 << 15, 16
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 64,
                              long_range_frac=0.02, seed=1)
    x_host = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    y_ref = spmv_ref_np(m, x_host)
    bs = n // 8 // 16

    # measured hardware parameters for THIS mesh (host devices = own nodes)
    hw = tune.measure_hardware(mesh, "data")
    print(f"calibrated w_private={hw.w_private/1e9:.2f}GB/s "
          f"w_remote={hw.w_remote/1e9:.2f}GB/s tau={hw.tau*1e6:.1f}us "
          f"cacheline={hw.cacheline}B")

    engines, measured = {}, {}
    for strategy in STRATEGIES:
        eng = DistributedSpMV(m, mesh, strategy=strategy, blocksize=bs,
                              shards_per_node=1)
        x = eng.shard_vector(x_host)
        np.testing.assert_allclose(np.asarray(eng(x)), y_ref, rtol=2e-4,
                                   atol=2e-4)
        engines[strategy] = eng
        measured[strategy] = _measure(eng, x)

    ranked = tune.rank_strategies(engines["condensed"].plan, r_nz, hw)
    predicted = dict(ranked)
    predicted_best = ranked[0][0]
    measured_best = min(measured, key=measured.get)
    print("predicted:", [(s, f"{t*1e6:.0f}us") for s, t in ranked])
    print("measured: ", sorted(((s, f"{t*1e6:.0f}us")
                                for s, t in measured.items()),
                               key=lambda kv: float(kv[1][:-2])))

    # structural claim: whole-block volume tax puts blockwise last
    assert ranked[-1][0] == "blockwise", ranked

    # prediction quality gate: the model's pick must be competitive, unless
    # the model itself declares a near-tie with the measured winner (same
    # symmetric-drift metric as the benchmark matrix gate; predicted_best/
    # measured_best each minimize their dict, so the ratios are >= 1)
    from model_error import model_error
    competitive = model_error(measured[predicted_best],
                              measured[measured_best]) <= 1.0
    near_tie = model_error(predicted[measured_best],
                           predicted[predicted_best]) <= 0.25
    assert competitive or near_tie, (
        f"model picked {predicted_best} "
        f"({measured[predicted_best]*1e6:.0f}us measured, "
        f"{predicted[predicted_best]*1e6:.0f}us predicted) but "
        f"{measured_best} measured {measured[measured_best]*1e6:.0f}us "
        f"({predicted[measured_best]*1e6:.0f}us predicted)")

    # auto resolves to a concrete rung and matches the reference
    eng = DistributedSpMV(m, mesh, strategy="auto", blocksize=bs,
                          shards_per_node=1, hw=hw)
    assert eng.strategy == predicted_best, (eng.strategy, predicted_best)
    x = eng.shard_vector(x_host)
    np.testing.assert_allclose(np.asarray(eng(x)), y_ref, rtol=2e-4,
                               atol=2e-4)
    print(f"AUTOTUNE_OK auto={eng.strategy} measured_best={measured_best}")


if __name__ == "__main__":
    main()
