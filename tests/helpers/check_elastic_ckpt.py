"""Subprocess helper: elastic checkpoint restore across mesh shapes (8 dev).

Saves a sharded tree from an (8,)-data mesh, restores onto a (2,4) mesh with
different shardings — the elastic-restart path.
"""
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import manager as ckpt

from repro import compat


def main():
    tmp = tempfile.mkdtemp()
    mesh_a = compat.make_mesh((8,), ("data",),
                              axis_types=compat.auto_axis_types(1))
    tree = {
        "w": jax.device_put(np.arange(64.0).reshape(8, 8),
                            NamedSharding(mesh_a, P("data", None))),
        "b": jax.device_put(np.arange(16.0),
                            NamedSharding(mesh_a, P("data"))),
    }
    ckpt.save(tmp, 3, tree)

    mesh_b = compat.make_mesh((2, 4), ("data", "model"),
                              axis_types=compat.auto_axis_types(2))
    shardings = {
        "w": NamedSharding(mesh_b, P("model", "data")),
        "b": NamedSharding(mesh_b, P(("data", "model"))),
    }
    target = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((16,))}
    restored, _ = ckpt.restore(tmp, 3, target, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64.0).reshape(8, 8))
    np.testing.assert_array_equal(np.asarray(restored["b"]),
                                  np.arange(16.0))
    assert restored["w"].sharding.spec == P("model", "data")
    print("ELASTIC_CKPT_OK")


if __name__ == "__main__":
    main()
