"""Shared predicted-vs-measured assertion — ONE tolerance source.

Every place that checks a §5 prediction against a measurement (unit tests,
subprocess helpers, the benchmark matrix's regression gate) must price
drift the same way, or the test suite and the CI gate diverge silently.
This helper is that single seam: the *metric* and the *budgets* both live
in ``repro.core.perfmodel`` (``model_error`` / ``error_budget``), and this
module only adds the assertion ergonomics tests want.

Import patterns served:
* pytest files: ``from helpers.model_error import assert_model_error``
  (``tests/`` is on the configured pythonpath);
* subprocess helper scripts run from ``tests/helpers``:
  ``import model_error``.
"""
from __future__ import annotations

from repro.core.perfmodel import error_budget, model_error

__all__ = ["model_error", "error_budget", "assert_model_error"]


def assert_model_error(measured: float, predicted: float, *,
                       budget: float | None = None, cell: dict | None = None,
                       label: str = "") -> float:
    """Assert ``model_error(measured, predicted) <= budget`` and return the
    error.

    ``budget`` may be given explicitly (exact-identity checks pass ~1e-9;
    the paper-table reproductions pass their published rtol) or derived
    from a matrix ``cell`` mapping via ``perfmodel.error_budget`` — the
    same call the benchmark gate makes, so a budget loosened for the bench
    is automatically loosened for the tests and vice versa.
    """
    if budget is None:
        budget = error_budget(cell or {})
    err = model_error(measured, predicted)
    assert err <= budget, (
        f"model error {err:.4g} exceeds budget {budget:.4g}"
        f"{' [' + label + ']' if label else ''}: "
        f"measured={measured:.6g} predicted={predicted:.6g}")
    return err
