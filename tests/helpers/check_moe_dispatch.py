"""Subprocess helper: the MoE-dispatch gather on 8 host devices.

Every ladder rung plus ``auto`` must reproduce the NumPy reference dispatch
bit-exactly (the gather moves values, it never computes on them), and the
§5 predictions — priced with this host's measured hardware parameters and
the token embedding width folded into ``elem`` — must be finite and cover
all rungs.  Run as:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 python check_moe_dispatch.py
Exits nonzero on failure.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.comm import STRATEGIES
from repro.core import tune
from repro.models.moe import (MoEDispatchGather, moe_dispatch_pattern,
                              moe_dispatch_ref, random_router)


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("data",))
    p = 8
    n_tok, k, d = 8 * 512, 2, 16
    e_total, cap = 32, 80
    rng = np.random.default_rng(0)
    # skewed routing (zipf-ish) so experts differ in load, like real routers
    top_e, _ = random_router(0, n_tok, e_total, k)
    x = rng.standard_normal((n_tok, d)).astype(np.float32)

    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, p)
    ref = moe_dispatch_ref(x, idx, valid, e_total, cap)

    hw = tune.measure_hardware(mesh, "data").replace(elem=4 * d)
    for strategy in STRATEGIES + ("auto",):
        g = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh,
                              strategy=strategy, blocksize=64,
                              shards_per_node=4, hw=hw)
        buf = np.asarray(g(g.shard_tokens(x)))
        np.testing.assert_array_equal(buf, ref)
        c = g.counts
        print(f"OK {strategy}->{g.strategy} "
              f"condensed_vol={c.total_condensed_volume()} "
              f"blockwise_vol={c.total_blockwise_volume()}")

    # auto must carry the full §5 ranking
    g = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh, strategy="auto",
                          blocksize=64, shards_per_node=4, hw=hw)
    assert set(g.predicted_times) == set(STRATEGIES)
    assert all(np.isfinite(t) and t > 0 for t in g.predicted_times.values())
    order = sorted(g.predicted_times, key=g.predicted_times.get)
    print(f"AUTO_OK resolved={g.strategy} predicted_order={'>'.join(order)}")
    print("MOE_DISPATCH_OK")


if __name__ == "__main__":
    main()
