"""Subprocess helper: verify the three gather strategies agree with the dense
reference on multiple host devices.  Run as:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 python check_strategies.py
Exits nonzero on failure.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
from repro.core.spmv import DistributedSpMV


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("data",))
    n = 8 * 512
    m = make_mesh_like_matrix(n, r_nz=16, locality_window=300,
                              long_range_frac=0.02, seed=3)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n).astype(np.float32)
    y_ref = spmv_ref_np(m, x)

    for strategy in ("replicate", "blockwise", "condensed", "overlap"):
        for bs in (64, 512):
            eng = DistributedSpMV(m, mesh, strategy=strategy, blocksize=bs,
                                  shards_per_node=4)
            xs = eng.shard_vector(x)
            y = np.asarray(eng(xs))
            np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)
            # gather correctness: each device's x_copy matches x at every
            # index that device's rows access
            xc = np.asarray(eng.gather_x_copy(xs))
            ss = eng.plan.shard_size
            for q in range(8):
                needed = np.unique(m.cols[q * ss:(q + 1) * ss])
                np.testing.assert_allclose(xc[q, needed], x[needed],
                                           rtol=0, atol=0)
            c = eng.counts
            print(f"OK {strategy} bs={bs} condensed_vol="
                  f"{c.total_condensed_volume()} blockwise_vol="
                  f"{c.total_blockwise_volume()} padded="
                  f"{c.padded_condensed_per_shard}")
    # paper claim: condensed volume <= blockwise volume <= replicate volume
    eng = DistributedSpMV(m, mesh, strategy="condensed", blocksize=64,
                          shards_per_node=4)
    c = eng.counts
    own = eng.plan.shard_size * 8  # blockwise includes own-shard copies
    assert c.total_condensed_volume() <= c.total_blockwise_volume() - own <= 8 * n

    # auto: resolves to a concrete runnable rung and matches the reference
    eng = DistributedSpMV(m, mesh, strategy="auto", blocksize=64,
                          shards_per_node=4)
    assert eng.requested_strategy == "auto"
    assert eng.strategy in ("replicate", "blockwise", "condensed", "overlap")
    y = np.asarray(eng(eng.shard_vector(x)))
    np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)
    print(f"AUTO_OK strategy={eng.strategy} predicted={eng.predicted_times}")
    print("ALL_STRATEGIES_OK")


if __name__ == "__main__":
    main()
