"""End-to-end driver: train the ~100M-param dense LM on synthetic data with
checkpointing and straggler watch (assignment deliverable b).

Run (a few hundred steps, CPU):
  python examples/train_lm.py --steps 300

This is a thin veneer over the production driver (repro.launch.train): the
example IS the deployable path.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main  # noqa: E402

if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = ["--preset", "lm100m", "--batch", "8", "--seq", "256",
                "--ckpt-dir", "/tmp/repro_lm100m_ckpt",
                "--metrics-out", "/tmp/repro_lm100m_metrics.json"]
    if "--steps" not in " ".join(args):
        defaults += ["--steps", "300"]
    train_main(defaults + args)
