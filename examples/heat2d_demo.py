"""Paper §8: 2D heat equation with halo exchange on a 2D device grid,
verified against the sequential stencil and timed vs the eq.(19)-(22) model.

Run: python examples/heat2d_demo.py   (re-execs itself with 8 devices)
"""
import os
import sys

if "--no-reexec" not in sys.argv and "device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    os.execvpe(sys.executable, [sys.executable] + sys.argv + ["--no-reexec"],
               env)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro.core.heat2d import Heat2D
from repro.core.perfmodel import Heat2DWorkload, predict_heat2d
from repro.core.plan import Topology

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import calibrate_host  # noqa: E402

from repro import compat


def main():
    mesh = compat.make_mesh((2, 4), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    big_m, big_n, steps = 1024, 2048, 200
    # default materialize="dest": the halo exchange lands straight in the
    # four named strips (up/down/left/right Destination slots) — O(halo)
    # unpack per step, no big_m*big_n x_copy ever assembled
    h = Heat2D(mesh, big_m, big_n, coef=0.1)
    phi = h.init_field(0)

    # correctness vs the sequential reference (few steps)
    got = np.asarray(h.run(phi, 5))
    want = h.reference(np.asarray(phi), 5)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    print("distributed heat2d matches sequential stencil ✓")

    def timed(solver):
        jax.block_until_ready(solver.run(phi, steps))
        t0 = time.perf_counter()
        jax.block_until_ready(solver.run(phi, steps))
        return time.perf_counter() - t0

    dt = timed(h)
    # the paper's layout for comparison: assemble the full-length copy,
    # then index the strips out of it (bit-identical results)
    dt_full = timed(Heat2D(mesh, big_m, big_n, coef=0.1,
                           materialize="full"))

    hw = calibrate_host()
    w = Heat2DWorkload(big_m=big_m, big_n=big_n, mprocs=2, nprocs=4,
                       topology=Topology(8, 8))
    pred = predict_heat2d(w, hw, steps=steps)
    print(f"{steps} steps on 2x4 grid: measured {dt:.3f}s targeted-unpack "
          f"({dt_full:.3f}s with full x_copy assembly), "
          f"predicted {pred['halo'] + pred['comp']:.3f}s "
          f"(halo {pred['halo']:.3f} + comp {pred['comp']:.3f})")


if __name__ == "__main__":
    main()
