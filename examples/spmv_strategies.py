"""The paper's core experiment: three communication strategies for the same
distributed SpMV, measured and modeled (Tables 3/4 in miniature).

Run: python examples/spmv_strategies.py   (re-execs itself with 8 devices)
"""
import os
import sys

if "--no-reexec" not in sys.argv and "device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    os.execvpe(sys.executable, [sys.executable] + sys.argv + ["--no-reexec"],
               env)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro import compat
from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
from repro.core.spmv import DistributedSpMV


def main():
    mesh = compat.make_mesh((8,), ("data",),
                            axis_types=compat.auto_axis_types(1))
    n, r_nz = 1 << 17, 16
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 64,
                              long_range_frac=0.02, seed=1)
    x_host = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    y_ref = spmv_ref_np(m, x_host)

    print(f"{'strategy':12s} {'volume(elem)':>14s} {'time/iter':>12s}")
    for strategy in ("replicate", "blockwise", "condensed", "overlap",
                     "auto"):
        eng = DistributedSpMV(m, mesh, strategy=strategy, blocksize=1024,
                              shards_per_node=4)
        x = eng.shard_vector(x_host)
        np.testing.assert_allclose(np.asarray(eng(x)), y_ref,
                                   rtol=2e-4, atol=2e-4)
        # time 30 iterations
        jax.block_until_ready(eng(x))
        t0 = time.perf_counter()
        for _ in range(30):
            y = eng(x)
        jax.block_until_ready(y)
        dt = (time.perf_counter() - t0) / 30
        c = eng.counts
        vol = {"replicate": 8 * (n - n // 8),
               "blockwise": c.total_blockwise_volume()}.get(
                   eng.strategy, c.total_condensed_volume())
        label = strategy
        if strategy == "auto":
            label = f"auto->{eng.strategy}"
        print(f"{label:12s} {vol:>14,d} {dt*1e3:>9.2f} ms")

    print("\npaper claim reproduced: condensed < blockwise < replicate in "
          "communication volume; 'auto' lets the calibrated §5 models pick "
          "the rung.  See benchmarks/run.py table3/table4 for the "
          "modeled-vs-measured comparison.")


if __name__ == "__main__":
    main()
