"""Batched serving example: prefill a prompt batch, decode tokens with the
ring-cache / SSM-state machinery (assignment deliverable b, serving flavor).

Run: python examples/serve_lm.py [--arch hymba-1.5b] [--gen 32]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main  # noqa: E402

if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = ["--batch", "4", "--prompt-len", "32", "--gen", "16",
                "--reduced"]
    if not any(a.startswith("--arch") or a == "--preset" for a in args):
        defaults = ["--arch", "llama3-8b"] + defaults
    serve_main(defaults + args)
