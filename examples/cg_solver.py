"""Conjugate gradient on the normal equations, one persistent exchange
window for the whole solve (``Schedule.scan``): every iteration is the
fused z = MᵀM p window of ``normal_equations_step`` plus psum dots, with
zero per-iteration host dispatch.  Verified against a dense
``numpy.linalg.solve`` and timed vs the per-step re-dispatch baseline and
the eq.-23 steady-state model.

Run: python examples/cg_solver.py   (re-execs itself with 8 devices)
"""
import os
import sys

if "--no-reexec" not in sys.argv and "device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    os.execvpe(sys.executable, [sys.executable] + sys.argv + ["--no-reexec"],
               env)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro.core.matrix import (make_mesh_like_matrix, spmv_ref_np,
                               spmv_t_ref_np)
from repro.core.solvers import ConjugateGradient
from repro.core.spmv import normal_equations_step

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import calibrate_host  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("data",))
    n, r_nz, iters = 1 << 12, 16, 60
    m = make_mesh_like_matrix(n, r_nz, seed=3)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n).astype(np.float32)

    hw = calibrate_host()
    cg = ConjugateGradient(m, mesh, strategy="auto", hw=hw,
                           n_steps_hint=iters)
    x = np.asarray(cg.solve(b, iters))

    # correctness: (MtM) x = b against a dense solve
    mtm_x = spmv_t_ref_np(m, spmv_ref_np(m, x))
    rel = np.abs(mtm_x - b).max() / np.abs(b).max()
    print(f"CG ({iters} iters, strategy {cg.strategies}): "
          f"|MtM x - b| / |b| = {rel:.2e}")
    assert rel < 1e-3, rel

    def timed(fn, *args):
        jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        return time.perf_counter() - t0

    carries = cg.carries(b)
    dt_scan = timed(lambda: cg.schedule(*carries, n_steps=iters))

    # the baseline this PR retires: one fused window per product, but
    # re-dispatched from the host every iteration
    step = normal_equations_step(m, mesh, strategy="condensed")

    def redispatch():
        x_i, r_i, p_i = (jax.numpy.zeros_like(carries[1]), carries[1],
                         carries[2])
        for _ in range(iters):
            z = step(p_i)
            rs = float(jax.numpy.vdot(r_i, r_i))
            pz = float(jax.numpy.vdot(p_i, z))
            alpha = rs / pz if pz else 0.0
            x_i = x_i + alpha * p_i
            r_i = r_i - alpha * z
            rs2 = float(jax.numpy.vdot(r_i, r_i))
            p_i = r_i + (rs2 / rs if rs else 0.0) * p_i
        return x_i

    dt_loop = timed(redispatch)
    pred = cg.predicted_loop(iters)
    line = (f"{iters} iterations: scanned window {dt_scan:.3f}s, "
            f"per-step re-dispatch {dt_loop:.3f}s")
    if pred is not None:
        line += (f", predicted {pred['total']:.3f}s "
                 f"(setup {pred['setup'] * 1e3:.2f}ms + "
                 f"{iters} x {pred['per_iter'] * 1e3:.2f}ms)")
    print(line)


if __name__ == "__main__":
    main()
