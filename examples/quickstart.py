"""Quickstart: distributed SpMV with the paper's condensed communication.

Runs on however many devices exist (1 CPU device works; for a multi-device
demo: XLA_FLAGS=--xla_force_host_platform_device_count=8 python
examples/quickstart.py).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
from repro.core.perfmodel import ABEL, TPU_V5E, SpmvWorkload, predict_all
from repro.core.spmv import DistributedSpMV

from repro import compat


def main():
    n_dev = len(jax.devices())
    mesh = compat.make_mesh((n_dev,), ("data",),
                            axis_types=compat.auto_axis_types(1))
    print(f"devices: {n_dev}")

    # a synthetic unstructured-mesh matrix (paper §6.1 structure)
    n, r_nz = n_dev * 8192, 16
    matrix = make_mesh_like_matrix(n, r_nz, long_range_frac=0.02, seed=0)

    # the paper's UPCv3: one-time plan -> condensed, consolidated messages
    engine = DistributedSpMV(matrix, mesh, strategy="condensed",
                             blocksize=512)
    x = engine.shard_vector(
        np.random.default_rng(0).standard_normal(n).astype(np.float32))
    y = engine(x)
    np.testing.assert_allclose(
        np.asarray(y), spmv_ref_np(matrix, np.asarray(x)),
        rtol=2e-4, atol=2e-4)
    print("condensed SpMV matches the dense reference ✓")

    c = engine.counts
    print(f"comm volume (elements): condensed={c.total_condensed_volume()} "
          f"blockwise={c.total_blockwise_volume()} replicate={n_dev * n}")

    # the paper's performance models predict this workload on Abel and on
    # a TPU v5e pod with the same four hardware parameters
    w = SpmvWorkload(n=n, r_nz=r_nz, p=n_dev, blocksize=512,
                     topology=engine.plan.topology, counts=c)
    for name, hw in (("Abel(paper)", ABEL), ("TPUv5e", TPU_V5E)):
        t = predict_all(w, hw)
        print(f"predicted seconds/iter on {name}: " +
              " ".join(f"{k}={v:.2e}" for k, v in t.items()))


if __name__ == "__main__":
    main()
