"""The workload-agnostic irregular-communication API, five consumers deep.

The paper's machinery — plan once (§4.3.1), pick a ladder rung (§4), price
it with the §5 models — is exposed behind ``repro.comm``, in both
directions:

  * ``SharedVector``    — a sharded vector with contiguous ownership,
  * ``AccessPattern``   — the global index set each accessor touches,
  * ``IrregularGather`` — pull: plans, autotunes, and gathers,
  * ``IrregularScatter``— push: the same plan transposed, duplicate
    targets combining under ``reduce="add"|"set"|"max"``.

This example drives the raw API, then the consumers built on it:
``DistributedSpMV`` (the paper's workload, plus ``transpose=True`` for
y = (D+A)ᵀx), ``Heat2D`` (§8 stencil halos), and the MoE pair
(``MoEDispatchGather`` token→expert, ``MoECombineScatter`` expert→token).

Run: python examples/irregular_gather.py   (re-execs itself with 8 devices)
"""
import os
import sys

if "--no-reexec" not in sys.argv and "device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    os.execvpe(sys.executable, [sys.executable] + sys.argv + ["--no-reexec"],
               env)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import compat
from repro.comm import AccessPattern, IrregularGather, SharedVector
from repro.core import perfmodel as pm


def raw_api(mesh):
    print("== raw API: any index set over any sharded vector ==")
    n = 1 << 14
    sv = SharedVector(mesh, n=n, axis_name="data")
    rng = np.random.default_rng(0)
    # every accessor reads 8 mostly-local indices + the occasional far one
    idx = (np.arange(n)[:, None]
           + rng.integers(-64, 65, size=(n, 8))).clip(0, n - 1)
    far = rng.random((n, 8)) < 0.01
    idx[far] = rng.integers(0, n, size=int(far.sum()))
    pattern = AccessPattern.from_indices(idx.astype(np.int32), n=n)

    g = IrregularGather(pattern, sv, strategy="auto", blocksize="auto")
    print(f"  resolved strategy={g.strategy} blocksize={g.plan.blocksize}")
    print("  predicted:", {s: f"{t*1e6:.0f}us"
                           for s, t in sorted(g.predicted_times.items(),
                                              key=lambda kv: kv[1])})
    c = g.counts
    print(f"  condensed volume={c.total_condensed_volume()} elems, "
          f"blockwise volume={c.total_blockwise_volume()} elems, "
          f"replicate volume={8 * n} elems")

    x = rng.standard_normal(n).astype(np.float32)
    x_copies = np.asarray(g(sv.put(x)))          # (P, >=n): private copies
    q = 3
    rows = pattern.m // g.p
    needed = np.unique(pattern.indices[q * rows:(q + 1) * rows])
    assert (x_copies[q][needed] == x[needed]).all()
    print(f"  device {q}: x_copy delivers all {len(needed)} needed indices\n")


def destination_api(mesh):
    print("== Destination: land values straight in named consumer slots ==")
    from jax.sharding import PartitionSpec as P
    from repro.comm import Destination

    n, p = 1 << 14, 8
    sv = SharedVector(mesh, n=n, axis_name="data")
    rng = np.random.default_rng(7)
    idx = rng.integers(0, n, size=(n, 4)).astype(np.int32)
    pattern = AccessPattern.from_indices(idx, n=n)
    # each device wants a sparse, named slice of its reads delivered; -1
    # slots are guaranteed to read exactly 0.0
    slots = idx[::32, :2].reshape(p, -1).astype(np.int64).copy()
    slots[:, -1] = Destination.ZERO
    dest = Destination.from_slots(window=slots)
    g = IrregularGather(pattern, sv, strategy="condensed", blocksize="auto",
                        destination=dest)

    def step_local(x_local, *plan_args):
        # O(slots + recv) delivery: no length-n x_copy is ever assembled
        return g.local(x_local, *plan_args)["window"][None]

    mapped = compat.shard_map(
        step_local, mesh=mesh, in_specs=(P("data"),) + g.in_specs,
        out_specs=P("data"), check_vma=False)
    x = rng.standard_normal(n).astype(np.float32)
    out = np.asarray(jax.jit(lambda v: mapped(v, *g.plan_args))(sv.put(x)))
    want = np.where(slots >= 0, x[np.clip(slots, 0, None)], 0.0)
    assert (out == want).all()
    print(f"  {dest.num_slots} slots/device delivered targeted "
          f"(vs assembling {n}-long x_copy); full mode still available "
          "via materialize=\"full\"\n")


def scatter_api(mesh):
    print("== push direction: IrregularScatter over the transposed plan ==")
    from repro.comm import IrregularScatter

    n = 1 << 14
    sv = SharedVector(mesh, n=n, axis_name="data")
    rng = np.random.default_rng(4)
    idx = (np.arange(n)[:, None]
           + rng.integers(-64, 65, size=(n, 8))).clip(0, n - 1)
    pattern = AccessPattern.from_indices(idx.astype(np.int32), n=n)
    s = IrregularScatter(pattern, sv, strategy="auto", reduce="add")
    print(f"  resolved strategy={s.strategy} (put-model ranking); "
          "scatter plan = gather plan transposed "
          f"(round-trips: {s.splan.transpose() is s.plan})")
    vals = rng.integers(-4, 5, size=idx.shape).astype(np.float32)
    y = np.asarray(s(s.shard_values(vals)))
    ref = np.zeros(n, np.float32)
    np.add.at(ref, idx.ravel(), vals.ravel())
    print(f"  scatter-add over {idx.size} contributions bit-exact: "
          f"{np.array_equal(y, ref)}\n")


def spmv_consumer(mesh):
    print("== consumer 1: DistributedSpMV (the paper's workload) ==")
    from repro.core.matrix import (make_mesh_like_matrix, spmv_ref_np,
                                   spmv_t_ref_np)
    from repro.core.spmv import DistributedSpMV

    n = 1 << 14
    m = make_mesh_like_matrix(n, 16, locality_window=n // 64,
                              long_range_frac=0.02, seed=1)
    eng = DistributedSpMV(m, mesh, strategy="auto", blocksize="auto",
                          shards_per_node=4)
    x = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    y = np.asarray(eng(eng.shard_vector(x)))
    err = np.abs(y - spmv_ref_np(m, x)).max()
    print(f"  auto -> {eng.strategy}, blocksize={eng.blocksize}, "
          f"max_err={err:.2e}")
    # the transposed product pushes partial products to the column owners
    engt = DistributedSpMV(m, mesh, strategy="auto", shards_per_node=4,
                           transpose=True)
    yt = np.asarray(engt(engt.shard_vector(x)))
    errt = np.abs(yt - spmv_t_ref_np(m, x)).max()
    print(f"  transpose=True (y = Mᵀx) auto -> {engt.strategy}, "
          f"max_err={errt:.2e}\n")


def heat2d_consumer():
    print("== consumer 2: Heat2D (§8 halo exchange as an AccessPattern) ==")
    from repro.core.heat2d import Heat2D

    mesh = compat.make_mesh((2, 4), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    for kw in (dict(strategy="condensed"), dict(strategy="auto"),
               dict(overlap=True)):
        h = Heat2D(mesh, 64, 128, coef=0.1, **kw)
        phi = h.init_field(0)
        got = np.asarray(h.run(phi, 10))
        want = h.reference(np.asarray(phi), 10)
        c = h.counts
        print(f"  {kw} -> strategy={h.strategy} "
              f"halo_volume={c.total_condensed_volume()} elems "
              f"max_err={np.abs(got - want).max():.2e}")
    print()


def moe_consumer(mesh):
    print("== consumer 3: MoE dispatch + combine (one plan, two directions) "
          "==")
    from repro.models.moe import (MoECombineScatter, MoEDispatchGather,
                                  moe_combine_ref, moe_combine_weights,
                                  moe_dispatch_pattern, moe_dispatch_ref)

    n_tok, k, d, e_total = 1 << 13, 2, 16, 32
    cap = int(1.25 * n_tok * k / e_total)
    rng = np.random.default_rng(2)
    top_e = rng.integers(0, e_total, size=(n_tok, k))
    x = rng.standard_normal((n_tok, d)).astype(np.float32)
    g = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh, strategy="auto",
                          hw=pm.ABEL.replace(elem=4 * d))
    buf = np.asarray(g(g.shard_tokens(x)))
    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, 8)
    ref = moe_dispatch_ref(x, idx, valid, e_total, cap)
    print(f"  dispatch auto -> {g.strategy}; expert buffers {buf.shape}; "
          f"bit-exact={np.array_equal(buf, ref)}")
    c = g.counts
    print(f"  condensed moves {c.total_condensed_volume()} of "
          f"{n_tok} token vectors; replicate would move {8 * n_tok}")

    # the return path: weighted expert->token combine over the SAME plan
    top_w = rng.random((n_tok, k)).astype(np.float32)
    comb = MoECombineScatter(top_e, top_w, n_tok, e_total, cap, mesh,
                             strategy="auto",
                             hw=pm.ABEL.replace(elem=4 * d))
    y = np.asarray(comb(comb.shard_expert_buf(buf)))
    w_slot = moe_combine_weights(top_e, top_w, n_tok, e_total, cap)
    want = moe_combine_ref(buf, idx, valid, w_slot, n_tok)
    print(f"  combine auto -> {comb.strategy}; tokens back {y.shape}; "
          f"max_err={np.abs(y - want).max():.2e}")


def main():
    mesh = compat.make_mesh((8,), ("data",),
                            axis_types=compat.auto_axis_types(1))
    raw_api(mesh)
    destination_api(mesh)
    scatter_api(mesh)
    spmv_consumer(mesh)
    heat2d_consumer()
    moe_consumer(mesh)


if __name__ == "__main__":
    main()
