"""Config-driven benchmark matrix + the standing model-error gate.

One YAML (``benchmarks/matrix.yaml``) declares four axes — mesh shapes x
strategy rungs x workloads x dtypes — and this module runs their full
cartesian product through ONE generic cell runner: build the workload's
``Schedule`` on the requested mesh at the requested rung, verify against
the numpy ground truth, measure, price with the §5 models, and score the
relative model error (``perfmodel.model_error``) against the cell's
tolerance (``perfmodel.error_budget``).  ``BENCH_matrix.json`` carries the
uniform per-cell records (measured, predicted, error, budget, plan-source
telemetry) and ``matrix_bench`` returns the budget violations so
``benchmarks.run`` can exit non-zero — the paper's central claim, that the
formulas *predict* measured exchange cost, gated on every push.

The per-rung ladder machinery the bespoke ``benchmarks/tables.py`` loops
used to duplicate lives here too (``measured_ladder`` / ``ladder_volume``)
and tables.py now rides it.
"""
from __future__ import annotations

import itertools
import json
import math
import os

import numpy as np

from benchmarks.common import csv_row, drain_rows, timeit

try:  # the matrix config is YAML; everything else degrades without it
    import yaml
except ImportError:  # pragma: no cover - pyyaml ships with the image
    yaml = None

RUNGS = ("replicate", "blockwise", "condensed", "overlap")
DTYPE_BYTES = {"float32": 4, "bfloat16": 2}
DEFAULT_CONFIG = os.path.join(os.path.dirname(__file__), "matrix.yaml")
_AXIS_NAMES = ("data", "model", "ax2", "ax3")


# --------------------------------------------------------------------------
# Generic per-rung ladder (shared with benchmarks/tables.py)
# --------------------------------------------------------------------------

def ladder_volume(counts, strategy: str, p: int, n: int) -> int:
    """The per-strategy moved-element count every ladder row reports."""
    return {"replicate": p * n,
            "blockwise": counts.total_blockwise_volume()}.get(
                strategy, counts.total_condensed_volume())


def measured_ladder(prefix: str, build, *, iters: int, preds,
                    vol_of=None) -> dict:
    """Run one strategy ladder (four rungs + auto) and emit its rows.

    ``build(strategy)`` returns ``(fn, args, engine)`` with correctness
    already verified; ``preds(engine)`` prices the rungs once (a
    ``{strategy: seconds}`` mapping, evaluated on the first engine built);
    ``vol_of(engine, strategy)`` optionally reports moved elements.

    Fixed rungs emit ``{prefix}.{strategy}`` rows with the §5 prediction
    and the ``accuracy = min/max`` column; the ``auto`` row reports the
    resolved rung, the full predicted ordering, whether the pick agrees
    with the measured-best fixed rung's model ranking, and the measured
    ratio to the best fixed rung.  Returns ``{strategy: seconds}``.
    """
    results: dict[str, float] = {}
    preds_d = None
    for strategy in RUNGS + ("auto",):
        fn, args, eng = build(strategy)
        if preds_d is None:
            preds_d = dict(preds(eng))
        t = timeit(fn, *args, iters=iters)
        results[strategy] = t
        if strategy == "auto":
            best_fixed = min(v for s, v in results.items() if s != "auto")
            order = ">".join(s for s, _ in sorted(preds_d.items(),
                                                  key=lambda kv: kv[1]))
            resolved = getattr(eng, "strategy", None)
            agree = resolved == min(preds_d, key=preds_d.get)
            csv_row(f"{prefix}.auto", t * 1e6,
                    f"resolved={resolved} predicted_order={order} "
                    f"pick_agrees_with_model={agree} "
                    f"vs_best_fixed={t/best_fixed:.2f}x")
        else:
            t_pred = preds_d[strategy]
            acc = min(t, t_pred) / max(t, t_pred)
            vol = f" vol_elems={vol_of(eng, strategy)}" if vol_of else ""
            csv_row(f"{prefix}.{strategy}", t * 1e6,
                    f"predicted_us={t_pred*1e6:.1f} accuracy={acc:.2f}{vol}")
    return results


# --------------------------------------------------------------------------
# Config loading
# --------------------------------------------------------------------------

def load_matrix_config(path: str | None = None) -> dict:
    """Load + structurally validate a matrix YAML (see matrix.yaml header)."""
    if yaml is None:
        raise RuntimeError(
            "benchmarks.matrix needs pyyaml for its config; install it or "
            "pass a pre-parsed dict to run_matrix")
    with open(path or DEFAULT_CONFIG) as f:
        cfg = yaml.safe_load(f)
    for key in ("matrix", "run", "workloads"):
        if key not in cfg:
            raise ValueError(f"matrix config missing top-level {key!r}")
    axes = cfg["matrix"]
    for axis in ("mesh", "rung", "workload", "dtype"):
        if not isinstance(axes.get(axis), list) or not axes[axis]:
            raise ValueError(f"matrix.{axis} must be a non-empty list")
    for d in axes["dtype"]:
        if d not in DTYPE_BYTES:
            raise ValueError(f"unknown dtype {d!r} (have {set(DTYPE_BYTES)})")
    for w in axes["workload"]:
        if w not in cfg["workloads"]:
            raise ValueError(f"workload {w!r} has no workloads: entry")
        if w not in _BUILDERS:
            raise ValueError(f"workload {w!r} has no registered builder "
                             f"(have {sorted(_BUILDERS)})")
    return cfg


def _smoke_merge(params: dict, smoke: bool) -> dict:
    out = {k: v for k, v in params.items() if k != "smoke"}
    if smoke:
        out.update(params.get("smoke") or {})
    return out


def iter_cells(cfg: dict, smoke: bool = False):
    """The full (workload x mesh x dtype x rung) product, rungs innermost
    so consecutive cells share the pattern's cached base plan."""
    axes = cfg["matrix"]
    run = _smoke_merge(cfg["run"], smoke)
    for workload, mesh, dtype, rung in itertools.product(
            axes["workload"], axes["mesh"], axes["dtype"], axes["rung"]):
        yield {
            "workload": workload,
            "mesh": [int(x) for x in mesh],
            "dtype": dtype,
            "rung": rung,
            "params": _smoke_merge(cfg["workloads"][workload], smoke),
            "iters": int(run.get("iters", 10)),
            "warmup": int(run.get("warmup", 3)),
        }


# --------------------------------------------------------------------------
# Cell building: one adapter per workload axis entry
# --------------------------------------------------------------------------

def _cast(arr, dtype: str):
    """Round a host array to the cell dtype (bfloat16 via jnp/ml_dtypes)."""
    if dtype == "float32":
        return np.asarray(arr, np.float32)
    import jax.numpy as jnp
    return np.asarray(jnp.asarray(np.asarray(arr)).astype(jnp.bfloat16))


def _f32(arr):
    return np.asarray(arr).astype(np.float32)


def _verify_tol(dtype: str) -> dict:
    # bf16 accumulates ~2^-8 relative error per term; the check only needs
    # to catch wrong *routing* (O(1) wrong values), not rounding
    return (dict(rtol=2e-4, atol=2e-4) if dtype == "float32"
            else dict(rtol=0.2, atol=0.2))


def _build_spmv(cell, mesh, axis_name, hw, *, skewed: bool,
                use_kernel: bool = False):
    from repro.comm.pattern import AccessPattern
    from repro.comm.schedule import Schedule
    from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np

    prm, dtype = cell["params"], cell["dtype"]
    n, r_nz, seed = int(prm["n"]), int(prm["r_nz"]), int(prm.get("seed", 1))
    if skewed:
        from repro.data.skewed import make_powerlaw_matrix
        m = make_powerlaw_matrix(n, r_nz, alpha=float(prm.get("alpha", 1.1)),
                                 seed=seed)
    else:
        m = make_mesh_like_matrix(n, r_nz, locality_window=n // 64,
                                  long_range_frac=0.02, seed=seed)
    diag, vals = _cast(m.diag, dtype), _cast(m.vals, dtype)
    x_host = _cast(np.random.default_rng(seed).standard_normal(n)
                   .astype(np.float32), dtype)
    # ground truth on the dtype-rounded operands, computed in f32
    ref = spmv_ref_np(
        type(m)(n=n, r_nz=r_nz, diag=_f32(diag), vals=_f32(vals),
                cols=m.cols), _f32(x_host))

    p = math.prod(cell["mesh"])
    sched = Schedule()
    x = sched.input("x")
    dg = sched.constant(diag, name="diag")
    vl = sched.constant(vals, name="vals")
    cl = sched.constant(m.cols, name="cols")
    g = sched.gather(AccessPattern.from_ellpack(m), src=x, name="exchange")
    sched.compute(lambda xc, d_, v_, c_, xl: d_ * xl + (v_ * xc[c_]).sum(-1),
                  g, dg, vl, cl, x, name="spmv")
    step = sched.compile(mesh, axis_name=axis_name, strategy=cell["rung"],
                         blocksize=max(8, n // p // 16), hw=hw,
                         use_kernel=use_kernel)
    xs = step.shard_input(x_host)
    np.testing.assert_allclose(_f32(step(xs)), ref, **_verify_tol(dtype))
    return step, (xs,), step.strategies["exchange"]


def _build_moe_dispatch(cell, mesh, axis_name, hw):
    from repro.comm.pattern import AccessPattern
    from repro.comm.schedule import Schedule
    from repro.models.moe import (moe_dispatch_pattern, moe_dispatch_ref,
                                  random_router)

    prm, dtype = cell["params"], cell["dtype"]
    n_tok, d = int(prm["n_tok"]), int(prm["d"])
    k, e_total = int(prm.get("k", 2)), int(prm.get("e_total", 32))
    seed = int(prm.get("seed", 3))
    p = math.prod(cell["mesh"])
    cap = int(1.25 * n_tok * k / e_total)
    top_e, _ = random_router(seed, n_tok, e_total, k)
    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, p)
    x_host = _cast(np.random.default_rng(seed)
                   .standard_normal((n_tok, d)).astype(np.float32), dtype)
    ref = moe_dispatch_ref(_f32(x_host), idx, valid,
                           e_total, cap).reshape(-1, d)

    sched = Schedule()
    x = sched.input("x")
    sl = sched.constant(idx, name="slots")
    vm = sched.constant(_cast(valid.astype(np.float32), dtype), name="valid")
    g = sched.gather(AccessPattern.from_indices(idx, n=n_tok), src=x,
                     name="exchange")
    sched.compute(lambda xc, s_, v_: xc[s_] * v_[:, None], g, sl, vm,
                  name="dispatch")
    step = sched.compile(mesh, axis_name=axis_name, strategy=cell["rung"],
                         blocksize=max(8, n_tok // p // 16), hw=hw)
    xs = step.shard_input(x_host)
    # dispatch is pure data movement: bf16 values move bit-exactly
    np.testing.assert_allclose(_f32(step(xs)), ref, rtol=1e-6, atol=1e-6)
    return step, (xs,), step.strategies["exchange"]


def _build_gnn(cell, mesh, axis_name, hw):
    from repro.models.gnn import (GNNNeighborAggregate, gnn_ref_np,
                                  random_neighbors)

    prm, dtype = cell["params"], cell["dtype"]
    n, r, d = int(prm["n"]), int(prm["r"]), int(prm["d"])
    seed = int(prm.get("seed", 4))
    p = math.prod(cell["mesh"])
    nbrs = random_neighbors(n, r, alpha=float(prm.get("alpha", 0.0)),
                            seed=seed)
    h_host = _cast(np.random.default_rng(seed)
                   .standard_normal((n, d)).astype(np.float32), dtype)
    layer = GNNNeighborAggregate(nbrs, n, mesh, axis_name=axis_name,
                                 strategy=cell["rung"],
                                 blocksize=max(8, n // p // 16), hw=hw)
    hs = layer.shard_features(h_host)
    np.testing.assert_allclose(_f32(layer(hs)),
                               gnn_ref_np(_f32(h_host), nbrs),
                               **_verify_tol(dtype))
    resolved = "+".join(layer.strategies[s] for s in ("gather_nbrs",
                                                      "scatter_upd"))
    return layer, (hs,), resolved


def _elem_bytes(cell) -> int:
    """hw.elem for the cell: dtype width, feature width folded in (every
    moved element of the token/feature workloads is one d-wide row)."""
    width = DTYPE_BYTES[cell["dtype"]]
    d = cell["params"].get("d")
    return width * int(d) if d else width


_BUILDERS = {
    "spmv": lambda cell, mesh, ax, hw: _build_spmv(cell, mesh, ax, hw,
                                                   skewed=False),
    "spmv_skewed": lambda cell, mesh, ax, hw: _build_spmv(cell, mesh, ax, hw,
                                                          skewed=True),
    # the same exchange driven through the fused Pallas pack/unpack kernels
    # (use_kernel=True), priced by the kernel-variant §5 compute terms
    "spmv_kernel": lambda cell, mesh, ax, hw: _build_spmv(
        cell, mesh, ax, hw, skewed=False, use_kernel=True),
    "moe_dispatch": _build_moe_dispatch,
    "gnn": _build_gnn,
}


# --------------------------------------------------------------------------
# The runner + the model-error gate
# --------------------------------------------------------------------------

def _get_mesh(shape: tuple[int, ...], cache: dict):
    import jax
    from repro import compat

    if shape not in cache:
        ndev = len(jax.devices())
        if math.prod(shape) > ndev:
            raise RuntimeError(
                f"mesh {list(shape)} needs {math.prod(shape)} devices, have "
                f"{ndev} (run via benchmarks.run, which forces 8)")
        names = _AXIS_NAMES[:len(shape)]
        mesh = compat.make_mesh(shape, names,
                                axis_types=compat.auto_axis_types(len(shape)))
        cache[shape] = (mesh, names[0] if len(shape) == 1 else names)
    return cache[shape]


def run_cell(cell: dict, mesh, axis_name, predict_scale: float = 1.0) -> dict:
    """Build, verify, measure and score ONE matrix cell."""
    from repro.comm import telemetry
    from repro.comm.exchange import measure_hw
    from repro.core import perfmodel as pm

    hw = measure_hw(mesh, axis_name).replace(elem=_elem_bytes(cell))
    snap = telemetry.stats.snapshot()
    step, args, resolved = _BUILDERS[cell["workload"]](cell, mesh, axis_name,
                                                       hw)
    tel = telemetry.stats.since(snap)
    source = max(pm.PLAN_SOURCES, key=lambda s: tel.get(s, 0))
    if tel.get(source, 0) == 0:
        source = "host-build"   # no acquisition recorded: price the worst

    measured = timeit(step, *args, iters=cell["iters"],
                      warmup=cell["warmup"])
    predicted = float(step.predicted_window["total"]) * float(predict_scale)
    err = round(pm.model_error(measured, predicted), 4)
    budget = pm.error_budget(cell)
    return {
        "workload": cell["workload"],
        "mesh": cell["mesh"],
        "rung": cell["rung"],
        "dtype": cell["dtype"],
        "resolved": resolved,
        "measured_us": round(measured * 1e6, 1),
        "predicted_us": round(predicted * 1e6, 1),
        "model_error": err,
        "budget": budget,
        "within_budget": bool(err <= budget),
        "plan_source": source,
        "plan_acquisitions": {s: int(c) for s, c in tel.items()},
    }


def run_matrix(cfg: dict, smoke: bool = False) -> tuple[list, list]:
    """Run every cell; returns ``(cells, violations)`` and emits one
    ``matrix.<workload>.<mesh>.<rung>.<dtype>`` csv row per cell."""
    scales = cfg.get("predict_scale") or {}
    mesh_cache: dict = {}
    cells, violations = [], []
    for cell in iter_cells(cfg, smoke):
        mesh, axis_name = _get_mesh(tuple(cell["mesh"]), mesh_cache)
        res = run_cell(cell, mesh, axis_name,
                       predict_scale=scales.get(cell["workload"], 1.0))
        cells.append(res)
        tag = "x".join(map(str, res["mesh"]))
        name = (f"matrix.{res['workload']}.{tag}.{res['rung']}"
                f".{res['dtype']}")
        csv_row(name, res["measured_us"],
                f"predicted_us={res['predicted_us']} "
                f"model_error={res['model_error']} "
                f"budget={res['budget']:g} "
                f"within_budget={res['within_budget']} "
                f"resolved={res['resolved']} "
                f"plan_source={res['plan_source']}")
        if not res["within_budget"]:
            violations.append(
                f"{name}: model_error {res['model_error']} exceeds budget "
                f"{res['budget']:g} (measured={res['measured_us']}us "
                f"predicted={res['predicted_us']}us)")
    return cells, violations


def write_matrix_json(cells: list, rows: list, smoke: bool,
                      path: str = "BENCH_matrix.json") -> None:
    from repro.comm import telemetry

    payload = {"bench": "matrix", "smoke": smoke, "rows": rows,
               "cells": cells, "telemetry": telemetry.stats.snapshot()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {path} ({len(cells)} cells)")


def matrix_bench(smoke: bool = False, config: str | None = None,
                 out_path: str = "BENCH_matrix.json") -> list:
    """The ``benchmarks.run matrix`` entry point.

    Runs the configured matrix, writes ``BENCH_matrix.json`` (rows +
    per-cell records + plan telemetry) and returns the list of model-error
    budget violations — the caller exits non-zero on any.
    """
    cfg = load_matrix_config(config)
    n_cells = len(list(iter_cells(cfg, smoke)))
    print(f"# matrix: {n_cells} cells "
          f"(mesh x rung x workload x dtype from "
          f"{config or DEFAULT_CONFIG}); model-error gate armed")
    drain_rows()   # cell rows only in the artifact, wherever we ran from
    cells, violations = run_matrix(cfg, smoke)
    write_matrix_json(cells, drain_rows(), smoke, path=out_path)
    worst = max(cells, key=lambda c: c["model_error"] / c["budget"])
    print(f"# matrix: worst cell {worst['workload']}.{worst['rung']}"
          f".{worst['dtype']} model_error={worst['model_error']} "
          f"(budget {worst['budget']:g}); violations={len(violations)}")
    return violations
