"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Multi-device benchmarks need 8
host devices, so this module RE-EXECS itself with the XLA flag when invoked
with a single device (keeping plain ``python -m benchmarks.run`` working).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table3 roofline
  python -m benchmarks.run table3 --smoke            # CI-sized quick pass
  python -m benchmarks.run matrix --smoke            # config-driven matrix

``matrix`` runs the declarative mesh x rung x workload x dtype product
from ``benchmarks/matrix.yaml`` (override with ``--config=PATH``), writes
``BENCH_matrix.json`` itself, and makes the process exit non-zero when any
cell's predicted-vs-measured drift exceeds its ``perfmodel.error_budget``
— the standing model-error regression gate.
"""
from __future__ import annotations

import inspect
import os
import sys


def _ensure_devices():
    if "--no-reexec" in sys.argv:
        sys.argv.remove("--no-reexec")
        return
    if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8")
        os.execvpe(sys.executable,
                   [sys.executable, "-m", "benchmarks.run", "--no-reexec"]
                   + sys.argv[1:], env)


def _write_bench_json(name: str, rows, smoke: bool) -> None:
    """Machine-readable per-PR perf trajectory (BENCH_<name>.json at the
    repo root, next to the CSV the CI job tees) — every csv_row of the
    bench, schedule + scatter rows included.  table3 additionally carries
    the plan-acquisition telemetry of the whole bench run (where every
    executor table came from: memory/disk/bucket/device/host — the §5
    T_plan closure; see repro.comm.telemetry)."""
    import json

    payload = {"bench": name, "smoke": smoke, "rows": rows}
    if name == "table3":
        from repro.comm import telemetry

        payload["telemetry"] = telemetry.stats.snapshot()
    path = f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {path} ({len(rows)} rows)")


def main() -> None:
    _ensure_devices()
    from benchmarks import common, tables

    smoke = "--smoke" in sys.argv[1:]
    config = None
    for arg in sys.argv[1:]:
        if arg.startswith("--config="):
            config = arg.split("=", 1)[1]
    which = [a for a in sys.argv[1:] if not a.startswith("-")]
    all_benches = {
        "table2": tables.table2_privatization,
        "table3": tables.table3_strategies,
        "table4": tables.table4_model_validation,
        "fig2": tables.fig2_volumes,
        "table5": tables.table5_heat2d,
        "roofline": tables.roofline_report,
        "serve": tables.table_serve,
        "matrix": None,  # dispatched below: writes its own JSON + gates
    }
    if not which:
        which = list(all_benches)
    print("name,us_per_call,derived")
    violations: list[str] = []
    for name in which:
        if name == "matrix":
            from benchmarks import matrix

            violations.extend(matrix.matrix_bench(smoke=smoke,
                                                  config=config))
            continue
        fn = all_benches[name]
        common.drain_rows()
        if smoke and "smoke" in inspect.signature(fn).parameters:
            fn(smoke=True)
        else:
            fn()
        if name in ("table3", "table5", "serve") and smoke:
            _write_bench_json(name, common.drain_rows(), smoke)
    if violations:
        print(f"# FAIL: {len(violations)} matrix cell(s) exceed their "
              "model-error budget", file=sys.stderr)
        for v in violations:
            print(f"#   {v}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
