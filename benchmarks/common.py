"""Shared benchmark utilities: timing, host calibration, CSV output."""
from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["timeit", "csv_row", "drain_rows", "calibrate_host"]

# every csv_row also lands here so the runner can persist a machine-
# readable copy (BENCH_table3.json) next to the human-readable CSV
_rows: list[dict] = []


def timeit(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Median wall seconds per call (blocking on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def csv_row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    _rows.append({"name": name, "us_per_call": round(float(us_per_call), 1),
                  "derived": derived})


def drain_rows() -> list[dict]:
    """All rows emitted since the last drain (for the JSON artifact)."""
    rows = list(_rows)
    _rows.clear()
    return rows


def calibrate_host(elem_bytes: int = 4):
    """Measure the paper's four hardware parameters on THIS host (§6.2).

    Delegates to ``repro.core.tune.measure_hardware`` — the same calibration
    the ``strategy="auto"`` engine uses — so benchmarks and the autotuner
    always see identical numbers.  Host devices are one-core XLA threads, so
    each device is modeled as its own "node" during validation: every
    inter-device message pays tau, exactly like the paper's inter-node
    accesses."""
    from repro.core import tune

    return tune.measure_hardware(elem_bytes=elem_bytes)
