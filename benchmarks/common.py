"""Shared benchmark utilities: timing, host calibration, CSV output."""
from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["timeit", "csv_row", "calibrate_host"]


def timeit(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Median wall seconds per call (blocking on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def csv_row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def calibrate_host(elem_bytes: int = 4):
    """Measure the paper's four hardware parameters on THIS host, following
    §6.2: a STREAM-like copy for w_private, a large ppermute ("ping-pong")
    between host devices for w_remote, and a tiny ppermute for tau (the
    per-message latency floor).  Host devices are one-core XLA threads, so
    each device is modeled as its own "node" during validation — every
    inter-device message pays tau, exactly like the paper's inter-node
    accesses."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.perfmodel import HardwareParams

    n = 1 << 22
    x = jnp.arange(n, dtype=jnp.float32)
    copy = jax.jit(lambda a: a * 1.0000001)
    t_copy = timeit(copy, x, iters=10)
    w_private = 2.0 * n * 4 / t_copy  # read + write

    ndev = len(jax.devices())
    if ndev > 1:
        mesh = jax.make_mesh((ndev,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        perm = [(i, (i + 1) % ndev) for i in range(ndev)]

        def ring(a):
            return jax.shard_map(
                lambda v: jax.lax.ppermute(v, "data", perm), mesh=mesh,
                in_specs=P("data"), out_specs=P("data"))(a)

        big = jax.device_put(
            jnp.zeros((ndev * (1 << 20),), jnp.float32),
            NamedSharding(mesh, P("data")))
        t_big = timeit(jax.jit(ring), big, iters=5)
        tiny = jax.device_put(jnp.zeros((ndev * 8,), jnp.float32),
                              NamedSharding(mesh, P("data")))
        tau = timeit(jax.jit(ring), tiny, iters=20)
        w_remote = (1 << 20) * 4 / max(t_big - tau, 1e-9)
    else:
        w_remote = w_private
        tau = timeit(copy, jnp.zeros((8,), jnp.float32), iters=30)

    return HardwareParams(
        w_private=w_private, w_remote=w_remote, tau=tau, cacheline=64,
        elem=elem_bytes, idx=4)
