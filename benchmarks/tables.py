"""One benchmark per paper table/figure (DESIGN.md §9).

All multi-device measurements run inside THIS process only when it was
launched with 8 forced host devices (benchmarks.run spawns itself that way);
single-device benchmarks run anywhere.

Output format: ``name,us_per_call,derived`` CSV rows on stdout.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import calibrate_host, csv_row, timeit
from benchmarks.matrix import ladder_volume, measured_ladder
from repro import compat
from repro.core import perfmodel as pm
from repro.core.heat2d import Heat2D
from repro.core.matrix import make_mesh_like_matrix, spmv_ref_np
from repro.core.plan import Topology
from repro.core.plan_cache import get_comm_plan
from repro.core.spmv import DistributedSpMV
from repro.kernels import ops as kops


def _mesh8():
    assert len(jax.devices()) >= 8, "run via benchmarks.run (8 host devices)"
    return compat.make_mesh((8,), ("data",),
                            axis_types=compat.auto_axis_types(1))


# --------------------------------------------------------------------------
# Table 2: naive vs thread-privatized (UPCv1) — single "node" scaling
# --------------------------------------------------------------------------

def table2_privatization(n=1 << 18, r_nz=16):
    """Paper Table 2: the per-access overhead tax.  UPC's pointer-to-shared
    pays owner/phase/address bookkeeping on EVERY access; privatization
    removes it.  The measurable host analogue of that per-access tax is a
    guarded gather (bounds-check + fill select) vs a trusted local gather
    (promise_in_bounds).  The Pallas windowed kernel is validated for
    correctness here; its wall-time on CPU is interpret-mode Python and is
    deliberately NOT compared (TPU is the target; see §Roofline)."""
    print("# table2: guarded (naive shared-access) vs privatized gather SpMV"
          f" (n={n}, r_nz={r_nz}; seconds per 1000 iters)")
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 256, seed=0)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
    diag, vals, cols = (jnp.asarray(m.diag), jnp.asarray(m.vals),
                        jnp.asarray(m.cols))

    naive = jax.jit(lambda d, v, c, xx: d * xx + (
        v * jnp.take(xx, c, mode="fill", fill_value=0.0)).sum(-1))
    t_naive = timeit(naive, diag, vals, cols, x)

    # trusted local gather: clamp-only indexing (x[c]), no fill-select
    priv = jax.jit(lambda d, v, c, xx: d * xx + (v * xx[c]).sum(-1))
    t_priv = timeit(priv, diag, vals, cols, x)

    y_ref = np.asarray(priv(diag, vals, cols, x))
    plan = kops.plan_spmv_windows(m.cols, rows_per_block=256)
    y_kern = np.asarray(kops.ellpack_spmv(diag, vals, m.cols, x, plan=plan))
    np.testing.assert_allclose(y_kern, y_ref, rtol=3e-5, atol=3e-5)

    csv_row("table2.naive_guarded", t_naive * 1e6,
            f"per_1000={t_naive*1e3:.2f}s")
    csv_row("table2.privatized", t_priv * 1e6,
            f"per_1000={t_priv*1e3:.2f}s speedup={t_naive/t_priv:.2f}x "
            f"pallas_kernel=validated(interpret)")


# --------------------------------------------------------------------------
# Table 3: the three strategies, measured on 8 host devices + modeled at
# paper scale (16..1024 threads, Abel parameters)
# --------------------------------------------------------------------------

def table3_strategies(n=1 << 17, r_nz=16, iters=50, smoke=False):
    if smoke:  # CI trajectory capture: small but same shape of output
        n, iters = 1 << 14, 5
    print(f"# table3: strategies measured on 8 host devices (n={n}) + "
          "modeled at Abel scale")
    mesh = _mesh8()
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 64,
                              long_range_frac=0.02, seed=1)
    x_host = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    y_ref = spmv_ref_np(m, x_host)

    from repro.comm import select
    from repro.core import tune
    hw = tune.measure_hardware(mesh, "data")

    def build(strategy):
        eng = DistributedSpMV(m, mesh, strategy=strategy,
                              blocksize=n // 8 // 16, shards_per_node=4)
        x = eng.shard_vector(x_host)
        np.testing.assert_allclose(np.asarray(eng(x)), y_ref, rtol=2e-4,
                                   atol=2e-4)
        return eng, (x,), eng

    results = measured_ladder(
        "table3.measured", build, iters=iters,
        preds=lambda eng: select.rank_strategies(eng.plan, r_nz, hw),
        vol_of=lambda eng, s: ladder_volume(eng.counts, s, 8, n))

    # modeled at paper scale with Abel parameters (prediction deliverable)
    print("# table3 model: Abel params, threads=16..1024 (seconds/1000 iters)")
    for threads in (16, 32, 64, 128, 256, 512, 1024):
        if threads > n // 64:
            continue
        topo = Topology(threads, 16)
        mm = make_mesh_like_matrix(n, r_nz, locality_window=n // 64,
                                   long_range_frac=0.02, seed=1)
        plan = get_comm_plan(mm.cols, n, threads,
                               blocksize=max(64, n // threads // 8),
                               topology=topo)
        w = pm.SpmvWorkload(n=n, r_nz=r_nz, p=threads,
                            blocksize=max(64, n // threads // 8),
                            topology=topo, counts=plan.counts)
        t = pm.predict_all(w, pm.ABEL)
        csv_row(f"table3.model.{threads}threads",
                t["v3_condensed"] * 1e6 * 1000,
                f"v1={t['v1_finegrained']*1000:.2f}s "
                f"v2={t['v2_blockwise']*1000:.2f}s "
                f"v3={t['v3_condensed']*1000:.2f}s "
                f"overlap={t['overlap']*1000:.2f}s per-1000")

    table3_unpack_modes(n=n, r_nz=r_nz, iters=iters, mesh=mesh, m=m,
                        x_host=x_host, y_ref=y_ref)
    table3_kernel(n=n, r_nz=r_nz, iters=iters, mesh=mesh, m=m,
                  x_host=x_host, y_ref=y_ref)
    table3_moe_dispatch(smoke=smoke, iters=iters)
    table3_scatter(smoke=smoke, iters=iters)
    table3_schedule(smoke=smoke, iters=iters)
    table3_dynamic(smoke=smoke, iters=iters)
    return results


# --------------------------------------------------------------------------
# Table 3c: the two unpack modes on the condensed rung — the paper's
# assemble-x_copy layout vs the Destination-targeted delivery, each priced
# by its own §5 term (docs/perf_model.md eqs. 14'/15')
# --------------------------------------------------------------------------

def table3_unpack_modes(*, n, r_nz, iters, mesh, m, x_host, y_ref):
    from repro.comm import select
    from repro.core import tune

    print("# table3 unpack: condensed rung, full x_copy assembly vs "
          "Destination-targeted delivery (per-mode §5 prediction)")
    hw = tune.measure_hardware(mesh, "data")
    for mode in ("full", "dest"):
        eng = DistributedSpMV(m, mesh, strategy="condensed",
                              blocksize=n // 8 // 16, shards_per_node=1,
                              materialize=mode)
        x = eng.shard_vector(x_host)
        np.testing.assert_allclose(np.asarray(eng(x)), y_ref, rtol=2e-4,
                                   atol=2e-4)
        t = timeit(eng, x, iters=iters)
        t_pred = dict(select.rank_strategies(
            eng.plan, r_nz, hw, materialize=mode))["condensed"]
        acc = min(t, t_pred) / max(t, t_pred)
        csv_row(f"table3.unpack.{mode}", t * 1e6,
                f"predicted_us={t_pred*1e6:.1f} accuracy={acc:.2f} "
                f"dest_slots={eng.plan.dest_len}")


# --------------------------------------------------------------------------
# Table 3g: the fused Pallas exchange path (use_kernel=True) on the
# condensed/overlap rungs, both directions, against the bit-identical jnp
# reference — priced by the kernel-variant §5 compute terms (eqs. 14ᵏ/15ᵏ,
# 14ᵀᵏ/15ᵀᵏ; docs/perf_model.md)
# --------------------------------------------------------------------------

def table3_kernel(*, n, r_nz, iters, mesh, m, x_host, y_ref):
    from repro.comm import select
    from repro.core import tune
    from repro.core.matrix import spmv_t_ref_np

    print("# table3 kernel: fused pack/unpack exchange kernels vs the jnp "
          "path (bit-identical), per-variant §5 prediction")
    hw = tune.measure_hardware(mesh, "data")
    yt_ref = spmv_t_ref_np(m, x_host)
    bs = n // 8 // 16
    for direction in ("gather", "scatter"):
        transpose = direction == "scatter"
        ref = yt_ref if transpose else y_ref
        # hold the local compute constant (dest-mode slot compute for the
        # gather, scatter-accumulate for the put) so the pair differs ONLY
        # in the exchange path — that is the bit-identity contract
        mat = None if transpose else "dest"
        for strategy in ("condensed", "overlap"):
            t, y = {}, {}
            for uk in (False, True):
                eng = DistributedSpMV(m, mesh, strategy=strategy,
                                      blocksize=bs, shards_per_node=1,
                                      transpose=transpose, use_kernel=uk,
                                      materialize=mat, hw=hw)
                x = eng.shard_vector(x_host)
                y[uk] = np.asarray(eng(x))
                np.testing.assert_allclose(y[uk], ref, rtol=2e-4, atol=2e-4)
                t[uk] = timeit(eng, x, iters=iters)
                if uk:
                    plan = eng.splan if transpose else eng.plan
                    t_pred = dict(select.rank_strategies(
                        plan, r_nz, hw, use_kernel=True, materialize=mat,
                        dest_slots=None if transpose else plan.dest_len,
                        direction="put" if transpose else "get"))[strategy]
            np.testing.assert_array_equal(y[True], y[False])
            acc = min(t[True], t_pred) / max(t[True], t_pred)
            csv_row(f"table3.kernel.{direction}.{strategy}", t[True] * 1e6,
                    f"predicted_us={t_pred*1e6:.1f} accuracy={acc:.2f} "
                    f"vs_jnp={t[True]/t[False]:.2f}x jnp_us={t[False]*1e6:.1f}"
                    " bit_identical=verified")


# --------------------------------------------------------------------------
# Table 3b: the MoE-dispatch consumer — the same ladder on the token→expert
# gather, measured on 8 host devices with §5 predicted-vs-measured
# --------------------------------------------------------------------------

def table3_moe_dispatch(n_tok=1 << 14, d=32, smoke=False, iters=50):
    from repro.comm import select
    from repro.core import tune
    from repro.models.moe import (MoEDispatchGather, moe_dispatch_pattern,
                                  moe_dispatch_ref, random_router)

    if smoke:
        n_tok, d, iters = 1 << 12, 8, 5
    k, e_total = 2, 32
    cap = int(1.25 * n_tok * k / e_total)
    print(f"# table3 moe_dispatch: token->expert gather ladder "
          f"(tokens={n_tok}, d={d}, experts={e_total}, capacity={cap})")
    mesh = _mesh8()
    rng = np.random.default_rng(3)
    # zipf-skewed routing: experts differ in load, like trained routers
    top_e, _ = random_router(3, n_tok, e_total, k)
    x_host = rng.standard_normal((n_tok, d)).astype(np.float32)
    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, 8)
    ref = moe_dispatch_ref(x_host, idx, valid, e_total, cap)

    # price with the host's measured parameters, feature width folded into
    # the element size (every moved "element" is one d-wide token vector)
    hw = tune.measure_hardware(mesh, "data").replace(elem=4 * d)

    def build(strategy):
        g = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh,
                              strategy=strategy, blocksize=n_tok // 8 // 16,
                              shards_per_node=1, hw=hw)
        x = g.shard_tokens(x_host)
        np.testing.assert_array_equal(np.asarray(g(x)), ref)
        return g, (x,), g

    return measured_ladder(
        "table3.moe_dispatch", build, iters=iters,
        preds=lambda g: select.rank_strategies(g.plan, 1, hw),
        vol_of=lambda g, s: ladder_volume(g.counts, s, 8, n_tok))


# --------------------------------------------------------------------------
# Table 3d: the push direction — MoE expert→token combine and transposed
# SpMV on the scatter ladder, measured on 8 host devices with the §5
# put-model predictions (docs/perf_model.md eqs. 12ᵀ–15ᵀ) per rung
# --------------------------------------------------------------------------

def table3_scatter(n=1 << 17, r_nz=16, smoke=False, iters=50):
    from repro.comm import select
    from repro.core import tune
    from repro.core.matrix import spmv_t_ref_np
    from repro.models.moe import (MoECombineScatter, moe_combine_ref,
                                  moe_combine_weights, moe_dispatch_pattern,
                                  random_router)

    if smoke:
        n, iters = 1 << 14, 5
    mesh = _mesh8()

    # -- spmv_transpose: y = (D + A)ᵀ x via scatter-accumulate --
    print(f"# table3 scatter: transposed SpMV (n={n}) + MoE combine on the "
          "put ladder, predicted (§5ᵀ) vs measured")
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 64,
                              long_range_frac=0.02, seed=1)
    x_host = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    y_ref = spmv_t_ref_np(m, x_host)
    hw = tune.measure_hardware(mesh, "data")

    def build_t(strategy):
        eng = DistributedSpMV(m, mesh, strategy=strategy,
                              blocksize=n // 8 // 16, shards_per_node=1,
                              transpose=True, hw=hw)
        x = eng.shard_vector(x_host)
        np.testing.assert_allclose(np.asarray(eng(x)), y_ref, rtol=2e-4,
                                   atol=2e-4)
        return eng, (x,), eng

    measured_ladder(
        "table3.scatter.spmv_transpose", build_t, iters=iters,
        preds=lambda eng: select.rank_strategies(eng.splan, r_nz, hw,
                                                 direction="put"),
        vol_of=lambda eng, s: ladder_volume(eng.counts, s, 8, n))

    # -- moe_combine: weighted expert→token return --
    n_tok, d = (1 << 12, 8) if smoke else (1 << 14, 32)
    k, e_total = 2, 32
    cap = int(1.25 * n_tok * k / e_total)
    rng = np.random.default_rng(3)
    top_e, top_w = random_router(3, n_tok, e_total, k)
    buf = rng.standard_normal((e_total, cap, d)).astype(np.float32)
    idx, valid = moe_dispatch_pattern(top_e, n_tok, e_total, cap, 8)
    w_slot = moe_combine_weights(top_e, top_w, n_tok, e_total, cap)
    ref = moe_combine_ref(buf, idx, valid, w_slot, n_tok)
    hw_tok = hw.replace(elem=4 * d)  # every moved element is a d-wide row

    def build_c(strategy):
        g = MoECombineScatter(top_e, top_w, n_tok, e_total, cap, mesh,
                              strategy=strategy, blocksize=n_tok // 8 // 16,
                              shards_per_node=1, hw=hw_tok)
        b = g.shard_expert_buf(buf)
        np.testing.assert_allclose(np.asarray(g(b)), ref, rtol=2e-4,
                                   atol=2e-4)
        return g, (b,), g

    return measured_ladder(
        "table3.scatter.moe_combine", build_c, iters=iters,
        preds=lambda g: select.rank_strategies(g.splan, 1, hw_tok,
                                               direction="put"),
        vol_of=lambda g, s: ladder_volume(g.counts, s, 8, n_tok))


# --------------------------------------------------------------------------
# Table 3e: the fused multi-exchange window — ExchangeSchedule chains vs
# their back-to-back one-shot baselines, with the §5 composition model
# (perfmodel.predict_schedule, eq. 23) predicted-vs-measured
# --------------------------------------------------------------------------

def table3_schedule(smoke=False, iters=50):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import tune
    from repro.core.matrix import spmv_ref_np, spmv_t_ref_np
    from repro.core.spmv import normal_equations_step
    from repro.models.moe import (MoECombineScatter, MoEDispatchGather,
                                  MoELayer, moe_expert_local, random_router)

    mesh = _mesh8()
    print("# table3 schedule: fused ExchangeSchedule windows vs back-to-back"
          " one-shot exchanges, predicted (eq. 23) vs measured")

    # -- moe_layer: dispatch → expert MLP → combine in ONE window --
    n_tok, d = (1 << 12, 8) if smoke else (1 << 14, 32)
    f, k, e_total = 2 * d, 2, 32
    cap = int(1.25 * n_tok * k / e_total)
    rng = np.random.default_rng(7)
    top_e, top_w = random_router(7, n_tok, e_total, k)
    x_host = rng.standard_normal((n_tok, d)).astype(np.float32)
    params = {
        "w1": (rng.standard_normal((e_total, d, f)) * 0.1).astype(np.float32),
        "w2": (rng.standard_normal((e_total, f, d)) * 0.1).astype(np.float32),
    }
    hw_tok = tune.measure_hardware(mesh, "data").replace(elem=4 * d)

    layer = MoELayer(params, top_e, top_w, n_tok, e_total, cap, mesh,
                     strategy="condensed", blocksize=n_tok // 8 // 16,
                     shards_per_node=1, hw=hw_tok)
    x = layer.shard_tokens(x_host)
    t_fused = timeit(layer, x, iters=iters)
    t_pred = layer.predicted_window["total"]

    # back-to-back one-shot baseline: three windows, same rungs, the
    # identical local expert math (moe_expert_local on both paths)
    disp = MoEDispatchGather(top_e, n_tok, e_total, cap, mesh,
                             strategy="condensed",
                             blocksize=n_tok // 8 // 16,
                             shards_per_node=1, hw=hw_tok)
    comb = MoECombineScatter(top_e, top_w, n_tok, e_total, cap, mesh,
                             strategy="condensed",
                             blocksize=n_tok // 8 // 16,
                             shards_per_node=1, hw=hw_tok)
    shard = NamedSharding(mesh, P("data"))
    w1 = jax.device_put(params["w1"], shard)
    w2 = jax.device_put(params["w2"], shard)
    expert = jax.jit(compat.shard_map(
        lambda b, a, c: moe_expert_local(b, a, c),
        mesh=mesh, in_specs=(P("data"),) * 3, out_specs=P("data"),
        check_vma=False))

    def baseline(xx):
        return comb(expert(disp(xx), w1, w2))

    np.testing.assert_array_equal(np.asarray(layer(x)),
                                  np.asarray(baseline(x)))
    t_base = timeit(baseline, x, iters=iters)
    acc = min(t_fused, t_pred) / max(t_fused, t_pred)
    csv_row("table3.schedule.moe_layer.fused", t_fused * 1e6,
            f"predicted_us={t_pred*1e6:.1f} accuracy={acc:.2f} "
            f"vs_baseline={t_fused/t_base:.2f}x "
            f"setup_saved_us={layer.predicted_window['setup_saved']*1e6:.1f}")
    csv_row("table3.schedule.moe_layer.baseline", t_base * 1e6,
            "back_to_back=dispatch+expert+combine (3 windows) "
            f"predicted_sum_us="
            f"{layer.predicted_window['sum_standalone']*1e6:.1f}")

    # -- normal_eq: z = MᵀM x (forward gather + transposed scatter) --
    n, r_nz = (1 << 14, 16) if smoke else (1 << 17, 16)
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 64,
                              long_range_frac=0.02, seed=1)
    x_host = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    z_ref = spmv_t_ref_np(m, spmv_ref_np(m, x_host))
    hw = tune.measure_hardware(mesh, "data")
    step = normal_equations_step(m, mesh, strategy="condensed",
                                 blocksize=n // 8 // 16, shards_per_node=1,
                                 hw=hw)
    x = step.shard_vector(x_host)
    np.testing.assert_allclose(np.asarray(step(x)), z_ref, rtol=2e-3,
                               atol=2e-3)
    t_fused = timeit(step, x, iters=iters)
    t_pred = step.predicted_window["total"]

    fwd = DistributedSpMV(m, mesh, strategy="condensed",
                          blocksize=n // 8 // 16, shards_per_node=1, hw=hw)
    bwd = DistributedSpMV(m, mesh, strategy="condensed",
                          blocksize=n // 8 // 16, shards_per_node=1,
                          transpose=True, hw=hw)

    def ne_baseline(xx):
        return bwd(fwd(xx))

    t_base = timeit(ne_baseline, x, iters=iters)
    acc = min(t_fused, t_pred) / max(t_fused, t_pred)
    csv_row("table3.schedule.normal_eq.fused", t_fused * 1e6,
            f"predicted_us={t_pred*1e6:.1f} accuracy={acc:.2f} "
            f"vs_baseline={t_fused/t_base:.2f}x")
    csv_row("table3.schedule.normal_eq.baseline", t_base * 1e6,
            "back_to_back=forward+transpose (2 windows)")


# --------------------------------------------------------------------------
# Table 3f: per-batch routing — the DynamicPattern tier (repro.comm.dynamic)
# vs the rebuild-every-batch baseline, with the T_plan-inclusive §5 pricing
# (perfmodel.plan_build_time threaded through rank_strategies(plan_cost=))
# --------------------------------------------------------------------------

def table3_dynamic(smoke=False, iters=50):
    import time as _time

    from repro.comm import telemetry
    from repro.core import tune
    from repro.models.moe import DynamicMoELayer, MoELayer, random_router

    n_tok, d = (1 << 12, 8) if smoke else (1 << 14, 32)
    f, k, e_total = 2 * d, 2, 32
    cap = int(1.25 * n_tok * k / e_total)
    n_batches = 4 if smoke else 8
    print(f"# table3 dynamic: per-batch routed MoE — device-derived tables "
          f"vs rebuild-every-batch (tokens={n_tok}, d={d}, "
          f"batches={n_batches})")
    mesh = _mesh8()
    rng = np.random.default_rng(9)
    params = {
        "w1": (rng.standard_normal((e_total, d, f)) * 0.1).astype(np.float32),
        "w2": (rng.standard_normal((e_total, f, d)) * 0.1).astype(np.float32),
    }
    routings = [random_router(100 + i, n_tok, e_total, k)
                for i in range(n_batches)]
    x_host = rng.standard_normal((n_tok, d)).astype(np.float32)
    hw_tok = tune.measure_hardware(mesh, "data").replace(elem=4 * d)
    bs = n_tok // 8 // 16

    # -- dynamic: one envelope plan, per-batch in-jit table derivation --
    layer = DynamicMoELayer(params, routings[0][0], n_tok, e_total, cap,
                            mesh, strategy="auto", blocksize=bs,
                            shards_per_node=1, hw=hw_tok)
    x = layer.shard_tokens(x_host)
    jax.block_until_ready(layer(x, *routings[0]))   # warmup: trace once
    snap = telemetry.stats.snapshot()

    def run_all():
        out = None
        for te, tw in routings:
            out = layer(x, te, tw)
        return out

    t_dyn = timeit(run_all, iters=max(3, iters // 10), warmup=1) / n_batches
    tel = telemetry.stats.since(snap)
    assert tel["host-build"] == 0, (
        f"dynamic path must be host-free after warmup, saw {tel}")
    gs, ss = layer.strategies["dispatch"], layer.strategies["combine"]
    # each rung prediction already carries plan_cost (the device-derive
    # T_plan); ONE derivation serves both directions, so count it once
    pred_dyn = (layer.predicted_times["dispatch"][gs]
                + layer.predicted_times["combine"][ss] - layer.plan_time)
    acc = min(t_dyn, pred_dyn) / max(t_dyn, pred_dyn)
    csv_row("table3.dynamic.per_batch", t_dyn * 1e6,
            f"strategies={gs}+{ss} predicted_us={pred_dyn*1e6:.1f} "
            f"accuracy={acc:.2f} t_plan_us={layer.plan_time*1e6:.2f} "
            f"telemetry=" + "/".join(f"{s}:{c}" for s, c in tel.items()))

    # -- baseline: honest host rebuild (plan + trace + compile) per batch --
    t_host_plan = pm.plan_build_time(e_total * cap, 1, hw_tok,
                                     source="host-build")
    y_dyn0 = np.asarray(layer(x, *routings[0]))
    rebuild_times = []
    for te, tw in routings[:min(n_batches, 3)]:
        t0 = _time.perf_counter()
        base = MoELayer(params, te, tw, n_tok, e_total, cap, mesh,
                        strategy="condensed", blocksize=bs,
                        shards_per_node=1, hw=hw_tok, use_plan_cache=False)
        y = jax.block_until_ready(base(base.shard_tokens(x_host)))
        rebuild_times.append(_time.perf_counter() - t0)
        if (te, tw) is routings[0]:
            np.testing.assert_allclose(y_dyn0, np.asarray(y), rtol=2e-4,
                                       atol=2e-4)
    t_rebuild = float(np.median(rebuild_times))
    # static per-step cost once a fresh host plan exists (no T_plan term),
    # and the rebuild's one-time cost on top — the break-even question:
    # after how many reuses of ONE routing does a host rebuild beat the
    # per-batch derivation?  (perfmodel.replan_break_even_steps)
    pred_static = (layer.predicted_times["dispatch"][gs]
                   + layer.predicted_times["combine"][ss]
                   - 2 * layer.plan_time)
    pred_rebuild = pred_static + t_host_plan
    be = pm.replan_break_even_steps(t_host_plan, t_dyn, pred_static)
    csv_row("table3.dynamic.rebuild_baseline", t_rebuild * 1e6,
            f"predicted_us={pred_rebuild*1e6:.1f} (excl. trace+compile) "
            f"t_plan_host_us={t_host_plan*1e6:.2f} "
            f"vs_dynamic={t_rebuild/t_dyn:.1f}x "
            f"break_even_steps={be:.0f}")
    assert t_dyn < t_rebuild, (
        f"per-batch dynamic ({t_dyn:.4f}s) must beat rebuild-every-batch "
        f"({t_rebuild:.4f}s)")
    return {"dynamic": t_dyn, "rebuild": t_rebuild}


# --------------------------------------------------------------------------
# Table serve: the continuous-batching engine (repro.serve) + §5-priced MoE
# decode exchanges.  Engine rows report tokens/s and p50/p99 per-token
# latency with the steady-state zero-host-build telemetry assertion; the
# decode_step rows compare a measured DynamicMoELayer step against
# perfmodel.predict_decode_step (the eqs. 12δ–15δ latency floors) at decode
# batch sizes {1, 8, 32}, each gated by perfmodel.error_budget.
# --------------------------------------------------------------------------

def table_serve(smoke=False, iters=30):
    import dataclasses as _dc

    from repro.comm import select
    from repro.configs.registry import get_config
    from repro.core import tune
    from repro.models import moe as M
    from repro.models.transformer import Model, RunCtx
    from repro.serve import Request, ServeEngine

    mesh = _mesh8()
    slots = 8
    cfg = get_config("mixtral-8x22b", reduced=True)
    # serving shape: experts divide the 8-way mesh, full attention (SWA
    # would clamp the ring cache), no-drop capacity so the engine matches
    # the batch-loop baseline bit-exactly (tests/test_serve.py)
    cfg = _dc.replace(cfg, num_experts=8, swa_window=0,
                      capacity_factor=8.0 / cfg.experts_per_token)
    ctx = RunCtx(remat="none", act_dtype=jnp.float32)
    model = Model(cfg, ctx)
    params = model.init_params(jax.random.PRNGKey(0))
    print(f"# table_serve: continuous batching on {cfg.name} reduced "
          f"(slots={slots}, experts={cfg.num_experts}, "
          f"layers={cfg.num_layers})")

    d = cfg.d_model
    hw_tok = tune.measure_hardware(mesh, "data").replace(elem=4 * d)
    cap = M.moe_capacity(slots, cfg)
    moe_p = params["layers"]["moe"]
    weights = {"w1": np.asarray(moe_p["w1"][0]),
               "w2": np.asarray(moe_p["w2"][0])}
    if "w3" in moe_p:
        weights["w3"] = np.asarray(moe_p["w3"][0])
    tmpl_e, _ = M.random_router(0, slots, cfg.num_experts,
                                cfg.experts_per_token)
    layer = M.DynamicMoELayer(weights, tmpl_e, slots, cfg.num_experts, cap,
                              mesh, act=cfg.act, strategy="auto",
                              shards_per_node=1, hw=hw_tok, decode=True)

    engine = ServeEngine(model, params, num_slots=slots, cache_len=48,
                         prefill_chunk=8, moe_layer=layer,
                         cache_dtype=jnp.float32)
    rng = np.random.default_rng(0)

    def submit(n, tag, gen):
        for i in range(n):
            engine.submit(Request(
                id=f"{tag}{i}",
                prompt=rng.integers(0, cfg.vocab_size, (16,)).tolist(),
                max_new_tokens=gen, arrival_time=float(i // 4)))

    submit(slots, "warm", 4)        # warmup: prefill/insert/decode traces
    engine.run()
    rep0 = engine.report()          # warmup watermark (compile ticks)
    snap = engine.snapshot()
    n_req, gen = (8, 6) if smoke else (24, 16)
    submit(n_req, "req", gen)
    rep = engine.run()
    # acceptance: zero host plan builds across the steady-state run
    delta = engine.assert_steady_state(snap)
    # steady-state slices: everything after the warmup watermark, so the
    # latency percentiles describe serving, not tracing/compilation
    tick_ss = rep.tick_seconds[rep0.ticks:]
    tok_ss = rep.token_seconds[len(rep0.token_seconds):]
    csv_row("table_serve.engine.decode", float(np.mean(tick_ss)) * 1e6,
            f"tokens_per_s={len(tok_ss)/sum(tick_ss):.1f} "
            f"p50_us={np.percentile(tok_ss, 50)*1e6:.0f} "
            f"p99_us={np.percentile(tok_ss, 99)*1e6:.0f} "
            f"requests={n_req} ticks={len(tick_ss)} "
            "telemetry=" + "/".join(f"{k}:{v}" for k, v in delta.items()))
    ttft = sorted(t for rid, t in rep.ttft_seconds.items()
                  if rid.startswith("req"))
    csv_row("table_serve.engine.prefill", float(np.mean(ttft)) * 1e6,
            f"ttft_p50_us={np.median(ttft)*1e6:.0f} requests={len(ttft)} "
            f"chunks={delta['prefill_chunks']}")

    # -- per-decode-step §5 pricing at decode batch sizes {1, 8, 32} --
    for b in (1, 8, 32):
        lanes = max(b, 8)           # DynamicMoELayer needs lanes % 8 == 0
        cap_b = M.moe_capacity(lanes, cfg)
        te, tw = M.random_router(b, lanes, cfg.num_experts,
                                 cfg.experts_per_token)
        lb = M.DynamicMoELayer(weights, te, lanes, cfg.num_experts, cap_b,
                               mesh, act=cfg.act, strategy="auto",
                               shards_per_node=1, hw=hw_tok, decode=True)
        x = lb.shard_tokens(
            rng.standard_normal((lanes, d)).astype(np.float32))
        jax.block_until_ready(lb(x, te, tw))
        t_meas = timeit(lb, x, te, tw, iters=(5 if smoke else iters))
        gs, ss = lb.strategies["dispatch"], lb.strategies["combine"]
        w_g = select.workload_from_plan(lb.gather.plan, 1,
                                        materialize="full")
        w_s = select.workload_from_plan(lb.scatter.splan, 1)
        pred = pm.predict_decode_step(
            [("dispatch", "get", w_g, gs), ("combine", "put", w_s, ss)],
            hw_tok)
        t_pred = pred["total"] + lb.plan_time
        err = pm.model_error(t_meas, t_pred)
        budget = pm.error_budget({"rung": gs, "workload": "moe_decode",
                                  "dtype": "float32", "mesh": [8]})
        ok = err <= budget
        pad = "" if b == lanes else f" (b={b} padded to {lanes} lanes)"
        csv_row(f"table_serve.decode_step.b{b}", t_meas * 1e6,
                f"lanes={lanes}{pad} strategies={gs}+{ss} "
                f"predicted_us={t_pred*1e6:.1f} model_error={err:.3f} "
                f"budget={budget:.0f} within_budget={ok} latency_bound="
                + (",".join(pred["latency_bound"]) or "none"))
        assert ok, (f"decode-step model error {err:.2f} exceeds budget "
                    f"{budget:.0f} at b={b}")


# --------------------------------------------------------------------------
# Table 4: measured vs predicted with calibrated host parameters
# --------------------------------------------------------------------------

def table4_model_validation(n=1 << 17, r_nz=16):
    print("# table4: measured vs predicted (calibrated host params)")
    hw = calibrate_host()
    csv_row("table4.calib.w_private", 0,
            f"{hw.w_private/1e9:.2f}GB/s")
    csv_row("table4.calib.w_remote", 0, f"{hw.w_remote/1e9:.2f}GB/s")
    csv_row("table4.calib.tau", hw.tau * 1e6, "us")

    mesh = _mesh8()
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 64,
                              long_range_frac=0.02, seed=1)
    x_host = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    # each host device is its own "node": every inter-device message pays
    # tau (calibration note in benchmarks.common.calibrate_host)
    topo = Topology(8, 1)
    bs = n // 8 // 16
    plan = get_comm_plan(m.cols, n, 8, blocksize=bs, topology=topo)
    w = pm.SpmvWorkload(n=n, r_nz=r_nz, p=8, blocksize=bs, topology=topo,
                        counts=plan.counts)
    preds = pm.predict_all(w, hw)
    name_map = {"replicate": "replicate", "blockwise": "v2_blockwise",
                "condensed": "v3_condensed", "overlap": "overlap"}
    for strategy in ("replicate", "blockwise", "condensed", "overlap"):
        eng = DistributedSpMV(m, mesh, strategy=strategy, blocksize=bs,
                              shards_per_node=1)
        x = eng.shard_vector(x_host)
        t_meas = timeit(eng, x, iters=30)
        t_pred = preds[name_map[strategy]]
        acc = min(t_meas, t_pred) / max(t_meas, t_pred)
        csv_row(f"table4.{strategy}", t_meas * 1e6,
                f"predicted_us={t_pred*1e6:.1f} accuracy={acc:.2f}")


# --------------------------------------------------------------------------
# Fig 2: per-shard communication volumes per strategy and BLOCKSIZE sweep
# --------------------------------------------------------------------------

def fig2_volumes(n=1 << 16, r_nz=16, p=8):
    print("# fig2: per-shard comm volumes (elements) + BLOCKSIZE sweep; "
          "blockwise volume excludes own-shard copies for comparability")
    m = make_mesh_like_matrix(n, r_nz, locality_window=n // 128,
                              long_range_frac=0.002, seed=2)
    shard = n // p
    for bs in (shard // 64, shard // 16, shard // 4, shard):
        plan = get_comm_plan(m.cols, n, p, blocksize=bs,
                               topology=Topology(p, 4))
        c = plan.counts
        per_shard_cond = (c.s_local_in + c.s_remote_in)
        blockwise_foreign = c.total_blockwise_volume() - p * shard
        csv_row(f"fig2.blocksize_{bs}", 0,
                f"condensed_total={c.total_condensed_volume()} "
                f"blockwise_foreign={blockwise_foreign} "
                f"replicate_total={p*(n-shard)} "
                f"cond_max_shard={int(per_shard_cond.max())} "
                f"cond_min_shard={int(per_shard_cond.min())} "
                f"padded_condensed={c.padded_condensed_per_shard*p}")


# --------------------------------------------------------------------------
# Table 5: heat2d measured vs predicted
# --------------------------------------------------------------------------

def table5_heat2d(big_m=512, big_n=1024, steps=100, smoke=False):
    if smoke:
        big_m, big_n, steps = 128, 256, 20
    print(f"# table5: heat2d {big_m}x{big_n}, {steps} steps, 2x4 device grid")
    hw = calibrate_host(elem_bytes=4)
    mesh = compat.make_mesh((2, 4), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))

    # each host device modeled as its own node (see table4 note): every
    # halo message pays the calibrated per-message tau
    w = pm.Heat2DWorkload(big_m=big_m, big_n=big_n, mprocs=2, nprocs=4,
                          topology=Topology(8, 1))

    # the eqs.(19)-(21) halo model prices the paper's in-place O(halo)
    # unpack — exactly what materialize="dest" runs; the "full" mode
    # additionally assembles the length-n x_copy each step (docs/
    # perf_model.md eq. 15'), priced by the model's materialize knob
    t_base = None
    for mode in ("dest", "full"):
        h = Heat2D(mesh, big_m, big_n, coef=0.1, materialize=mode)
        phi = h.init_field(0)
        t = timeit(lambda p: h.run(p, steps), phi, iters=3, warmup=1)
        pred_mode = pm.predict_heat2d(w, hw, steps=steps, materialize=mode)
        t_pred = pred_mode["halo"] + pred_mode["comp"]
        acc = min(t, t_pred) / max(t, t_pred)
        name = "table5.heat2d" if mode == "dest" else "table5.heat2d_full"
        csv_row(name, t * 1e6,
                f"unpack={mode} predicted_us={t_pred*1e6:.0f} "
                f"(halo={pred_mode['halo']*1e6:.0f} "
                f"comp={pred_mode['comp']*1e6:.0f}) "
                f"accuracy={acc:.2f}")
        if mode == "dest":
            t_base = t

    h = Heat2D(mesh, big_m, big_n, coef=0.1, overlap=True)
    phi = h.init_field(0)
    t = timeit(lambda p: h.run(p, steps), phi, iters=3, warmup=1)
    # the full-window overlap prediction incl. the edge-ring recompute term
    # (the refinement strategy="auto" ranks overlap vs condensed with)
    win = pm.predict_heat2d_window(w, hw, steps=steps)
    acc = min(t, win["overlap"]) / max(t, win["overlap"])
    csv_row("table5.heat2d_overlap", t * 1e6,
            f"predicted_us={win['overlap']*1e6:.0f} accuracy={acc:.2f} "
            f"vs_base={t/t_base:.2f}x "
            "(interior/edge split so halo exchange can overlap)")

    table5_scan(smoke=smoke)


def table5_scan(smoke=False):
    """Per-iteration scan-window rows (eq. 23′): the scanned ``Heat2D.run``
    loop and the CG solver, each against the per-step re-dispatch baseline
    over the same single-step window and against the steady-state model."""
    big_m, big_n, steps = (128, 256, 20) if smoke else (512, 1024, 100)
    print(f"# table5.scan: persistent windows, {steps}-step loops")
    hw = calibrate_host(elem_bytes=4)
    mesh = compat.make_mesh((2, 4), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    w = pm.Heat2DWorkload(big_m=big_m, big_n=big_n, mprocs=2, nprocs=4,
                          topology=Topology(8, 1))

    # the scanned double-buffered overlap loop: Heat2D.run is ONE window
    # around lax.scan; the baseline re-dispatches the identical one-step
    # window (h.schedule) from a Python loop — same plan, same rung, the
    # only difference is where the loop runs
    h = Heat2D(mesh, big_m, big_n, coef=0.1, overlap=True, hw=hw,
               n_steps_hint=steps)
    phi = h.init_field(0)
    t_scan = timeit(lambda p_: h.run(p_, steps), phi, iters=3, warmup=1)

    def redispatch(p_):
        x = p_
        for _ in range(steps):
            x = h.schedule(x)
        return x

    t_loop = timeit(redispatch, phi, iters=3, warmup=1)
    scn = pm.predict_heat2d_scan(w, hw, steps)
    pred_iter = scn["per_iter"]["overlap"]
    meas_iter = t_scan / steps
    acc = min(meas_iter, pred_iter) / max(meas_iter, pred_iter)
    csv_row("table5.scan.heat2d", meas_iter * 1e6,
            f"per_iter steps={steps} predicted_us={pred_iter*1e6:.0f} "
            f"accuracy={acc:.2f} vs_redispatch={t_loop/t_scan:.2f}x "
            "(double-buffered halos, one persistent window)")

    # CG on the fused z = MtM p window: the scan carries (x, r, p); the
    # baseline drives the same fused product window per iteration with the
    # recurrence on the host
    from repro.core.solvers import ConjugateGradient
    from repro.core.spmv import normal_equations_step

    mesh1d = _mesh8()
    n, r_nz = (1 << 12, 8) if smoke else (1 << 14, 16)
    k = 20 if smoke else 50
    m = make_mesh_like_matrix(n, r_nz, seed=5)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n).astype(np.float32)
    cg = ConjugateGradient(m, mesh1d, strategy="condensed", hw=hw,
                           n_steps_hint=k)
    carries = cg.carries(b)
    t_scan = timeit(lambda *c: cg.schedule(*c, n_steps=k), *carries,
                    iters=3, warmup=1)

    step = normal_equations_step(m, mesh1d, strategy="condensed", hw=hw)

    def cg_redispatch(x, r, pv):
        for _ in range(k):
            z = step(pv)
            rs, pz = jnp.vdot(r, r), jnp.vdot(pv, z)
            alpha = jnp.where(pz != 0, rs / jnp.where(pz != 0, pz, 1), 0)
            x = x + alpha * pv
            r2 = r - alpha * z
            beta = jnp.where(rs != 0,
                             jnp.vdot(r2, r2) / jnp.where(rs != 0, rs, 1), 0)
            pv, r = r2 + beta * pv, r2
        return x

    t_loop = timeit(cg_redispatch, *carries, iters=3, warmup=1)
    pred = cg.predicted_loop(k)
    meas_iter = t_scan / k
    pred_iter = pred["per_iter"] if pred is not None else meas_iter
    acc = min(meas_iter, pred_iter) / max(meas_iter, pred_iter)
    csv_row("table5.scan.cg", meas_iter * 1e6,
            f"per_iter iters={k} n={n} predicted_us={pred_iter*1e6:.0f} "
            f"accuracy={acc:.2f} vs_redispatch={t_loop/t_scan:.2f}x "
            "(CGNR, one fused MtM window per iteration)")


# --------------------------------------------------------------------------
# Roofline report from dry-run artifacts
# --------------------------------------------------------------------------

def roofline_report(art_dir=None):
    import glob
    import os
    if art_dir is None:
        art_dir = ("experiments/dryrun_optimized"
                   if os.path.isdir("experiments/dryrun_optimized")
                   else "experiments/dryrun")
    baseline_dir = "experiments/dryrun"
    print(f"# roofline: per (arch x shape x mesh) from {art_dir} "
          "(baseline deltas vs experiments/dryrun where available)")
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        art = json.load(open(path))
        if art.get("skipped"):
            csv_row(f"roofline.{art['name']}", 0, "SKIP:" +
                    art["reason"][:60])
            continue
        rows.append(art)
        delta = ""
        bpath = os.path.join(baseline_dir, os.path.basename(path))
        if baseline_dir != art_dir and os.path.exists(bpath):
            base = json.load(open(bpath))
            if not base.get("skipped") and base.get("roofline_fraction"):
                delta = (" frac_gain="
                         f"{art['roofline_fraction']/base['roofline_fraction']:.2f}x")
        csv_row(
            f"roofline.{art['name']}", art["step_time_bound_s"] * 1e6,
            f"dominant={art['dominant']} "
            f"compute={art['compute_term_s']:.3e} "
            f"memory={art['memory_term_s']:.3e} "
            f"collective={art['collective_term_s']:.3e} "
            f"useful={art['useful_flops_ratio']:.2f} "
            f"roofline_frac={art['roofline_fraction']:.3f} "
            f"peakGiB={art['memory_analysis']['peak_bytes_per_device']/2**30:.1f}"
            + delta)
    if rows:
        worst = min(rows, key=lambda a: a["roofline_fraction"])
        coll = max(rows, key=lambda a: a["collective_term_s"])
        csv_row("roofline.summary", 0,
                f"cells={len(rows)} worst_fraction={worst['name']} "
                f"most_collective_bound={coll['name']}")
