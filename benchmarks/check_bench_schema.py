"""Schema gate for the BENCH_*.json perf-trajectory artifacts.

CI runs this between the smoke bench and the artifact upload so a
malformed artifact fails the job instead of silently poisoning the
per-PR trajectory.  Checked, per file:

* top level: ``{"bench": str, "smoke": bool, "rows": list}``;
* every row: ``{"name": str, "us_per_call": number >= 0, "derived": str}``
  with a non-empty dotted name;
* ``BENCH_table3.json`` additionally carries the plan-acquisition
  ``telemetry`` block (``repro.comm.telemetry.PlanTelemetry.snapshot()``):
  ``sources`` covering exactly the five ``PLAN_SOURCES``, per-source
  ``build_seconds``, and a ``total`` consistent with the source counts —
  with at least one hot-path acquisition recorded (the dynamic rows ran);
* table3 must include the ``table3.dynamic.*`` rows AND the
  ``table3.kernel.*`` rows (the fused Pallas exchange path, each carrying
  ``predicted_us=`` and ``vs_jnp=`` in ``derived``);
* table5 must include the ``table5.scan.*`` rows (the persistent
  scan-window loops — heat2d + CG — actually ran);
* ``BENCH_serve.json`` (the continuous-batching serving bench) must carry
  a ``table_serve.engine.*`` row with ``tokens_per_s=`` and one with
  ``p99_us=`` in ``derived``, plus ``table_serve.decode_step.*`` rows each
  carrying ``predicted_us=``, ``model_error=`` and ``within_budget=`` (the
  §5 decode-regime predictions the serve bench gates on);
* ``BENCH_matrix.json`` carries the per-cell ``cells`` records of the
  config-driven benchmark matrix: workload/rung/dtype strings, a
  positive-int mesh shape, non-negative measured/predicted/error numbers,
  a positive ``budget``, a ``within_budget`` flag CONSISTENT with
  ``model_error <= budget`` (the gate's verdict can't contradict its
  inputs), and a ``plan_source`` drawn from ``PLAN_SOURCES``.

Usage:  python -m benchmarks.check_bench_schema BENCH_table3.json ...
Exits nonzero listing every violation found.
"""
from __future__ import annotations

import json
import sys

# mirrors repro.comm.telemetry.PLAN_SOURCES without importing jax at
# check time (the gate must run in a bare interpreter)
PLAN_SOURCES = ("memory-hit", "disk-hit", "bucket-reuse", "device-derive",
                "host-build")


def check_rows(doc: dict, errors: list, path: str) -> None:
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        errors.append(f"{path}: 'rows' must be a non-empty list")
        return
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{path}: rows[{i}] is not an object")
            continue
        name = row.get("name")
        if not isinstance(name, str) or "." not in name:
            errors.append(f"{path}: rows[{i}].name must be a dotted string, "
                          f"got {name!r}")
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or us < 0:
            errors.append(f"{path}: rows[{i}].us_per_call must be a "
                          f"non-negative number, got {us!r}")
        if not isinstance(row.get("derived"), str):
            errors.append(f"{path}: rows[{i}].derived must be a string")


def check_telemetry(doc: dict, errors: list, path: str) -> None:
    tel = doc.get("telemetry")
    if not isinstance(tel, dict):
        errors.append(f"{path}: table3 must carry a 'telemetry' block "
                      "(plan-acquisition counters)")
        return
    sources = tel.get("sources")
    if not isinstance(sources, dict) or set(sources) != set(PLAN_SOURCES):
        errors.append(f"{path}: telemetry.sources must cover exactly "
                      f"{PLAN_SOURCES}, got "
                      f"{sorted(sources) if isinstance(sources, dict) else sources!r}")
        return
    if not all(isinstance(v, int) and v >= 0 for v in sources.values()):
        errors.append(f"{path}: telemetry.sources counts must be "
                      f"non-negative ints, got {sources}")
    build = tel.get("build_seconds")
    if not isinstance(build, dict) or not set(build) <= set(PLAN_SOURCES):
        errors.append(f"{path}: telemetry.build_seconds must map known "
                      f"sources to seconds, got {build!r}")
    elif not all(isinstance(v, (int, float)) and v >= 0
                 for v in build.values()):
        errors.append(f"{path}: telemetry.build_seconds values must be "
                      f"non-negative numbers, got {build}")
    total = tel.get("total")
    if total != sum(sources.values()):
        errors.append(f"{path}: telemetry.total ({total!r}) != sum of "
                      f"source counts ({sum(sources.values())})")
    hot = sum(v for s, v in sources.items() if s != "host-build")
    if hot <= 0:
        errors.append(f"{path}: telemetry records no hot-path acquisition "
                      "(memory/disk/bucket/device) — the dynamic rows "
                      "cannot have run")


def check_matrix_cells(doc: dict, errors: list, path: str) -> None:
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        errors.append(f"{path}: matrix must carry a non-empty 'cells' list")
        return
    for i, cell in enumerate(cells):
        if not isinstance(cell, dict):
            errors.append(f"{path}: cells[{i}] is not an object")
            continue
        for key in ("workload", "rung", "dtype", "resolved"):
            if not isinstance(cell.get(key), str) or not cell.get(key):
                errors.append(f"{path}: cells[{i}].{key} must be a non-empty "
                              f"string, got {cell.get(key)!r}")
        mesh = cell.get("mesh")
        if (not isinstance(mesh, list) or not mesh
                or not all(isinstance(a, int) and a > 0 for a in mesh)):
            errors.append(f"{path}: cells[{i}].mesh must be a list of "
                          f"positive ints, got {mesh!r}")
        for key in ("measured_us", "predicted_us", "model_error"):
            v = cell.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"{path}: cells[{i}].{key} must be a "
                              f"non-negative number, got {v!r}")
        budget = cell.get("budget")
        if not isinstance(budget, (int, float)) or budget <= 0:
            errors.append(f"{path}: cells[{i}].budget must be a positive "
                          f"number, got {budget!r}")
        within = cell.get("within_budget")
        if not isinstance(within, bool):
            errors.append(f"{path}: cells[{i}].within_budget must be a "
                          f"boolean, got {within!r}")
        elif (isinstance(budget, (int, float)) and budget > 0
              and isinstance(cell.get("model_error"), (int, float))
              and within != (cell["model_error"] <= budget)):
            errors.append(f"{path}: cells[{i}].within_budget={within} "
                          f"contradicts model_error={cell['model_error']} "
                          f"vs budget={budget}")
        if cell.get("plan_source") not in PLAN_SOURCES:
            errors.append(f"{path}: cells[{i}].plan_source must be one of "
                          f"{PLAN_SOURCES}, got {cell.get('plan_source')!r}")


def check_serve_rows(doc: dict, errors: list, path: str) -> None:
    rows = [r for r in doc.get("rows", []) if isinstance(r, dict)]
    engine = [r for r in rows
              if str(r.get("name", "")).startswith("table_serve.engine.")]
    if not any("tokens_per_s=" in str(r.get("derived", "")) for r in engine):
        errors.append(f"{path}: serve needs a table_serve.engine.* row "
                      "carrying tokens_per_s= (throughput)")
    if not any("p99_us=" in str(r.get("derived", "")) for r in engine):
        errors.append(f"{path}: serve needs a table_serve.engine.* row "
                      "carrying p99_us= (tail per-token latency)")
    steps = [r for r in rows
             if str(r.get("name", "")).startswith("table_serve.decode_step.")]
    if not steps:
        errors.append(f"{path}: missing table_serve.decode_step.* rows "
                      "(§5 decode-regime predicted-vs-measured)")
    for r in steps:
        derived = str(r.get("derived", ""))
        missing = [k for k in ("predicted_us=", "model_error=",
                               "within_budget=") if k not in derived]
        if missing:
            errors.append(f"{path}: {r.get('name')}: decode_step rows must "
                          f"carry {', '.join(missing)} in 'derived', got "
                          f"{derived!r}")


def check_file(path: str) -> list:
    errors: list = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level must be an object"]
    bench = doc.get("bench")
    if not isinstance(bench, str) or not bench:
        errors.append(f"{path}: 'bench' must be a non-empty string")
    if not isinstance(doc.get("smoke"), bool):
        errors.append(f"{path}: 'smoke' must be a boolean")
    check_rows(doc, errors, path)
    names = {r.get("name", "") for r in doc.get("rows", [])
             if isinstance(r, dict)}
    if bench == "table3":
        check_telemetry(doc, errors, path)
        if not any(n.startswith("table3.dynamic.") for n in names):
            errors.append(f"{path}: missing table3.dynamic.* rows "
                          "(per-batch routed MoE bench)")
        kernel_rows = [r for r in doc.get("rows", [])
                       if isinstance(r, dict) and str(r.get("name", ""))
                       .startswith("table3.kernel.")]
        if not kernel_rows:
            errors.append(f"{path}: missing table3.kernel.* rows "
                          "(fused Pallas exchange-path bench)")
        for r in kernel_rows:
            derived = r.get("derived", "")
            if ("predicted_us=" not in derived
                    or "vs_jnp=" not in derived):
                errors.append(
                    f"{path}: {r.get('name')}: kernel rows must carry "
                    "predicted_us= and vs_jnp= in 'derived', got "
                    f"{derived!r}")
    if bench == "table5":
        if not any(n.startswith("table5.scan.") for n in names):
            errors.append(f"{path}: missing table5.scan.* rows "
                          "(persistent scan-window loops)")
    if bench == "serve":
        check_serve_rows(doc, errors, path)
    if bench == "matrix":
        check_matrix_cells(doc, errors, path)
    return errors


def main(argv: list) -> int:
    if not argv:
        print("usage: python -m benchmarks.check_bench_schema "
              "BENCH_table3.json [...]", file=sys.stderr)
        return 2
    failures = []
    for path in argv:
        errs = check_file(path)
        if errs:
            failures.extend(errs)
        else:
            print(f"OK {path}")
    for e in failures:
        print(f"SCHEMA ERROR {e}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
