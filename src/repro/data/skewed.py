"""Power-law-skewed sparse access generators (the adversarial column mix).

``make_mesh_like_matrix`` draws columns from a locality band plus a uniform
long-range tail — kind to the blocksize model, because every remote shard is
touched about equally and the eq.-11 sweep sees a flat volume landscape.
Real irregular workloads are not flat: graph adjacency, trained MoE routers
and contact lists concentrate accesses on a few *hub* elements with a
power-law (Zipf) popularity tail.  Under that skew the needed-block counts
collapse onto the hubs' shards, so the BLOCKSIZE dial and the strategy
ladder both face a much sharper trade-off — exactly the regime the
benchmark matrix's ``spmv_skewed`` axis entry gates model error on.

Deterministic in ``seed`` (same contract as ``make_mesh_like_matrix``).
"""
from __future__ import annotations

import numpy as np

from repro.core.matrix import EllpackMatrix

__all__ = ["zipf_column_weights", "make_powerlaw_matrix", "skew_summary"]


def zipf_column_weights(n: int, alpha: float = 1.1, *,
                        seed: int = 0) -> np.ndarray:
    """Normalized Zipf popularity over ``n`` columns, hubs scattered.

    Rank k gets weight 1/k^alpha; ranks are then assigned to column ids by
    a seeded permutation so the hubs do NOT all live on shard 0 (which
    would make the skew trivially local for one lucky device).
    """
    assert n > 0 and alpha >= 0.0
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** alpha
    w /= w.sum()
    perm = np.random.default_rng(seed).permutation(n)
    out = np.empty(n, np.float64)
    out[perm] = w
    return out


def make_powerlaw_matrix(
    n: int,
    r_nz: int = 16,
    *,
    alpha: float = 1.1,
    local_frac: float = 0.25,
    seed: int = 0,
    dtype=np.float32,
) -> EllpackMatrix:
    """EllPack matrix whose columns follow a Zipf(``alpha``) popularity law.

    Each row keeps a ``local_frac`` fraction of near-diagonal columns (the
    mesh-like residue — rows still touch their own neighborhood) and draws
    the rest from the global hub distribution via inverse-CDF sampling.
    Larger ``alpha`` sharpens the hubs; ``alpha=0`` degrades to uniform.
    """
    assert 0.0 <= local_frac <= 1.0
    rng = np.random.default_rng(seed)
    weights = zipf_column_weights(n, alpha, seed=seed + 1)
    cdf = np.cumsum(weights)
    cdf[-1] = 1.0  # guard the float tail so searchsorted stays in-range

    cols = np.searchsorted(cdf, rng.random((n, r_nz)),
                           side="right").astype(np.int64)
    # the mesh-like residue: a band draw, like make_mesh_like_matrix
    w_band = max(1, n // 256)
    offsets = rng.integers(-w_band, w_band + 1, size=(n, r_nz))
    offsets[offsets == 0] = 1
    band = np.clip(np.arange(n)[:, None] + offsets, 0, n - 1)
    local = rng.random((n, r_nz)) < local_frac
    cols = np.where(local, band, cols)

    vals = rng.standard_normal((n, r_nz)).astype(dtype) / r_nz
    diag = (np.abs(vals).sum(axis=1) + 1.0).astype(dtype)
    return EllpackMatrix(n=n, r_nz=r_nz, diag=diag, vals=vals,
                         cols=cols.astype(np.int32))


def skew_summary(cols: np.ndarray, n: int, p: int) -> dict:
    """How concentrated is this access pattern?  (diagnostic, not a model)

    Returns the fraction of all accesses landing on the hottest 1% of
    columns (``top1pct_frac``) and the max/mean per-shard access ratio
    (``shard_imbalance``) — uniform patterns sit near 0.01 and 1.0.
    """
    cols = np.asarray(cols).ravel()
    counts = np.bincount(cols, minlength=n).astype(np.float64)
    k = max(1, n // 100)
    top = np.sort(counts)[::-1][:k].sum() / counts.sum()
    per_shard = counts.reshape(p, n // p).sum(axis=1)
    return {"top1pct_frac": float(top),
            "shard_imbalance": float(per_shard.max() / per_shard.mean())}
