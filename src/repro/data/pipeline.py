"""Deterministic, resumable synthetic LM data pipeline.

Design goals for 1000+ node runs (DESIGN.md §7):
  * **stateless indexing** — batch contents are a pure function of
    (seed, step), so any worker can regenerate any batch: restart/elastic
    re-shard never replays or skips data;
  * **checkpointable state** == a single integer (the step counter);
  * batches are produced host-side in numpy and placed with the caller's
    sharding (device layout is the runtime's concern, not the pipeline's).

The token stream is a mixture of Zipf-distributed "language-like" ids and
structured spans (repeats), giving non-degenerate loss curves for the
end-to-end examples without shipping a corpus.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLM", "DataState"]


@dataclasses.dataclass
class DataState:
    step: int = 0

    def to_json(self):
        return {"step": self.step}

    @classmethod
    def from_json(cls, d):
        return cls(step=int(d["step"]))


class SyntheticLM:
    """Yields (tokens, labels) of shape (batch, seq_len) int32."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int,
                 *, seed: int = 0, zipf_a: float = 1.3):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.zipf_a = zipf_a

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        """Pure function of (seed, step) — the resumability contract."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        v = self.vocab_size
        raw = rng.zipf(self.zipf_a, size=(self.batch, self.seq_len + 1))
        toks = (raw - 1) % v
        # structured spans: copy a prefix window forward (predictable
        # substructure so models actually reduce loss)
        span = max(2, self.seq_len // 8)
        start = rng.integers(0, max(1, self.seq_len - 2 * span),
                             size=self.batch)
        for b in range(self.batch):
            s = start[b]
            end = min(s + 2 * span, toks.shape[1])
            toks[b, s + span:end] = toks[b, s:s + (end - s - span)]
        toks = toks.astype(np.int32)
        return toks[:, :-1], toks[:, 1:]

    def iterate(self, state: DataState):
        while True:
            yield self.batch_at(state.step)
            state.step += 1
