"""Direction-agnostic exchange core — shared by gather (pull) and scatter
(push).

The paper's machinery is symmetric in direction: the one-time plan, the
strategy rung ladder, the §5 pricing, and the start/compute/finish overlap
protocol all depend only on *which elements cross which (sender, receiver)
boundary*, never on which side initiates the transfer.  ``IrregularExchange``
owns everything that is common to both directions for one
``AccessPattern`` on one mesh:

* mesh / ``SharedVector`` resolution and partitioning checks,
* BLOCKSIZE resolution (fixed or eq.-11 ``"auto"``),
* the cached destination-independent base ``CommPlan``,
* strategy resolution (any rung or ``"auto"`` via ``select.rank_strategies``
  with the subclass's direction — get-models for ``IrregularGather``,
  put-models for ``IrregularScatter``),
* one-per-mesh hardware calibration (memoized module-wide, see
  ``measure_hw``),
* the ``OverlapHandle`` protocol type.

Subclasses implement ``_bind`` to wire the resolved strategy to their
direction's ``shard_map``-local functions (``repro.comm.strategies``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import plan_cache
from repro.comm import select
from repro.comm import strategies as strat
from repro.comm.dynamic import DYNAMIC_STRATEGIES, DynamicPattern
from repro.comm.pattern import AccessPattern
from repro.comm.plan import CommPlan, Topology
from repro.comm.shared import SharedVector, axis_size

__all__ = ["IrregularExchange", "OverlapHandle", "measure_hw",
           "clear_hw_memo"]


# One microbenchmark per (device set, axis) for the life of the process:
# constructing several gathers/scatters on the same mesh must not re-run
# the §5.4 latency/bandwidth calibration each time.  (repro.core.tune keeps
# its own cache too; this memo also skips its import and probe overhead on
# every construction after the first.)
_HW_MEMO: dict[tuple, object] = {}


def _hw_key(mesh, axis_name) -> tuple:
    axis = tuple(axis_name) if isinstance(axis_name, (tuple, list)) \
        else axis_name
    # the axis *size* must participate: the same devices factorized
    # (2, 4) vs (4, 2) calibrate different ring lengths on the same name
    return (tuple(d.id for d in mesh.devices.flat), mesh.axis_names, axis,
            axis_size(mesh, axis_name))


def clear_hw_memo() -> None:
    _HW_MEMO.clear()


def measure_hw(mesh, axis_name):
    """§5.4 hardware parameters for one mesh axis, memoized per
    (mesh devices, axis_name)."""
    key = _hw_key(mesh, axis_name)
    if key not in _HW_MEMO:
        from repro.core import tune
        if isinstance(axis_name, (tuple, list)):
            # multi-axis exchange: calibrate over the whole visible device
            # set (the parameters describe the machine, not the mesh
            # factorization)
            _HW_MEMO[key] = tune.measure_hardware()
        else:
            _HW_MEMO[key] = tune.measure_hardware(mesh, axis_name)
    return _HW_MEMO[key]


@dataclasses.dataclass
class OverlapHandle:
    """An in-flight exchange: the collective has been issued, the landed
    messages are not yet delivered.  Everything computed before ``finish``
    that only reads the local operand runs inside the communication window.

    For a gather, ``finish`` has two materializations:

    * ``materialize="full"`` — assemble the classic device-private
      ``x_copy`` (length >= n, indexable with global indices);
    * ``materialize="dest"`` — requires the gather to own a ``Destination``:
      scatter the landed recv buffer straight into the consumer's named
      slots and return ``{name: (slot_shape..., feat...) array}``.  No
      full-length intermediate is built — O(slots + recv) work.

    The default is ``"dest"`` when the gather was constructed with a
    ``Destination``, else ``"full"``.

    For a scatter (push), ``finish`` takes no options: it runs the
    own-accumulate (no dependency on the collective, so it overlaps) and
    combines the landed foreign contributions into the owned slice.
    """

    x_local: jax.Array
    _finish: Callable[..., jax.Array]

    def finish(self, *, extra_slots: int = 0, copy_own: bool = True,
               materialize: str | None = None):
        """Deliver the landed messages (see class docstring for modes).

        ``extra_slots`` (gather, full mode): number of guaranteed-zero
        slots appended after the recv dump — x_copy[n+1 .. n+extra_slots]
        read as 0 for any strategy, so consumers can point padding indices
        there.  ``copy_own=False`` (gather, full mode) skips the eq.-14
        own-shard memcpy for consumers that read their own shard from
        ``x_local`` directly.
        """
        return self._finish(extra_slots=extra_slots, copy_own=copy_own,
                            materialize=materialize)


class IrregularExchange:
    """Plan + strategy + device state for one ``AccessPattern`` over one
    mesh axis (or tuple of axes), in one direction.

    ``direction`` is a class attribute: ``"get"`` (gather — accessors pull
    the elements they read) or ``"put"`` (scatter — accessors push
    contributions to the elements they write); it selects which §5 model
    family prices ``strategy="auto"``.
    """

    direction = "get"

    def __init__(
        self,
        pattern: AccessPattern,
        where: jax.sharding.Mesh | SharedVector,
        *,
        axis_name: str | tuple = "data",
        strategy: str = "auto",
        blocksize: int | str | None = None,
        shards_per_node: int | None = None,
        topology: Topology | None = None,
        hw=None,
        candidates=None,
        use_plan_cache: bool = True,
        base_plan: CommPlan | None = None,
        scan_steps: int | None = None,
        plan_cost: float = 0.0,
        use_kernel: bool = False,
        decode: bool = False,
    ):
        # ``use_kernel`` swaps the jnp pack/unpack around the collective for
        # the fused Pallas kernels (repro.kernels), bit-identical on every
        # rung; the §5 ranking prices the kernelized compute terms so
        # strategy="auto" stays honest either way.  ``decode`` prices the
        # rungs for a token-by-token serving step instead (the eqs. 12δ–15δ
        # α/latency floors via predict_decode_exchange) — at decode batch
        # sizes the per-message τ terms decide the ladder, not the volumes
        self.use_kernel = use_kernel
        self.decode = decode
        if isinstance(where, SharedVector):
            assert where.n == pattern.n, (where.n, pattern.n)
            mesh = where.mesh
            axis_name = where.axis_name
            topology = topology or where.topology
        else:
            mesh = where
        valid = strat.STRATEGIES + ("auto",)
        if strategy not in valid:
            raise ValueError(f"strategy must be one of {valid}")
        # a DynamicPattern duck-types the AccessPattern surface (indices /
        # n / m / r come from its template) but switches plan resolution to
        # the bucketed envelope tier and restricts the rung ladder to the
        # strategies whose executor tables comm.dynamic can re-derive
        # per batch in-jit
        self.dynamic_pattern = (pattern if isinstance(pattern, DynamicPattern)
                                else None)
        if self.dynamic_pattern is not None:
            if strategy == "auto":
                if candidates is None:
                    candidates = DYNAMIC_STRATEGIES
                else:
                    bad = tuple(c for c in candidates
                                if c not in DYNAMIC_STRATEGIES)
                    if bad:
                        raise ValueError(
                            f"candidates {bad} cannot serve a "
                            f"DynamicPattern — device-side table "
                            f"derivation covers {DYNAMIC_STRATEGIES}")
            elif strategy not in DYNAMIC_STRATEGIES:
                raise ValueError(
                    f"strategy {strategy!r} cannot serve a DynamicPattern "
                    f"— device-side table derivation covers "
                    f"{DYNAMIC_STRATEGIES}")
        self.pattern = pattern
        self.mesh = mesh
        self.axis_name = axis_name
        p = axis_size(mesh, axis_name)
        self.p = p
        n = pattern.n
        assert n % p == 0, "pad the vector so n divides the mesh axis"
        assert pattern.m % p == 0, "pad the pattern so m divides the mesh axis"
        if topology is None:
            topology = Topology(p, shards_per_node or p)

        if base_plan is not None:
            # an already-resolved destination-independent base plan (e.g.
            # one ExchangeSchedule stage sharing it with a sibling stage of
            # the same pattern): skip the probe and any blocksize sweep
            assert (base_plan.n == n and base_plan.p == p
                    and base_plan.m == pattern.m), (
                "base_plan was built for a different pattern/partitioning: "
                f"{(base_plan.n, base_plan.p, base_plan.m)} != "
                f"{(n, p, pattern.m)}")
            blocksize = base_plan.blocksize
        else:
            if blocksize == "auto":
                if hw is None:
                    hw = measure_hw(mesh, axis_name)
                blocksize = select.choose_blocksize(
                    pattern.indices, n, p, topology=topology, hw=hw)
            # destination-independent base plan first: the strategy resolves
            # against it, and any direction- or consumer-specific delta (the
            # scatter executor tables, a Destination descriptor) is attached
            # only afterwards
            if self.dynamic_pattern is not None:
                # the bucketed-reuse tier: an envelope plan keyed on
                # quantized pattern stats, shared across routings — its
                # static geometry and pricing serve this exchange while the
                # exact tables are (re-)derived from the template / each
                # batch on device
                base_plan = plan_cache.get_envelope_plan(
                    pattern.indices, n, p, blocksize=blocksize,
                    topology=topology, s_max=self.dynamic_pattern.s_max,
                    cache=use_plan_cache,
                )
            else:
                base_plan = plan_cache.get_comm_plan(
                    pattern.indices, n, p, blocksize=blocksize,
                    topology=topology, cache=use_plan_cache,
                )
        self._use_plan_cache = use_plan_cache
        self._prepare(base_plan)

        self.requested_strategy = strategy
        self.scan_steps = scan_steps
        self.predicted_times: dict[str, float] | None = None
        if strategy == "auto":
            if hw is None:
                hw = measure_hw(mesh, axis_name)
            # scan_steps (a ScanSchedule resolving this stage) prices the
            # rungs on the n-step steady-state loop cost — setup amortized
            # over the persistent window — instead of the single-call cost
            # plan_cost (the §5 T_plan term for however this exchange
            # obtains its tables) is a flat per-use addend — it never
            # reorders the rungs but makes predicted_times comparable
            # against wall clocks that include the plan acquisition
            ranked = select.rank_strategies(
                self._ranking_plan(base_plan), pattern.r, hw,
                candidates=candidates, direction=self.direction,
                scan_steps=scan_steps, plan_cost=plan_cost,
                decode=decode, **self._price_kwargs())
            self.predicted_times = dict(ranked)
            strategy = ranked[0][0]
        self.strategy = strategy
        self.hw = hw

        self._bind(base_plan, strategy)

    # ---- subclass hooks ----
    def _prepare(self, base_plan: CommPlan) -> None:
        """Derive direction-specific plan state before strategy resolution."""

    def _ranking_plan(self, base_plan: CommPlan):
        """The plan whose counts feed the §5 ranking (base by default)."""
        return base_plan

    def _price_kwargs(self) -> dict:
        """Extra ``rank_strategies`` kwargs (e.g. gather unpack pricing)."""
        return {"use_kernel": self.use_kernel}

    def _bind(self, base_plan: CommPlan, strategy: str) -> None:
        """Wire the resolved strategy: set ``self.plan`` / ``plan_args`` /
        ``in_specs`` / local start+finish and the standalone jit."""
        raise NotImplementedError

    # ---- shared surface ----
    def shard_vector(self, x) -> jax.Array:
        """Place host values on the mesh in the plan's contiguous layout."""
        return jax.device_put(
            x, NamedSharding(self.mesh, P(self.axis_name)))

    @property
    def counts(self):
        """The plan's exact per-shard volume counts (§5.2 model inputs)."""
        return self.plan.counts
