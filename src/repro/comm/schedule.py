"""ExchangeSchedule — chains of exchanges fused into one planned window.

The paper optimizes one exchange at a time; real consumers issue *chains*
of them: MoE dispatch-gather → expert MLP → combine-scatter, SpMV
``y = A x`` followed by ``z = Aᵀ y``, a halo exchange before every stencil
step.  Run through the one-shot front doors, each link pays its own plan
resolution, hardware calibration, ``shard_map`` window and unpack.  A
``Schedule`` declares the whole chain up front so ``compile`` can resolve
every stage against **one shared exchange-core context**:

* one hardware-calibration memo hit (``exchange.measure_hw``) prices every
  ``strategy="auto"`` stage;
* one plan-cache probe batch — each unique pattern's destination-independent
  base ``CommPlan`` is resolved once and shared by every stage that uses it;
* a scatter stage whose pattern matches a sibling gather stage reuses that
  gather's base plan, so its executor tables are a cheap transpose-derived
  delta (``CommPlan.transpose()``), never a second O(nnz) build;
* the §5 composition model (``perfmodel.predict_schedule``) prices the
  *fused* window — per-stage eq. 12–15 / 12ᵀ–15ᵀ terms with the
  window-setup latency paid once per consolidated window — so ``"auto"``
  may pick a different rung per stage while sharing one consolidation
  point.

``compile`` emits a **single** ``shard_map``.  Inside it the stages
pipeline through the handle protocol: an exchange stage *issues* its
collective (``start_local``) when reached, and its landed messages are
delivered (``finish``) only when a later stage actually consumes them —
every stage scheduled in between runs inside the collective's window, and
a scatter's own-shard accumulate overlaps its own exchange by
construction.  Stage order in the builder is therefore the schedule: put
the compute that should hide an exchange *after* that exchange stage and
*before* the stage that reads its result.

``IrregularGather`` / ``IrregularScatter`` stay exactly what they were —
a schedule stage IS one of them, constructed against the shared context —
so a single-stage schedule is bit-identical to the one-shot front door
(shim-tested in ``tests/test_schedule.py``).

>>> import jax, numpy as np
>>> from repro.comm import AccessPattern, Schedule
>>> p = len(jax.devices())
>>> mesh = jax.make_mesh((p,), ("data",))
>>> n = 16 * p
>>> rng = np.random.default_rng(0)
>>> idx = rng.integers(0, n, size=(n, 3)).astype(np.int32)
>>> pattern = AccessPattern.from_indices(idx, n=n)
>>> sched = Schedule()
>>> x = sched.input("x")
>>> rows = sched.constant(idx)      # (n, 3) index table, row-sharded
>>> g = sched.gather(pattern, src=x)
>>> y = sched.compute(lambda xc, r: xc[r].sum(-1), g, rows)
>>> step = sched.compile(mesh, strategy="condensed", blocksize=8)
>>> xv = rng.standard_normal(n).astype(np.float32)
>>> out = np.asarray(step(step.shard_input(xv)))
>>> bool(np.allclose(out, xv[idx].sum(-1), rtol=1e-5))
True
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm import plan_cache
from repro.comm import select
from repro.comm import strategies as strat
from repro.comm.exchange import measure_hw
from repro.comm.gather import IrregularGather
from repro.comm.pattern import AccessPattern
from repro.comm.plan import CommPlan, Topology
from repro.comm.scatter import IrregularScatter
from repro.comm.shared import axis_size

__all__ = ["Schedule", "ExchangeSchedule", "StageRef"]


@dataclasses.dataclass(frozen=True)
class StageRef:
    """Symbolic handle to one stage's output inside a ``Schedule``."""

    sid: int
    kind: str
    name: str
    owner: int = 0      # id() of the owning Schedule — refs don't cross


class _Stage:
    """Builder-side record of one stage (mutable until compile)."""

    def __init__(self, sid: int, kind: str, name: str, owner: int, **kw):
        self.sid = sid
        self.kind = kind
        self.name = name
        self.owner = owner
        self.__dict__.update(kw)

    @property
    def ref(self) -> StageRef:
        return StageRef(self.sid, self.kind, self.name, self.owner)


class Schedule:
    """Declarative builder for an ``ExchangeSchedule``.

    Build stages in execution order (the order IS the pipeline schedule),
    then ``compile(mesh, strategy="auto")``::

        sched = Schedule()
        h = sched.gather(pattern, destination=dest)
        y = sched.compute(expert_fn, h, weights)
        sched.scatter(pattern, y, reduce="add")
        step = sched.compile(mesh, strategy="auto")

    ``resolve`` may be called explicitly before the compute stages are
    added when a later stage's shape depends on the resolved rung (e.g.
    Heat2D only adds its interior stage when ``"auto"`` picks ``overlap``).
    """

    def __init__(self):
        self._stages: list[_Stage] = []
        self._ctx: dict | None = None       # set by resolve()
        self._exchanges: dict[int, Any] = {}
        self._compiled = False

    # ---- builder surface ----
    def _add(self, kind: str, name: str | None, **kw) -> StageRef:
        assert not self._compiled, "schedule already compiled"
        sid = len(self._stages)
        name = name or f"{kind}{sid}"
        if any(s.name == name for s in self._stages):
            raise ValueError(
                f"duplicate stage name {name!r} — names key the "
                ".strategies/.predicted_times reporting, so each stage "
                "needs its own")
        st = _Stage(sid, kind, name, id(self), **kw)
        self._stages.append(st)
        return st.ref

    def _check_ref(self, ref, *, array_valued: bool = False) -> StageRef:
        assert isinstance(ref, StageRef), (
            f"stage arguments must be StageRefs, got {type(ref).__name__}")
        if ref.owner != id(self):
            raise ValueError(
                f"stage ref {ref.name!r} belongs to a different Schedule "
                "— refs cannot cross builders")
        assert 0 <= ref.sid < len(self._stages), ref
        st = self._stages[ref.sid]
        if array_valued and st.kind == "gather" and st.destination is not None:
            raise ValueError(
                f"stage {st.name!r} delivers named Destination slots (a "
                "dict); wrap it in a compute stage that selects/combines "
                "the slots before feeding an exchange")
        return ref

    def input(self, name: str | None = None, *, spec=None) -> StageRef:
        """Declare an external operand of the compiled step (call-time
        positional argument, in declaration order).  ``spec`` is its
        ``PartitionSpec`` (default: sharded over the comm axis)."""
        return self._add("input", name, spec=spec)

    def constant(self, value, name: str | None = None, *, spec=None,
                 replicated: bool = False) -> StageRef:
        """Bind a fixed array operand (matrix values, expert weights,
        combine weights).  It is ``device_put`` once at compile time and
        rides the single ``shard_map`` with ``spec`` (default: dim 0
        sharded over the comm axis; ``replicated=True`` for ``P()``)."""
        if replicated:
            assert spec is None, "pass spec OR replicated, not both"
            spec = P()
        return self._add("constant", name, value=value, spec=spec)

    def gather(self, pattern: AccessPattern, *, src: StageRef | None = None,
               destination=None, dest_slots: int | None = None,
               strategy: str | None = None, blocksize=None,
               finish_kwargs: dict | None = None,
               name: str | None = None) -> StageRef:
        """Pull stage: deliver ``pattern``'s elements of the ``src`` value
        (default: the first declared input, auto-declared if absent).

        The stage value is the strategy's default materialization: the
        ``{name: slots}`` dict with a ``destination``, else the full
        ``x_copy``.  ``strategy`` / ``blocksize`` override the schedule
        defaults per stage; ``finish_kwargs`` are forwarded to
        ``OverlapHandle.finish`` (``extra_slots=`` / ``copy_own=``)."""
        if src is None:
            src = next((s.ref for s in self._stages if s.kind == "input"),
                       None)
            if src is None:
                src = self.input()
        self._check_ref(src, array_valued=True)
        return self._add("gather", name, pattern=pattern, src=src,
                         destination=destination, dest_slots=dest_slots,
                         strategy=strategy, blocksize=blocksize,
                         finish_kwargs=dict(finish_kwargs or {}))

    def compute(self, fn: Callable, *args: StageRef,
                name: str | None = None) -> StageRef:
        """Local compute stage: ``fn(*values)`` runs per device inside the
        fused ``shard_map``, where each value is the referenced stage's
        device-local output.  A compute stage placed after an exchange
        stage but before anything consumes that exchange runs inside its
        collective window."""
        for a in args:
            self._check_ref(a)
        return self._add("compute", name, fn=fn, args=tuple(args))

    def scatter(self, pattern: AccessPattern, src: StageRef, *,
                reduce: str = "add", strategy: str | None = None,
                blocksize=None, name: str | None = None) -> StageRef:
        """Push stage: ``src``'s value is the (rows_local, r, feat...)
        contribution table; the stage value is the combined owned slice.
        A pattern already gathered by a sibling stage reuses its base plan
        (the scatter tables are a transpose-derived delta)."""
        self._check_ref(src, array_valued=True)
        if reduce not in strat.SCATTER_REDUCES:
            raise ValueError(f"reduce must be one of {strat.SCATTER_REDUCES}")
        return self._add("scatter", name, pattern=pattern, src=src,
                         reduce=reduce, strategy=strategy,
                         blocksize=blocksize)

    # ---- resolution (shared exchange-core context) ----
    def _exchange_stages(self) -> list[_Stage]:
        return [s for s in self._stages if s.kind in ("gather", "scatter")]

    def resolve(self, mesh, *, axis_name="data", strategy: str = "auto",
                blocksize=None, topology: Topology | None = None,
                shards_per_node: int | None = None, hw=None,
                use_plan_cache: bool = True) -> "Schedule":
        """Resolve every exchange stage against one shared context: one
        ``measure_hw`` memo hit, one base-plan probe per unique pattern,
        transpose-derived scatter plans reused from sibling gathers.

        Idempotent prerequisite of ``compile``; call it explicitly when a
        later stage's shape depends on a resolved rung
        (``strategy_of(ref)``)."""
        assert self._ctx is None, "schedule already resolved"
        exchanges = self._exchange_stages()
        assert exchanges, "a schedule needs at least one exchange stage"
        p = axis_size(mesh, axis_name)
        if topology is None:
            topology = Topology(p, shards_per_node or p)

        needs_hw = any((s.strategy or strategy) == "auto"
                       or (s.blocksize if s.blocksize is not None
                           else blocksize) == "auto"
                       for s in exchanges)
        if needs_hw and hw is None:
            hw = measure_hw(mesh, axis_name)   # ONE memo hit for all stages

        # one plan-cache probe per unique (pattern, blocksize): every stage
        # over the same index set shares one base CommPlan object, so a
        # scatter stage derives its executor tables from the sibling
        # gather's plan instead of rebuilding
        base_plans: dict[str, CommPlan] = {}
        for st in exchanges:
            bs = st.blocksize if st.blocksize is not None else blocksize
            if bs == "auto":
                bs = select.choose_blocksize(
                    st.pattern.indices, st.pattern.n, p, topology=topology,
                    hw=hw)
            shard_size = st.pattern.n // p
            bs_key = shard_size if bs is None else bs
            key = plan_cache.plan_key(st.pattern.indices, st.pattern.n, p,
                                      bs_key, topology)
            if key not in base_plans:
                base_plans[key] = plan_cache.get_comm_plan(
                    st.pattern.indices, st.pattern.n, p, blocksize=bs,
                    topology=topology, cache=use_plan_cache)
            st_strategy = st.strategy if st.strategy is not None else strategy
            kwargs = dict(axis_name=axis_name, strategy=st_strategy,
                          topology=topology, hw=hw,
                          use_plan_cache=use_plan_cache,
                          base_plan=base_plans[key])
            if st.kind == "gather":
                ex = IrregularGather(
                    st.pattern, mesh, destination=st.destination,
                    dest_slots=st.dest_slots, **kwargs)
            else:
                ex = IrregularScatter(st.pattern, mesh, reduce=st.reduce,
                                      **kwargs)
            self._exchanges[st.sid] = ex

        self._ctx = dict(mesh=mesh, axis_name=axis_name, topology=topology,
                         hw=hw, default_strategy=strategy)
        return self

    def exchange_of(self, ref: StageRef):
        """The resolved ``IrregularGather``/``IrregularScatter`` behind one
        exchange stage (available after ``resolve``)."""
        assert self._ctx is not None, "call resolve()/compile() first"
        return self._exchanges[ref.sid]

    def strategy_of(self, ref: StageRef) -> str:
        """The resolved rung of one exchange stage."""
        return self.exchange_of(ref).strategy

    def _predict_window(self):
        """§5 fused-window composition for the resolved rungs (None when
        no hardware parameters are in scope)."""
        hw = self._ctx["hw"]
        if hw is None:
            return None
        from repro.core import perfmodel as pm
        specs = []
        for st in self._exchange_stages():
            ex = self._exchanges[st.sid]
            if st.kind == "gather":
                materialize = "dest" if ex.destination is not None else None
                dest_slots = (ex.destination.num_slots
                              if ex.destination is not None else None)
                w = select.workload_from_plan(
                    ex.plan, st.pattern.r, materialize=materialize,
                    dest_slots=dest_slots)
                specs.append((st.name, "get", w, ex.strategy))
            else:
                w = select.workload_from_plan(ex.splan, st.pattern.r)
                specs.append((st.name, "put", w, ex.strategy))
        return pm.predict_schedule(specs, hw)

    # ---- compilation (the single shard_map) ----
    def compile(self, mesh=None, *, output: StageRef | None = None,
                out_spec=None, **resolve_kw) -> "ExchangeSchedule":
        """Finalize into an ``ExchangeSchedule``: one ``shard_map`` whose
        stages pipeline through the handle protocol.

        ``output`` picks the stage whose value the step returns (default:
        the last stage; must be array-valued); ``out_spec`` its
        ``PartitionSpec`` (default: sharded over the comm axis).  ``mesh``
        and the remaining keywords are forwarded to ``resolve`` unless it
        already ran."""
        assert not self._compiled, "schedule already compiled"
        if self._ctx is None:
            assert mesh is not None, "compile() needs a mesh (or resolve())"
            self.resolve(mesh, **resolve_kw)
        else:
            assert mesh is None or mesh is self._ctx["mesh"], (
                "schedule was resolved on a different mesh")
            if resolve_kw:
                raise ValueError(
                    "schedule already resolved — these compile() keywords "
                    f"would be silently ignored: {sorted(resolve_kw)}; "
                    "pass them to resolve() instead")
        if output is None:
            output = self._stages[-1].ref
        self._check_ref(output, array_valued=True)
        self._compiled = True
        return ExchangeSchedule(self, output, out_spec)


class ExchangeSchedule:
    """A compiled multi-exchange step: one ``shard_map``, one fused window.

    * ``step(*inputs)`` — jitted end-to-end call (inputs in declaration
      order, placed like ``shard_input`` expects);
    * ``.mapped`` / ``.step_args`` / ``.in_specs`` — the raw
      ``shard_map``-ed local function and its bound operands, for
      consumers that embed the step in their own ``jit``/``scan``;
    * ``.strategies`` — resolved rung per exchange stage;
    * ``.predicted_times`` — per-stage §5 rung rankings (auto stages);
    * ``.predicted_window`` — the fused-window composition prediction
      (``perfmodel.predict_schedule``), with per-stage terms and the
      consolidation saving; ``None`` when no hardware parameters were in
      scope (every stage on a fixed rung and no ``hw=`` passed).
    """

    def __init__(self, sched: Schedule, output: StageRef, out_spec):
        ctx = sched._ctx
        mesh, axis_name = ctx["mesh"], ctx["axis_name"]
        self.mesh = mesh
        self.axis_name = axis_name
        self.topology = ctx["topology"]
        self.hw = ctx["hw"]
        self._stages = sched._stages
        self._exchanges = sched._exchanges
        self._output = output
        stages = self._stages

        self.strategies = {st.name: self._exchanges[st.sid].strategy
                           for st in stages
                           if st.kind in ("gather", "scatter")}
        self.predicted_times = {
            st.name: self._exchanges[st.sid].predicted_times
            for st in stages if st.kind in ("gather", "scatter")}
        self.predicted_window = sched._predict_window()

        # operand layout: all inputs first (call order), then per-stage
        # bound operands (constants + plan arrays) in stage order
        self._input_sids = [st.sid for st in stages if st.kind == "input"]
        self._input_specs = tuple(
            st.spec if st.spec is not None else P(axis_name)
            for st in stages if st.kind == "input")
        shard = NamedSharding(mesh, P(axis_name))
        step_args: list = []
        bound_specs: list = []
        slots: dict[int, slice] = {}     # sid -> slice into bound args
        for st in stages:
            lo = len(step_args)
            if st.kind == "constant":
                spec = st.spec if st.spec is not None else P(axis_name)
                step_args.append(jax.device_put(
                    np.asarray(st.value), NamedSharding(mesh, spec)))
                bound_specs.append(spec)
                st.value = None   # free the host copy; only the device
                # array (in step_args) is ever read again
            elif st.kind in ("gather", "scatter"):
                ex = self._exchanges[st.sid]
                step_args.extend(ex.plan_args)
                bound_specs.extend(ex.in_specs)
            slots[st.sid] = slice(lo, len(step_args))
        self.step_args = tuple(step_args)
        self.in_specs = self._input_specs + tuple(bound_specs)
        n_inputs = len(self._input_sids)
        exchanges = self._exchanges

        def step_local(*args):
            inputs, bound = args[:n_inputs], args[n_inputs:]
            env: dict[int, Any] = {}
            pending: dict[int, Callable[[], Any]] = {}

            def force(sid):
                if sid in pending:
                    env[sid] = pending.pop(sid)()
                return env[sid]

            for st in stages:
                if st.kind == "input":
                    env[st.sid] = inputs[self._input_sids.index(st.sid)]
                elif st.kind == "constant":
                    (env[st.sid],) = bound[slots[st.sid]]
                elif st.kind == "compute":
                    vals = [force(a.sid) for a in st.args]
                    env[st.sid] = st.fn(*vals)
                else:
                    # exchange stage: ISSUE the collective now; deliver
                    # (finish) lazily when a later stage consumes it —
                    # everything in between runs inside its window
                    ex = exchanges[st.sid]
                    src = force(st.src.sid)
                    handle = ex.start_local(src, *bound[slots[st.sid]])
                    if st.kind == "gather" and st.finish_kwargs:
                        kw = st.finish_kwargs
                        pending[st.sid] = lambda h=handle, kw=kw: h.finish(
                            **kw)
                    else:
                        pending[st.sid] = handle.finish
            return force(output.sid)

        self.mapped = compat.shard_map(
            step_local, mesh=mesh, in_specs=self.in_specs,
            out_specs=out_spec if out_spec is not None else P(axis_name),
            check_vma=False,
        )
        step_args_t = self.step_args

        @jax.jit
        def step(*inputs):
            return self.mapped(*inputs, *step_args_t)

        self._step = step

    def shard_input(self, value, which: int = 0) -> jax.Array:
        """Place a host value on the mesh with input ``which``'s spec."""
        spec = self._input_specs[which]
        return jax.device_put(value, NamedSharding(self.mesh, spec))

    # kept as the SpMV-flavored alias every front door exposes
    def shard_vector(self, value) -> jax.Array:
        return self.shard_input(value, 0)

    def __call__(self, *inputs) -> jax.Array:
        return self._step(*inputs)
