"""ExchangeSchedule — chains of exchanges fused into one planned window.

The paper optimizes one exchange at a time; real consumers issue *chains*
of them: MoE dispatch-gather → expert MLP → combine-scatter, SpMV
``y = A x`` followed by ``z = Aᵀ y``, a halo exchange before every stencil
step.  Run through the one-shot front doors, each link pays its own plan
resolution, hardware calibration, ``shard_map`` window and unpack.  A
``Schedule`` declares the whole chain up front so ``compile`` can resolve
every stage against **one shared exchange-core context**:

* one hardware-calibration memo hit (``exchange.measure_hw``) prices every
  ``strategy="auto"`` stage;
* one plan-cache probe batch — each unique pattern's destination-independent
  base ``CommPlan`` is resolved once and shared by every stage that uses it;
* a scatter stage whose pattern matches a sibling gather stage reuses that
  gather's base plan, so its executor tables are a cheap transpose-derived
  delta (``CommPlan.transpose()``), never a second O(nnz) build;
* the §5 composition model (``perfmodel.predict_schedule``) prices the
  *fused* window — per-stage eq. 12–15 / 12ᵀ–15ᵀ terms with the
  window-setup latency paid once per consolidated window — so ``"auto"``
  may pick a different rung per stage while sharing one consolidation
  point.

``compile`` emits a **single** ``shard_map``.  Inside it the stages
pipeline through the handle protocol: an exchange stage *issues* its
collective (``start_local``) when reached, and its landed messages are
delivered (``finish``) only when a later stage actually consumes them —
every stage scheduled in between runs inside the collective's window, and
a scatter's own-shard accumulate overlaps its own exchange by
construction.  Stage order in the builder is therefore the schedule: put
the compute that should hide an exchange *after* that exchange stage and
*before* the stage that reads its result.

``IrregularGather`` / ``IrregularScatter`` stay exactly what they were —
a schedule stage IS one of them, constructed against the shared context —
so a single-stage schedule is bit-identical to the one-shot front door
(shim-tested in ``tests/test_schedule.py``).

Time loops go one level further: ``Schedule.scan`` compiles the same stage
pipeline through ``lax.scan`` *inside* the single ``shard_map``, so the
exchange window is persistent across iterations — one plan-cache probe and
one hardware-calibration memo hit for the entire loop, and zero per-step
host dispatch (the whole n-step loop is one XLA program).  See
``ScanSchedule`` and docs/schedules.md for the carry and double-buffer
contracts.

>>> import jax, numpy as np
>>> from repro.comm import AccessPattern, Schedule
>>> p = len(jax.devices())
>>> mesh = jax.make_mesh((p,), ("data",))
>>> n = 16 * p
>>> rng = np.random.default_rng(0)
>>> idx = rng.integers(0, n, size=(n, 3)).astype(np.int32)
>>> pattern = AccessPattern.from_indices(idx, n=n)
>>> sched = Schedule()
>>> x = sched.input("x")
>>> rows = sched.constant(idx)      # (n, 3) index table, row-sharded
>>> g = sched.gather(pattern, src=x)
>>> y = sched.compute(lambda xc, r: xc[r].sum(-1), g, rows)
>>> step = sched.compile(mesh, strategy="condensed", blocksize=8)
>>> xv = rng.standard_normal(n).astype(np.float32)
>>> out = np.asarray(step(step.shard_input(xv)))
>>> bool(np.allclose(out, xv[idx].sum(-1), rtol=1e-5))
True
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm import plan_cache
from repro.comm import select
from repro.comm import strategies as strat
from repro.comm.exchange import measure_hw
from repro.comm.gather import IrregularGather
from repro.comm.pattern import AccessPattern
from repro.comm.plan import CommPlan, Topology
from repro.comm.scatter import IrregularScatter
from repro.comm.shared import axis_size

__all__ = ["Schedule", "ExchangeSchedule", "ScanSchedule", "StageRef"]


def _unwrap_dynamic(pattern) -> AccessPattern:
    """Schedules resolve stages against host plans, so a ``DynamicPattern``
    degrades to its template here (a documented limitation: per-batch
    device-derived tables inside a compiled schedule need the consumer to
    thread ``derive_plan_args`` output through its own shard_map — see
    ``models.moe.DynamicMoELayer`` for the fused pattern done by hand)."""
    from repro.comm.dynamic import DynamicPattern
    if isinstance(pattern, DynamicPattern):
        return pattern.template
    return pattern


@dataclasses.dataclass(frozen=True)
class StageRef:
    """Symbolic handle to one stage's output inside a ``Schedule``."""

    sid: int
    kind: str
    name: str
    owner: int = 0      # id() of the owning Schedule — refs don't cross


class _Stage:
    """Builder-side record of one stage (mutable until compile)."""

    def __init__(self, sid: int, kind: str, name: str, owner: int, **kw):
        self.sid = sid
        self.kind = kind
        self.name = name
        self.owner = owner
        self.__dict__.update(kw)

    @property
    def ref(self) -> StageRef:
        return StageRef(self.sid, self.kind, self.name, self.owner)


class Schedule:
    """Declarative builder for an ``ExchangeSchedule``.

    Build stages in execution order (the order IS the pipeline schedule),
    then ``compile(mesh, strategy="auto")``::

        sched = Schedule()
        h = sched.gather(pattern, destination=dest)
        y = sched.compute(expert_fn, h, weights)
        sched.scatter(pattern, y, reduce="add")
        step = sched.compile(mesh, strategy="auto")

    ``resolve`` may be called explicitly before the compute stages are
    added when a later stage's shape depends on the resolved rung (e.g.
    Heat2D only adds its interior stage when ``"auto"`` picks ``overlap``).
    """

    def __init__(self):
        self._stages: list[_Stage] = []
        self._ctx: dict | None = None       # set by resolve()
        self._exchanges: dict[int, Any] = {}
        self._compiled = False

    # ---- builder surface ----
    def _add(self, kind: str, name: str | None, **kw) -> StageRef:
        assert not self._compiled, "schedule already compiled"
        sid = len(self._stages)
        name = name or f"{kind}{sid}"
        if any(s.name == name for s in self._stages):
            raise ValueError(
                f"duplicate stage name {name!r} — names key the "
                ".strategies/.predicted_times reporting, so each stage "
                "needs its own")
        st = _Stage(sid, kind, name, id(self), **kw)
        self._stages.append(st)
        return st.ref

    def _check_ref(self, ref, *, array_valued: bool = False) -> StageRef:
        assert isinstance(ref, StageRef), (
            f"stage arguments must be StageRefs, got {type(ref).__name__}")
        if ref.owner != id(self):
            raise ValueError(
                f"stage ref {ref.name!r} belongs to a different Schedule "
                "— refs cannot cross builders")
        assert 0 <= ref.sid < len(self._stages), ref
        st = self._stages[ref.sid]
        if array_valued and st.kind == "gather" and st.destination is not None:
            raise ValueError(
                f"stage {st.name!r} delivers named Destination slots (a "
                "dict); wrap it in a compute stage that selects/combines "
                "the slots before feeding an exchange")
        return ref

    def input(self, name: str | None = None, *, spec=None) -> StageRef:
        """Declare an external operand of the compiled step (call-time
        positional argument, in declaration order).  ``spec`` is its
        ``PartitionSpec`` (default: sharded over the comm axis)."""
        return self._add("input", name, spec=spec)

    def constant(self, value, name: str | None = None, *, spec=None,
                 replicated: bool = False) -> StageRef:
        """Bind a fixed array operand (matrix values, expert weights,
        combine weights).  It is ``device_put`` once at compile time and
        rides the single ``shard_map`` with ``spec`` (default: dim 0
        sharded over the comm axis; ``replicated=True`` for ``P()``)."""
        if replicated:
            assert spec is None, "pass spec OR replicated, not both"
            spec = P()
        return self._add("constant", name, value=value, spec=spec)

    def gather(self, pattern: AccessPattern, *, src: StageRef | None = None,
               destination=None, dest_slots: int | None = None,
               strategy: str | None = None, blocksize=None,
               use_kernel: bool | None = None,
               finish_kwargs: dict | None = None,
               double_buffer: bool = False, prime: StageRef | None = None,
               name: str | None = None) -> StageRef:
        """Pull stage: deliver ``pattern``'s elements of the ``src`` value
        (default: the first declared input, auto-declared if absent).

        The stage value is the strategy's default materialization: the
        ``{name: slots}`` dict with a ``destination``, else the full
        ``x_copy``.  ``strategy`` / ``blocksize`` / ``use_kernel`` override
        the schedule defaults per stage; ``finish_kwargs`` are forwarded to
        ``OverlapHandle.finish`` (``extra_slots=`` / ``copy_own=``).

        ``double_buffer=True`` (only under ``Schedule.scan``): the stage's
        value is the delivery of the exchange issued by this schedule's
        matching ``feed()`` stage one iteration EARLIER, carried across the
        scan boundary — so the compute of iteration k+1 hides inside the
        window opened during iteration k.  Such a stage has no in-body
        ``src``; ``prime=`` names the exchange-free stage whose value seeds
        iteration 0's exchange before the loop starts."""
        if double_buffer:
            if src is not None:
                raise ValueError(
                    "a double_buffer gather has no in-body src: its value "
                    "is the delivery of the exchange issued by feed() one "
                    "iteration earlier — pass prime= (the stage seeding "
                    "iteration 0) and add a feed() stage instead")
            if prime is None:
                raise ValueError(
                    "double_buffer=True needs prime= — the stage whose "
                    "value seeds iteration 0's exchange in the scan "
                    "prologue (it must not depend on any exchange stage)")
            src = prime
        elif prime is not None:
            raise ValueError("prime= only applies to double_buffer=True")
        else:
            if src is None:
                src = next((s.ref for s in self._stages
                            if s.kind == "input"), None)
                if src is None:
                    src = self.input()
        self._check_ref(src, array_valued=True)
        pattern = _unwrap_dynamic(pattern)
        return self._add("gather", name, pattern=pattern, src=src,
                         destination=destination, dest_slots=dest_slots,
                         strategy=strategy, blocksize=blocksize,
                         use_kernel=use_kernel,
                         double_buffer=double_buffer,
                         finish_kwargs=dict(finish_kwargs or {}))

    def compute(self, fn: Callable, *args: StageRef,
                name: str | None = None) -> StageRef:
        """Local compute stage: ``fn(*values)`` runs per device inside the
        fused ``shard_map``, where each value is the referenced stage's
        device-local output.  A compute stage placed after an exchange
        stage but before anything consumes that exchange runs inside its
        collective window."""
        for a in args:
            self._check_ref(a)
        return self._add("compute", name, fn=fn, args=tuple(args))

    def feed(self, gather: StageRef, src: StageRef, *,
             name: str | None = None) -> StageRef:
        """Issue the NEXT iteration's exchange of a ``double_buffer``
        gather stage (only meaningful under ``Schedule.scan``).

        ``src``'s value — typically this iteration's refreshed operand —
        is packed and sent where the feed stage sits in the pipeline; the
        delivery is finished at the end of the body and carried across the
        scan boundary, becoming the gather stage's value next iteration.
        Every stage between the feed and the end of the body (and the next
        iteration's stages up to the gather's first consumer) runs inside
        the collective's window.  The final iteration's feed issues one
        exchange whose delivery is never consumed — the price of the
        branch-free scan body."""
        self._check_ref(gather)
        g = self._stages[gather.sid]
        if g.kind != "gather" or not g.double_buffer:
            raise ValueError(
                "feed() targets a gather(double_buffer=True, ...) stage; "
                f"{g.name!r} is not one")
        self._check_ref(src, array_valued=True)
        if any(s.kind == "feed" and s.gather.sid == gather.sid
               for s in self._stages):
            raise ValueError(
                f"stage {g.name!r} already has a feed() stage — a "
                "double-buffer depth of one carries exactly one in-flight "
                "exchange")
        return self._add("feed", name, gather=gather, src=src)

    def scatter(self, pattern: AccessPattern, src: StageRef, *,
                reduce: str = "add", strategy: str | None = None,
                blocksize=None, use_kernel: bool | None = None,
                name: str | None = None) -> StageRef:
        """Push stage: ``src``'s value is the (rows_local, r, feat...)
        contribution table; the stage value is the combined owned slice.
        A pattern already gathered by a sibling stage reuses its base plan
        (the scatter tables are a transpose-derived delta).  ``strategy`` /
        ``blocksize`` / ``use_kernel`` override the schedule defaults per
        stage."""
        self._check_ref(src, array_valued=True)
        if reduce not in strat.SCATTER_REDUCES:
            raise ValueError(f"reduce must be one of {strat.SCATTER_REDUCES}")
        pattern = _unwrap_dynamic(pattern)
        return self._add("scatter", name, pattern=pattern, src=src,
                         reduce=reduce, strategy=strategy,
                         blocksize=blocksize, use_kernel=use_kernel)

    # ---- resolution (shared exchange-core context) ----
    def _exchange_stages(self) -> list[_Stage]:
        return [s for s in self._stages if s.kind in ("gather", "scatter")]

    def resolve(self, mesh, *, axis_name="data", strategy: str = "auto",
                blocksize=None, use_kernel: bool = False,
                topology: Topology | None = None,
                shards_per_node: int | None = None, hw=None,
                use_plan_cache: bool = True,
                scan_steps: int | None = None) -> "Schedule":
        """Resolve every exchange stage against one shared context: one
        ``measure_hw`` memo hit, one base-plan probe per unique pattern,
        transpose-derived scatter plans reused from sibling gathers.

        ``use_kernel`` is the schedule-wide default for the fused Pallas
        pack/unpack path (each stage's own ``use_kernel=`` wins when set);
        ``"auto"`` stages are priced with the kernelized compute terms so
        the ranking matches what the window will actually run.

        ``scan_steps`` (set by ``Schedule.scan(n_steps_hint=...)``) makes
        every ``"auto"`` stage rank rungs on the n-step steady-state LOOP
        cost (``perfmodel.scan_loop_cost`` — window setup paid once)
        instead of the single-call cost.

        Idempotent prerequisite of ``compile``; call it explicitly when a
        later stage's shape depends on a resolved rung
        (``strategy_of(ref)``)."""
        assert self._ctx is None, "schedule already resolved"
        exchanges = self._exchange_stages()
        assert exchanges, "a schedule needs at least one exchange stage"
        p = axis_size(mesh, axis_name)
        if topology is None:
            topology = Topology(p, shards_per_node or p)

        needs_hw = any((s.strategy or strategy) == "auto"
                       or (s.blocksize if s.blocksize is not None
                           else blocksize) == "auto"
                       for s in exchanges)
        if needs_hw and hw is None:
            hw = measure_hw(mesh, axis_name)   # ONE memo hit for all stages

        # one plan-cache probe per unique (pattern, blocksize): every stage
        # over the same index set shares one base CommPlan object, so a
        # scatter stage derives its executor tables from the sibling
        # gather's plan instead of rebuilding
        base_plans: dict[str, CommPlan] = {}
        for st in exchanges:
            bs = st.blocksize if st.blocksize is not None else blocksize
            if bs == "auto":
                bs = select.choose_blocksize(
                    st.pattern.indices, st.pattern.n, p, topology=topology,
                    hw=hw)
            shard_size = st.pattern.n // p
            bs_key = shard_size if bs is None else bs
            key = plan_cache.plan_key(st.pattern.indices, st.pattern.n, p,
                                      bs_key, topology)
            if key not in base_plans:
                base_plans[key] = plan_cache.get_comm_plan(
                    st.pattern.indices, st.pattern.n, p, blocksize=bs,
                    topology=topology, cache=use_plan_cache)
            st_strategy = st.strategy if st.strategy is not None else strategy
            st_use_kernel = (st.use_kernel if st.use_kernel is not None
                             else use_kernel)
            kwargs = dict(axis_name=axis_name, strategy=st_strategy,
                          topology=topology, hw=hw,
                          use_plan_cache=use_plan_cache,
                          base_plan=base_plans[key],
                          scan_steps=scan_steps,
                          use_kernel=st_use_kernel)
            if st.kind == "gather":
                ex = IrregularGather(
                    st.pattern, mesh, destination=st.destination,
                    dest_slots=st.dest_slots, **kwargs)
            else:
                ex = IrregularScatter(st.pattern, mesh, reduce=st.reduce,
                                      **kwargs)
            self._exchanges[st.sid] = ex

        self._ctx = dict(mesh=mesh, axis_name=axis_name, topology=topology,
                         hw=hw, default_strategy=strategy)
        return self

    def exchange_of(self, ref: StageRef):
        """The resolved ``IrregularGather``/``IrregularScatter`` behind one
        exchange stage (available after ``resolve``)."""
        assert self._ctx is not None, "call resolve()/compile() first"
        return self._exchanges[ref.sid]

    def strategy_of(self, ref: StageRef) -> str:
        """The resolved rung of one exchange stage."""
        return self.exchange_of(ref).strategy

    def _stage_specs(self):
        """Per-exchange-stage §5 pricing specs: the ``(name, direction,
        workload, strategy)`` rows ``perfmodel.predict_schedule`` /
        ``predict_scan_schedule`` consume (available after ``resolve``)."""
        specs = []
        for st in self._exchange_stages():
            ex = self._exchanges[st.sid]
            if st.kind == "gather":
                materialize = "dest" if ex.destination is not None else None
                dest_slots = (ex.destination.num_slots
                              if ex.destination is not None else None)
                w = select.workload_from_plan(
                    ex.plan, st.pattern.r, materialize=materialize,
                    dest_slots=dest_slots, use_kernel=ex.use_kernel)
                specs.append((st.name, "get", w, ex.strategy))
            else:
                w = select.workload_from_plan(ex.splan, st.pattern.r,
                                              use_kernel=ex.use_kernel)
                specs.append((st.name, "put", w, ex.strategy))
        return specs

    def _predict_window(self):
        """§5 fused-window composition for the resolved rungs (None when
        no hardware parameters are in scope)."""
        hw = self._ctx["hw"]
        if hw is None:
            return None
        from repro.core import perfmodel as pm
        return pm.predict_schedule(self._stage_specs(), hw)

    def _finish_build(self, mesh, resolve_kw):
        assert not self._compiled, "schedule already compiled"
        if self._ctx is None:
            assert mesh is not None, "compile() needs a mesh (or resolve())"
            self.resolve(mesh, **resolve_kw)
        else:
            assert mesh is None or mesh is self._ctx["mesh"], (
                "schedule was resolved on a different mesh")
            if resolve_kw:
                raise ValueError(
                    "schedule already resolved — these compile() keywords "
                    f"would be silently ignored: {sorted(resolve_kw)}; "
                    "pass them to resolve() instead")

    # ---- compilation (the single shard_map) ----
    def compile(self, mesh=None, *, output=None,
                out_spec=None, **resolve_kw) -> "ExchangeSchedule":
        """Finalize into an ``ExchangeSchedule``: one ``shard_map`` whose
        stages pipeline through the handle protocol.

        ``output`` picks the stage whose value the step returns (default:
        the last stage; must be array-valued) — a tuple of refs makes the
        step return the matching tuple; ``out_spec`` its ``PartitionSpec``
        (or tuple thereof; default: sharded over the comm axis).  ``mesh``
        and the remaining keywords are forwarded to ``resolve`` unless it
        already ran."""
        bad = [s.name for s in self._stages
               if (s.kind == "feed"
                   or (s.kind == "gather" and s.double_buffer))]
        if bad:
            raise ValueError(
                f"stages {bad} double-buffer across iterations; a one-shot "
                "compile() has no previous iteration to carry the delivery "
                "from — build them through Schedule.scan() instead")
        self._finish_build(mesh, resolve_kw)
        if output is None:
            output = self._stages[-1].ref
        single = not isinstance(output, (tuple, list))
        outputs = (output,) if single else tuple(output)
        for o in outputs:
            self._check_ref(o, array_valued=True)
        self._compiled = True
        return ExchangeSchedule(self, outputs, out_spec, single=single)

    def scan(self, mesh=None, *, carry, output,
             n_steps_hint: int | None = None,
             **resolve_kw) -> "ScanSchedule":
        """Finalize into a ``ScanSchedule``: the stage pipeline becomes the
        body of a ``lax.scan`` running INSIDE one persistent ``shard_map``
        window — plans, calibration and dispatch are paid once for the
        whole loop, not per step.

        ``carry`` — every declared input stage, as a tuple of refs in call
        order (a bare ref for a single carry); ``output`` — a matching
        tuple: the stage whose value becomes the corresponding carry next
        iteration (and the loop's final result).  ``n_steps_hint`` prices
        ``strategy="auto"`` stages on the hinted steady-state loop cost
        (setup amortized) instead of the single-call cost.  The compiled
        object is called as ``scan(*carries, n_steps=k)`` with ``n_steps``
        static per compilation."""
        single = not isinstance(carry, (tuple, list))
        carry = (carry,) if single else tuple(carry)
        output = (output,) if not isinstance(output, (tuple, list)) \
            else tuple(output)
        if self._ctx is None:
            resolve_kw.setdefault("scan_steps", n_steps_hint)
        self._finish_build(mesh, resolve_kw)
        self._compiled = True
        return ScanSchedule(self, carry, output, single=single,
                            n_steps_hint=n_steps_hint)


def _bind_operands(stages, exchanges, mesh, axis_name):
    """Operand layout shared by ``ExchangeSchedule`` and ``ScanSchedule``:
    all inputs first (call order), then per-stage bound operands
    (constants + plan arrays) in stage order.  Returns ``(input_sids,
    input_specs, step_args, bound_specs, slots)`` with ``slots[sid]`` the
    slice of the bound-args tuple belonging to stage ``sid``."""
    input_sids = [st.sid for st in stages if st.kind == "input"]
    input_specs = tuple(
        st.spec if st.spec is not None else P(axis_name)
        for st in stages if st.kind == "input")
    step_args: list = []
    bound_specs: list = []
    slots: dict[int, slice] = {}
    for st in stages:
        lo = len(step_args)
        if st.kind == "constant":
            spec = st.spec if st.spec is not None else P(axis_name)
            step_args.append(jax.device_put(
                np.asarray(st.value), NamedSharding(mesh, spec)))
            bound_specs.append(spec)
            st.value = None   # free the host copy; only the device
            # array (in step_args) is ever read again
        elif st.kind in ("gather", "scatter"):
            ex = exchanges[st.sid]
            step_args.extend(ex.plan_args)
            bound_specs.extend(ex.in_specs)
        slots[st.sid] = slice(lo, len(step_args))
    return (input_sids, input_specs, tuple(step_args), tuple(bound_specs),
            slots)


def _run_stages(stages, exchanges, slots, input_pos, inputs, bound, *,
                db_vals=None, prologue=False):
    """Trace the stage pipeline once (one ``shard_map`` body, one scan
    body, or — with ``prologue=True`` — the exchange-free prefix that
    seeds a scan's double-buffer carries).

    Returns ``(force, finish_feeds)``: ``force(sid)`` delivers a stage's
    value, finishing any exchange it consumes lazily so everything
    scheduled between issue and first consumption runs inside the
    collective's window; ``finish_feeds()`` delivers the ``feed()``
    exchanges issued this body — the next iteration's double-buffer
    carries."""
    env: dict[int, Any] = {}
    pending: dict[int, Callable[[], Any]] = {}
    feeds: dict[int, Callable[[], Any]] = {}

    def force(sid):
        if sid in pending:
            env[sid] = pending.pop(sid)()
        return env[sid]

    def finish_of(handle, finish_kwargs):
        if finish_kwargs:
            return lambda h=handle, kw=finish_kwargs: h.finish(**kw)
        return handle.finish

    for st in stages:
        if st.kind == "input":
            env[st.sid] = inputs[input_pos[st.sid]]
        elif st.kind == "constant":
            (env[st.sid],) = bound[slots[st.sid]]
        elif st.kind == "compute":
            if prologue:
                continue   # forced on demand below only via ancestors
            vals = [force(a.sid) for a in st.args]
            env[st.sid] = st.fn(*vals)
        elif prologue:
            continue       # no exchange ever runs in the prologue
        elif st.kind == "feed":
            # issue the NEXT iteration's exchange of a double-buffer
            # gather; its delivery is collected by finish_feeds() at the
            # end of the body and carried across the scan boundary
            g = stages[st.gather.sid]
            ex = exchanges[g.sid]
            handle = ex.start_local(force(st.src.sid), *bound[slots[g.sid]])
            feeds[g.sid] = finish_of(handle, g.finish_kwargs)
            env[st.sid] = ()
        elif st.kind == "gather" and st.double_buffer:
            # value delivered by the previous iteration's feed()
            env[st.sid] = db_vals[st.sid]
        else:
            # exchange stage: ISSUE the collective now; deliver (finish)
            # lazily when a later stage consumes it — everything in
            # between runs inside its window
            ex = exchanges[st.sid]
            handle = ex.start_local(force(st.src.sid), *bound[slots[st.sid]])
            pending[st.sid] = finish_of(
                handle, st.finish_kwargs if st.kind == "gather" else None)

    if prologue:
        # compute stages were skipped above; force() must still be able to
        # evaluate the exchange-free ancestry of a prime ref on demand
        def force_prologue(sid):
            if sid not in env:
                st = stages[sid]
                assert st.kind == "compute", (
                    f"prologue reached a {st.kind!r} stage — prime refs "
                    "must have exchange-free ancestry")
                env[sid] = st.fn(*[force_prologue(a.sid) for a in st.args])
            return env[sid]
        return force_prologue, None

    def finish_feeds():
        return {sid: fn() for sid, fn in feeds.items()}

    return force, finish_feeds


class ExchangeSchedule:
    """A compiled multi-exchange step: one ``shard_map``, one fused window.

    * ``step(*inputs)`` — jitted end-to-end call (inputs in declaration
      order, placed like ``shard_input`` expects);
    * ``.mapped`` / ``.step_args`` / ``.in_specs`` — the raw
      ``shard_map``-ed local function and its bound operands, for
      consumers that embed the step in their own ``jit``/``scan``;
    * ``.strategies`` — resolved rung per exchange stage;
    * ``.predicted_times`` — per-stage §5 rung rankings (auto stages);
    * ``.predicted_window`` — the fused-window composition prediction
      (``perfmodel.predict_schedule``), with per-stage terms and the
      consolidation saving; ``None`` when no hardware parameters were in
      scope (every stage on a fixed rung and no ``hw=`` passed).
    """

    def __init__(self, sched: Schedule, outputs: tuple, out_spec,
                 single: bool = True):
        ctx = sched._ctx
        mesh, axis_name = ctx["mesh"], ctx["axis_name"]
        self.mesh = mesh
        self.axis_name = axis_name
        self.topology = ctx["topology"]
        self.hw = ctx["hw"]
        self._stages = sched._stages
        self._exchanges = sched._exchanges
        self._outputs = outputs
        self._single = single
        stages = self._stages

        self.strategies = {st.name: self._exchanges[st.sid].strategy
                           for st in stages
                           if st.kind in ("gather", "scatter")}
        self.predicted_times = {
            st.name: self._exchanges[st.sid].predicted_times
            for st in stages if st.kind in ("gather", "scatter")}
        self.predicted_window = sched._predict_window()

        (self._input_sids, self._input_specs, self.step_args, bound_specs,
         slots) = _bind_operands(stages, self._exchanges, mesh, axis_name)
        self.in_specs = self._input_specs + bound_specs
        n_inputs = len(self._input_sids)
        input_pos = {sid: i for i, sid in enumerate(self._input_sids)}
        exchanges = self._exchanges

        def step_local(*args):
            inputs, bound = args[:n_inputs], args[n_inputs:]
            force, _ = _run_stages(stages, exchanges, slots, input_pos,
                                   inputs, bound)
            vals = tuple(force(o.sid) for o in outputs)
            return vals[0] if single else vals

        if out_spec is None:
            out_specs = P(axis_name) if single \
                else tuple(P(axis_name) for _ in outputs)
        else:
            out_specs = out_spec if single else tuple(out_spec)
        self.mapped = compat.shard_map(
            step_local, mesh=mesh, in_specs=self.in_specs,
            out_specs=out_specs, check_vma=False,
        )
        step_args_t = self.step_args

        @jax.jit
        def step(*inputs):
            return self.mapped(*inputs, *step_args_t)

        self._step = step

    def shard_input(self, value, which: int = 0) -> jax.Array:
        """Place a host value on the mesh with input ``which``'s spec."""
        spec = self._input_specs[which]
        return jax.device_put(value, NamedSharding(self.mesh, spec))

    # kept as the SpMV-flavored alias every front door exposes
    def shard_vector(self, value) -> jax.Array:
        return self.shard_input(value, 0)

    def __call__(self, *inputs) -> jax.Array:
        return self._step(*inputs)


def _exchange_free(stages, sid) -> bool:
    """True when stage ``sid``'s ancestry contains no exchange/feed stage
    (so the scan prologue can evaluate it from the initial carries)."""
    st = stages[sid]
    if st.kind in ("gather", "scatter", "feed"):
        return False
    if st.kind == "compute":
        return all(_exchange_free(stages, a.sid) for a in st.args)
    return True


class ScanSchedule:
    """A compiled scan-level schedule: ``lax.scan`` INSIDE one persistent
    ``shard_map`` window.

    Where ``ExchangeSchedule`` fuses a chain of exchanges into one window
    per call, ``ScanSchedule`` keeps that window open across a whole time
    loop: the scan body is the stage pipeline, so the entire n-step loop is
    ONE jitted XLA program entered once — one plan-cache probe and one
    ``measure_hw`` memo hit at build time, zero per-step host dispatch at
    run time.  ``n_steps`` is a static argument of the call: each distinct
    step count compiles once and is cached by jit.

    Carry contract: every ``input`` stage is a loop carry; calling
    ``scan(*carries, n_steps=k)`` runs ``k`` iterations where iteration
    outputs (the ``output=`` refs passed to ``Schedule.scan``) become the
    next iteration's inputs, and returns the final carries (a bare array
    when a single carry was declared).

    Double-buffer contract: a ``gather(double_buffer=True, prime=...)``
    stage reads the delivery of the exchange issued by its ``feed()`` stage
    one iteration earlier — the delivered value (not the in-flight handle)
    is an implicit extra carry, so step k+1's compute between the feed and
    the gather's consumer hides inside step k's collective window.  The
    prologue seeds iteration 0 from ``prime`` (evaluated on the initial
    carries); the final iteration's feed issues one exchange that is never
    consumed.

    * ``.strategies`` / ``.predicted_times`` / ``.predicted_window`` — as
      on ``ExchangeSchedule`` (the window entries price ONE iteration);
    * ``.predicted_loop(n_steps)`` — the eq.-23 steady-state extension
      (``perfmodel.predict_scan_schedule``): setup paid once, per-iteration
      window term, optional overlap credit.
    """

    def __init__(self, sched: Schedule, carry: tuple, outputs: tuple, *,
                 single: bool, n_steps_hint: int | None):
        ctx = sched._ctx
        mesh, axis_name = ctx["mesh"], ctx["axis_name"]
        self.mesh = mesh
        self.axis_name = axis_name
        self.topology = ctx["topology"]
        self.hw = ctx["hw"]
        self.n_steps_hint = n_steps_hint
        stages = sched._stages
        exchanges = sched._exchanges
        self._stages = stages
        self._single = single

        self.strategies = {st.name: exchanges[st.sid].strategy
                           for st in stages
                           if st.kind in ("gather", "scatter")}
        self.predicted_times = {
            st.name: exchanges[st.sid].predicted_times
            for st in stages if st.kind in ("gather", "scatter")}
        self.predicted_window = sched._predict_window()
        self._pricing_specs = sched._stage_specs()

        # ---- carry/output validation ----
        for c in carry:
            sched._check_ref(c)
            if c.kind != "input":
                raise ValueError(
                    f"carry refs must be input stages; {c.name!r} is a "
                    f"{c.kind} stage")
        input_sids = [st.sid for st in stages if st.kind == "input"]
        if sorted(c.sid for c in carry) != sorted(input_sids):
            raise ValueError(
                "carry= must name every input stage exactly once (each "
                "input is re-fed from its paired output every iteration)")
        if len(outputs) != len(carry):
            raise ValueError(
                f"output= must pair one stage per carry ({len(carry)} "
                f"carries, {len(outputs)} outputs)")
        for o in outputs:
            sched._check_ref(o, array_valued=True)

        db_stages = [st for st in stages
                     if st.kind == "gather" and st.double_buffer]
        fed = {st.gather.sid for st in stages if st.kind == "feed"}
        for st in db_stages:
            if st.sid not in fed:
                raise ValueError(
                    f"double_buffer stage {st.name!r} has no feed() stage "
                    "— nothing would issue its next-iteration exchange")
            if not _exchange_free(stages, st.src.sid):
                raise ValueError(
                    f"prime stage of {st.name!r} depends on an exchange "
                    "stage; the scan prologue runs before any exchange, "
                    "so prime ancestry must be input/constant/compute only")

        (all_input_sids, input_specs, self.step_args, bound_specs,
         slots) = _bind_operands(stages, exchanges, mesh, axis_name)
        spec_of = dict(zip(all_input_sids, input_specs))
        self._carry_specs = tuple(spec_of[c.sid] for c in carry)
        self.in_specs = self._carry_specs + bound_specs
        # inputs arrive in CARRY order (the call order), not declaration
        # order
        input_pos = {c.sid: i for i, c in enumerate(carry)}
        n_carry = len(carry)

        def loop_local(n_steps, *args):
            carries, bound = args[:n_carry], args[n_carry:]
            db0 = {}
            if db_stages:
                # prologue: seed each double-buffer carry by running its
                # prime exchange on the initial carries
                force0, _ = _run_stages(stages, exchanges, slots, input_pos,
                                        carries, bound, prologue=True)
                for st in db_stages:
                    ex = exchanges[st.sid]
                    handle = ex.start_local(force0(st.src.sid),
                                            *bound[slots[st.sid]])
                    kw = st.finish_kwargs
                    db0[st.sid] = handle.finish(**kw) if kw \
                        else handle.finish()

            def body(c, _):
                user, db_vals = c
                force, finish_feeds = _run_stages(
                    stages, exchanges, slots, input_pos, user, bound,
                    db_vals=db_vals)
                new_user = tuple(force(o.sid) for o in outputs)
                return (new_user, finish_feeds()), None

            (final, _), _ = jax.lax.scan(body, (tuple(carries), db0), None,
                                         length=n_steps)
            return final

        step_args_t = self.step_args
        in_specs_t = self.in_specs
        out_specs_t = self._carry_specs

        # n_steps must reach the scan as a static length, so the shard_map
        # is constructed inside the jit: one persistent window per distinct
        # step count, cached by jit like any static argument
        @functools.partial(jax.jit, static_argnames=("n_steps",))
        def run(n_steps, *carries):
            mapped = compat.shard_map(
                functools.partial(loop_local, n_steps), mesh=mesh,
                in_specs=in_specs_t, out_specs=out_specs_t, check_vma=False)
            return mapped(*carries, *step_args_t)

        self._run = run

    def shard_input(self, value, which: int = 0) -> jax.Array:
        """Place a host value on the mesh with carry ``which``'s spec."""
        spec = self._carry_specs[which]
        return jax.device_put(value, NamedSharding(self.mesh, spec))

    # the SpMV-flavored alias every front door exposes
    def shard_vector(self, value) -> jax.Array:
        return self.shard_input(value, 0)

    def predicted_loop(self, n_steps: int, *,
                       overlap_credit: float = 0.0) -> dict | None:
        """§5 steady-state loop pricing (``perfmodel.
        predict_scan_schedule``): setup paid once, ``n_steps`` per-iteration
        window terms, ``overlap_credit`` seconds of cross-step compute
        hidden per iteration by double-buffered stages.  ``None`` when no
        hardware parameters were in scope at resolve time."""
        if self.hw is None:
            return None
        from repro.core import perfmodel as pm
        return pm.predict_scan_schedule(self._pricing_specs, self.hw,
                                        n_steps,
                                        overlap_credit=overlap_credit)

    def __call__(self, *carries, n_steps: int):
        out = self._run(n_steps, *carries)
        return out[0] if self._single else out
