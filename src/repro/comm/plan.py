"""Communication planning — the paper's "one-time preparation step" (§4.3.1).

Given the access pattern of an indirectly indexed computation (any global
index set ``cols``; the column index table ``J`` of an EllPack SpMV is one
instance), this module computes — on the host, once, exactly like the paper's
preparation step — everything the communication strategies need at run time:

* ``condensed``  (paper UPCv3): per (sender, receiver) pair, the exact sorted
  list of **unique** owned elements the receiver needs; messages are condensed
  (only needed values) and consolidated (one message per pair).
* ``blockwise``  (paper UPCv2): per receiver, the bitmap of *virtual blocks*
  (``blocksize`` elements each, the paper's BLOCKSIZE dial) containing at
  least one needed element; whole blocks are moved.
* ``replicate``  (naive baseline): no plan — the whole vector is all-gathered.

The access pattern is ``m`` accessor rows of ``r`` global indices each into a
shared vector of length ``n``; accessor rows and vector elements are both
partitioned contiguously over the same ``p`` shards.  For SpMV ``m == n``
(row i's accesses); for a token→expert dispatch ``m`` is the number of
expert-capacity slots while ``n`` is the number of tokens.

Because XLA requires static shapes, ragged per-pair messages are padded to the
plan-wide maximum (``s_max`` / ``b_max``).  The padding volume is *counted and
exposed* (``padded_*`` fields) so the performance model can report the
TPU-specific padding tax the paper's ragged UPC messages did not pay.

The plan also produces every count the paper's performance models (§5.2) need:
``C_local_indv`` / ``C_remote_indv`` (UPCv1, eq. 10), ``B_local`` /
``B_remote`` (UPCv2, eq. 11), and ``S_*`` / ``C_remote_out`` (UPCv3,
eqs. 12–15), split intra-node vs inter-node through a ``Topology``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Topology", "GatherCounts", "CommPlan", "ScatterPlan",
           "build_comm_plan", "blockwise_block_counts", "attach_destination",
           "pattern_cols", "derive_scatter_plan", "transpose_counts"]


@dataclasses.dataclass(frozen=True)
class Topology:
    """Maps shards ("threads") to nodes, like the paper's Abel layout.

    On TPU, a "node" is a pod (the slow DCI boundary); on the host-device
    validation runs it models the paper's compute nodes.
    """

    num_shards: int
    shards_per_node: int

    def __post_init__(self):
        assert self.num_shards % self.shards_per_node == 0

    @property
    def num_nodes(self) -> int:
        return self.num_shards // self.shards_per_node

    def node_of(self, shard: np.ndarray | int):
        return np.asarray(shard) // self.shards_per_node


@dataclasses.dataclass(frozen=True)
class GatherCounts:
    """Per-shard communication counts feeding the §5 performance models.

    All arrays have length P (num shards).  Sizes are in *elements*.
    """

    # UPCv1 (eq. 10): occurrences of non-owned accesses (duplicates counted).
    c_local_indv: np.ndarray
    c_remote_indv: np.ndarray
    # UPCv2 (eq. 11): needed blocks by residence (own-node blocks include the
    # shard's own blocks — the diagonal term makes every own block needed).
    b_local: np.ndarray
    b_remote: np.ndarray
    blocksize: int
    # UPCv3 (eqs. 12–15): per-shard unique-value message volumes.
    s_local_out: np.ndarray
    s_remote_out: np.ndarray
    s_local_in: np.ndarray
    s_remote_in: np.ndarray
    c_remote_out: np.ndarray  # number of outgoing inter-node messages
    # TPU padding tax: total elements actually moved by the padded collectives.
    padded_condensed_per_shard: int
    padded_blockwise_per_shard: int

    def total_condensed_volume(self) -> int:
        return int((self.s_local_out + self.s_remote_out).sum())

    def total_blockwise_volume(self) -> int:
        return int((self.b_local + self.b_remote).sum() * self.blocksize)


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """Static gather plan for one access pattern over one partitioning."""

    n: int                     # global vector length
    p: int                     # number of shards on the comm axis
    shard_size: int            # n // p
    blocksize: int             # virtual block size (paper BLOCKSIZE)
    topology: Topology
    m: int                     # accessor rows (== n for SpMV-like patterns)

    # --- condensed (UPCv3) ---
    s_max: int
    send_counts: np.ndarray     # (P, P) int32; [src, dst]
    send_local_idx: np.ndarray  # (P, P, s_max) int32, local idx into src shard
    recv_global_idx: np.ndarray # (P, P, s_max) int32; [dst, src, k] -> global
                                # position in x_copy; padding -> n (dump slot)

    # --- blockwise (UPCv2) ---
    b_max: int
    send_block_counts: np.ndarray  # (P, P) int32
    send_local_blk: np.ndarray     # (P, P, b_max) int32, local block id in src
    recv_global_blk: np.ndarray    # (P, P, b_max) int32; [dst, src, j] ->
                                   # global block id; padding -> nblks (dump)

    # --- overlap (own/foreign compute split) ---
    # Per-row compaction of ``cols`` into own-shard accesses (resolvable from
    # x_local alone, while the all_to_all is in flight) and foreign accesses
    # (resolvable only after the condensed exchange lands).  ``*_src`` maps
    # each compacted slot back to its original r_nz slot so the engine can
    # split ``vals`` the same way on the host.
    r_loc_max: int
    r_rem_max: int
    loc_cols: np.ndarray  # (m, r_loc_max) int32 shard-local; padding -> shard_size
    loc_src: np.ndarray   # (m, r_loc_max) int32 original slot; padding -> 0
    rem_cols: np.ndarray  # (m, r_rem_max) int32 global; padding -> n + 1
    rem_src: np.ndarray   # (m, r_rem_max) int32 original slot; padding -> 0

    counts: GatherCounts

    # --- consumer-targeted unpack (optional ``Destination`` descriptor) ---
    # Precomputed recv-buffer -> consumer-slot gathers so ``finish`` can land
    # messages straight in the consumer's named buffers (O(L) slots) instead
    # of assembling the full-length x_copy.  All arrays are (P, L); each slot
    # is exactly one of {owned, foreign, zero}: ``dest_own_idx`` reads
    # x_local, ``dest_cond_src`` / ``dest_blk_src`` read the flattened
    # condensed / blockwise recv buffer, ``dest_global_idx`` reads the
    # replicate all-gather; the two int8 masks zero out the other source.
    dest_len: int = 0
    dest_own_idx: np.ndarray | None = None    # (P, L) int32 into x_local
    dest_own_mask: np.ndarray | None = None   # (P, L) int8, 1 where owned
    dest_rem_mask: np.ndarray | None = None   # (P, L) int8, 1 where foreign
    dest_cond_src: np.ndarray | None = None   # (P, L) int32 into (P*s_max)
    dest_blk_src: np.ndarray | None = None    # (P, L) into (P*b_max*BS)
    dest_global_idx: np.ndarray | None = None  # (P, L) int32 global ids

    @property
    def nblks(self) -> int:
        return self.n // self.blocksize

    @property
    def blocks_per_shard(self) -> int:
        return self.shard_size // self.blocksize

    @property
    def rows_per_shard(self) -> int:
        """Accessor rows owned by each shard (== shard_size when m == n)."""
        return self.m // self.p

    def transpose(self) -> "ScatterPlan":
        """The push-direction (put/scatter) plan for the same access pattern.

        The paper's condensing/consolidation machinery is direction-agnostic:
        its per-pair message lists depend only on *which* elements cross each
        (sender, receiver) boundary, not on which side initiates.  The
        transposed plan therefore reuses this plan's tables with the roles
        swapped — the gather's unpack table (``recv_global_idx``) becomes the
        scatter's pack table, and the gather's pack table (``send_local_idx``)
        becomes the scatter's accumulate-unpack table — plus a few O(m·r)
        derived arrays (message-slot positions per contribution, the
        ``reduce="set"`` winner mask, the touched-element mask).

        ``transpose()`` of the result returns this plan again (an involution);
        ``repro.comm.plan_cache.get_scatter_plan`` persists the derived
        arrays as a format-v4 delta so re-runs skip the derivation.
        """
        return derive_scatter_plan(self)


def pattern_cols(plan: CommPlan) -> np.ndarray:
    """Reconstruct the (m, r) global index table the plan was built from.

    The overlap-split arrays (``loc_cols``/``loc_src``/``rem_cols``/
    ``rem_src``) are a lossless per-row compaction of the original ``cols``:
    valid owned slots carry local indices (< shard_size, padding ==
    shard_size), valid foreign slots carry global indices (< n, padding ==
    n + 1), and the ``*_src`` maps give each compacted slot's original
    position.  Inverting them recovers ``cols`` exactly, so a scatter plan
    can be derived from a cached gather plan without re-supplying the
    pattern.
    """
    m, shard = plan.m, plan.shard_size
    rows_shard = np.repeat(np.arange(plan.p), plan.rows_per_shard)
    lvalid = plan.loc_cols != shard
    rvalid = plan.rem_cols != plan.n + 1
    r = int(lvalid[0].sum() + rvalid[0].sum())
    cols = np.zeros((m, r), np.int64)
    li, lk = np.nonzero(lvalid)
    cols[li, plan.loc_src[li, lk]] = (plan.loc_cols[li, lk]
                                      + rows_shard[li] * shard)
    ri, rk = np.nonzero(rvalid)
    cols[ri, plan.rem_src[ri, rk]] = plan.rem_cols[ri, rk]
    return cols.astype(np.int32)


def transpose_counts(plan: CommPlan) -> GatherCounts:
    """Put-direction §5 volume counts: send and recv roles swapped.

    Per-shard outgoing volume in the put direction equals the gather's
    incoming volume (``s_*_in``) and vice versa; the outgoing inter-node
    message count becomes the number of distinct inter-node *receivers* this
    shard contributes to; block counts become the blocks this shard pushes,
    split by the receiver's node.  The fine-grained occurrence counts
    (``c_*_indv``) are unchanged — they count the accessor shard's foreign
    touches, which is the sender in the put direction.
    """
    p = plan.p
    node = plan.topology.node_of(np.arange(p))
    c = plan.counts
    sc = plan.send_counts          # [src, dst] in the gather direction
    sbc = plan.send_block_counts
    same = node[:, None] == node[None, :]   # [src, dst]
    # put sender q's message to s has the gather pair (s -> q)'s size
    c_rem_out = ((sc > 0) & ~same).sum(axis=0).astype(np.int64)
    b_local = (np.where(same, sbc, 0).sum(axis=0)
               + plan.blocks_per_shard).astype(np.int64)
    b_remote = np.where(same, 0, sbc).sum(axis=0).astype(np.int64)
    return GatherCounts(
        c_local_indv=c.c_local_indv,
        c_remote_indv=c.c_remote_indv,
        b_local=b_local,
        b_remote=b_remote,
        blocksize=plan.blocksize,
        s_local_out=c.s_local_in,
        s_remote_out=c.s_remote_in,
        s_local_in=c.s_local_out,
        s_remote_in=c.s_remote_out,
        c_remote_out=c_rem_out,
        padded_condensed_per_shard=c.padded_condensed_per_shard,
        padded_blockwise_per_shard=c.padded_blockwise_per_shard,
    )


@dataclasses.dataclass(frozen=True)
class ScatterPlan:
    """Static push-direction (put/scatter) executor tables for one pattern.

    Derived from a gather ``CommPlan`` by ``CommPlan.transpose()`` — the
    base plan's per-pair tables are reused with send/recv roles swapped, so
    the O(nnz) preparation step is never repeated for the reverse direction.
    Accessor row i's slot j *contributes* a value to global element
    ``tgt_global[i, j]``; duplicate targets combine under a ``reduce``
    semantic chosen at execution time (``"add"`` / ``"set"`` / ``"max"``).

    All executor arrays are host numpy, shaped for ``shard_map`` delivery
    (leading dim m or P, sharded contiguously like the base plan):

    * ``cond_msg_idx``: flat position of each contribution in the sender's
      padded (P, s_max) condensed message buffer (owned targets -> the dump
      slot ``p * s_max``); the receiver accumulates the landed buffer at
      ``base.send_local_idx[me]`` — the gather's pack table, role-swapped.
    * ``blk_msg_idx``: same for the blockwise (P, b_max, BS) buffer.
    * ``own_tgt_idx``: local position of owned targets (foreign -> the dump
      slot ``shard_size``) so own contributions accumulate without touching
      the network.
    * ``win_mask``: 1 on the single contribution slot that wins each target
      under ``reduce="set"`` (the last contributor in row-major accessor
      order) — masking all other slots to the reduce identity makes "set"
      deterministic on every rung.
    * ``touched``: 1 where an owned element receives at least one
      contribution — ``reduce="max"`` returns 0 (not the -inf identity) on
      untouched elements.
    """

    base: CommPlan
    tgt_global: np.ndarray    # (m, r) int32 global target per contribution
    cond_msg_idx: np.ndarray  # (m, r) int32 into (P*s_max); owned -> dump
    blk_msg_idx: np.ndarray   # (m, r) int32 into (P*b_max*BS); owned -> dump
    own_tgt_idx: np.ndarray   # (m, r) int32 into own shard; foreign -> dump
    win_mask: np.ndarray      # (m, r) int8, reduce="set" winner slots
    touched: np.ndarray       # (P, shard_size) int8, >=1 contribution
    counts: GatherCounts      # put-direction counts (see transpose_counts)

    # -- partitioning facts proxied from the base plan --
    @property
    def n(self) -> int:
        return self.base.n

    @property
    def p(self) -> int:
        return self.base.p

    @property
    def m(self) -> int:
        return self.base.m

    @property
    def r(self) -> int:
        return self.tgt_global.shape[1]

    @property
    def shard_size(self) -> int:
        return self.base.shard_size

    @property
    def blocksize(self) -> int:
        return self.base.blocksize

    @property
    def topology(self) -> Topology:
        return self.base.topology

    @property
    def s_max(self) -> int:
        return self.base.s_max

    @property
    def b_max(self) -> int:
        return self.base.b_max

    @property
    def blocks_per_shard(self) -> int:
        return self.base.blocks_per_shard

    @property
    def rows_per_shard(self) -> int:
        return self.base.rows_per_shard

    @property
    def dest_len(self) -> int:
        """Scatter delivery is always owner-targeted; no Destination."""
        return 0

    def transpose(self) -> CommPlan:
        """The pull-direction plan this was derived from (involution)."""
        return self.base


def derive_scatter_plan(plan: CommPlan) -> ScatterPlan:
    """Derive the push-direction executor tables from a gather plan.

    O(m·r·log s_max) searchsorted passes over the base plan's already-sorted
    per-pair lists — never a second O(nnz) planning step.  Prefer
    ``CommPlan.transpose()`` (this function is its implementation) or the
    cached ``plan_cache.get_scatter_plan``.
    """
    cols = pattern_cols(plan)
    p, n, shard = plan.p, plan.n, plan.shard_size
    m, r = cols.shape
    bs = plan.blocksize
    rows_per_shard = plan.rows_per_shard
    rows_shard = np.repeat(np.arange(p), rows_per_shard)
    owner = cols // shard
    own = owner == rows_shard[:, None]

    cond_msg = np.full((m, r), p * plan.s_max, np.int64)       # dump slot
    blk_msg = np.full((m, r), p * plan.b_max * bs, np.int64)   # dump slot
    for q in range(p):
        rows = slice(q * rows_per_shard, (q + 1) * rows_per_shard)
        # group this shard's foreign contributions by owner once (one
        # stable sort), then resolve each owner's contiguous segment —
        # O(m·r·log) total, never p passes over every contribution
        flat_c = cols[rows].ravel()
        foreign = np.flatnonzero(~own[rows].ravel())
        if not len(foreign):
            continue
        fo = owner[rows].ravel()[foreign]
        grp = np.argsort(fo, kind="stable")
        fo, fc, fslot = fo[grp], flat_c[foreign][grp], foreign[grp]
        bounds = np.searchsorted(fo, np.arange(p + 1))
        cflat = cond_msg[rows].reshape(-1)
        bflat = blk_msg[rows].reshape(-1)
        for s in range(p):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            if lo == hi:
                continue
            tgt, slot = fc[lo:hi], fslot[lo:hi]
            # the gather's unpack list for pair (q <- s) IS the put
            # direction's message contents for pair (q -> s): sorted unique
            # globals owned by s that q touches
            k = int(plan.send_counts[s, q])
            need = plan.recv_global_idx[q, s, :k]
            pos = np.searchsorted(need, tgt)
            assert k and (need[np.minimum(pos, k - 1)] == tgt).all(), (
                "gather plan does not cover this pattern")
            cflat[slot] = s * plan.s_max + pos
            kb = int(plan.send_block_counts[s, q])
            bneed = plan.recv_global_blk[q, s, :kb]
            bpos = np.searchsorted(bneed, tgt // bs)
            assert kb and (bneed[np.minimum(bpos, kb - 1)]
                           == tgt // bs).all(), (
                "gather plan is missing a needed block")
            bflat[slot] = s * plan.b_max * bs + bpos * bs + tgt % bs

    own_tgt = np.where(own, cols - rows_shard[:, None] * shard, shard)

    # reduce="set" winner: the last contribution in row-major accessor order
    flat_t = cols.ravel().astype(np.int64)
    order = np.arange(m * r, dtype=np.int64)
    last = np.full(n, -1, np.int64)
    np.maximum.at(last, flat_t, order)
    win = (last[flat_t] == order).reshape(m, r)

    touched = np.zeros(n, np.int8)
    touched[flat_t] = 1

    return ScatterPlan(
        base=plan,
        tgt_global=cols,
        cond_msg_idx=cond_msg.astype(np.int32),
        blk_msg_idx=blk_msg.astype(np.int32),
        own_tgt_idx=own_tgt.astype(np.int32),
        win_mask=win.astype(np.int8),
        touched=touched.reshape(p, shard),
        counts=transpose_counts(plan),
    )


def blockwise_block_counts(
    cols: np.ndarray,
    n: int,
    p: int,
    blocksize: int,
    topology: Topology,
) -> tuple[np.ndarray, np.ndarray]:
    """Eq.-11 block counts (B_local, B_remote per shard) for one BLOCKSIZE.

    A cheap standalone pass (no message arrays) so the autotuner can sweep
    BLOCKSIZE candidates without building a full plan per candidate.
    """
    cols = np.asarray(cols)
    m = cols.shape[0]
    shard_size = n // p
    rows_per_shard = m // p
    node = topology.node_of(np.arange(p))
    b_local = np.zeros(p, np.int64)
    b_remote = np.zeros(p, np.int64)
    own_blocks = shard_size // blocksize
    for q in range(p):
        cq = cols[q * rows_per_shard:(q + 1) * rows_per_shard].ravel()
        uniq = np.unique(cq[(cq // shard_size) != q])
        fblk = np.unique(uniq // blocksize)
        blk_owner_node = node[(fblk * blocksize) // shard_size]
        b_local[q] = int((blk_owner_node == node[q]).sum()) + own_blocks
        b_remote[q] = int((blk_owner_node != node[q]).sum())
    return b_local, b_remote


def attach_destination(plan: CommPlan, destination) -> CommPlan:
    """Precompute the recv→slot gathers for one ``Destination`` descriptor.

    ``destination`` is a ``repro.comm.pattern.Destination`` (anything with a
    ``(P, L)`` int ``indices`` table; sentinel -1 = deliver exactly 0.0).
    For each device the L slots are classified owned / foreign / zero, and
    each foreign slot is resolved to its position in the landed condensed
    recv buffer ``(P, s_max)`` and blockwise recv buffer ``(P, b_max, BS)``.
    Raises ``ValueError`` if a foreign slot's global id is not part of the
    plan's access pattern — that value would never be exchanged.

    Returns a new ``CommPlan`` with the ``dest_*`` fields populated; the
    plan cache stores the combined (pattern, destination) entry under its
    own content key (format v3).
    """
    dest_idx = np.asarray(destination.indices)
    p, L = dest_idx.shape
    assert p == plan.p, (p, plan.p)
    shard_size = plan.shard_size
    n = plan.n

    g = dest_idx.astype(np.int64)
    zero = g < 0
    owner = np.where(zero, 0, g) // shard_size
    own = (~zero) & (owner == np.arange(p)[:, None])
    rem = (~zero) & ~own

    own_idx = np.where(
        own, g - (np.arange(p) * shard_size)[:, None], 0).astype(np.int32)
    cond_src = np.zeros((p, L), np.int32)
    blk_src = np.zeros((p, L), np.int32)
    bs = plan.blocksize
    for q in range(p):
        gq = g[q][rem[q]]
        if not len(gq):
            continue
        # condensed: position of each foreign id in the landed (P, s_max)
        rg = plan.recv_global_idx[q].ravel()
        valid = np.flatnonzero(rg != n)
        order = np.argsort(rg[valid], kind="stable")
        sorted_ids, flat_pos = rg[valid][order], valid[order]
        loc = np.searchsorted(sorted_ids, gq)
        hit = np.zeros(len(gq), bool)
        inb = loc < len(sorted_ids)
        hit[inb] = sorted_ids[loc[inb]] == gq[inb]
        if not hit.all():
            missing = np.unique(gq[~hit])[:8]
            raise ValueError(
                f"destination slot(s) on shard {q} read global ids "
                f"{missing.tolist()} that the access pattern never "
                "gathers — every foreign destination index must appear "
                "in the AccessPattern the plan was built from")
        cond_src[q][rem[q]] = flat_pos[loc]
        # blockwise: whole blocks land; position = block slot * BS + offset
        rb = plan.recv_global_blk[q].ravel()
        bvalid = np.flatnonzero(rb != plan.nblks)
        border = np.argsort(rb[bvalid], kind="stable")
        sorted_blk, blk_pos = rb[bvalid][border], bvalid[border]
        bloc = np.searchsorted(sorted_blk, gq // bs)
        assert (sorted_blk[np.minimum(bloc, len(sorted_blk) - 1)]
                == gq // bs).all(), "block plan missing a needed block"
        blk_src[q][rem[q]] = (blk_pos[bloc] * bs + gq % bs).astype(np.int32)

    return dataclasses.replace(
        plan,
        dest_len=L,
        dest_own_idx=own_idx,
        dest_own_mask=own.astype(np.int8),
        dest_rem_mask=rem.astype(np.int8),
        dest_cond_src=cond_src,
        dest_blk_src=blk_src,
        dest_global_idx=np.where(zero, 0, g).astype(np.int32),
    )


def build_comm_plan(
    cols: np.ndarray,
    n: int,
    p: int,
    *,
    blocksize: int | None = None,
    topology: Topology | None = None,
    destination=None,
    s_max: int | None = None,
) -> CommPlan:
    """One-time preparation step (paper §4.3.1).

    ``cols``: (m, r) global indices accessed while computing accessor row i.
    Vector elements are partitioned contiguously: shard q owns elements
    ``[q*shard_size, (q+1)*shard_size)``; accessor rows likewise: shard q owns
    rows ``[q*rows_per_shard, (q+1)*rows_per_shard)``.  ``m == n`` for
    SpMV-like patterns where every element is also an accessor.

    ``s_max`` widens the condensed padding to an *envelope* bound (≥ the
    pattern's natural per-pair maximum).  Every routing with the same shape
    then shares one executor-table geometry, which is what lets
    ``comm.dynamic`` swap per-batch device-derived tables into a cached
    envelope plan and what ``plan_cache.get_envelope_plan`` keys on.  The
    padded volume grows accordingly and is priced by ``counts.padded_*``.
    """
    assert n % p == 0, f"n={n} must divide into p={p} shards (pad upstream)"
    shard_size = n // p
    if blocksize is None:
        blocksize = shard_size
    assert shard_size % blocksize == 0, (
        f"shard_size={shard_size} must be a multiple of blocksize={blocksize}"
    )
    if topology is None:
        topology = Topology(num_shards=p, shards_per_node=p)
    assert topology.num_shards == p

    cols = np.asarray(cols)
    if cols.ndim == 1:
        cols = cols[:, None]
    m = cols.shape[0]
    assert m % p == 0, f"m={m} accessor rows must divide into p={p} shards"
    rows_per_shard = m // p
    owner = cols // shard_size  # (m, r_nz) owning shard of each access

    shard_rows = [slice(q * rows_per_shard, (q + 1) * rows_per_shard)
                  for q in range(p)]
    node = topology.node_of(np.arange(p))

    # ---- per-pair unique needed indices (condensed) ----
    # need[q][s] = sorted unique globals owned by s that shard q needs, s != q
    need: list[list[np.ndarray]] = []
    c_local_indv = np.zeros(p, np.int64)
    c_remote_indv = np.zeros(p, np.int64)
    b_local = np.zeros(p, np.int64)
    b_remote = np.zeros(p, np.int64)
    for q in range(p):
        cq = cols[shard_rows[q]].ravel()
        oq = owner[shard_rows[q]].ravel()
        foreign = oq != q
        same_node = node[oq] == node[q]
        c_local_indv[q] = int((foreign & same_node).sum())
        c_remote_indv[q] = int((foreign & ~same_node).sum())

        uniq = np.unique(cq[foreign])
        per_src = [uniq[(uniq // shard_size) == s] for s in range(p)]
        need.append(per_src)

        # blockwise: needed blocks (foreign blocks from J + all own blocks,
        # own blocks are always needed via the diagonal x[offset+k] term)
        fblk = np.unique(uniq // blocksize)
        own_blk_node_local = shard_size // blocksize  # own blocks, same node
        blk_owner_node = node[(fblk * blocksize) // shard_size]
        b_local[q] = int((blk_owner_node == node[q]).sum()) + own_blk_node_local
        b_remote[q] = int((blk_owner_node != node[q]).sum())

    # ---- condensed plan arrays ----
    send_counts = np.zeros((p, p), np.int32)
    for q in range(p):
        for s in range(p):
            send_counts[s, q] = len(need[q][s])
    natural_s_max = max(1, int(send_counts.max()))
    if s_max is None:
        s_max = natural_s_max
    assert s_max >= natural_s_max, (
        f"envelope s_max={s_max} is below the pattern's per-pair maximum "
        f"{natural_s_max}; widening-only (entries would be dropped)")

    send_local_idx = np.zeros((p, p, s_max), np.int32)
    recv_global_idx = np.full((p, p, s_max), n, np.int32)  # dump slot = n
    for q in range(p):
        for s in range(p):
            g = need[q][s]
            k = len(g)
            if k:
                send_local_idx[s, q, :k] = g - s * shard_size
                recv_global_idx[q, s, :k] = g

    # ---- blockwise plan arrays ----
    nblks = n // blocksize
    blocks_per_shard = shard_size // blocksize
    send_block_counts = np.zeros((p, p), np.int32)
    blk_need: list[list[np.ndarray]] = []
    for q in range(p):
        per_src = []
        for s in range(p):
            if len(need[q][s]):
                bl = np.unique(need[q][s] // blocksize)
            else:
                bl = np.zeros(0, np.int64)
            per_src.append(bl)
            send_block_counts[s, q] = len(bl)
        blk_need.append(per_src)
    b_max = max(1, int(send_block_counts.max()))

    send_local_blk = np.zeros((p, p, b_max), np.int32)
    recv_global_blk = np.full((p, p, b_max), nblks, np.int32)  # dump block
    for q in range(p):
        for s in range(p):
            bl = blk_need[q][s]
            k = len(bl)
            if k:
                send_local_blk[s, q, :k] = bl - s * blocks_per_shard
                recv_global_blk[q, s, :k] = bl

    # ---- overlap split: compact each row's accesses into own-shard vs
    # foreign slots (vectorized; stable order preserves the original slot
    # sequence inside each group) ----
    r_nz = cols.shape[1]
    rows_shard = np.repeat(np.arange(p), rows_per_shard)  # owning shard per row
    is_loc = owner == rows_shard[:, None]                 # (m, r_nz)
    loc_count = is_loc.sum(axis=1)
    rem_count = r_nz - loc_count
    r_loc_max = max(1, int(loc_count.max()))
    r_rem_max = max(1, int(rem_count.max()))
    pos = np.arange(r_nz)[None, :]

    order_loc = np.argsort(~is_loc, axis=1, kind="stable")  # own slots first
    cols_by_loc = np.take_along_axis(cols, order_loc, axis=1)
    lvalid = pos < loc_count[:, None]
    # padding -> shard_size: x_local is extended with one zero slot there
    loc_cols = np.where(
        lvalid, cols_by_loc - (rows_shard * shard_size)[:, None], shard_size
    )[:, :r_loc_max].astype(np.int32)
    loc_src = np.where(lvalid, order_loc, 0)[:, :r_loc_max].astype(np.int32)

    order_rem = np.argsort(is_loc, axis=1, kind="stable")   # foreign first
    cols_by_rem = np.take_along_axis(cols, order_rem, axis=1)
    rvalid = pos < rem_count[:, None]
    # padding -> n + 1: x_copy keeps that slot zero (n is the recv dump)
    rem_cols = np.where(rvalid, cols_by_rem, n + 1)[:, :r_rem_max].astype(
        np.int32)
    rem_src = np.where(rvalid, order_rem, 0)[:, :r_rem_max].astype(np.int32)

    # ---- perf-model counts (§5.2) ----
    s_out_l = np.zeros(p, np.int64)
    s_out_r = np.zeros(p, np.int64)
    s_in_l = np.zeros(p, np.int64)
    s_in_r = np.zeros(p, np.int64)
    c_rem_out = np.zeros(p, np.int64)
    for s in range(p):
        for q in range(p):
            k = int(send_counts[s, q])
            if k == 0:
                continue
            if node[s] == node[q]:
                s_out_l[s] += k
                s_in_l[q] += k
            else:
                s_out_r[s] += k
                s_in_r[q] += k
                c_rem_out[s] += 1

    counts = GatherCounts(
        c_local_indv=c_local_indv,
        c_remote_indv=c_remote_indv,
        b_local=b_local,
        b_remote=b_remote,
        blocksize=blocksize,
        s_local_out=s_out_l,
        s_remote_out=s_out_r,
        s_local_in=s_in_l,
        s_remote_in=s_in_r,
        c_remote_out=c_rem_out,
        padded_condensed_per_shard=p * s_max,
        padded_blockwise_per_shard=p * b_max * blocksize,
    )

    plan = CommPlan(
        n=n,
        p=p,
        shard_size=shard_size,
        blocksize=blocksize,
        topology=topology,
        m=m,
        s_max=s_max,
        send_counts=send_counts,
        send_local_idx=send_local_idx,
        recv_global_idx=recv_global_idx,
        b_max=b_max,
        send_block_counts=send_block_counts,
        send_local_blk=send_local_blk,
        recv_global_blk=recv_global_blk,
        r_loc_max=r_loc_max,
        r_rem_max=r_rem_max,
        loc_cols=loc_cols,
        loc_src=loc_src,
        rem_cols=rem_cols,
        rem_src=rem_src,
        counts=counts,
    )
    if destination is not None:
        plan = attach_destination(plan, destination)
    return plan
