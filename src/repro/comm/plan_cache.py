"""Persistent CommPlan cache — skip the O(nnz) host-side preparation step.

The paper amortizes its one-time preparation step (§4.3.1) over ~1000 SpMV
iterations *within one run*.  Real workloads re-run: the same mesh is loaded
again tomorrow, on the same pod, with the same partitioning.  This module
extends the amortization *across processes* by memoizing ``build_comm_plan``
on a content hash of everything the plan depends on:

    sha256(cols bytes) + n + p + blocksize + topology  ->  plan arrays (.npz)

Two layers:
  * an in-process dict (free; hit when the same engine is constructed twice
    in one process, e.g. to compare strategies over one matrix), and
  * an on-disk ``.npz`` store under ``$REPRO_PLAN_CACHE_DIR`` (default
    ``~/.cache/repro/commplans``), safe against concurrent writers via
    write-to-temp + atomic rename.

``stats`` counts hits/misses/builds so tests (and users) can verify that a
second construction performs no plan rebuild.  Set ``REPRO_PLAN_CACHE=0`` to
disable entirely.  Plans whose arrays exceed ``REPRO_PLAN_CACHE_MAX_BYTES``
(default 256 MiB, pre-compression) stay memory-only so pathological
partitionings cannot silently fill the user's disk; entries are written with
``np.savez_compressed`` (plan arrays are mostly padding and compress well).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import tempfile

import numpy as np

from repro.comm.plan import CommPlan, GatherCounts, Topology, build_comm_plan

__all__ = ["plan_key", "get_comm_plan", "clear_memory_cache", "stats",
           "CacheStats", "cache_dir"]

# Bump when the CommPlan field set/serialization changes OR when
# build_comm_plan's output semantics change for the same inputs (planner bug
# fixes included) — the version participates in the content key, so bumping
# invalidates every stale on-disk entry.
# v2: accessor-row count ``m`` decoupled from vector length ``n``.
_FORMAT_VERSION = 2

# fields serialized verbatim as arrays
_PLAN_ARRAYS = ("send_counts", "send_local_idx", "recv_global_idx",
                "send_block_counts", "send_local_blk", "recv_global_blk",
                "loc_cols", "loc_src", "rem_cols", "rem_src")
_COUNT_ARRAYS = ("c_local_indv", "c_remote_indv", "b_local", "b_remote",
                 "s_local_out", "s_remote_out", "s_local_in", "s_remote_in",
                 "c_remote_out")
_COUNT_SCALARS = ("blocksize", "padded_condensed_per_shard",
                  "padded_blockwise_per_shard")


@dataclasses.dataclass
class CacheStats:
    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0     # full plan builds performed

    def reset(self) -> None:
        self.memory_hits = self.disk_hits = self.misses = 0

    @property
    def hits(self) -> int:
        return self.memory_hits + self.disk_hits


stats = CacheStats()
# LRU-bounded: long-lived processes sweeping many matrices must not retain
# every plan ever built (large partitionings are hundreds of MB each)
_memory: "collections.OrderedDict[str, CommPlan]" = collections.OrderedDict()


def _max_memory_entries() -> int:
    return int(os.environ.get("REPRO_PLAN_CACHE_MEM_ENTRIES", 16))


def clear_memory_cache() -> None:
    _memory.clear()


def _memory_put(key: str, plan: CommPlan) -> None:
    _memory[key] = plan
    _memory.move_to_end(key)
    while len(_memory) > max(1, _max_memory_entries()):
        _memory.popitem(last=False)


def cache_dir() -> str:
    return os.environ.get(
        "REPRO_PLAN_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "commplans"),
    )


def _enabled() -> bool:
    return os.environ.get("REPRO_PLAN_CACHE", "1") != "0"


def _max_disk_bytes() -> int:
    return int(os.environ.get("REPRO_PLAN_CACHE_MAX_BYTES", 256 << 20))


def plan_key(
    cols: np.ndarray, n: int, p: int, blocksize: int, topology: Topology
) -> str:
    """Content hash of every input ``build_comm_plan`` depends on."""
    cols = np.ascontiguousarray(np.asarray(cols, dtype=np.int32))
    h = hashlib.sha256()
    h.update(f"v{_FORMAT_VERSION}|{n}|{p}|{blocksize}|"
             f"{topology.num_shards}|{topology.shards_per_node}|"
             f"{cols.shape}".encode())
    h.update(cols.tobytes())
    return h.hexdigest()


def _serialize(plan: CommPlan) -> dict[str, np.ndarray]:
    out = {name: getattr(plan, name) for name in _PLAN_ARRAYS}
    for name in _COUNT_ARRAYS:
        out[f"counts.{name}"] = getattr(plan.counts, name)
    meta = np.array(
        [_FORMAT_VERSION, plan.n, plan.p, plan.shard_size, plan.blocksize,
         plan.topology.num_shards, plan.topology.shards_per_node,
         plan.s_max, plan.b_max, plan.r_loc_max, plan.r_rem_max]
        + [getattr(plan.counts, name) for name in _COUNT_SCALARS]
        + [plan.m],
        dtype=np.int64,
    )
    out["meta"] = meta
    return out


def _deserialize(data) -> CommPlan:
    meta = data["meta"]
    if int(meta[0]) != _FORMAT_VERSION:
        raise ValueError("stale plan-cache format")
    topo = Topology(num_shards=int(meta[5]), shards_per_node=int(meta[6]))
    counts = GatherCounts(
        **{name: np.asarray(data[f"counts.{name}"]) for name in _COUNT_ARRAYS},
        blocksize=int(meta[11]),
        padded_condensed_per_shard=int(meta[12]),
        padded_blockwise_per_shard=int(meta[13]),
    )
    return CommPlan(
        n=int(meta[1]), p=int(meta[2]), shard_size=int(meta[3]),
        blocksize=int(meta[4]), topology=topo, m=int(meta[14]),
        s_max=int(meta[7]), b_max=int(meta[8]),
        r_loc_max=int(meta[9]), r_rem_max=int(meta[10]),
        counts=counts,
        **{name: np.asarray(data[name]) for name in _PLAN_ARRAYS},
    )


def _disk_path(key: str) -> str:
    return os.path.join(cache_dir(), f"{key}.npz")


def _load_disk(key: str) -> CommPlan | None:
    path = _disk_path(key)
    if not os.path.exists(path):
        return None
    try:
        with np.load(path) as data:
            return _deserialize(data)
    except Exception:
        # corrupt / stale entry: treat as miss, rebuild will overwrite
        return None


def _store_disk(key: str, plan: CommPlan) -> None:
    data = _serialize(plan)
    if sum(a.nbytes for a in data.values()) > _max_disk_bytes():
        return  # memory-only: don't let huge plans fill the disk
    path = _disk_path(key)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, **data)
        os.replace(tmp, path)  # atomic: concurrent writers race harmlessly
    except Exception:
        if os.path.exists(tmp):
            os.unlink(tmp)


def get_comm_plan(
    cols: np.ndarray,
    n: int,
    p: int,
    *,
    blocksize: int | None = None,
    topology: Topology | None = None,
    cache: bool = True,
) -> CommPlan:
    """Cached drop-in for ``build_comm_plan`` (same semantics, same result)."""
    shard_size = n // p
    bs = shard_size if blocksize is None else blocksize
    topo = topology if topology is not None else Topology(p, p)
    if not (cache and _enabled()):
        stats.misses += 1
        return build_comm_plan(cols, n, p, blocksize=blocksize,
                               topology=topology)

    key = plan_key(cols, n, p, bs, topo)
    plan = _memory.get(key)
    if plan is not None:
        stats.memory_hits += 1
        _memory.move_to_end(key)
        return plan
    plan = _load_disk(key)
    if plan is not None:
        stats.disk_hits += 1
        _memory_put(key, plan)
        return plan

    stats.misses += 1
    plan = build_comm_plan(cols, n, p, blocksize=blocksize, topology=topology)
    _memory_put(key, plan)
    _store_disk(key, plan)
    return plan
