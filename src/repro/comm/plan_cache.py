"""Persistent CommPlan cache — skip the O(nnz) host-side preparation step.

The paper amortizes its one-time preparation step (§4.3.1) over ~1000 SpMV
iterations *within one run*.  Real workloads re-run: the same mesh is loaded
again tomorrow, on the same pod, with the same partitioning.  This module
extends the amortization *across processes* by memoizing ``build_comm_plan``
on a content hash of everything the plan depends on:

    sha256(cols bytes) + n + p + blocksize + topology  ->  plan arrays (.npz)

Two layers:
  * an in-process dict (free; hit when the same engine is constructed twice
    in one process, e.g. to compare strategies over one matrix), and
  * an on-disk ``.npz`` store under ``$REPRO_PLAN_CACHE_DIR`` (default
    ``~/.cache/repro/commplans``), safe against concurrent writers via
    write-to-temp + atomic rename.

``stats`` counts hits/misses/builds so tests (and users) can verify that a
second construction performs no plan rebuild.  Set ``REPRO_PLAN_CACHE=0`` to
disable entirely.  Plans whose arrays exceed ``REPRO_PLAN_CACHE_MAX_BYTES``
(default 256 MiB, pre-compression) stay memory-only so pathological
partitionings cannot silently fill the user's disk; entries are written with
``np.savez_compressed`` (plan arrays are mostly padding and compress well).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import os
import tempfile
import threading
import time
import warnings

import numpy as np

from repro.comm import telemetry
from repro.comm.plan import (CommPlan, GatherCounts, ScatterPlan, Topology,
                             attach_destination, build_comm_plan,
                             derive_scatter_plan)

__all__ = ["plan_key", "get_comm_plan", "get_scatter_plan",
           "get_envelope_plan", "envelope_plan_key", "clear_memory_cache",
           "stats", "CacheStats", "isolated", "cache_dir",
           "StalePlanCacheError"]

# Bump when the CommPlan field set/serialization changes OR when
# build_comm_plan's output semantics change for the same inputs (planner bug
# fixes included) — the version participates in the content key, so bumping
# invalidates every stale on-disk entry.
# v2: accessor-row count ``m`` decoupled from vector length ``n``.
# v3: optional ``Destination`` descriptor (consumer-targeted unpack arrays
#     ``dest_*``); the destination content participates in the key.
# v4: transpose-derived scatter (put-direction) executor tables, stored as
#     O(m*r) delta entries referencing the direction-agnostic base plan.
# v5: bucketed envelope-plan reuse for dynamic (per-batch) patterns —
#     ``get_envelope_plan`` entries are keyed on *quantized pattern stats*
#     (per-destination unique counts rounded up to bucket boundaries) plus
#     the envelope ``s_max``, never on the exact index bytes, so a
#     compatible cached envelope is reused across routings with a cheap
#     in-window permutation (the device-derived tables of ``comm.dynamic``).
_FORMAT_VERSION = 5

# fields serialized verbatim as arrays
_PLAN_ARRAYS = ("send_counts", "send_local_idx", "recv_global_idx",
                "send_block_counts", "send_local_blk", "recv_global_blk",
                "loc_cols", "loc_src", "rem_cols", "rem_src")
# destination arrays, present only when the plan was built with one
_DEST_ARRAYS = ("dest_own_idx", "dest_own_mask", "dest_rem_mask",
                "dest_cond_src", "dest_blk_src", "dest_global_idx")
# scatter (put-direction) delta arrays; a scatter entry stores these plus
# its put-direction counts and a reference to the base (gather) entry
_SCATTER_ARRAYS = ("tgt_global", "cond_msg_idx", "blk_msg_idx",
                   "own_tgt_idx", "win_mask", "touched")


class StalePlanCacheError(ValueError):
    """An on-disk plan entry uses an older format than this build writes.

    Raised by ``_deserialize`` and converted into a rebuild (with a visible
    warning) by the cache lookup — a stale entry must never be silently
    reinterpreted as current-format garbage.
    """
_COUNT_ARRAYS = ("c_local_indv", "c_remote_indv", "b_local", "b_remote",
                 "s_local_out", "s_remote_out", "s_local_in", "s_remote_in",
                 "c_remote_out")
_COUNT_SCALARS = ("blocksize", "padded_condensed_per_shard",
                  "padded_blockwise_per_shard")


_STAT_FIELDS = ("memory_hits", "disk_hits", "misses", "derives", "evictions")


@dataclasses.dataclass
class CacheStats:
    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0     # full O(nnz) plan builds performed
    derives: int = 0    # scatter-delta derivations performed
    evictions: int = 0  # stale legacy-format entries deleted from disk

    def reset(self) -> None:
        for field in _STAT_FIELDS:
            setattr(self, field, 0)

    def bump(self, field: str) -> None:
        """Increment one counter under the cache lock — a bare ``+= 1``
        loses increments under the concurrent access this module supports."""
        with _memory_lock:
            setattr(self, field, getattr(self, field) + 1)

    def snapshot(self) -> dict:
        """A detached copy of every counter — safe to compare later.

        >>> s = CacheStats(misses=2, evictions=1)
        >>> snap = s.snapshot()
        >>> snap["misses"], snap["evictions"], snap["hits"]
        (2, 1, 0)
        """
        with _memory_lock:
            out = {field: getattr(self, field) for field in _STAT_FIELDS}
        out["hits"] = out["memory_hits"] + out["disk_hits"]
        return out

    @property
    def hits(self) -> int:
        return self.memory_hits + self.disk_hits


stats = CacheStats()


@contextlib.contextmanager
def isolated():
    """Capture-safe scope: a fresh ``CacheStats`` becomes the module global
    for the duration and the previous one is restored after — tests observe
    their own counters without mutating (or racing on) the process-wide
    ``stats``.  The plan caches themselves are untouched; pair with
    ``clear_memory_cache()`` / ``REPRO_PLAN_CACHE_DIR`` for full isolation.
    """
    global stats
    prev = stats
    stats = CacheStats()
    try:
        yield stats
    finally:
        stats = prev
# LRU-bounded: long-lived processes sweeping many matrices must not retain
# every plan ever built (large partitionings are hundreds of MB each).
# Every access goes through _memory_get/_memory_put/clear_memory_cache
# under _memory_lock: get-then-move_to_end is not atomic on its own, and a
# concurrent clear between the two steps raises KeyError.
_memory: "collections.OrderedDict[str, object]" = collections.OrderedDict()
_memory_lock = threading.Lock()


def _max_memory_entries() -> int:
    return int(os.environ.get("REPRO_PLAN_CACHE_MEM_ENTRIES", 16))


def clear_memory_cache() -> None:
    with _memory_lock:
        _memory.clear()


def _memory_get(key: str):
    with _memory_lock:
        plan = _memory.get(key)
        if plan is not None:
            _memory.move_to_end(key)
        return plan


def _memory_put(key: str, plan) -> None:
    with _memory_lock:
        _memory[key] = plan
        _memory.move_to_end(key)
        while len(_memory) > max(1, _max_memory_entries()):
            _memory.popitem(last=False)


def cache_dir() -> str:
    return os.environ.get(
        "REPRO_PLAN_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "commplans"),
    )


def _enabled() -> bool:
    return os.environ.get("REPRO_PLAN_CACHE", "1") != "0"


def _max_disk_bytes() -> int:
    return int(os.environ.get("REPRO_PLAN_CACHE_MAX_BYTES", 256 << 20))


def _key_for_version(
    version: int, cols: np.ndarray, n: int, p: int, blocksize: int,
    topology: Topology, destination=None, scatter: bool = False,
) -> str:
    cols = np.ascontiguousarray(np.asarray(cols, dtype=np.int32))
    h = hashlib.sha256()
    h.update(f"v{version}|{n}|{p}|{blocksize}|"
             f"{topology.num_shards}|{topology.shards_per_node}|"
             f"{cols.shape}".encode())
    h.update(cols.tobytes())
    if destination is not None:
        h.update(b"|dest|")
        h.update(destination.key_bytes())
    if scatter:
        h.update(b"|scatter|")
    return h.hexdigest()


def plan_key(
    cols: np.ndarray, n: int, p: int, blocksize: int, topology: Topology,
    destination=None, scatter: bool = False,
) -> str:
    """Content hash of every input ``build_comm_plan`` depends on.

    A plan built with a ``Destination`` descriptor hashes the destination
    content too, so the same access pattern with different consumer slot
    tables yields distinct cache entries; ``scatter=True`` keys the
    transpose-derived put-direction delta for the same pattern.
    """
    return _key_for_version(_FORMAT_VERSION, cols, n, p, blocksize,
                            topology, destination, scatter)


# On-disk formats this build knows how to *recognize* (not read): their
# version prefix participated in the content key, so a newer build would
# otherwise never open them and the orphans would silently count against
# REPRO_PLAN_CACHE_MAX_BYTES forever.
_LEGACY_VERSIONS = (2, 3, 4)


def _evict_stale_entries(cols, n, p, blocksize, topology) -> None:
    """Surface + remove pre-v5 entries for this exact plan input.

    An older build stored this plan under its version-prefixed content key;
    probe those filenames so a genuine upgrade gets the explicit migration
    warning and the stale file is deleted rather than orphaned.  Each
    deletion is recorded in ``stats.evictions``.
    """
    for old in _LEGACY_VERSIONS:
        path = _disk_path(_key_for_version(old, cols, n, p, blocksize,
                                           topology))
        if os.path.exists(path):
            warnings.warn(
                f"plan-cache entry {os.path.basename(path)} was written by "
                f"a v{old}-format build; this build reads "
                f"v{_FORMAT_VERSION} (v5 added bucketed envelope-plan "
                "reuse for dynamic patterns) — the stale entry is deleted "
                "and the plan rebuilt", stacklevel=3)
            try:
                os.unlink(path)
                stats.bump("evictions")
            except OSError:
                pass


def _serialize(plan: CommPlan,
               base_key: str | None = None) -> dict[str, np.ndarray]:
    """Entry payload.  A destination-keyed plan with a ``base_key`` is
    stored as a *delta*: only the O(L) ``dest_*`` arrays plus a reference
    to the destination-free base entry — the O(nnz) base arrays are never
    duplicated on disk per destination."""
    if plan.dest_len and base_key is not None:
        out = {name: getattr(plan, name) for name in _DEST_ARRAYS}
        out["base_key"] = np.frombuffer(
            base_key.encode("ascii"), dtype=np.uint8).copy()
    else:
        out = {name: getattr(plan, name) for name in _PLAN_ARRAYS}
        for name in _COUNT_ARRAYS:
            out[f"counts.{name}"] = getattr(plan.counts, name)
        if plan.dest_len:
            for name in _DEST_ARRAYS:
                out[name] = getattr(plan, name)
    meta = np.array(
        [_FORMAT_VERSION, plan.n, plan.p, plan.shard_size, plan.blocksize,
         plan.topology.num_shards, plan.topology.shards_per_node,
         plan.s_max, plan.b_max, plan.r_loc_max, plan.r_rem_max]
        + [getattr(plan.counts, name) for name in _COUNT_SCALARS]
        + [plan.m, plan.dest_len],
        dtype=np.int64,
    )
    out["meta"] = meta
    return out


def _check_version(meta) -> None:
    found = int(meta[0])
    if found != _FORMAT_VERSION:
        raise StalePlanCacheError(
            f"plan-cache entry has format v{found} but this build reads "
            f"v{_FORMAT_VERSION} (v5 added bucketed envelope-plan reuse "
            f"for dynamic patterns); the entry is ignored and the plan "
            f"rebuilt — delete {cache_dir()} to clear stale entries")


def _deserialize(data) -> CommPlan:
    meta = data["meta"]
    _check_version(meta)
    topo = Topology(num_shards=int(meta[5]), shards_per_node=int(meta[6]))
    counts = GatherCounts(
        **{name: np.asarray(data[f"counts.{name}"]) for name in _COUNT_ARRAYS},
        blocksize=int(meta[11]),
        padded_condensed_per_shard=int(meta[12]),
        padded_blockwise_per_shard=int(meta[13]),
    )
    dest_len = int(meta[15])
    dest = {name: np.asarray(data[name]) for name in _DEST_ARRAYS} \
        if dest_len else {}
    return CommPlan(
        n=int(meta[1]), p=int(meta[2]), shard_size=int(meta[3]),
        blocksize=int(meta[4]), topology=topo, m=int(meta[14]),
        s_max=int(meta[7]), b_max=int(meta[8]),
        r_loc_max=int(meta[9]), r_rem_max=int(meta[10]),
        counts=counts, dest_len=dest_len, **dest,
        **{name: np.asarray(data[name]) for name in _PLAN_ARRAYS},
    )


def _disk_path(key: str) -> str:
    return os.path.join(cache_dir(), f"{key}.npz")


def _serialize_scatter(splan: ScatterPlan, base_key: str) -> dict:
    """Scatter entries are always deltas: the O(m*r) executor tables plus
    the put-direction counts and a reference to the base (gather) entry —
    the O(nnz) base arrays are never duplicated on disk per direction."""
    out = {name: getattr(splan, name) for name in _SCATTER_ARRAYS}
    for name in _COUNT_ARRAYS:
        out[f"counts.{name}"] = getattr(splan.counts, name)
    out["base_key"] = np.frombuffer(
        base_key.encode("ascii"), dtype=np.uint8).copy()
    out["meta"] = np.array(
        [_FORMAT_VERSION]
        + [getattr(splan.counts, name) for name in _COUNT_SCALARS],
        dtype=np.int64)
    return out


def _load_disk(key: str) -> CommPlan | ScatterPlan | None:
    path = _disk_path(key)
    if not os.path.exists(path):
        return None
    try:
        with np.load(path) as data:
            if "base_key" not in data.files:
                return _deserialize(data)
            # delta entry (destination or scatter): small arrays + a
            # reference to the direction-agnostic base entry
            meta = data["meta"]
            _check_version(meta)
            base_key = data["base_key"].tobytes().decode("ascii")
            is_scatter = "tgt_global" in data.files
            if is_scatter:
                delta = {name: np.asarray(data[name])
                         for name in _SCATTER_ARRAYS}
                counts = GatherCounts(
                    **{name: np.asarray(data[f"counts.{name}"])
                       for name in _COUNT_ARRAYS},
                    blocksize=int(meta[1]),
                    padded_condensed_per_shard=int(meta[2]),
                    padded_blockwise_per_shard=int(meta[3]),
                )
            else:
                dest_len = int(meta[15])
                dest = {name: np.asarray(data[name])
                        for name in _DEST_ARRAYS}
        base = _memory_get(base_key)
        if not isinstance(base, CommPlan):
            base = None
        if base is None:
            base = _load_disk(base_key)
        if base is None:
            return None  # base evicted; caller re-derives from scratch
        if is_scatter:
            return ScatterPlan(base=base, counts=counts, **delta)
        return dataclasses.replace(base, dest_len=dest_len, **dest)
    except StalePlanCacheError as e:
        # pre-v4 entry: reject loudly with the migration message and
        # rebuild — never reinterpret old bytes as a current-format plan
        warnings.warn(str(e), stacklevel=2)
        return None
    except Exception:
        # corrupt entry: treat as miss, rebuild will overwrite
        return None


def _store_disk_data(key: str, data: dict) -> None:
    if sum(a.nbytes for a in data.values()) > _max_disk_bytes():
        return  # memory-only: don't let huge plans fill the disk
    path = _disk_path(key)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, **data)
        os.replace(tmp, path)  # atomic: concurrent writers race harmlessly
    except Exception:
        if os.path.exists(tmp):
            os.unlink(tmp)


def _store_disk(key: str, plan: CommPlan, base_key: str | None = None) -> None:
    _store_disk_data(key, _serialize(plan, base_key))


def get_comm_plan(
    cols: np.ndarray,
    n: int,
    p: int,
    *,
    blocksize: int | None = None,
    topology: Topology | None = None,
    destination=None,
    base: CommPlan | None = None,
    cache: bool = True,
) -> CommPlan:
    """Cached drop-in for ``build_comm_plan`` (same semantics, same result).

    With ``destination`` the entry is keyed on (pattern, destination); on a
    miss the pattern-only base plan is looked up first, so attaching a new
    ``Destination`` to an already-planned pattern skips the O(nnz) build
    and pays only the O(L) slot-resolution pass.  The on-disk entry stores
    only that delta (dest arrays + base reference), never a second copy of
    the base arrays.  A caller that already holds the destination-free plan
    for the same inputs passes it as ``base`` to skip even the lookup.
    """
    shard_size = n // p
    bs = shard_size if blocksize is None else blocksize
    topo = topology if topology is not None else Topology(p, p)
    if not (cache and _enabled()):
        if destination is not None and base is not None:
            return attach_destination(base, destination)
        stats.bump("misses")
        t0 = time.perf_counter()
        plan = build_comm_plan(cols, n, p, blocksize=blocksize,
                               topology=topology, destination=destination)
        telemetry.record("host-build", time.perf_counter() - t0)
        return plan

    key = plan_key(cols, n, p, bs, topo, destination)
    plan = _memory_get(key)
    if isinstance(plan, CommPlan):
        stats.bump("memory_hits")
        telemetry.record("memory-hit")
        return plan
    plan = _load_disk(key)
    if plan is not None:
        stats.bump("disk_hits")
        telemetry.record("disk-hit")
        _memory_put(key, plan)
        return plan

    if destination is not None:
        # the O(nnz) part is destination-independent: reuse (and populate)
        # the base entry, then attach the cheap O(L) destination arrays
        # (the base lookup records its own telemetry event)
        if base is None:
            base = get_comm_plan(cols, n, p, blocksize=blocksize,
                                 topology=topology, cache=cache)
        plan = attach_destination(base, destination)
        _memory_put(key, plan)
        _store_disk(key, plan, base_key=plan_key(cols, n, p, bs, topo))
    else:
        _evict_stale_entries(cols, n, p, bs, topo)
        stats.bump("misses")
        t0 = time.perf_counter()
        plan = build_comm_plan(cols, n, p, blocksize=blocksize,
                               topology=topology)
        telemetry.record("host-build", time.perf_counter() - t0)
        _memory_put(key, plan)
        _store_disk(key, plan)
    return plan


def get_scatter_plan(
    cols: np.ndarray,
    n: int,
    p: int,
    *,
    blocksize: int | None = None,
    topology: Topology | None = None,
    base: CommPlan | None = None,
    cache: bool = True,
) -> ScatterPlan:
    """Cached drop-in for ``CommPlan.transpose()`` (same semantics).

    The entry is keyed on (pattern, partitioning, ``scatter`` marker); on a
    miss the direction-agnostic base plan is looked up first (and built at
    most once — a gather and a scatter of the same pattern share it), then
    the O(m*r) put-direction executor tables are derived and stored as a
    format-v4 delta referencing the base entry.  A caller that already
    holds the base plan passes it as ``base`` to skip even the lookup.
    """
    shard_size = n // p
    bs = shard_size if blocksize is None else blocksize
    topo = topology if topology is not None else Topology(p, p)
    if not (cache and _enabled()):
        if base is None:
            stats.bump("misses")
            t0 = time.perf_counter()
            base = build_comm_plan(cols, n, p, blocksize=blocksize,
                                   topology=topology)
            telemetry.record("host-build", time.perf_counter() - t0)
        stats.bump("derives")
        t0 = time.perf_counter()
        splan = derive_scatter_plan(base)
        telemetry.record("host-build", time.perf_counter() - t0)
        return splan

    key = plan_key(cols, n, p, bs, topo, scatter=True)
    splan = _memory_get(key)
    if isinstance(splan, ScatterPlan):
        stats.bump("memory_hits")
        telemetry.record("memory-hit")
        return splan
    splan = _load_disk(key)
    if splan is not None:
        stats.bump("disk_hits")
        telemetry.record("disk-hit")
        _memory_put(key, splan)
        return splan

    if base is None:
        base = get_comm_plan(cols, n, p, blocksize=blocksize,
                             topology=topology, cache=cache)
    stats.bump("derives")
    t0 = time.perf_counter()
    splan = derive_scatter_plan(base)
    telemetry.record("host-build", time.perf_counter() - t0)
    _memory_put(key, splan)
    _store_disk_data(key, _serialize_scatter(
        splan, base_key=plan_key(cols, n, p, bs, topo)))
    return splan


def _quantized_pattern_stats(
    cols: np.ndarray, n: int, p: int, bucket: int,
) -> np.ndarray:
    """Per-(reader, owner) unique foreign counts, rounded UP to ``bucket``
    multiples — the shape-stable fingerprint two routings share when one's
    envelope plan can stand in for the other's."""
    cols = np.asarray(cols)
    if cols.ndim == 1:
        cols = cols[:, None]
    m = cols.shape[0]
    shard_size = n // p
    rows_per_shard = m // p
    counts = np.zeros((p, p), np.int64)
    for q in range(p):
        cq = cols[q * rows_per_shard:(q + 1) * rows_per_shard].ravel()
        uniq = np.unique(cq[(cq // shard_size) != q])
        counts[q] = np.bincount(uniq // shard_size, minlength=p)
    return (-(-counts // bucket) * bucket).astype(np.int64)


def envelope_plan_key(
    cols: np.ndarray, n: int, p: int, blocksize: int, topology: Topology,
    s_max: int, bucket: int = 8,
) -> str:
    """Content key of the bucketed-reuse tier (format v5).

    Unlike ``plan_key`` this never hashes the index bytes: two routings of
    the same shape whose quantized per-destination unique counts round to
    the same bucket boundaries — and that share the envelope ``s_max`` —
    map to the same entry, so the second one reuses the first's envelope
    plan instead of paying a host rebuild.
    """
    cols = np.asarray(cols)
    if cols.ndim == 1:
        cols = cols[:, None]
    quant = _quantized_pattern_stats(cols, n, p, bucket)
    h = hashlib.sha256()
    h.update(f"env|v{_FORMAT_VERSION}|{n}|{p}|{cols.shape}|{blocksize}|"
             f"{topology.num_shards}|{topology.shards_per_node}|"
             f"{s_max}|{bucket}".encode())
    h.update(np.ascontiguousarray(quant).tobytes())
    return h.hexdigest()


def get_envelope_plan(
    cols: np.ndarray,
    n: int,
    p: int,
    *,
    blocksize: int | None = None,
    topology: Topology | None = None,
    s_max: int | None = None,
    bucket: int = 8,
    cache: bool = True,
) -> CommPlan:
    """The bucketed-reuse tier: a capacity-padded plan shared across routings.

    Builds (or reuses) a ``build_comm_plan(..., s_max=s_max)`` *envelope*
    plan keyed on ``envelope_plan_key`` — quantized pattern stats, never the
    exact index bytes.  A hit means a compatible envelope already exists:
    its static geometry (``s_max`` padding, in_specs shapes) and §5 pricing
    (volumes correct to within one bucket per pair) stand in for this
    routing's, and the *exact* executor tables come from the cheap in-window
    permutation — ``comm.dynamic.derive_gather_tables`` /
    ``derive_scatter_tables`` evaluated on the batch's indices inside the
    consumer's jit.  The hit is recorded as ``bucket-reuse`` telemetry; a
    miss pays (and records) one ``host-build``.

    The returned plan's index tables correspond to the entry's *founding*
    routing, not necessarily ``cols`` — callers on the dynamic path must
    override them with device-derived tables and must not read
    ``send_local_idx`` / ``recv_global_idx`` et al. as this batch's truth.
    ``s_max`` defaults to the shape's envelope bound
    (``dynamic.envelope_s_max``), which every same-shaped routing satisfies.
    """
    from repro.comm.dynamic import envelope_s_max

    cols = np.asarray(cols)
    if cols.ndim == 1:
        cols = cols[:, None]
    m, r = cols.shape
    shard_size = n // p
    bs = shard_size if blocksize is None else blocksize
    topo = topology if topology is not None else Topology(p, p)
    if s_max is None:
        s_max = envelope_s_max(m, r, n, p)

    def _build() -> CommPlan:
        stats.bump("misses")
        t0 = time.perf_counter()
        plan = build_comm_plan(cols, n, p, blocksize=blocksize,
                               topology=topology, s_max=s_max)
        telemetry.record("host-build", time.perf_counter() - t0)
        return plan

    if not (cache and _enabled()):
        return _build()

    key = envelope_plan_key(cols, n, p, bs, topo, s_max, bucket)
    plan = _memory_get(key)
    if isinstance(plan, CommPlan):
        stats.bump("memory_hits")
        telemetry.record("bucket-reuse")
        return plan
    plan = _load_disk(key)
    if plan is not None:
        stats.bump("disk_hits")
        telemetry.record("bucket-reuse")
        _memory_put(key, plan)
        return plan

    plan = _build()
    _memory_put(key, plan)
    _store_disk(key, plan)
    return plan
