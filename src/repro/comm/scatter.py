"""IrregularScatter — the push-direction front door to the strategy ladder.

The paper's condensing/consolidation strategies and §5 cost models apply
symmetrically to puts and gets: the performance formulas hinge only on
message volumes, not direction.  ``IrregularScatter`` is the put-side dual
of ``IrregularGather``: accessor row i's slot j *contributes* a value to
global element ``pattern.indices[i, j]`` of a sharded vector, duplicate
targets combine under a ``reduce`` semantic, and every ladder rung (or
``"auto"`` via the put-direction §5 models) moves exactly the same per-pair
message sets as the gather of the same pattern — the plan is literally the
gather plan with send/recv tables swapped (``CommPlan.transpose()``,
persisted as a format-v4 plan-cache delta).

Reduce semantics (all deterministic, see ``strategies.SCATTER_REDUCES``):

* ``"add"`` — y[t] = sum of contributions (0 where none); the MoE
  expert→token combine and the SpMV-transpose accumulate.
* ``"max"`` — y[t] = max of contributions (0 where none).
* ``"set"`` — y[t] = the last contribution in row-major accessor order
  (0 where none), via the plan's precomputed winner mask.

Composition mirrors the gather exactly:

* standalone: ``y = scatter(vals)`` with ``vals`` the (m, r, feat...)
  contribution table sharded over accessor rows; returns the combined
  length-n vector sharded over owners.
* fused: thread ``scatter.plan_args`` through your own ``shard_map`` and
  call ``scatter.local(vals_local, *plan_args_l)`` inside — or use the
  handle protocol to hide the exchange behind local compute::

      def step_local(vals_local, *plan_args_l):
          handle = scatter.start_local(vals_local, *plan_args_l)  # issued
          extra = ...            # anything that doesn't need the landed msgs
          y_local = handle.finish()   # own-accumulate + landed foreign
          return y_local + extra

  ``finish`` runs the own-shard accumulate first — it has no data
  dependency on the collective, so XLA's latency-hiding scheduler overlaps
  it (that is the ``overlap`` rung's whole trick; as a pure scatter it is
  identical to ``condensed``).

See docs/comm_api.md for a runnable walkthrough and docs/perf_model.md for
the put-direction pricing.  In a ``repro.comm.schedule`` chain a scatter
is one *stage*: it reuses a sibling gather stage's base plan (its executor
tables are the transpose-derived delta) and its own-shard accumulate runs
inside the fused window.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm import dynamic as dyn
from repro.comm import plan_cache
from repro.comm import strategies as strat
from repro.comm.exchange import IrregularExchange
from repro.comm.plan import CommPlan, ScatterPlan, transpose_counts

__all__ = ["IrregularScatter", "ScatterHandle"]


@dataclasses.dataclass
class ScatterHandle:
    """An in-flight scatter: the packed contributions are on the wire, the
    owned slice is not yet combined.  ``finish()`` returns the device's
    combined ``y_local`` (shard_size, feat...)."""

    vals_local: jax.Array
    _finish: Callable[[], jax.Array]

    def finish(self) -> jax.Array:
        return self._finish()


class IrregularScatter(IrregularExchange):
    """Plan + strategy + device state for scattering contributions to one
    ``AccessPattern``'s targets over one mesh axis (or tuple of axes).

    The pattern plays the transposed role: its (m, r) indices are *write*
    targets.  Accessor rows and vector elements are partitioned contiguously
    over the same shards, exactly as for the gather — so a gather and a
    scatter of the same pattern share one cached base plan.
    """

    direction = "put"

    def __init__(self, pattern, where, *, reduce: str = "add", **kwargs):
        """``reduce`` picks the duplicate-combining semantic (``"add"`` /
        ``"set"`` / ``"max"``).  Remaining keyword arguments (``axis_name``,
        ``strategy``, ``blocksize``, ``shards_per_node``, ``topology``,
        ``hw``, ``candidates``, ``use_plan_cache``, ``use_kernel``) are the
        shared ``IrregularExchange`` surface."""
        if reduce not in strat.SCATTER_REDUCES:
            raise ValueError(
                f"reduce must be one of {strat.SCATTER_REDUCES}")
        self.reduce = reduce
        super().__init__(pattern, where, **kwargs)

    def _prepare(self, base_plan: CommPlan) -> None:
        # the transpose-derived executor tables are strategy-independent,
        # so they are resolved (and cached as a v4 delta) before the §5
        # ranking, whose put-direction counts they carry
        if self.dynamic_pattern is not None:
            # the envelope base plan's tables may belong to a different
            # founding routing (bucket reuse), so the host transpose-derive
            # cannot probe them — derive the template's put tables on
            # device instead (bit-identical to the host derivation at the
            # envelope s_max); blockwise is outside the dynamic ladder, its
            # table stays all-dump
            cols = np.asarray(self.pattern.indices)
            n, p, s_max = base_plan.n, base_plan.p, base_plan.s_max
            g = dyn.derive_gather_tables(cols, n, p, s_max)
            s = dyn.derive_scatter_tables(cols, n, p, s_max, gather=g)
            m, r = cols.shape
            dump_blk = base_plan.p * base_plan.b_max * base_plan.blocksize
            self._dyn_send_local_idx = np.asarray(g.send_local_idx)
            self.splan = ScatterPlan(
                base=base_plan,
                tgt_global=cols.astype(np.int32),
                cond_msg_idx=np.asarray(s.cond_msg_idx),
                blk_msg_idx=np.full((m, r), dump_blk, np.int32),
                own_tgt_idx=np.asarray(s.own_tgt_idx),
                win_mask=np.asarray(s.win_mask),
                touched=np.asarray(s.touched),
                counts=transpose_counts(base_plan),
            )
            return
        self.splan = plan_cache.get_scatter_plan(
            self.pattern.indices, base_plan.n, base_plan.p,
            blocksize=base_plan.blocksize, topology=base_plan.topology,
            base=base_plan, cache=self._use_plan_cache,
        )

    def _ranking_plan(self, base_plan: CommPlan):
        return self.splan

    def _bind(self, base_plan: CommPlan, strategy: str) -> None:
        mesh, axis_name = self.mesh, self.axis_name
        self.plan = base_plan  # the shared (direction-agnostic) base plan
        splan = self.splan

        shard = NamedSharding(mesh, P(axis_name))
        self.in_specs = strat.scatter_in_specs(strategy, axis_name)
        if self.dynamic_pattern is not None:
            # same substitution as the gather: the envelope base plan's
            # accumulate-unpack table may belong to a different founding
            # routing, so the static surface carries the template's own
            # device-derived table (the other four came from _prepare)
            device_args = (splan.cond_msg_idx, self._dyn_send_local_idx,
                           splan.own_tgt_idx, splan.win_mask, splan.touched)
        else:
            device_args = strat.scatter_plan_device_args(splan, strategy)
        self.plan_args = tuple(
            jax.device_put(a, shard) for a in device_args
        )
        self._start, self._finish = strat.make_scatter_start_local(
            splan, strategy, axis_name, self.reduce,
            use_kernel=self.use_kernel)

        self._scatter_all = jax.jit(compat.shard_map(
            self.local,
            mesh=mesh,
            in_specs=(P(axis_name),) + self.in_specs,
            out_specs=P(axis_name),
            check_vma=False,
        ))

    @property
    def counts(self):
        """Put-direction per-shard volume counts (§5 put-model inputs)."""
        return self.splan.counts

    # ---- shard_map-local surface (compose inside a consumer's step) ----
    def local(self, vals_local: jax.Array, *plan_args) -> jax.Array:
        """One-shot local scatter: contributions (rows, r, feat...) ->
        combined owned slice (shard_size, feat...)."""
        in_flight = self._start(vals_local, *plan_args)
        return self._finish(in_flight, vals_local, *plan_args)

    def start_local(self, vals_local: jax.Array,
                    *plan_args) -> ScatterHandle:
        """Pack + issue the exchange; compute while it flies.  The
        own-shard accumulate runs inside ``finish`` and has no dependency
        on the collective, so the scheduler hides the exchange behind it
        (plus anything the consumer schedules in between)."""
        in_flight = self._start(vals_local, *plan_args)

        def finish():
            return self._finish(in_flight, vals_local, *plan_args)

        return ScatterHandle(vals_local=vals_local, _finish=finish)

    # ---- dynamic surface (per-batch patterns, see repro.comm.dynamic) ----
    def derive_plan_args(self, cols, gather_tables=None) -> tuple:
        """Traced per-batch replacement for ``plan_args``.

        ``cols`` is this batch's (m, r) int32 target table — traced inside
        the consumer's jit.  Pass ``gather_tables`` (the
        ``DynamicGatherTables`` a sibling gather of the same pattern
        already derived) to share the one sort between both directions —
        the ``CommPlan.transpose()`` economy, in-jit.  Returns the five
        condensed/overlap executor tables in ``in_specs`` order.  The
        caller records ``telemetry.record("device-derive")`` once per call
        (not here: this body runs once per trace).
        """
        if self.strategy not in dyn.DYNAMIC_STRATEGIES:
            raise ValueError(
                f"derive_plan_args serves {dyn.DYNAMIC_STRATEGIES} "
                f"executor tables, not {self.strategy!r}")
        n, p, s_max = self.plan.n, self.p, self.plan.s_max
        g = gather_tables
        if g is None:
            g = dyn.derive_gather_tables(cols, n, p, s_max)
        s = dyn.derive_scatter_tables(cols, n, p, s_max, gather=g)
        return (s.cond_msg_idx, g.send_local_idx, s.own_tgt_idx,
                s.win_mask, s.touched)

    # ---- standalone surface ----
    def shard_values(self, vals) -> jax.Array:
        """Place a host (m, r, feat...) contribution table on the mesh,
        sharded over accessor rows like the plan expects (the scatter-
        flavored name for the inherited contiguous placement)."""
        return self.shard_vector(vals)

    def __call__(self, vals: jax.Array) -> jax.Array:
        """Combined length-n vector (plus feature dims), sharded over the
        owning devices: y[t] = reduce of all contributions targeting t."""
        return self._scatter_all(vals, *self.plan_args)
