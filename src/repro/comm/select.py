"""Model-driven strategy and BLOCKSIZE selection (closing the §5 loop).

The paper's performance models are quantitative enough to *predict* which
communication strategy wins for a given access pattern and topology.  This
module is the selection half of the autotuner (the hardware-calibration half,
``measure_hardware``, lives in ``repro.core.tune`` — it is about the machine,
not about any one plan):

* ``rank_strategies`` feeds the exact ``CommPlan`` volume counts through the
  §5 formulas (``perfmodel.STRATEGY_PREDICTORS``) and sorts.
* ``choose_strategy`` returns the predicted-fastest runnable strategy.
* ``choose_blocksize`` sweeps BLOCKSIZE candidates through eq. 11 (the UPCv2
  model) using the cheap per-candidate block counts — the paper's Fig. 4
  BLOCKSIZE dial, turned by the model instead of by hand.

Every ranking is pure arithmetic over already-counted volumes: autotuning
costs a handful of closed-form evaluations plus the one-time calibration.
"""
from __future__ import annotations

import numpy as np

from repro.comm.plan import CommPlan, Topology, blockwise_block_counts

__all__ = ["rank_strategies", "choose_strategy", "choose_blocksize",
           "blocksize_sweep", "blocksize_candidates", "workload_from_plan"]


def _perfmodel():
    # function-level import: perfmodel lives in repro.core (it is the paper's
    # §5 equations, not comm machinery) and repro.core's package init pulls
    # the consumers back in — importing lazily keeps the layering acyclic
    from repro.core import perfmodel
    return perfmodel


def workload_from_plan(plan, r_nz: int, *,
                       materialize: str | None = None,
                       dest_slots: int | None = None,
                       use_kernel: bool = False):
    """Build the §5 workload record for one plan.

    ``plan`` may be a gather ``CommPlan`` or a put-direction
    ``ScatterPlan`` (``CommPlan.transpose()``): both expose the same
    partitioning facts, and a scatter plan's ``counts`` already carry the
    send/recv-swapped volumes the put models price.

    ``materialize`` selects the gather unpack pricing: ``None`` keeps the
    paper's in-place unpack (eq. 15 as written), ``"full"`` adds the O(n)
    x_copy-assembly tax our functional XLA unpack pays, ``"dest"`` prices
    the consumer-targeted O(slots + recv) unpack instead.  ``dest_slots``
    defaults to the plan's ``dest_len`` (the flattened ``Destination``
    size).

    ``use_kernel=True`` prices the fused Pallas pack/unpack variants of
    the compute terms (eqs. 14/15 and 14ᵀ/15ᵀ) instead of the jnp
    formulas — one HBM pass per element on each side of the wire.
    """
    pm = _perfmodel()
    if dest_slots is None and materialize == "dest":
        dest_slots = plan.dest_len
    return pm.SpmvWorkload(
        n=plan.n, r_nz=r_nz, p=plan.p, blocksize=plan.blocksize,
        topology=plan.topology, counts=plan.counts, m=plan.m,
        materialize=materialize, dest_slots=dest_slots,
        use_kernel=use_kernel)


def rank_strategies(
    plan,
    r_nz: int,
    hw,
    *,
    candidates=None,
    materialize: str | None = None,
    dest_slots: int | None = None,
    use_kernel: bool = False,
    direction: str = "get",
    scan_steps: int | None = None,
    overlap_credit: float = 0.0,
    plan_cost: float = 0.0,
    decode: bool = False,
) -> list[tuple[str, float]]:
    """[(strategy, predicted_seconds)] sorted fastest-first (§5 formulas).

    ``direction`` selects the model family: ``"get"`` prices the gather
    rungs (``perfmodel.STRATEGY_PREDICTORS``); ``"put"`` prices the push
    rungs (``perfmodel.PUT_STRATEGY_PREDICTORS`` — the same formulas with
    send/recv volumes swapped plus the accumulate-unpack term) and expects
    ``plan`` to be a ``ScatterPlan`` so the counts are already transposed.

    ``materialize`` / ``dest_slots`` thread the gather unpack-mode pricing
    through (see ``workload_from_plan``) so a consumer with a
    ``Destination`` descriptor ranks rungs by the targeted-unpack cost it
    will actually pay; ``use_kernel`` likewise prices the fused Pallas
    pack/unpack variants of the compute terms.

    ``scan_steps`` re-prices every rung as a steady-state LOOP of that
    many iterations inside one persistent scan window
    (``perfmodel.scan_loop_cost``: window setup paid once, per-iteration
    term thereafter, ``overlap_credit`` seconds of cross-step compute
    hidden per iteration) — the ranking a ``ScanSchedule`` resolves
    ``strategy="auto"`` stages on.  Loop scaling is monotone per rung but
    NOT order-preserving across rungs: a rung that wins one call on cheap
    setup can lose the loop once setup amortizes away.

    ``plan_cost`` is the §5 ``T_plan`` term (``perfmodel.plan_build_time``
    priced for however this exchange obtains its executor tables): a flat
    per-use addend applied AFTER any ``scan_steps`` loop scaling, because
    a plan is (re)built once per use of the plan — once per loop, not once
    per iteration.  It closes the "is replanning worth it this step?"
    question: rank once with the rebuild's ``T_plan`` and once with the
    reuse tier's, and compare (``perfmodel.replan_break_even_steps``).

    ``decode=True`` prices each rung for a token-by-token decode step
    (``perfmodel.predict_decode_exchange``: max of the β throughput model
    and the tiny-m α/latency floor, eqs. 12δ–15δ).  The floor can only
    raise a rung's prediction, so throughput-regime rankings are
    untouched — but at decode batch sizes the per-message τ terms decide
    the ladder, which is exactly what keeps ``strategy="auto"`` honest
    for serving workloads.
    """
    pm = _perfmodel()
    if direction not in ("get", "put"):
        raise ValueError(f"direction must be 'get' or 'put', got {direction!r}")
    w = workload_from_plan(plan, r_nz, materialize=materialize,
                           dest_slots=dest_slots, use_kernel=use_kernel)
    predictors = (pm.PUT_STRATEGY_PREDICTORS if direction == "put"
                  else pm.STRATEGY_PREDICTORS)
    names = tuple(candidates) if candidates else tuple(predictors)
    ranked = [(name, float(predictors[name](w, hw))) for name in names]
    if decode:
        ranked = [(name, pm.predict_decode_exchange(
            w, hw, strategy=name, direction=direction))
            for name, _ in ranked]
    if scan_steps is not None:
        setup = pm.window_setup_time(w.topology, hw)
        ranked = [(name, pm.scan_loop_cost(t, setup, scan_steps,
                                           overlap_credit=overlap_credit))
                  for name, t in ranked]
    if plan_cost:
        ranked = [(name, t + float(plan_cost)) for name, t in ranked]
    ranked.sort(key=lambda kv: kv[1])
    return ranked


def choose_strategy(
    plan,
    r_nz: int,
    *,
    hw=None,
    mesh=None,
    axis_name=None,
    candidates=None,
    materialize: str | None = None,
    dest_slots: int | None = None,
    direction: str = "get",
) -> str:
    """Predicted-fastest strategy for this plan on this hardware."""
    if hw is None:
        from repro.core import tune
        hw = tune.measure_hardware(mesh, axis_name)
    return rank_strategies(plan, r_nz, hw, candidates=candidates,
                           materialize=materialize, dest_slots=dest_slots,
                           direction=direction)[0][0]


def blocksize_candidates(shard_size: int, *, min_bs: int = 8) -> list[int]:
    """Power-of-two divisors of ``shard_size`` (plus shard_size itself)."""
    out = []
    bs = min_bs
    while bs < shard_size:
        if shard_size % bs == 0:
            out.append(bs)
        bs *= 2
    out.append(shard_size)
    return out


def blocksize_sweep(
    cols: np.ndarray,
    n: int,
    p: int,
    *,
    r_nz: int | None = None,
    topology: Topology | None = None,
    hw=None,
    candidates=None,
) -> list[tuple[int, float]]:
    """The full eq.-11 BLOCKSIZE sweep: ``[(blocksize, seconds), ...]``.

    For each candidate BLOCKSIZE the UPCv2 model needs only the per-shard
    needed-block counts (B_local / B_remote) — counted directly from the
    index set without building a full plan per candidate.  Small blocks
    shrink the whole-block volume tax; large blocks amortize per-message
    latency; eq. 11 prices both sides.  Candidates that do not divide the
    shard size are skipped; the list keeps candidate order so callers can
    inspect the sweep's shape (the Fig. 4 curve — how sharply the optimum
    is peaked tells you how much a skew-concentrated pattern punishes a
    mis-sized block).  ``choose_blocksize`` is this sweep's argmin.
    """
    pm = _perfmodel()
    cols = np.asarray(cols)
    if cols.ndim == 1:
        cols = cols[:, None]
    shard_size = n // p
    if topology is None:
        topology = Topology(p, p)
    if r_nz is None:
        r_nz = cols.shape[1]
    if hw is None:
        from repro.core import tune
        hw = tune.measure_hardware()
    if candidates is None:
        candidates = blocksize_candidates(shard_size)

    sweep: list[tuple[int, float]] = []
    for bs in candidates:
        if shard_size % bs:
            continue
        b_local, b_remote = blockwise_block_counts(cols, n, p, bs, topology)
        zeros = np.zeros(p, np.int64)
        counts = pm.GatherCounts(
            c_local_indv=zeros, c_remote_indv=zeros,
            b_local=b_local, b_remote=b_remote, blocksize=bs,
            s_local_out=zeros, s_remote_out=zeros,
            s_local_in=zeros, s_remote_in=zeros, c_remote_out=zeros,
            padded_condensed_per_shard=0, padded_blockwise_per_shard=0)
        w = pm.SpmvWorkload(n=n, r_nz=r_nz, p=p, blocksize=bs,
                            topology=topology, counts=counts,
                            m=cols.shape[0])
        sweep.append((int(bs), float(pm.predict_v2(w, hw))))
    assert sweep, "no candidate divides the shard size"
    return sweep


def choose_blocksize(
    cols: np.ndarray,
    n: int,
    p: int,
    *,
    r_nz: int | None = None,
    topology: Topology | None = None,
    hw=None,
    candidates=None,
) -> int:
    """Eq.-11-minimizing virtual block size for this access pattern (the
    argmin of ``blocksize_sweep`` — the paper's Fig. 4 BLOCKSIZE dial,
    turned by the model instead of by hand)."""
    sweep = blocksize_sweep(cols, n, p, r_nz=r_nz, topology=topology,
                            hw=hw, candidates=candidates)
    return min(sweep, key=lambda kv: kv[1])[0]
