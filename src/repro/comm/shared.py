"""SharedVector — a UPC shared array on a JAX mesh.

The paper's base object is a shared array distributed over threads with
affinity: thread q owns a contiguous slice, and any thread may read any
element (at a cost the §5 models price).  ``SharedVector`` is that object on
a JAX mesh: it fixes the partitioning (mesh axis / axes + contiguous slices
+ a node ``Topology``) that ``AccessPattern`` indices refer to and that
``IrregularGather`` plans against.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.plan import Topology

__all__ = ["SharedVector", "axis_size"]


def axis_size(mesh: jax.sharding.Mesh, axis_name) -> int:
    """Device count on a mesh axis or product over a tuple of axes."""
    if isinstance(axis_name, (tuple, list)):
        return int(math.prod(mesh.shape[a] for a in axis_name))
    return int(mesh.shape[axis_name])


@dataclasses.dataclass(frozen=True)
class SharedVector:
    """A length-``n`` vector (optional trailing feature dims) sharded in
    contiguous slices over ``axis_name`` of ``mesh``.

    ``axis_name`` may be a tuple of mesh axes; ownership then follows the
    mesh's row-major rank order over those axes (rank = i0*s1*… + i1*… + …),
    matching ``PartitionSpec((a, b, …))`` placement.

    >>> import jax, numpy as np
    >>> p = len(jax.devices())
    >>> sv = SharedVector(jax.make_mesh((p,), ("data",)), n=16 * p)
    >>> sv.shard_size == 16 and int(sv.owner_of(16 * p - 1)) == p - 1
    True
    """

    mesh: jax.sharding.Mesh
    n: int
    axis_name: str | tuple = "data"
    topology: Topology | None = None

    def __post_init__(self):
        p = self.p
        assert self.n % p == 0, (
            f"n={self.n} must divide over {p} shards (pad upstream)")
        if self.topology is None:
            object.__setattr__(self, "topology", Topology(p, p))
        assert self.topology.num_shards == p

    @property
    def p(self) -> int:
        return axis_size(self.mesh, self.axis_name)

    @property
    def shard_size(self) -> int:
        return self.n // self.p

    @property
    def spec(self) -> P:
        return P(self.axis_name)

    @property
    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec)

    def owner_of(self, idx):
        """Owning shard of global element(s) ``idx``."""
        return np.asarray(idx) // self.shard_size

    def node_of(self, idx):
        """Owning node (Topology) of global element(s) ``idx``."""
        return self.topology.node_of(self.owner_of(idx))

    def local_slice(self, shard: int) -> slice:
        return slice(shard * self.shard_size, (shard + 1) * self.shard_size)

    def put(self, values) -> jax.Array:
        """Place host values (length n, plus feature dims) onto the mesh."""
        values = np.asarray(values)
        assert values.shape[0] == self.n, (values.shape, self.n)
        return jax.device_put(values, self.sharding)
