"""AccessPattern — the optimization unit of the paper, workload-agnostic.

The paper's ladder optimizes *an index set*, not a workload: which global
elements of a shared vector does each accessor touch?  SpMV's EllPack ``J``
is one such set; a stencil's halo neighborhood and a router's token→expert
assignment are others.  ``AccessPattern`` captures exactly that set (plus the
two partitioning facts the planner needs: vector length ``n`` and accessor
count ``m``) so every consumer feeds the same planner, the same strategies,
and the same §5 models.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["AccessPattern", "Destination"]


@dataclasses.dataclass(frozen=True)
class AccessPattern:
    """A static set of global indices read by each of ``m`` accessor rows.

    ``indices``: (m, r) int32, values in [0, n).  Accessor rows and vector
    elements are partitioned contiguously over the same shards: shard q of p
    owns vector slice [q*n/p, (q+1)*n/p) and accessor rows
    [q*m/p, (q+1)*m/p).  Rows needing fewer than r indices pad with an
    *owned* index (e.g. the row's own element) — owned accesses cost nothing.

    When the index set *changes every batch* (per-batch MoE routing), wrap
    one representative pattern in ``repro.comm.dynamic.DynamicPattern``:
    the front doors then take a capacity-bounded envelope plan and
    re-derive the executor tables in-jit per batch, no host round-trip.
    """

    indices: np.ndarray
    n: int

    def __post_init__(self):
        idx = np.asarray(self.indices)
        assert idx.ndim == 2, f"indices must be (m, r), got {idx.shape}"
        assert idx.dtype == np.int32, "indices must be int32"

    @property
    def m(self) -> int:
        return self.indices.shape[0]

    @property
    def r(self) -> int:
        return self.indices.shape[1]

    @classmethod
    def from_indices(cls, idx, n: int | None = None) -> "AccessPattern":
        """Any global index set: (m,) or (m, r) integers into a length-n
        vector.  ``n`` defaults to max(idx)+1 (pad upstream so n % p == 0)."""
        idx = np.asarray(idx)
        if idx.ndim == 1:
            idx = idx[:, None]
        if n is None:
            n = int(idx.max()) + 1
        assert idx.min() >= 0 and idx.max() < n, (
            f"indices must lie in [0, {n})")
        return cls(indices=np.ascontiguousarray(idx, dtype=np.int32), n=n)

    @classmethod
    def from_ellpack(cls, matrix) -> "AccessPattern":
        """The SpMV instance: row i accesses x[J[i, :]] (m == n)."""
        return cls.from_indices(matrix.cols, n=matrix.n)

    @classmethod
    def from_stencil5(cls, big_m: int, big_n: int, mprocs: int,
                      nprocs: int) -> "AccessPattern":
        """5-point stencil neighbors over an (mprocs × nprocs) tile grid.

        The field is flattened *tile-major*: rank r = ip*nprocs + kp owns the
        contiguous slice [r*tile, (r+1)*tile) holding its (m_loc × n_loc)
        tile row-major — exactly the SharedVector contiguous-ownership
        layout.  Each cell's pattern row holds its four neighbors' global
        ids; out-of-domain neighbors pad with the cell's own id (an owned,
        zero-cost access; the solver masks the global boundary anyway).
        """
        assert big_m % mprocs == 0 and big_n % nprocs == 0
        m_loc, n_loc = big_m // mprocs, big_n // nprocs
        tile = m_loc * n_loc

        def gid(gi, gk):
            """Global row/col -> tile-major global id (arrays ok)."""
            ip, i = gi // m_loc, gi % m_loc
            kp, k = gk // n_loc, gk % n_loc
            return (ip * nprocs + kp) * tile + i * n_loc + k

        gi, gk = np.meshgrid(np.arange(big_m), np.arange(big_n),
                             indexing="ij")
        own = gid(gi, gk)
        nbrs = []
        for di, dk in ((-1, 0), (1, 0), (0, -1), (0, 1)):
            ni, nk = gi + di, gk + dk
            ok = (ni >= 0) & (ni < big_m) & (nk >= 0) & (nk < big_n)
            nbrs.append(np.where(
                ok, gid(np.clip(ni, 0, big_m - 1), np.clip(nk, 0, big_n - 1)),
                own))
        # order pattern rows by owning rank then tile-row-major so accessor
        # row g is the accessor of vector element g (m == n, SpMV-like)
        order = np.argsort(own.ravel(), kind="stable")
        idx = np.stack([nb.ravel()[order] for nb in nbrs], axis=1)
        return cls.from_indices(idx.astype(np.int32), n=big_m * big_n)


@dataclasses.dataclass(frozen=True)
class Destination:
    """Named consumer slots that gathered values land in directly.

    The paper's UPCv3 unpack scatters each landed message into a full-length
    private copy (``mythread_x_copy``) — O(n) buffer work per exchange even
    when the consumer only reads O(halo) foreign values.  A ``Destination``
    instead *names* where each device wants values delivered: halo strips,
    EllPack slots, expert-capacity rows — any set of named arrays of global
    indices, one table per device.  The planner precomputes, per strategy, a
    recv-buffer→slot gather so ``OverlapHandle.finish()`` writes the landed
    messages straight into the named buffers, never materializing ``x_copy``
    (which stays available behind ``finish(materialize="full")``).

    ``indices`` is ``(p, L)`` int32: device q's flattened slot table, holding
    the *global* vector index each slot reads.  The sentinel ``Destination.
    ZERO`` (-1) marks slots that must read exactly 0.0 (out-of-domain halo
    cells, padding).  Every non-sentinel foreign index must appear in the
    ``AccessPattern`` the plan was built from — the planner raises otherwise,
    because that value would never arrive.

    >>> import numpy as np
    >>> d = Destination.from_slots(
    ...     up=np.array([[4, 5], [0, 1]]),     # 2 devices x 2 slots
    ...     left=np.array([[6], [-1]]))        # -1: guaranteed-zero slot
    >>> d.names, d.num_slots
    (('up', 'left'), 3)
    >>> d.split_local(np.array([10., 11., 12.]))['up']
    array([10., 11.])
    """

    names: tuple[str, ...]
    shapes: tuple[tuple[int, ...], ...]  # per-device slot-array shapes
    indices: np.ndarray                  # (p, L) int32 global ids; -1 -> 0.0

    ZERO = -1

    def __post_init__(self):
        idx = np.asarray(self.indices)
        assert idx.ndim == 2, f"indices must be (p, L), got {idx.shape}"
        assert idx.dtype == np.int32, "indices must be int32"
        assert len(self.names) == len(self.shapes)
        total = sum(int(np.prod(s)) for s in self.shapes)
        assert total == idx.shape[1], (total, idx.shape[1])
        assert idx.min() >= self.ZERO, "indices must be >= -1 (ZERO sentinel)"

    @classmethod
    def from_slots(cls, **slots) -> "Destination":
        """Build from named per-device global-index tables.

        Each value is an ``(p, *slot_shape)`` integer array; entries equal to
        ``Destination.ZERO`` (-1) read as exactly 0.0.  Slot order follows
        keyword order, which is also the order ``split_local`` returns.
        """
        assert slots, "at least one named slot table required"
        names = tuple(slots)
        arrays = [np.asarray(slots[k]) for k in names]
        p = arrays[0].shape[0]
        assert all(a.shape[0] == p for a in arrays), (
            "every slot table needs the same leading device dim")
        shapes = tuple(a.shape[1:] for a in arrays)
        flat = np.concatenate([a.reshape(p, -1) for a in arrays], axis=1)
        return cls(names=names, shapes=shapes,
                   indices=np.ascontiguousarray(flat, dtype=np.int32))

    @property
    def p(self) -> int:
        return self.indices.shape[0]

    @property
    def num_slots(self) -> int:
        """Flattened slots per device (the O(L) the targeted unpack pays)."""
        return self.indices.shape[1]

    def split_local(self, flat):
        """Split one device's flat ``(L, ...)`` buffer back into named slot
        arrays (works on numpy and traced jnp values alike)."""
        out, off = {}, 0
        for name, shape in zip(self.names, self.shapes):
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            out[name] = flat[off:off + size].reshape(
                tuple(shape) + flat.shape[1:])
            off += size
        return out

    def key_bytes(self) -> bytes:
        """Content bytes for the plan-cache key."""
        head = "|".join(
            f"{n}:{','.join(map(str, s))}"
            for n, s in zip(self.names, self.shapes)).encode()
        return head + b"#" + np.ascontiguousarray(self.indices).tobytes()
