"""Device-side plan derivation for capacity-bounded dynamic patterns.

Every other module in ``repro.comm`` assumes the paper's premise: the access
pattern is known once, up front, so the O(nnz) preparation step (§4.3.1)
runs on the host exactly once.  The repo's flagship pattern breaks that
premise — MoE token→expert routing changes *every batch* — and at traffic
rates the host build + content hash sit on the hot path with a plan cache
that can only miss.

The way out is that capacity-bounded patterns have *fixed shape*: a router
always produces ``(num_experts, capacity)`` slots over ``num_tokens``
tokens, so every executor table of the condensed rung has a static bound —
the per-pair unique count can never exceed ``min(shard_size,
rows_per_shard * r)``.  With that **envelope** ``s_max`` fixed at trace
time, the tables themselves become ordinary fixed-shape XLA computations:

* ``derive_gather_tables`` reproduces ``plan.build_comm_plan``'s condensed
  tables (``send_local_idx`` / ``recv_global_idx``) in-jit, bit-identical
  to the host build at the same ``s_max``;
* ``derive_scatter_tables`` reproduces ``plan.derive_scatter_plan``'s put
  duals (``cond_msg_idx`` / ``own_tgt_idx`` / ``win_mask`` / ``touched``)
  from one shared derivation pass — the ``CommPlan.transpose()`` semantics
  carried over, so a fused dispatch→combine pair derives BOTH directions
  from a single sort.

``DynamicPattern`` is the front door: it wraps a representative *template*
``AccessPattern`` (fixing ``m``, ``r``, ``n`` and the envelope) and is
accepted by ``IrregularGather`` / ``IrregularScatter`` / ``Schedule``
wherever an ``AccessPattern`` is.  The host-side envelope plan those front
doors resolve (via ``plan_cache.get_envelope_plan`` — the bucketed-reuse
tier) provides the static scalars and the §5 pricing; the per-batch tables
come from ``derive_plan_args(cols)`` inside the consumer's own ``jit`` and
flow through the *unchanged* ``shard_map`` in_specs and strategy-local
functions.  See ``models.moe.DynamicMoELayer`` for the proving consumer
and ``docs/comm_api.md`` for the walkthrough.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.pattern import AccessPattern

__all__ = ["DynamicPattern", "DynamicGatherTables", "DynamicScatterTables",
           "envelope_s_max", "derive_gather_tables", "derive_scatter_tables"]

# Rungs whose executor tables the device derivation covers: condensed and
# its own/foreign-split consumption (overlap) share the same two tables.
DYNAMIC_STRATEGIES = ("condensed", "overlap")


def envelope_s_max(m: int, r: int, n: int, p: int) -> int:
    """The capacity bound on any per-pair unique count.

    Reader shard q can need at most ``rows_per_shard * r`` distinct
    elements in total, and owner shard s only owns ``shard_size`` elements
    — whichever is smaller bounds every (s, q) message for every routing
    the pattern shape admits.

    >>> envelope_s_max(m=64, r=1, n=1024, p=8)   # 8 slots/shard, 1 idx each
    8
    >>> envelope_s_max(m=4096, r=2, n=64, p=8)   # tiny vector: shard wins
    8
    """
    assert n % p == 0 and m % p == 0, (n, m, p)
    return max(1, min(n // p, (m // p) * r))


@dataclasses.dataclass(frozen=True)
class DynamicPattern:
    """A capacity-bounded family of access patterns, one member per batch.

    ``template`` is any representative member: it fixes the static facts
    every batch shares — accessor count ``m``, row width ``r``, vector
    length ``n`` — and seeds the envelope plan the front doors resolve
    against.  ``s_max`` is the envelope bound on per-pair unique counts;
    the default (``envelope_s_max``) is always safe.  The per-batch index
    table is supplied *traced*, inside the consumer's jit, to
    ``derive_plan_args`` — never to the constructor.
    """

    template: AccessPattern
    s_max: int

    def __post_init__(self):
        assert isinstance(self.template, AccessPattern), type(self.template)
        assert self.s_max >= 1, self.s_max

    @classmethod
    def from_template(cls, template: AccessPattern, p: int,
                      s_max: int | None = None) -> "DynamicPattern":
        """Wrap a representative pattern; ``p`` (the comm-axis size) fixes
        the envelope.  Pass ``s_max`` to tighten it when the workload
        guarantees a smaller bound (e.g. expert capacity < shard size)."""
        env = envelope_s_max(template.m, template.r, template.n, p)
        if s_max is None:
            s_max = env
        assert 1 <= s_max <= env, (
            f"s_max={s_max} must lie in [1, {env}] — above the envelope it "
            "wastes padded volume for nothing, and a bound the routing can "
            "exceed would silently drop table entries")
        return cls(template=template, s_max=s_max)

    # -- AccessPattern-shaped surface (the front doors read these) --
    @property
    def indices(self) -> np.ndarray:
        return self.template.indices

    @property
    def n(self) -> int:
        return self.template.n

    @property
    def m(self) -> int:
        return self.template.m

    @property
    def r(self) -> int:
        return self.template.r


class DynamicGatherTables(NamedTuple):
    """In-jit condensed gather tables (``CommPlan`` field names kept)."""

    send_local_idx: jax.Array   # (P, P, s_max) int32, pad 0
    recv_global_idx: jax.Array  # (P, P, s_max) int32, pad n (dump slot)
    send_counts: jax.Array      # (P, P) int32; [src, dst]


class DynamicScatterTables(NamedTuple):
    """In-jit put-direction duals (``ScatterPlan`` field names kept)."""

    cond_msg_idx: jax.Array     # (m, r) int32 into (P*s_max); owned -> dump
    own_tgt_idx: jax.Array      # (m, r) int32 into own shard; foreign -> dump
    win_mask: jax.Array         # (m, r) int8, reduce="set" winner slots
    touched: jax.Array          # (P, shard_size) int8


def derive_gather_tables(cols: jax.Array, n: int, p: int,
                         s_max: int) -> DynamicGatherTables:
    """The condensed tables of §4.3.1, as a fixed-shape XLA computation.

    ``cols`` is the batch's (m, r) int32 global index table (replicated —
    derivation runs *outside* the ``shard_map``, on tiny int32 data, and
    the resulting global tables flow through the unchanged plan-arg
    in_specs).  Bit-identical to ``build_comm_plan(cols, n, p,
    s_max=s_max)``'s condensed arrays: per reader q and owner s, the sorted
    unique foreign globals, padded to ``s_max`` with the dump conventions
    (``recv`` pads to ``n``, ``send`` pads to 0).

    One global sort per reader replaces the host's per-pair unique lists:
    foreign globals sort ascending (own accesses keyed to ``n`` fall to the
    end), first-occurrence masking dedups, and a per-owner segment rank
    places each unique at ``(owner, rank)``.  Cost: O(m·r·log(m·r)) on
    device — no host round-trip, no content hash.
    """
    cols = jnp.asarray(cols, jnp.int32)
    if cols.ndim == 1:
        cols = cols[:, None]
    m = cols.shape[0]
    assert n % p == 0 and m % p == 0, (n, m, p)
    shard_size = n // p
    rows_per_shard = m // p

    def per_reader(q, cq):
        flat = cq.ravel()
        owner = flat // shard_size
        foreign = owner != q
        # own/padding keyed past every real global -> sorts to the tail
        key = jnp.where(foreign, flat, jnp.int32(n))
        skey = jnp.sort(key)
        first = jnp.concatenate(
            [jnp.ones((1,), bool), skey[1:] != skey[:-1]])
        uniq = first & (skey < n)
        sowner = jnp.clip(skey // shard_size, 0, p - 1)
        per_owner = jax.ops.segment_sum(
            uniq.astype(jnp.int32), sowner, num_segments=p)
        start = jnp.cumsum(per_owner) - per_owner
        rank = jnp.cumsum(uniq.astype(jnp.int32)) - 1 - start[sowner]
        # envelope violations (rank >= s_max) drop into the dump slot
        # rather than corrupting a neighbor segment
        pos = jnp.where(uniq & (rank < s_max),
                        sowner * s_max + rank, p * s_max)
        recv = jnp.full((p * s_max + 1,), n, jnp.int32)
        recv = recv.at[pos].set(skey, mode="drop")
        return recv[:p * s_max].reshape(p, s_max), \
            jnp.minimum(per_owner, s_max).astype(jnp.int32)

    recv_global_idx, recv_counts = jax.vmap(per_reader)(
        jnp.arange(p, dtype=jnp.int32),
        cols.reshape(p, rows_per_shard, -1))
    owner_base = (jnp.arange(p, dtype=jnp.int32)
                  * shard_size)[None, :, None]
    send_local_idx = jnp.where(
        recv_global_idx != n, recv_global_idx - owner_base, 0
    ).transpose(1, 0, 2).astype(jnp.int32)
    return DynamicGatherTables(
        send_local_idx=send_local_idx,
        recv_global_idx=recv_global_idx,
        send_counts=recv_counts.T,
    )


def derive_scatter_tables(cols: jax.Array, n: int, p: int, s_max: int,
                          gather: DynamicGatherTables | None = None,
                          ) -> DynamicScatterTables:
    """The put-direction duals of ``derive_scatter_plan``, in-jit.

    Pass the ``gather`` tables when both directions are derived from one
    pattern (the fused dispatch→combine shape) — the shared sort is the
    whole point of ``CommPlan.transpose()`` and is preserved here: the
    scatter's message-slot positions are ``searchsorted`` probes into the
    gather's already-sorted per-pair lists.  Bit-identical to the host
    ``derive_scatter_plan`` on the matching envelope plan.
    """
    cols = jnp.asarray(cols, jnp.int32)
    if cols.ndim == 1:
        cols = cols[:, None]
    m, r = cols.shape
    shard_size = n // p
    rows_per_shard = m // p
    if gather is None:
        gather = derive_gather_tables(cols, n, p, s_max)
    recv = gather.recv_global_idx          # (P, P, s_max), rows sorted

    def per_reader(q, cq, recv_q):
        flat = cq.ravel()                   # (rows*r,)
        owner = flat // shard_size
        own = owner == q
        # rank of each foreign target inside its (q <- owner) sorted unique
        # list; rows pad with n > any target, so searchsorted lands exactly
        rows = recv_q[jnp.clip(owner, 0, p - 1)]        # (rows*r, s_max)
        pos = jax.vmap(jnp.searchsorted)(rows, flat)
        cond = jnp.where(own, p * s_max, owner * s_max + pos)
        own_tgt = jnp.where(own, flat - q * shard_size, shard_size)
        return (cond.reshape(cq.shape).astype(jnp.int32),
                own_tgt.reshape(cq.shape).astype(jnp.int32))

    cond_msg_idx, own_tgt_idx = jax.vmap(per_reader)(
        jnp.arange(p, dtype=jnp.int32),
        cols.reshape(p, rows_per_shard, r), recv)

    # reduce="set" winner: the last contribution in row-major accessor
    # order, global across shards (duplicates may span senders)
    flat_t = cols.ravel()
    order = jnp.arange(m * r, dtype=jnp.int32)
    last = jnp.full((n,), -1, jnp.int32).at[flat_t].max(order)
    win_mask = (last[flat_t] == order).reshape(m, r).astype(jnp.int8)
    touched = jnp.zeros((n,), jnp.int8).at[flat_t].set(1)

    return DynamicScatterTables(
        cond_msg_idx=cond_msg_idx.reshape(m, r),
        own_tgt_idx=own_tgt_idx.reshape(m, r),
        win_mask=win_mask,
        touched=touched.reshape(p, shard_size),
    )
