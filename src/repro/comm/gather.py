"""IrregularGather — the pull-direction front door to the strategy ladder.

One object owns everything the paper's §4 machinery needs for one access
pattern on one mesh: the one-time ``CommPlan`` (persistently cached), the
resolved strategy (any ladder rung or ``"auto"`` via the §5 models), the
device-resident plan arrays, and the ``shard_map``-local gather functions.
The direction-agnostic machinery (plan resolution, rung dispatch, hardware
calibration memo, the ``OverlapHandle`` protocol) lives in
``repro.comm.exchange`` and is shared with the push-direction
``IrregularScatter``.

Consumers compose it two ways:

* standalone: ``x_copy_all = gather(x)`` returns every device's private copy
  stacked (row q = device q's ``mythread_x_copy``) — convenient for tests
  and simple pipelines;
* fused: the consumer threads ``gather.plan_args`` through its own
  ``shard_map`` (as operands, with ``gather.in_specs`` — each device must
  see only its slice) and calls ``gather.local(x_local, *plan_args_l)``
  inside — or, to hide the exchange behind own-shard compute (the
  generalized own/foreign split of the ``overlap`` rung), the
  ``OverlapHandle`` protocol::

      def step_local(x_local, *plan_args_l):
          handle = gather.start_local(x_local, *plan_args_l)  # issued
          y_own = ...                           # depends on x_local only
          x_copy = handle.finish()              # unpack landed messages
          return y_own + foreign_part(x_copy)

      mapped = shard_map(step_local, mesh=mesh,
                         in_specs=(P(axis),) + gather.in_specs, ...)
      y = jax.jit(lambda x: mapped(x, *gather.plan_args))(x)

  XLA's latency-hiding scheduler overlaps the collective with everything
  scheduled between ``start_local`` and ``finish`` that does not consume the
  collective's result.

With a ``Destination`` descriptor (named consumer slots — halo strips,
EllPack rows, expert-capacity slots), ``finish()`` / ``local()`` default to
``materialize="dest"``: the landed recv buffer is scattered straight into
the named slots and returned as ``{name: slot_array}`` — O(slots + recv)
work, no full-length ``x_copy`` ever assembled.  ``materialize="full"``
keeps the classic assembled copy on the same gather, bit-identically, and
``strategy="auto"`` prices whichever unpack the consumer will actually run
(the §5 extension in docs/perf_model.md).

The shared vector may carry trailing feature dimensions (token embeddings,
stacked right-hand sides): strategies move whole feature rows and all §5
volumes scale by the feature width.  A chain of exchanges fuses through
the third front door, ``repro.comm.schedule`` — there a gather is one
*stage*, constructed against the schedule's shared plan/calibration
context (a single-stage schedule is bit-identical to this class).  See
docs/comm_api.md for runnable walkthroughs of every surface.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm import dynamic as dyn
from repro.comm import plan_cache
from repro.comm import strategies as strat
from repro.comm.exchange import (IrregularExchange, OverlapHandle,
                                 measure_hw)
from repro.comm.pattern import AccessPattern, Destination
from repro.comm.plan import CommPlan
from repro.comm.shared import SharedVector

__all__ = ["IrregularGather", "OverlapHandle"]


def _measure_hw(mesh, axis_name):
    """Deprecated alias — use ``repro.comm.exchange.measure_hw`` (memoized
    per (mesh, axis_name) so repeated constructions skip the
    microbenchmark)."""
    return measure_hw(mesh, axis_name)


class IrregularGather(IrregularExchange):
    """Plan + strategy + device state for gathering one ``AccessPattern``
    over one mesh axis (or tuple of axes)."""

    direction = "get"

    def __init__(
        self,
        pattern: AccessPattern,
        where: jax.sharding.Mesh | SharedVector,
        *,
        destination: Destination | None = None,
        dest_slots: int | None = None,
        **kwargs,
    ):
        """``destination`` may be a ``Destination`` or a callable
        ``(resolved_strategy, base_plan) -> Destination`` for consumers
        whose slot layout depends on the resolved rung (e.g. SpMV targets
        foreign slots only under ``overlap``); it is materialized and
        attached once, after strategy resolution, so no throwaway plan
        entry is ever cached.  ``dest_slots`` is the flattened slot count
        the auto ranking prices when ``destination`` is a callable (a
        plain ``Destination`` knows its own).  Remaining keyword arguments
        (``axis_name``, ``strategy``, ``blocksize``, ``shards_per_node``,
        ``topology``, ``hw``, ``candidates``, ``use_plan_cache``,
        ``use_kernel``) are the shared ``IrregularExchange`` surface."""
        self._destination_arg = destination
        self._dest_slots = dest_slots
        super().__init__(pattern, where, **kwargs)

    def _price_kwargs(self) -> dict:
        kw = super()._price_kwargs()
        destination = self._destination_arg
        if destination is None:
            return kw
        # with a destination, price the targeted O(slots + recv) unpack
        # instead of the O(n) full-copy assembly (§5 + the new term)
        if callable(destination):
            if self._dest_slots is None:
                raise ValueError(
                    'strategy="auto" with a callable destination '
                    "requires dest_slots= — the flattened slot "
                    "count the ranking prices (otherwise the "
                    "targeted unpack would be priced at 0 slots "
                    "and skew the rung selection)")
            slots = self._dest_slots
        else:
            slots = destination.num_slots
        kw.update(materialize="dest", dest_slots=slots)
        return kw

    def _bind(self, base_plan: CommPlan, strategy: str) -> None:
        mesh, axis_name, p, n = self.mesh, self.axis_name, self.p, self.pattern.n
        destination = self._destination_arg
        if self.dynamic_pattern is not None and destination is not None:
            raise ValueError(
                "Destination descriptors are host-precomputed per pattern "
                "and cannot serve a DynamicPattern (whose tables change "
                "every batch) — land with materialize='full' instead")
        if callable(destination):
            destination = destination(strategy, base_plan)
        if destination is not None:
            assert destination.p == p, (
                f"destination has {destination.p} per-device slot tables "
                f"for a {p}-shard mesh axis")
            assert destination.indices.max() < n, (
                "destination indices must lie in [-1, n)")
            self.plan: CommPlan = plan_cache.get_comm_plan(
                self.pattern.indices, n, p, blocksize=base_plan.blocksize,
                topology=base_plan.topology, destination=destination,
                base=base_plan, cache=self._use_plan_cache,
            )
        else:
            self.plan = base_plan
        self.destination = destination

        with_dest = destination is not None
        shard = NamedSharding(mesh, P(axis_name))
        self.in_specs = strat.gather_in_specs(strategy, axis_name,
                                              with_dest=with_dest)
        if self.dynamic_pattern is not None:
            # on a bucket-reuse hit the envelope plan's index tables belong
            # to the entry's founding routing, not this template — derive
            # the template's own tables on device (bit-identical to a host
            # build at the envelope s_max) so the static surface stays
            # honest; per-batch consumers swap in derive_plan_args(cols)
            g = dyn.derive_gather_tables(
                self.pattern.indices, n, p, self.plan.s_max)
            device_args = (g.send_local_idx, g.recv_global_idx)
        else:
            device_args = strat.plan_device_args(self.plan, strategy,
                                                 with_dest=with_dest)
        self.plan_args = tuple(
            jax.device_put(a, shard) for a in device_args
        )
        self._start, self._finish = strat.make_start_local(
            self.plan, strategy, axis_name, use_kernel=self.use_kernel)

        def gather_only_local(x_local, *plan_args):
            recv = self._start(x_local, *plan_args)
            return self._finish(recv, x_local, *plan_args,
                                materialize="full")[None]

        self._gather_all = jax.jit(compat.shard_map(
            gather_only_local,
            mesh=mesh,
            in_specs=(P(axis_name),) + self.in_specs,
            out_specs=P(axis_name),
            check_vma=False,
        ))

    def _resolve_materialize(self, materialize: str | None) -> str:
        if materialize is None:
            return "dest" if self.destination is not None else "full"
        if materialize == "dest" and self.destination is None:
            raise ValueError(
                'materialize="dest" requires constructing the gather with '
                "a Destination descriptor")
        if materialize not in ("dest", "full"):
            raise ValueError(f"unknown materialize mode {materialize!r}")
        return materialize

    # ---- shard_map-local surface (compose inside a consumer's step) ----
    def local(self, x_local: jax.Array, *plan_args,
              materialize: str | None = None):
        """One-shot local gather.

        ``materialize="full"`` (default without a destination): x_local
        (shard, ...) -> x_copy (>= n, ...).  ``materialize="dest"`` (default
        with one): -> ``{name: slots}`` named consumer buffers, no
        full-length intermediate.
        """
        mode = self._resolve_materialize(materialize)
        recv = self._start(x_local, *plan_args)
        out = self._finish(recv, x_local, *plan_args, materialize=mode)
        if mode == "dest":
            return self.destination.split_local(out)
        return out

    def start_local(self, x_local: jax.Array, *plan_args) -> OverlapHandle:
        """Issue the exchange; compute on ``x_local`` while it flies."""
        in_flight = self._start(x_local, *plan_args)

        def finish(*, extra_slots=0, copy_own=True, materialize=None):
            mode = self._resolve_materialize(materialize)
            out = self._finish(in_flight, x_local, *plan_args,
                               extra_slots=extra_slots, copy_own=copy_own,
                               materialize=mode)
            if mode == "dest":
                return self.destination.split_local(out)
            return out

        return OverlapHandle(x_local=x_local, _finish=finish)

    # ---- dynamic surface (per-batch patterns, see repro.comm.dynamic) ----
    def derive_plan_args(self, cols) -> tuple:
        """Traced per-batch replacement for ``plan_args``.

        ``cols`` is this batch's (m, r) int32 global index table — a traced
        array inside the consumer's jit (replicated; derivation runs
        *outside* the ``shard_map``).  Returns the condensed/overlap
        executor tables ``(send_local_idx, recv_global_idx)`` computed on
        device, bit-identical to a host plan build at the envelope
        ``s_max``; feed them through the unchanged ``in_specs`` in place of
        the static ``plan_args``.  No host round-trip, no plan-cache probe
        — the caller records ``telemetry.record("device-derive")`` once per
        *call* (not here: this body runs once per trace).
        """
        if self.strategy not in dyn.DYNAMIC_STRATEGIES:
            raise ValueError(
                f"derive_plan_args serves {dyn.DYNAMIC_STRATEGIES} "
                f"executor tables, not {self.strategy!r}")
        if self.destination is not None:
            raise ValueError(
                "derive_plan_args cannot rebuild host-precomputed "
                "Destination arrays")
        g = dyn.derive_gather_tables(cols, self.plan.n, self.p,
                                     self.plan.s_max)
        return (g.send_local_idx, g.recv_global_idx)

    # ---- standalone surface ----
    def __call__(self, x: jax.Array) -> jax.Array:
        """(P, >=n, ...) array: row q is device q's private x_copy.

        Always the full materialization (tests and simple pipelines want
        the global-indexable copy), regardless of any ``Destination``.
        """
        return self._gather_all(x, *self.plan_args)
