"""IrregularGather — the single front door to the strategy ladder.

One object owns everything the paper's §4 machinery needs for one access
pattern on one mesh: the one-time ``CommPlan`` (persistently cached), the
resolved strategy (any ladder rung or ``"auto"`` via the §5 models), the
device-resident plan arrays, and the ``shard_map``-local gather functions.

Consumers compose it two ways:

* standalone: ``x_copy_all = gather(x)`` returns every device's private copy
  stacked (row q = device q's ``mythread_x_copy``) — convenient for tests
  and simple pipelines;
* fused: the consumer threads ``gather.plan_args`` through its own
  ``shard_map`` (as operands, with ``gather.in_specs`` — each device must
  see only its slice) and calls ``gather.local(x_local, *plan_args_l)``
  inside — or, to hide the exchange behind own-shard compute (the
  generalized own/foreign split of the ``overlap`` rung), the
  ``OverlapHandle`` protocol::

      def step_local(x_local, *plan_args_l):
          handle = gather.start_local(x_local, *plan_args_l)  # issued
          y_own = ...                           # depends on x_local only
          x_copy = handle.finish()              # unpack landed messages
          return y_own + foreign_part(x_copy)

      mapped = shard_map(step_local, mesh=mesh,
                         in_specs=(P(axis),) + gather.in_specs, ...)
      y = jax.jit(lambda x: mapped(x, *gather.plan_args))(x)

  XLA's latency-hiding scheduler overlaps the collective with everything
  scheduled between ``start_local`` and ``finish`` that does not consume the
  collective's result.

With a ``Destination`` descriptor (named consumer slots — halo strips,
EllPack rows, expert-capacity slots), ``finish()`` / ``local()`` default to
``materialize="dest"``: the landed recv buffer is scattered straight into
the named slots and returned as ``{name: slot_array}`` — O(slots + recv)
work, no full-length ``x_copy`` ever assembled.  ``materialize="full"``
keeps the classic assembled copy on the same gather, bit-identically, and
``strategy="auto"`` prices whichever unpack the consumer will actually run
(the §5 extension in docs/perf_model.md).

The shared vector may carry trailing feature dimensions (token embeddings,
stacked right-hand sides): strategies move whole feature rows and all §5
volumes scale by the feature width.  See docs/comm_api.md for runnable
walkthroughs of every surface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm import plan_cache
from repro.comm import select
from repro.comm import strategies as strat
from repro.comm.pattern import AccessPattern, Destination
from repro.comm.plan import CommPlan, Topology
from repro.comm.shared import SharedVector, axis_size

__all__ = ["IrregularGather", "OverlapHandle"]


@dataclasses.dataclass
class OverlapHandle:
    """An in-flight gather: the collective has been issued, the landed
    messages are not yet delivered.  Everything computed before ``finish``
    that only reads ``x_local`` runs inside the communication window.

    ``finish`` has two materializations:

    * ``materialize="full"`` — assemble the classic device-private
      ``x_copy`` (length >= n, indexable with global indices);
    * ``materialize="dest"`` — requires the gather to own a ``Destination``:
      scatter the landed recv buffer straight into the consumer's named
      slots and return ``{name: (slot_shape..., feat...) array}``.  No
      full-length intermediate is built — O(slots + recv) work.

    The default is ``"dest"`` when the gather was constructed with a
    ``Destination``, else ``"full"``.
    """

    x_local: jax.Array
    _finish: Callable[..., jax.Array]

    def finish(self, *, extra_slots: int = 0, copy_own: bool = True,
               materialize: str | None = None):
        """Deliver the landed messages (see class docstring for modes).

        ``extra_slots`` (full mode): number of guaranteed-zero slots
        appended after the recv dump — x_copy[n+1 .. n+extra_slots] read as
        0 for any strategy, so consumers can point padding indices there.
        ``copy_own=False`` (full mode) skips the eq.-14 own-shard memcpy for
        consumers that read their own shard from ``x_local`` directly.
        """
        return self._finish(extra_slots=extra_slots, copy_own=copy_own,
                            materialize=materialize)


def _measure_hw(mesh, axis_name):
    from repro.core import tune
    if isinstance(axis_name, (tuple, list)):
        # multi-axis gather: calibrate over the whole visible device set
        # (the parameters describe the machine, not the mesh factorization)
        return tune.measure_hardware()
    return tune.measure_hardware(mesh, axis_name)


class IrregularGather:
    """Plan + strategy + device state for gathering one ``AccessPattern``
    over one mesh axis (or tuple of axes)."""

    def __init__(
        self,
        pattern: AccessPattern,
        where: jax.sharding.Mesh | SharedVector,
        *,
        axis_name: str | tuple = "data",
        strategy: str = "auto",
        blocksize: int | str | None = None,
        shards_per_node: int | None = None,
        topology: Topology | None = None,
        destination: Destination | None = None,
        dest_slots: int | None = None,
        hw=None,
        candidates=None,
        use_plan_cache: bool = True,
    ):
        """``destination`` may be a ``Destination`` or a callable
        ``(resolved_strategy, base_plan) -> Destination`` for consumers
        whose slot layout depends on the resolved rung (e.g. SpMV targets
        foreign slots only under ``overlap``); it is materialized and
        attached once, after strategy resolution, so no throwaway plan
        entry is ever cached.  ``dest_slots`` is the flattened slot count
        the auto ranking prices when ``destination`` is a callable (a
        plain ``Destination`` knows its own)."""
        if isinstance(where, SharedVector):
            assert where.n == pattern.n, (where.n, pattern.n)
            mesh = where.mesh
            axis_name = where.axis_name
            topology = topology or where.topology
        else:
            mesh = where
        valid = strat.STRATEGIES + ("auto",)
        if strategy not in valid:
            raise ValueError(f"strategy must be one of {valid}")
        self.pattern = pattern
        self.mesh = mesh
        self.axis_name = axis_name
        p = axis_size(mesh, axis_name)
        self.p = p
        n = pattern.n
        assert n % p == 0, "pad the vector so n divides the mesh axis"
        assert pattern.m % p == 0, "pad the pattern so m divides the mesh axis"
        if topology is None:
            topology = Topology(p, shards_per_node or p)

        if blocksize == "auto":
            if hw is None:
                hw = _measure_hw(mesh, axis_name)
            blocksize = select.choose_blocksize(
                pattern.indices, n, p, topology=topology, hw=hw)
        # destination-independent base plan first: the strategy resolves
        # against it, and the (possibly strategy-dependent) destination is
        # attached only afterwards — exactly one dest-keyed cache entry
        base_plan: CommPlan = plan_cache.get_comm_plan(
            pattern.indices, n, p, blocksize=blocksize, topology=topology,
            cache=use_plan_cache,
        )

        self.requested_strategy = strategy
        self.predicted_times: dict[str, float] | None = None
        if strategy == "auto":
            if hw is None:
                hw = _measure_hw(mesh, axis_name)
            # with a destination, price the targeted O(slots + recv) unpack
            # instead of the O(n) full-copy assembly (§5 + the new term)
            if destination is None:
                price_mode, price_slots = None, None
            else:
                price_mode = "dest"
                if callable(destination):
                    if dest_slots is None:
                        raise ValueError(
                            'strategy="auto" with a callable destination '
                            "requires dest_slots= — the flattened slot "
                            "count the ranking prices (otherwise the "
                            "targeted unpack would be priced at 0 slots "
                            "and skew the rung selection)")
                    price_slots = dest_slots
                else:
                    price_slots = destination.num_slots
            ranked = select.rank_strategies(
                base_plan, pattern.r, hw, candidates=candidates,
                materialize=price_mode, dest_slots=price_slots)
            self.predicted_times = dict(ranked)
            strategy = ranked[0][0]
        self.strategy = strategy
        self.hw = hw

        if callable(destination):
            destination = destination(strategy, base_plan)
        if destination is not None:
            assert destination.p == p, (
                f"destination has {destination.p} per-device slot tables "
                f"for a {p}-shard mesh axis")
            assert destination.indices.max() < n, (
                "destination indices must lie in [-1, n)")
            self.plan: CommPlan = plan_cache.get_comm_plan(
                pattern.indices, n, p, blocksize=blocksize,
                topology=topology, destination=destination,
                base=base_plan, cache=use_plan_cache,
            )
        else:
            self.plan = base_plan
        self.destination = destination

        with_dest = destination is not None
        shard = NamedSharding(mesh, P(axis_name))
        self.in_specs = strat.gather_in_specs(strategy, axis_name,
                                              with_dest=with_dest)
        self.plan_args = tuple(
            jax.device_put(a, shard)
            for a in strat.plan_device_args(self.plan, strategy,
                                            with_dest=with_dest)
        )
        self._start, self._finish = strat.make_start_local(
            self.plan, strategy, axis_name)

        def gather_only_local(x_local, *plan_args):
            recv = self._start(x_local, *plan_args)
            return self._finish(recv, x_local, *plan_args,
                                materialize="full")[None]

        self._gather_all = jax.jit(compat.shard_map(
            gather_only_local,
            mesh=mesh,
            in_specs=(P(axis_name),) + self.in_specs,
            out_specs=P(axis_name),
            check_vma=False,
        ))

    def _resolve_materialize(self, materialize: str | None) -> str:
        if materialize is None:
            return "dest" if self.destination is not None else "full"
        if materialize == "dest" and self.destination is None:
            raise ValueError(
                'materialize="dest" requires constructing the gather with '
                "a Destination descriptor")
        if materialize not in ("dest", "full"):
            raise ValueError(f"unknown materialize mode {materialize!r}")
        return materialize

    # ---- shard_map-local surface (compose inside a consumer's step) ----
    def local(self, x_local: jax.Array, *plan_args,
              materialize: str | None = None):
        """One-shot local gather.

        ``materialize="full"`` (default without a destination): x_local
        (shard, ...) -> x_copy (>= n, ...).  ``materialize="dest"`` (default
        with one): -> ``{name: slots}`` named consumer buffers, no
        full-length intermediate.
        """
        mode = self._resolve_materialize(materialize)
        recv = self._start(x_local, *plan_args)
        out = self._finish(recv, x_local, *plan_args, materialize=mode)
        if mode == "dest":
            return self.destination.split_local(out)
        return out

    def start_local(self, x_local: jax.Array, *plan_args) -> OverlapHandle:
        """Issue the exchange; compute on ``x_local`` while it flies."""
        in_flight = self._start(x_local, *plan_args)

        def finish(*, extra_slots=0, copy_own=True, materialize=None):
            mode = self._resolve_materialize(materialize)
            out = self._finish(in_flight, x_local, *plan_args,
                               extra_slots=extra_slots, copy_own=copy_own,
                               materialize=mode)
            if mode == "dest":
                return self.destination.split_local(out)
            return out

        return OverlapHandle(x_local=x_local, _finish=finish)

    # ---- standalone surface ----
    def shard_vector(self, x) -> jax.Array:
        """Place host values on the mesh in the plan's contiguous layout."""
        return jax.device_put(
            x, NamedSharding(self.mesh, P(self.axis_name)))

    def __call__(self, x: jax.Array) -> jax.Array:
        """(P, >=n, ...) array: row q is device q's private x_copy.

        Always the full materialization (tests and simple pipelines want
        the global-indexable copy), regardless of any ``Destination``.
        """
        return self._gather_all(x, *self.plan_args)

    @property
    def counts(self):
        """The plan's exact per-shard volume counts (§5.2 model inputs)."""
        return self.plan.counts
