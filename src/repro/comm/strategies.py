"""The paper's communication-strategy ladder, as shard_map-local gathers.

Each strategy turns a sharded vector ``x`` (one contiguous shard per device on
the communication mesh axis) into a device-private copy ``x_copy`` — the
paper's ``mythread_x_copy`` — that the local computation then indexes with
*global* indices (the paper stresses that retaining global indices is what
keeps UPCv3 easier than MPI; we retain them too).

All functions here are *local* functions: they must be called inside a
``shard_map`` over ``axis_name`` (a mesh axis name, or a tuple of axis names
to gather over their product — e.g. Heat2D's 2D process grid).  They return
an array whose leading dimension is >= n with the first n entries valid;
entries at index >= n are a padding dump.  ``x`` may carry trailing feature
dimensions (e.g. token embeddings of width d): every strategy moves whole
feature rows.

Strategies (paper §4):
  * ``replicate`` — naive: all-gather the whole vector (volume n per device).
  * ``blockwise`` — UPCv2: move whole virtual blocks that contain >=1 needed
    element, via a padded block all_to_all (volume = needed blocks × BS).
  * ``condensed`` — UPCv3: pack exactly the unique needed values, one padded
    message per pair, single all_to_all, scatter-unpack (volume = Σ unique).
  * ``overlap``   — beyond paper: same condensed exchange, but the consumer
    splits its compute so the own-shard partial runs while the all_to_all is
    in flight (see ``comm.gather.OverlapHandle``); as a pure gather it is
    identical to ``condensed``.

The ``*_start_local`` / ``*_finish_local`` pairs split each strategy at its
collective so ``OverlapHandle`` can expose an own-compute window between the
two (XLA's latency-hiding scheduler overlaps anything scheduled in between
that has no data dependency on the collective's result).

When the plan carries a ``Destination`` descriptor (``plan.dest_len > 0``),
each strategy additionally exposes a *targeted* finish: the landed recv
buffer is gathered straight into the consumer's flat slot buffer (length
``dest_len``) — O(slots + recv) work instead of the O(n) zeros+scatter that
assembling ``x_copy`` costs.  The assembled full copy remains available via
``finish(..., materialize="full")``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.comm.plan import CommPlan, ScatterPlan

__all__ = [
    "STRATEGIES",
    "SCATTER_REDUCES",
    "replicate_gather_local",
    "blockwise_gather_local",
    "condensed_gather_local",
    "dest_gather_local",
    "plan_device_args",
    "gather_in_specs",
    "make_gather_local",
    "make_start_local",
    "replicate_scatter_local",
    "blockwise_scatter_local",
    "condensed_scatter_local",
    "scatter_plan_device_args",
    "scatter_in_specs",
    "make_scatter_start_local",
]


def _my_shard(axis_name) -> jax.Array:
    """Linear shard index on the comm axis (handles tuple axis names)."""
    return jax.lax.axis_index(axis_name)


def replicate_gather_local(x_local: jax.Array, *, axis_name: str) -> jax.Array:
    """Naive strategy: materialize the entire shared vector on every device."""
    return jax.lax.all_gather(x_local, axis_name, tiled=True)


def condensed_start_local(
    x_local: jax.Array,
    send_local_idx: jax.Array,   # (1, P, s_max) local slice of plan array
    *,
    axis_name: str,
) -> jax.Array:
    """UPCv3 pack + consolidated exchange (paper Listing 5 pack loop +
    ``upc_memput``/``upc_barrier``).  Returns the landed (P, s_max, ...) recv
    buffer, not yet unpacked."""
    buf = x_local[send_local_idx[0]]                      # (P, s_max, ...) pack
    return jax.lax.all_to_all(                            # memput + barrier
        buf, axis_name, split_axis=0, concat_axis=0, tiled=True
    )


def condensed_finish_local(
    recv: jax.Array,
    x_local: jax.Array,
    recv_global_idx: jax.Array,  # (1, P, s_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
    extra_slots: int = 0,
    copy_own: bool = True,
) -> jax.Array:
    """UPCv3 unpack: scatter the landed messages into x_copy.

    Slot ``n`` is the recv padding dump (holds garbage); slots
    ``n+1 .. n+extra_slots`` are guaranteed zero (consumers use them as the
    padding target of their own index tables)."""
    feat = x_local.shape[1:]
    x_copy = jnp.zeros((n + 1 + extra_slots,) + feat, x_local.dtype)
    x_copy = x_copy.at[recv_global_idx[0].ravel()].set(
        recv.reshape((-1,) + feat))                       # unpack
    if copy_own:
        me = _my_shard(axis_name)
        # copy own shard (paper: memcpy of own blocks into mythread_x_copy)
        x_copy = jax.lax.dynamic_update_slice(
            x_copy, x_local, (me * shard_size,) + (0,) * len(feat))
    return x_copy


def condensed_gather_local(
    x_local: jax.Array,
    send_local_idx: jax.Array,   # (1, P, s_max) local slice of plan array
    recv_global_idx: jax.Array,  # (1, P, s_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
) -> jax.Array:
    """UPCv3: pack -> one consolidated message per pair -> unpack.

    The pack loop (paper Listing 5) is the gather ``x_local[send_idx]``; the
    ``upc_memput`` + ``upc_barrier`` pair is the bulk-synchronous
    ``all_to_all``; the unpack loop is the scatter into ``x_copy``.  Padding
    lands in the dump slot at index n.
    """
    recv = condensed_start_local(x_local, send_local_idx, axis_name=axis_name)
    return condensed_finish_local(
        recv, x_local, recv_global_idx,
        axis_name=axis_name, n=n, shard_size=shard_size,
    )


def blockwise_start_local(
    x_local: jax.Array,
    send_local_blk: jax.Array,   # (1, P, b_max)
    *,
    axis_name: str,
    shard_size: int,
    blocksize: int,
) -> jax.Array:
    """UPCv2 block exchange.  Returns the landed (P, b_max, BS, ...) blocks."""
    feat = x_local.shape[1:]
    blocks_per_shard = shard_size // blocksize
    xb = x_local.reshape((blocks_per_shard, blocksize) + feat)
    buf = xb[send_local_blk[0]]                            # (P, b_max, BS, ..)
    return jax.lax.all_to_all(
        buf, axis_name, split_axis=0, concat_axis=0, tiled=True
    )


def blockwise_finish_local(
    recv: jax.Array,
    x_local: jax.Array,
    recv_global_blk: jax.Array,  # (1, P, b_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
    blocksize: int,
    extra_slots: int = 0,
    copy_own: bool = True,
) -> jax.Array:
    """UPCv2 unpack: scatter whole landed blocks into x_copy.

    With ``extra_slots`` the dump block is remapped past the zero-guaranteed
    region so slots ``n+1 .. n+extra_slots`` stay zero (requires
    ``extra_slots < blocksize``)."""
    feat = x_local.shape[1:]
    nblks = n // blocksize
    blk_idx = recv_global_blk[0].ravel()
    if extra_slots:
        assert extra_slots < blocksize, (
            "zero-slot region must fit inside one virtual block")
        # dump block nblks would cover slots [n, n+BS); remap it one block
        # further so [n, n+BS) — including the zero slots — is never written
        blk_idx = jnp.where(blk_idx == nblks, nblks + 1, blk_idx)
        x_blocks = jnp.zeros((nblks + 2, blocksize) + feat, x_local.dtype)
    else:
        x_blocks = jnp.zeros((nblks + 1, blocksize) + feat, x_local.dtype)
    x_blocks = x_blocks.at[blk_idx].set(
        recv.reshape((-1, blocksize) + feat))
    x_copy = x_blocks.reshape((-1,) + feat)
    if copy_own:
        me = _my_shard(axis_name)
        x_copy = jax.lax.dynamic_update_slice(
            x_copy, x_local, (me * shard_size,) + (0,) * len(feat))
    return x_copy


def dest_gather_local(
    recv_flat: jax.Array,   # (R, ...) flattened landed recv buffer
    x_local: jax.Array,     # (shard, ...)
    src_idx: jax.Array,     # (L,) position in recv_flat of each foreign slot
    own_idx: jax.Array,     # (L,) position in x_local of each owned slot
    own_mask: jax.Array,    # (L,) int8: 1 where the slot is owned
    rem_mask: jax.Array,    # (L,) int8: 1 where the slot is foreign
) -> jax.Array:
    """Consumer-targeted unpack: deliver values straight into the L named
    slots.  Each slot is exactly one of {owned, foreign, zero}: owned slots
    gather from ``x_local``, foreign slots from the landed recv buffer, and
    zero slots (both masks 0) read exactly 0.0.  All operands are O(L) or
    O(recv) — the full-length x_copy is never built."""
    feat = x_local.shape[1:]

    def bmask(mask):
        return mask.reshape(mask.shape + (1,) * len(feat)).astype(
            x_local.dtype)

    return (recv_flat[src_idx] * bmask(rem_mask)
            + x_local[own_idx] * bmask(own_mask))


def blockwise_gather_local(
    x_local: jax.Array,
    send_local_blk: jax.Array,   # (1, P, b_max)
    recv_global_blk: jax.Array,  # (1, P, b_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
    blocksize: int,
) -> jax.Array:
    """UPCv2: move whole needed virtual blocks (upc_memget analogue).

    Every needed block travels in its entirety regardless of how many of its
    elements are actually used — exactly the paper's trade-off: fewer, larger,
    latency-amortizing transfers at the price of extra volume.
    """
    recv = blockwise_start_local(
        x_local, send_local_blk,
        axis_name=axis_name, shard_size=shard_size, blocksize=blocksize)
    return blockwise_finish_local(
        recv, x_local, recv_global_blk,
        axis_name=axis_name, n=n, shard_size=shard_size, blocksize=blocksize,
    )


def plan_device_args(plan: CommPlan, strategy: str,
                     with_dest: bool = False) -> tuple[Any, ...]:
    """Host (numpy) plan arrays each strategy needs, to be passed through
    shard_map with ``gather_in_specs`` so every device holds only its slice.

    ``with_dest=True`` (requires a plan built with a ``Destination``)
    appends the four targeted-unpack arrays: the strategy's recv-buffer
    source index, the own-shard index, and the owned/foreign masks.
    """
    if strategy == "replicate":
        base = ()
    elif strategy in ("condensed", "overlap"):
        base = (plan.send_local_idx, plan.recv_global_idx)
    elif strategy == "blockwise":
        base = (plan.send_local_blk, plan.recv_global_blk)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    if not with_dest:
        return base
    assert plan.dest_own_idx is not None, (
        "plan has no Destination; build it with destination=")
    src = {"replicate": plan.dest_global_idx,
           "blockwise": plan.dest_blk_src}.get(strategy, plan.dest_cond_src)
    return base + (src, plan.dest_own_idx, plan.dest_own_mask,
                   plan.dest_rem_mask)


def gather_in_specs(strategy: str, axis_name, with_dest: bool = False):
    """PartitionSpecs matching ``plan_device_args`` (sharded on dim 0)."""
    p = jax.sharding.PartitionSpec
    base = () if strategy == "replicate" else (p(axis_name), p(axis_name))
    if with_dest:
        base = base + (p(axis_name),) * 4
    return base


def make_gather_local(plan: CommPlan, strategy: str, axis_name):
    """Returns local_fn(x_local, *plan_args) -> x_copy (len >= n)."""
    if strategy == "replicate":
        return functools.partial(replicate_gather_local, axis_name=axis_name)
    if strategy in ("condensed", "overlap"):
        return functools.partial(
            condensed_gather_local,
            axis_name=axis_name,
            n=plan.n,
            shard_size=plan.shard_size,
        )
    if strategy == "blockwise":
        return functools.partial(
            blockwise_gather_local,
            axis_name=axis_name,
            n=plan.n,
            shard_size=plan.shard_size,
            blocksize=plan.blocksize,
        )
    raise ValueError(f"unknown strategy {strategy!r}")


def make_start_local(plan: CommPlan, strategy: str, axis_name, *,
                     use_kernel: bool = False):
    """Returns (start_fn, finish_fn) splitting the strategy at its collective.

    ``start_fn(x_local, *plan_args) -> in_flight``; ``finish_fn(in_flight,
    x_local, *plan_args, extra_slots=..., copy_own=..., materialize=...)``.
    Between the two calls the consumer runs compute that depends only on
    ``x_local`` — the generalized own/foreign window of the ``overlap`` rung.

    When the plan args carry the four targeted-unpack arrays (built via
    ``plan_device_args(plan, strategy, with_dest=True)``), ``finish``
    honors ``materialize``: ``"full"`` assembles the classic x_copy (len >=
    n); ``"dest"`` returns the flat ``(dest_len, ...)`` consumer-slot buffer
    with no full-length intermediate.  Without a destination only
    ``"full"`` is available.

    ``use_kernel=True`` swaps the jnp pack/unpack around the (unchanged)
    collective for the fused Pallas kernels in ``repro.kernels`` — one HBM
    pass per element on each side of the wire, bit-identical to the jnp
    path (the kernels execute the same op sequence; see
    kernels/pack_gather.py).  Replicate has no pack side, so only its
    targeted unpack kernelizes.
    """
    if use_kernel:
        return _make_kernel_start_local(plan, strategy, axis_name)

    def unpack_dest(recv_flat, x_local, dest):
        src, own_idx, own_mask, rem_mask = dest
        return dest_gather_local(recv_flat, x_local, src[0], own_idx[0],
                                 own_mask[0], rem_mask[0])

    if strategy == "replicate":
        def start(x_local, *args):
            return replicate_gather_local(x_local, axis_name=axis_name)

        def finish(recv, x_local, *args, extra_slots=0, copy_own=True,
                   materialize="full"):
            if materialize == "dest":
                return unpack_dest(recv, x_local, args)
            if extra_slots:
                feat = x_local.shape[1:]
                pad = jnp.zeros((1 + extra_slots,) + feat, x_local.dtype)
                return jnp.concatenate([recv, pad], axis=0)
            return recv

        return start, finish
    if strategy in ("condensed", "overlap"):
        def start(x_local, send_idx, recv_idx, *dest):
            return condensed_start_local(
                x_local, send_idx, axis_name=axis_name)

        def finish(recv, x_local, send_idx, recv_idx, *dest, extra_slots=0,
                   copy_own=True, materialize="full"):
            if materialize == "dest":
                feat = x_local.shape[1:]
                return unpack_dest(recv.reshape((-1,) + feat), x_local, dest)
            return condensed_finish_local(
                recv, x_local, recv_idx, axis_name=axis_name, n=plan.n,
                shard_size=plan.shard_size, extra_slots=extra_slots,
                copy_own=copy_own)

        return start, finish
    if strategy == "blockwise":
        def start(x_local, send_blk, recv_blk, *dest):
            return blockwise_start_local(
                x_local, send_blk, axis_name=axis_name,
                shard_size=plan.shard_size, blocksize=plan.blocksize)

        def finish(recv, x_local, send_blk, recv_blk, *dest, extra_slots=0,
                   copy_own=True, materialize="full"):
            if materialize == "dest":
                feat = x_local.shape[1:]
                return unpack_dest(recv.reshape((-1,) + feat), x_local, dest)
            return blockwise_finish_local(
                recv, x_local, recv_blk, axis_name=axis_name, n=plan.n,
                shard_size=plan.shard_size, blocksize=plan.blocksize,
                extra_slots=extra_slots, copy_own=copy_own)

        return start, finish
    raise ValueError(f"unknown strategy {strategy!r}")


def _make_kernel_start_local(plan: CommPlan, strategy: str, axis_name):
    """Kernelized (start, finish) pair: fused Pallas pack / unpack around
    the same collective (the ``use_kernel=True`` arm of
    ``make_start_local``).

    Pack = ``kernels.pack_gather`` (Listing 5's pack loop, shard
    VMEM-resident); full finish = ``kernels.unpack_scatter_set`` (eq.-15
    scatter + eq.-14 own memcpy in one pass); dest finish =
    ``kernels.unpack_dest`` (the fused ``dest_gather_local``).  Blockwise
    rides the same kernels with whole virtual blocks as the unit rows.
    """
    from repro.kernels import ops as kops  # deferred: kernels never import comm

    def unpack_dest(recv_flat, x_local, dest):
        src, own_idx, own_mask, rem_mask = dest
        return kops.unpack_dest(recv_flat, x_local, src[0], own_idx[0],
                                own_mask[0], rem_mask[0])

    if strategy == "replicate":
        def start(x_local, *args):
            return replicate_gather_local(x_local, axis_name=axis_name)

        def finish(recv, x_local, *args, extra_slots=0, copy_own=True,
                   materialize="full"):
            if materialize == "dest":
                return unpack_dest(recv, x_local, args)
            if extra_slots:
                feat = x_local.shape[1:]
                pad = jnp.zeros((1 + extra_slots,) + feat, x_local.dtype)
                return jnp.concatenate([recv, pad], axis=0)
            return recv

        return start, finish
    if strategy in ("condensed", "overlap"):
        def start(x_local, send_idx, recv_idx, *dest):
            feat = x_local.shape[1:]
            p, s_max = send_idx.shape[1], send_idx.shape[2]
            buf = kops.pack_gather(x_local, send_idx[0].reshape(-1))
            return jax.lax.all_to_all(
                buf.reshape((p, s_max) + feat), axis_name,
                split_axis=0, concat_axis=0, tiled=True)

        def finish(recv, x_local, send_idx, recv_idx, *dest, extra_slots=0,
                   copy_own=True, materialize="full"):
            feat = x_local.shape[1:]
            if materialize == "dest":
                return unpack_dest(recv.reshape((-1,) + feat), x_local, dest)
            me = _my_shard(axis_name)
            return kops.unpack_scatter_set(
                recv.reshape((-1,) + feat), recv_idx[0].ravel(), x_local,
                me * plan.shard_size, out_len=plan.n + 1 + extra_slots,
                copy_own=copy_own)

        return start, finish
    if strategy == "blockwise":
        blocksize = plan.blocksize
        blocks_per_shard = plan.shard_size // blocksize
        nblks = plan.n // blocksize

        def start(x_local, send_blk, recv_blk, *dest):
            feat = x_local.shape[1:]
            p, b_max = send_blk.shape[1], send_blk.shape[2]
            xb = x_local.reshape((blocks_per_shard, blocksize) + feat)
            buf = kops.pack_gather(xb, send_blk[0].reshape(-1))
            return jax.lax.all_to_all(
                buf.reshape((p, b_max, blocksize) + feat), axis_name,
                split_axis=0, concat_axis=0, tiled=True)

        def finish(recv, x_local, send_blk, recv_blk, *dest, extra_slots=0,
                   copy_own=True, materialize="full"):
            feat = x_local.shape[1:]
            if materialize == "dest":
                return unpack_dest(recv.reshape((-1,) + feat), x_local, dest)
            blk_idx = recv_blk[0].ravel()
            if extra_slots:
                assert extra_slots < blocksize, (
                    "zero-slot region must fit inside one virtual block")
                blk_idx = jnp.where(blk_idx == nblks, nblks + 1, blk_idx)
                out_blocks = nblks + 2
            else:
                out_blocks = nblks + 1
            me = _my_shard(axis_name)
            # own copy lands at flat offset me*shard_size == block row
            # me*blocks_per_shard — block-aligned, so the block-unit kernel
            # writes the exact same elements as the flat jnp update
            x_blocks = kops.unpack_scatter_set(
                recv.reshape((-1, blocksize) + feat), blk_idx,
                x_local.reshape((blocks_per_shard, blocksize) + feat),
                me * blocks_per_shard, out_len=out_blocks,
                copy_own=copy_own)
            return x_blocks.reshape((-1,) + feat)

        return start, finish
    raise ValueError(f"unknown strategy {strategy!r}")


STRATEGIES = ("replicate", "blockwise", "condensed", "overlap")

# --------------------------------------------------------------------------
# Push direction (put / scatter): the same rung ladder, roles swapped.
#
# Each scatter strategy turns a sharded table of *contributions* ``vals``
# ((rows_per_shard, r) per device, optional trailing feature dims; slot
# (i, j) contributes to global element ``tgt_global[i, j]``) into each
# device's combined owned slice ``y_local`` (shard_size, ...).  Duplicate
# targets combine under ``reduce``:
#
#   * "add" — y[t] = sum of contributions (0 where none);
#   * "max" — y[t] = max of contributions (0 where none; the -inf identity
#     is masked out by the plan's static ``touched`` table);
#   * "set" — y[t] = the last contribution in row-major accessor order
#     (0 where none).  Implemented as "add" with the plan's precomputed
#     winner mask zeroing every non-winning slot, so it is deterministic
#     and rides the identical collective on every rung.
#
# The pack side combines duplicates *before* the wire (sender-side
# condensing); padded message lanes carry the reduce identity, so the
# receiver's accumulate treats them as no-ops without any masking.
# --------------------------------------------------------------------------

SCATTER_REDUCES = ("add", "set", "max")


def _reduce_identity(dtype, reduce: str):
    if reduce == "max":
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.array(-jnp.inf, dtype)
        return jnp.array(jnp.iinfo(dtype).min, dtype)
    return jnp.array(0, dtype)


def _accumulate(acc: jax.Array, idx: jax.Array, vals: jax.Array,
                reduce: str) -> jax.Array:
    """Combine ``vals`` into ``acc`` at ``idx`` under the reduce semantic."""
    if reduce == "max":
        return acc.at[idx].max(vals)
    return acc.at[idx].add(vals)


def _apply_set_mask(vals: jax.Array, win_mask: jax.Array,
                    reduce: str) -> jax.Array:
    if reduce != "set":
        return vals
    feat = vals.shape[2:]
    return vals * win_mask.reshape(win_mask.shape + (1,) * len(feat)).astype(
        vals.dtype)


def _mask_untouched(y: jax.Array, touched: jax.Array,
                    reduce: str) -> jax.Array:
    """reduce="max" leaves the -inf identity on never-written elements;
    the static touched table replaces it with the documented 0."""
    if reduce != "max":
        return y
    feat = y.shape[1:]
    return jnp.where(
        touched.reshape(touched.shape + (1,) * len(feat)) > 0, y,
        jnp.zeros((), y.dtype))


def replicate_scatter_local(
    vals: jax.Array,       # (rows, r, ...) contributions
    tgt: jax.Array,        # (rows, r) global targets
    win_mask: jax.Array,   # (rows, r) int8
    touched: jax.Array,    # (1, shard_size) int8
    *,
    axis_name,
    n: int,
    shard_size: int,
    reduce: str,
) -> jax.Array:
    """Naive put: every device combines ALL its contributions into a private
    full-length accumulator, then a whole-vector cross-device reduction
    (psum / pmax) delivers each owner its slice — the push dual of the
    replicate all-gather, O(n) volume per device."""
    feat = vals.shape[2:]
    vals = _apply_set_mask(vals, win_mask, reduce)
    acc = jnp.full((n,) + feat, _reduce_identity(vals.dtype, reduce),
                   vals.dtype)
    acc = _accumulate(acc, tgt.ravel(), vals.reshape((-1,) + feat), reduce)
    if reduce == "max":
        y_full = jax.lax.pmax(acc, axis_name)
    else:
        y_full = jax.lax.psum(acc, axis_name)
    me = _my_shard(axis_name)
    y = jax.lax.dynamic_slice_in_dim(y_full, me * shard_size, shard_size, 0)
    return _mask_untouched(y, touched[0], reduce)


def condensed_scatter_start_local(
    vals: jax.Array,
    cond_msg_idx: jax.Array,   # (rows, r) flat pos in (P*s_max); own -> dump
    win_mask: jax.Array,
    *,
    axis_name,
    p: int,
    s_max: int,
    reduce: str,
) -> jax.Array:
    """UPCv3 put: sender-side segment-combine into one padded message per
    (sender, receiver) pair, then the consolidated exchange (the transpose
    of the gather's pack + ``upc_memput``).  Returns the landed (P, s_max,
    ...) contribution buffer, not yet accumulated."""
    feat = vals.shape[2:]
    vals = _apply_set_mask(vals, win_mask, reduce)
    buf = jnp.full((p * s_max + 1,) + feat,
                   _reduce_identity(vals.dtype, reduce), vals.dtype)
    buf = _accumulate(buf, cond_msg_idx.ravel(),
                      vals.reshape((-1,) + feat), reduce)
    return jax.lax.all_to_all(
        buf[:p * s_max].reshape((p, s_max) + feat), axis_name,
        split_axis=0, concat_axis=0, tiled=True)


def condensed_scatter_finish_local(
    recv: jax.Array,
    vals: jax.Array,
    unpack_idx: jax.Array,   # (1, P, s_max) = base send_local_idx, swapped
    own_idx: jax.Array,      # (rows, r) local target; foreign -> shard_size
    win_mask: jax.Array,
    touched: jax.Array,
    *,
    shard_size: int,
    reduce: str,
) -> jax.Array:
    """Accumulate-unpack: landed foreign contributions combine into the
    owned slice at the gather's pack positions (send/recv tables swap
    roles); own contributions combine directly, never touching the wire.
    Padded lanes carry the reduce identity, so no masking is needed."""
    feat = vals.shape[2:]
    vals = _apply_set_mask(vals, win_mask, reduce)
    acc = jnp.full((shard_size + 1,) + feat,
                   _reduce_identity(vals.dtype, reduce), vals.dtype)
    acc = _accumulate(acc, own_idx.ravel(), vals.reshape((-1,) + feat),
                      reduce)
    acc = _accumulate(acc, unpack_idx[0].ravel(),
                      recv.reshape((-1,) + feat), reduce)
    return _mask_untouched(acc[:shard_size], touched[0], reduce)


def condensed_scatter_local(vals, cond_msg_idx, unpack_idx, own_idx,
                            win_mask, touched, *, axis_name, p, s_max,
                            shard_size, reduce):
    recv = condensed_scatter_start_local(
        vals, cond_msg_idx, win_mask, axis_name=axis_name, p=p, s_max=s_max,
        reduce=reduce)
    return condensed_scatter_finish_local(
        recv, vals, unpack_idx, own_idx, win_mask, touched,
        shard_size=shard_size, reduce=reduce)


def blockwise_scatter_start_local(
    vals: jax.Array,
    blk_msg_idx: jax.Array,   # (rows, r) flat pos in (P*b_max*BS)
    win_mask: jax.Array,
    *,
    axis_name,
    p: int,
    b_max: int,
    blocksize: int,
    reduce: str,
) -> jax.Array:
    """UPCv2 put: contributions combine into whole virtual blocks (only
    blocks containing >= 1 target travel); one padded block all_to_all.
    Returns the landed (P, b_max, BS, ...) blocks."""
    feat = vals.shape[2:]
    vals = _apply_set_mask(vals, win_mask, reduce)
    buf = jnp.full((p * b_max * blocksize + 1,) + feat,
                   _reduce_identity(vals.dtype, reduce), vals.dtype)
    buf = _accumulate(buf, blk_msg_idx.ravel(),
                      vals.reshape((-1,) + feat), reduce)
    return jax.lax.all_to_all(
        buf[:p * b_max * blocksize].reshape((p, b_max * blocksize) + feat),
        axis_name, split_axis=0, concat_axis=0, tiled=True)


def blockwise_scatter_finish_local(
    recv: jax.Array,
    vals: jax.Array,
    unpack_blk: jax.Array,   # (1, P, b_max) = base send_local_blk, swapped
    own_idx: jax.Array,
    win_mask: jax.Array,
    touched: jax.Array,
    *,
    shard_size: int,
    blocksize: int,
    reduce: str,
) -> jax.Array:
    feat = vals.shape[2:]
    vals = _apply_set_mask(vals, win_mask, reduce)
    ident = _reduce_identity(vals.dtype, reduce)
    blocks_per_shard = shard_size // blocksize
    accb = jnp.full((blocks_per_shard + 1, blocksize) + feat, ident,
                    vals.dtype)
    accb = _accumulate(accb, unpack_blk[0].ravel(),
                       recv.reshape((-1, blocksize) + feat), reduce)
    y_blocks = accb[:blocks_per_shard].reshape((shard_size,) + feat)
    acc = jnp.full((shard_size + 1,) + feat, ident, vals.dtype)
    acc = _accumulate(acc, own_idx.ravel(), vals.reshape((-1,) + feat),
                      reduce)
    y_own = acc[:shard_size]
    y = jnp.maximum(y_blocks, y_own) if reduce == "max" else y_blocks + y_own
    return _mask_untouched(y, touched[0], reduce)


def blockwise_scatter_local(vals, blk_msg_idx, unpack_blk, own_idx,
                            win_mask, touched, *, axis_name, p, b_max,
                            shard_size, blocksize, reduce):
    recv = blockwise_scatter_start_local(
        vals, blk_msg_idx, win_mask, axis_name=axis_name, p=p, b_max=b_max,
        blocksize=blocksize, reduce=reduce)
    return blockwise_scatter_finish_local(
        recv, vals, unpack_blk, own_idx, win_mask, touched,
        shard_size=shard_size, blocksize=blocksize, reduce=reduce)


def scatter_plan_device_args(splan: ScatterPlan, strategy: str):
    """Host plan arrays each scatter strategy needs, passed through
    shard_map with ``scatter_in_specs`` (all sharded on dim 0).

    The condensed/overlap and blockwise rungs reuse the *base gather
    plan's* pack tables (``send_local_idx`` / ``send_local_blk``) as their
    accumulate-unpack tables — the send/recv role swap made concrete.
    """
    if strategy == "replicate":
        return (splan.tgt_global, splan.win_mask, splan.touched)
    if strategy in ("condensed", "overlap"):
        return (splan.cond_msg_idx, splan.base.send_local_idx,
                splan.own_tgt_idx, splan.win_mask, splan.touched)
    if strategy == "blockwise":
        return (splan.blk_msg_idx, splan.base.send_local_blk,
                splan.own_tgt_idx, splan.win_mask, splan.touched)
    raise ValueError(f"unknown strategy {strategy!r}")


def scatter_in_specs(strategy: str, axis_name):
    """PartitionSpecs matching ``scatter_plan_device_args``."""
    p = jax.sharding.PartitionSpec
    nargs = 3 if strategy == "replicate" else 5
    return (p(axis_name),) * nargs


def make_scatter_start_local(splan: ScatterPlan, strategy: str, axis_name,
                             reduce: str, *, use_kernel: bool = False):
    """Returns (start_fn, finish_fn) splitting the scatter at its collective.

    ``start_fn(vals_local, *plan_args) -> in_flight`` packs (sender-side
    combine) and issues the exchange; ``finish_fn(in_flight, vals_local,
    *plan_args) -> y_local`` runs the own-accumulate — which depends only on
    local contributions, so XLA's latency-hiding scheduler overlaps it (and
    anything else scheduled in between) with the in-flight collective — and
    then combines the landed foreign contributions.  The ``overlap`` rung is
    the ``condensed`` exchange consumed through this split.

    ``use_kernel=True`` swaps the jnp segment-combines for the push-side
    split kernels: ``kernels.accumulate_segments`` for the sender-side pack
    (12ᵀ) and the own-target accumulate (the half of 15ᵀ with no data
    dependency on the collective — it runs while the all_to_all is in
    flight, mirroring ``ops.make_spmv_overlap_sharded``'s own/foreign
    split), then ``kernels.accumulate_into`` folds the landed foreign
    contributions into that result.  Bit-identical to the jnp path on every
    rung × reduce (same op sequence, single-program combine order).
    """
    if use_kernel:
        return _make_kernel_scatter_start_local(splan, strategy, axis_name,
                                                reduce)
    if reduce not in SCATTER_REDUCES:
        raise ValueError(f"reduce must be one of {SCATTER_REDUCES}")
    shard_size = splan.shard_size
    if strategy == "replicate":
        def start(vals, tgt, win, touched):
            feat = vals.shape[2:]
            v = _apply_set_mask(vals, win, reduce)
            acc = jnp.full((splan.n,) + feat,
                           _reduce_identity(v.dtype, reduce), v.dtype)
            acc = _accumulate(acc, tgt.ravel(), v.reshape((-1,) + feat),
                              reduce)
            if reduce == "max":
                return jax.lax.pmax(acc, axis_name)
            return jax.lax.psum(acc, axis_name)

        def finish(y_full, vals, tgt, win, touched):
            me = _my_shard(axis_name)
            y = jax.lax.dynamic_slice_in_dim(
                y_full, me * shard_size, shard_size, 0)
            return _mask_untouched(y, touched[0], reduce)

        return start, finish
    if strategy in ("condensed", "overlap"):
        def start(vals, msg_idx, unpack_idx, own_idx, win, touched):
            return condensed_scatter_start_local(
                vals, msg_idx, win, axis_name=axis_name, p=splan.p,
                s_max=splan.s_max, reduce=reduce)

        def finish(recv, vals, msg_idx, unpack_idx, own_idx, win, touched):
            return condensed_scatter_finish_local(
                recv, vals, unpack_idx, own_idx, win, touched,
                shard_size=shard_size, reduce=reduce)

        return start, finish
    if strategy == "blockwise":
        def start(vals, msg_idx, unpack_blk, own_idx, win, touched):
            return blockwise_scatter_start_local(
                vals, msg_idx, win, axis_name=axis_name, p=splan.p,
                b_max=splan.b_max, blocksize=splan.blocksize, reduce=reduce)

        def finish(recv, vals, msg_idx, unpack_blk, own_idx, win, touched):
            return blockwise_scatter_finish_local(
                recv, vals, unpack_blk, own_idx, win, touched,
                shard_size=shard_size, blocksize=splan.blocksize,
                reduce=reduce)

        return start, finish
    raise ValueError(f"unknown strategy {strategy!r}")


def _make_kernel_scatter_start_local(splan: ScatterPlan, strategy: str,
                                     axis_name, reduce: str):
    """Kernelized (start, finish) pair for the put direction (the
    ``use_kernel=True`` arm of ``make_scatter_start_local``).

    The winner mask for ``reduce="set"`` stays a jnp elementwise multiply
    outside the kernels (deterministic either way; keeps the kernels
    reduce-generic), exactly mirroring where the jnp path applies it.
    """
    from repro.kernels import ops as kops  # deferred: kernels never import comm

    if reduce not in SCATTER_REDUCES:
        raise ValueError(f"reduce must be one of {SCATTER_REDUCES}")
    shard_size = splan.shard_size
    if strategy == "replicate":
        def start(vals, tgt, win, touched):
            feat = vals.shape[2:]
            v = _apply_set_mask(vals, win, reduce)
            acc = kops.accumulate_segments(
                v.reshape((-1,) + feat), tgt.ravel(), out_len=splan.n,
                reduce=reduce)
            if reduce == "max":
                return jax.lax.pmax(acc, axis_name)
            return jax.lax.psum(acc, axis_name)

        def finish(y_full, vals, tgt, win, touched):
            me = _my_shard(axis_name)
            y = jax.lax.dynamic_slice_in_dim(
                y_full, me * shard_size, shard_size, 0)
            return _mask_untouched(y, touched[0], reduce)

        return start, finish
    if strategy in ("condensed", "overlap"):
        p, s_max = splan.p, splan.s_max

        def start(vals, msg_idx, unpack_idx, own_idx, win, touched):
            feat = vals.shape[2:]
            v = _apply_set_mask(vals, win, reduce)
            buf = kops.accumulate_segments(
                v.reshape((-1,) + feat), msg_idx.ravel(),
                out_len=p * s_max + 1, reduce=reduce)
            return jax.lax.all_to_all(
                buf[:p * s_max].reshape((p, s_max) + feat), axis_name,
                split_axis=0, concat_axis=0, tiled=True)

        def finish(recv, vals, msg_idx, unpack_idx, own_idx, win, touched):
            feat = vals.shape[2:]
            v = _apply_set_mask(vals, win, reduce)
            # push-side split: the own-accumulate reads only local
            # contributions, so it runs while the all_to_all is in flight;
            # the landed-foreign kernel then folds recv into its result
            own = kops.accumulate_segments(
                v.reshape((-1,) + feat), own_idx.ravel(),
                out_len=shard_size + 1, reduce=reduce)
            acc = kops.accumulate_into(
                own, recv.reshape((-1,) + feat), unpack_idx[0].ravel(),
                reduce=reduce)
            return _mask_untouched(acc[:shard_size], touched[0], reduce)

        return start, finish
    if strategy == "blockwise":
        p, b_max, blocksize = splan.p, splan.b_max, splan.blocksize
        blocks_per_shard = shard_size // blocksize

        def start(vals, msg_idx, unpack_blk, own_idx, win, touched):
            feat = vals.shape[2:]
            v = _apply_set_mask(vals, win, reduce)
            buf = kops.accumulate_segments(
                v.reshape((-1,) + feat), msg_idx.ravel(),
                out_len=p * b_max * blocksize + 1, reduce=reduce)
            return jax.lax.all_to_all(
                buf[:p * b_max * blocksize].reshape(
                    (p, b_max * blocksize) + feat),
                axis_name, split_axis=0, concat_axis=0, tiled=True)

        def finish(recv, vals, msg_idx, unpack_blk, own_idx, win, touched):
            feat = vals.shape[2:]
            v = _apply_set_mask(vals, win, reduce)
            own = kops.accumulate_segments(
                v.reshape((-1,) + feat), own_idx.ravel(),
                out_len=shard_size + 1, reduce=reduce)
            y_own = own[:shard_size]
            accb = kops.accumulate_segments(
                recv.reshape((-1, blocksize) + feat), unpack_blk[0].ravel(),
                out_len=blocks_per_shard + 1, reduce=reduce)
            y_blocks = accb[:blocks_per_shard].reshape((shard_size,) + feat)
            y = (jnp.maximum(y_blocks, y_own) if reduce == "max"
                 else y_blocks + y_own)
            return _mask_untouched(y, touched[0], reduce)

        return start, finish
    raise ValueError(f"unknown strategy {strategy!r}")
