"""The paper's communication-strategy ladder, as shard_map-local gathers.

Each strategy turns a sharded vector ``x`` (one contiguous shard per device on
the communication mesh axis) into a device-private copy ``x_copy`` — the
paper's ``mythread_x_copy`` — that the local computation then indexes with
*global* indices (the paper stresses that retaining global indices is what
keeps UPCv3 easier than MPI; we retain them too).

All functions here are *local* functions: they must be called inside a
``shard_map`` over ``axis_name`` (a mesh axis name, or a tuple of axis names
to gather over their product — e.g. Heat2D's 2D process grid).  They return
an array whose leading dimension is >= n with the first n entries valid;
entries at index >= n are a padding dump.  ``x`` may carry trailing feature
dimensions (e.g. token embeddings of width d): every strategy moves whole
feature rows.

Strategies (paper §4):
  * ``replicate`` — naive: all-gather the whole vector (volume n per device).
  * ``blockwise`` — UPCv2: move whole virtual blocks that contain >=1 needed
    element, via a padded block all_to_all (volume = needed blocks × BS).
  * ``condensed`` — UPCv3: pack exactly the unique needed values, one padded
    message per pair, single all_to_all, scatter-unpack (volume = Σ unique).
  * ``overlap``   — beyond paper: same condensed exchange, but the consumer
    splits its compute so the own-shard partial runs while the all_to_all is
    in flight (see ``comm.gather.OverlapHandle``); as a pure gather it is
    identical to ``condensed``.

The ``*_start_local`` / ``*_finish_local`` pairs split each strategy at its
collective so ``OverlapHandle`` can expose an own-compute window between the
two (XLA's latency-hiding scheduler overlaps anything scheduled in between
that has no data dependency on the collective's result).

When the plan carries a ``Destination`` descriptor (``plan.dest_len > 0``),
each strategy additionally exposes a *targeted* finish: the landed recv
buffer is gathered straight into the consumer's flat slot buffer (length
``dest_len``) — O(slots + recv) work instead of the O(n) zeros+scatter that
assembling ``x_copy`` costs.  The assembled full copy remains available via
``finish(..., materialize="full")``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.comm.plan import CommPlan

__all__ = [
    "STRATEGIES",
    "replicate_gather_local",
    "blockwise_gather_local",
    "condensed_gather_local",
    "dest_gather_local",
    "plan_device_args",
    "gather_in_specs",
    "make_gather_local",
    "make_start_local",
]


def _my_shard(axis_name) -> jax.Array:
    """Linear shard index on the comm axis (handles tuple axis names)."""
    return jax.lax.axis_index(axis_name)


def replicate_gather_local(x_local: jax.Array, *, axis_name: str) -> jax.Array:
    """Naive strategy: materialize the entire shared vector on every device."""
    return jax.lax.all_gather(x_local, axis_name, tiled=True)


def condensed_start_local(
    x_local: jax.Array,
    send_local_idx: jax.Array,   # (1, P, s_max) local slice of plan array
    *,
    axis_name: str,
) -> jax.Array:
    """UPCv3 pack + consolidated exchange (paper Listing 5 pack loop +
    ``upc_memput``/``upc_barrier``).  Returns the landed (P, s_max, ...) recv
    buffer, not yet unpacked."""
    buf = x_local[send_local_idx[0]]                      # (P, s_max, ...) pack
    return jax.lax.all_to_all(                            # memput + barrier
        buf, axis_name, split_axis=0, concat_axis=0, tiled=True
    )


def condensed_finish_local(
    recv: jax.Array,
    x_local: jax.Array,
    recv_global_idx: jax.Array,  # (1, P, s_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
    extra_slots: int = 0,
    copy_own: bool = True,
) -> jax.Array:
    """UPCv3 unpack: scatter the landed messages into x_copy.

    Slot ``n`` is the recv padding dump (holds garbage); slots
    ``n+1 .. n+extra_slots`` are guaranteed zero (consumers use them as the
    padding target of their own index tables)."""
    feat = x_local.shape[1:]
    x_copy = jnp.zeros((n + 1 + extra_slots,) + feat, x_local.dtype)
    x_copy = x_copy.at[recv_global_idx[0].ravel()].set(
        recv.reshape((-1,) + feat))                       # unpack
    if copy_own:
        me = _my_shard(axis_name)
        # copy own shard (paper: memcpy of own blocks into mythread_x_copy)
        x_copy = jax.lax.dynamic_update_slice(
            x_copy, x_local, (me * shard_size,) + (0,) * len(feat))
    return x_copy


def condensed_gather_local(
    x_local: jax.Array,
    send_local_idx: jax.Array,   # (1, P, s_max) local slice of plan array
    recv_global_idx: jax.Array,  # (1, P, s_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
) -> jax.Array:
    """UPCv3: pack -> one consolidated message per pair -> unpack.

    The pack loop (paper Listing 5) is the gather ``x_local[send_idx]``; the
    ``upc_memput`` + ``upc_barrier`` pair is the bulk-synchronous
    ``all_to_all``; the unpack loop is the scatter into ``x_copy``.  Padding
    lands in the dump slot at index n.
    """
    recv = condensed_start_local(x_local, send_local_idx, axis_name=axis_name)
    return condensed_finish_local(
        recv, x_local, recv_global_idx,
        axis_name=axis_name, n=n, shard_size=shard_size,
    )


def blockwise_start_local(
    x_local: jax.Array,
    send_local_blk: jax.Array,   # (1, P, b_max)
    *,
    axis_name: str,
    shard_size: int,
    blocksize: int,
) -> jax.Array:
    """UPCv2 block exchange.  Returns the landed (P, b_max, BS, ...) blocks."""
    feat = x_local.shape[1:]
    blocks_per_shard = shard_size // blocksize
    xb = x_local.reshape((blocks_per_shard, blocksize) + feat)
    buf = xb[send_local_blk[0]]                            # (P, b_max, BS, ..)
    return jax.lax.all_to_all(
        buf, axis_name, split_axis=0, concat_axis=0, tiled=True
    )


def blockwise_finish_local(
    recv: jax.Array,
    x_local: jax.Array,
    recv_global_blk: jax.Array,  # (1, P, b_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
    blocksize: int,
    extra_slots: int = 0,
    copy_own: bool = True,
) -> jax.Array:
    """UPCv2 unpack: scatter whole landed blocks into x_copy.

    With ``extra_slots`` the dump block is remapped past the zero-guaranteed
    region so slots ``n+1 .. n+extra_slots`` stay zero (requires
    ``extra_slots < blocksize``)."""
    feat = x_local.shape[1:]
    nblks = n // blocksize
    blk_idx = recv_global_blk[0].ravel()
    if extra_slots:
        assert extra_slots < blocksize, (
            "zero-slot region must fit inside one virtual block")
        # dump block nblks would cover slots [n, n+BS); remap it one block
        # further so [n, n+BS) — including the zero slots — is never written
        blk_idx = jnp.where(blk_idx == nblks, nblks + 1, blk_idx)
        x_blocks = jnp.zeros((nblks + 2, blocksize) + feat, x_local.dtype)
    else:
        x_blocks = jnp.zeros((nblks + 1, blocksize) + feat, x_local.dtype)
    x_blocks = x_blocks.at[blk_idx].set(
        recv.reshape((-1, blocksize) + feat))
    x_copy = x_blocks.reshape((-1,) + feat)
    if copy_own:
        me = _my_shard(axis_name)
        x_copy = jax.lax.dynamic_update_slice(
            x_copy, x_local, (me * shard_size,) + (0,) * len(feat))
    return x_copy


def dest_gather_local(
    recv_flat: jax.Array,   # (R, ...) flattened landed recv buffer
    x_local: jax.Array,     # (shard, ...)
    src_idx: jax.Array,     # (L,) position in recv_flat of each foreign slot
    own_idx: jax.Array,     # (L,) position in x_local of each owned slot
    own_mask: jax.Array,    # (L,) int8: 1 where the slot is owned
    rem_mask: jax.Array,    # (L,) int8: 1 where the slot is foreign
) -> jax.Array:
    """Consumer-targeted unpack: deliver values straight into the L named
    slots.  Each slot is exactly one of {owned, foreign, zero}: owned slots
    gather from ``x_local``, foreign slots from the landed recv buffer, and
    zero slots (both masks 0) read exactly 0.0.  All operands are O(L) or
    O(recv) — the full-length x_copy is never built."""
    feat = x_local.shape[1:]

    def bmask(mask):
        return mask.reshape(mask.shape + (1,) * len(feat)).astype(
            x_local.dtype)

    return (recv_flat[src_idx] * bmask(rem_mask)
            + x_local[own_idx] * bmask(own_mask))


def blockwise_gather_local(
    x_local: jax.Array,
    send_local_blk: jax.Array,   # (1, P, b_max)
    recv_global_blk: jax.Array,  # (1, P, b_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
    blocksize: int,
) -> jax.Array:
    """UPCv2: move whole needed virtual blocks (upc_memget analogue).

    Every needed block travels in its entirety regardless of how many of its
    elements are actually used — exactly the paper's trade-off: fewer, larger,
    latency-amortizing transfers at the price of extra volume.
    """
    recv = blockwise_start_local(
        x_local, send_local_blk,
        axis_name=axis_name, shard_size=shard_size, blocksize=blocksize)
    return blockwise_finish_local(
        recv, x_local, recv_global_blk,
        axis_name=axis_name, n=n, shard_size=shard_size, blocksize=blocksize,
    )


def plan_device_args(plan: CommPlan, strategy: str,
                     with_dest: bool = False) -> tuple[Any, ...]:
    """Host (numpy) plan arrays each strategy needs, to be passed through
    shard_map with ``gather_in_specs`` so every device holds only its slice.

    ``with_dest=True`` (requires a plan built with a ``Destination``)
    appends the four targeted-unpack arrays: the strategy's recv-buffer
    source index, the own-shard index, and the owned/foreign masks.
    """
    if strategy == "replicate":
        base = ()
    elif strategy in ("condensed", "overlap"):
        base = (plan.send_local_idx, plan.recv_global_idx)
    elif strategy == "blockwise":
        base = (plan.send_local_blk, plan.recv_global_blk)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    if not with_dest:
        return base
    assert plan.dest_own_idx is not None, (
        "plan has no Destination; build it with destination=")
    src = {"replicate": plan.dest_global_idx,
           "blockwise": plan.dest_blk_src}.get(strategy, plan.dest_cond_src)
    return base + (src, plan.dest_own_idx, plan.dest_own_mask,
                   plan.dest_rem_mask)


def gather_in_specs(strategy: str, axis_name, with_dest: bool = False):
    """PartitionSpecs matching ``plan_device_args`` (sharded on dim 0)."""
    p = jax.sharding.PartitionSpec
    base = () if strategy == "replicate" else (p(axis_name), p(axis_name))
    if with_dest:
        base = base + (p(axis_name),) * 4
    return base


def make_gather_local(plan: CommPlan, strategy: str, axis_name):
    """Returns local_fn(x_local, *plan_args) -> x_copy (len >= n)."""
    if strategy == "replicate":
        return functools.partial(replicate_gather_local, axis_name=axis_name)
    if strategy in ("condensed", "overlap"):
        return functools.partial(
            condensed_gather_local,
            axis_name=axis_name,
            n=plan.n,
            shard_size=plan.shard_size,
        )
    if strategy == "blockwise":
        return functools.partial(
            blockwise_gather_local,
            axis_name=axis_name,
            n=plan.n,
            shard_size=plan.shard_size,
            blocksize=plan.blocksize,
        )
    raise ValueError(f"unknown strategy {strategy!r}")


def make_start_local(plan: CommPlan, strategy: str, axis_name):
    """Returns (start_fn, finish_fn) splitting the strategy at its collective.

    ``start_fn(x_local, *plan_args) -> in_flight``; ``finish_fn(in_flight,
    x_local, *plan_args, extra_slots=..., copy_own=..., materialize=...)``.
    Between the two calls the consumer runs compute that depends only on
    ``x_local`` — the generalized own/foreign window of the ``overlap`` rung.

    When the plan args carry the four targeted-unpack arrays (built via
    ``plan_device_args(plan, strategy, with_dest=True)``), ``finish``
    honors ``materialize``: ``"full"`` assembles the classic x_copy (len >=
    n); ``"dest"`` returns the flat ``(dest_len, ...)`` consumer-slot buffer
    with no full-length intermediate.  Without a destination only
    ``"full"`` is available.
    """
    def unpack_dest(recv_flat, x_local, dest):
        src, own_idx, own_mask, rem_mask = dest
        return dest_gather_local(recv_flat, x_local, src[0], own_idx[0],
                                 own_mask[0], rem_mask[0])

    if strategy == "replicate":
        def start(x_local, *args):
            return replicate_gather_local(x_local, axis_name=axis_name)

        def finish(recv, x_local, *args, extra_slots=0, copy_own=True,
                   materialize="full"):
            if materialize == "dest":
                return unpack_dest(recv, x_local, args)
            if extra_slots:
                feat = x_local.shape[1:]
                pad = jnp.zeros((1 + extra_slots,) + feat, x_local.dtype)
                return jnp.concatenate([recv, pad], axis=0)
            return recv

        return start, finish
    if strategy in ("condensed", "overlap"):
        def start(x_local, send_idx, recv_idx, *dest):
            return condensed_start_local(
                x_local, send_idx, axis_name=axis_name)

        def finish(recv, x_local, send_idx, recv_idx, *dest, extra_slots=0,
                   copy_own=True, materialize="full"):
            if materialize == "dest":
                feat = x_local.shape[1:]
                return unpack_dest(recv.reshape((-1,) + feat), x_local, dest)
            return condensed_finish_local(
                recv, x_local, recv_idx, axis_name=axis_name, n=plan.n,
                shard_size=plan.shard_size, extra_slots=extra_slots,
                copy_own=copy_own)

        return start, finish
    if strategy == "blockwise":
        def start(x_local, send_blk, recv_blk, *dest):
            return blockwise_start_local(
                x_local, send_blk, axis_name=axis_name,
                shard_size=plan.shard_size, blocksize=plan.blocksize)

        def finish(recv, x_local, send_blk, recv_blk, *dest, extra_slots=0,
                   copy_own=True, materialize="full"):
            if materialize == "dest":
                feat = x_local.shape[1:]
                return unpack_dest(recv.reshape((-1,) + feat), x_local, dest)
            return blockwise_finish_local(
                recv, x_local, recv_blk, axis_name=axis_name, n=plan.n,
                shard_size=plan.shard_size, blocksize=plan.blocksize,
                extra_slots=extra_slots, copy_own=copy_own)

        return start, finish
    raise ValueError(f"unknown strategy {strategy!r}")


STRATEGIES = ("replicate", "blockwise", "condensed", "overlap")
