"""Plan-source telemetry — where did each exchange's executor tables come from?

The paper's "one-time preparation step" (§4.3.1) stops being one-time the
moment the access pattern changes per batch: at traffic rates the question
"did this exchange pay a host plan build?" is the difference between a hot
path and a stall.  This module counts, per process, how every plan was
obtained:

* ``memory-hit``    — exact plan served from the in-process LRU;
* ``disk-hit``      — exact plan loaded from the persistent cache;
* ``bucket-reuse``  — a compatible cached *envelope* plan reused after the
  pattern's quantized stats matched (``plan_cache.get_envelope_plan``);
* ``device-derive`` — executor tables computed in-jit from the batch's
  routing (``comm.dynamic``), no host round-trip at all;
* ``host-build``    — the full O(nnz) host preparation step ran.

Build latency is accumulated per source so the §5 ``T_plan`` model
(``perfmodel.plan_build_time``) can be validated against what actually
happened.  The counters are surfaced as the ``telemetry`` block of
``BENCH_table3.json`` and asserted by the dynamic-MoE acceptance test
("N distinct routings, zero host builds after warmup").

Thread-safe like ``plan_cache.CacheStats`` (bump under a lock); tests use
``isolated()`` instead of mutating the module-global ``stats``.

Beyond the plan sources, a second counter group ticks the *serving loop*
(``TICK_KINDS``): the continuous-batching engine (``repro.serve``) bumps
``decode_steps`` once per jitted decode tick and ``prefill_chunks`` once
per prefill chunk, so "zero host plan-builds during steady-state decode"
is an assertable interval fact: snapshot, run N ticks, check
``since(snap)`` shows ``decode_steps >= N`` and ``host-build == 0``
(``decode_host_free`` packages exactly that).

>>> from repro.comm import telemetry
>>> with telemetry.isolated() as t:
...     telemetry.record("host-build", seconds=0.25)   # warmup
...     snap = t.snapshot()
...     telemetry.record("device-derive")
...     telemetry.record("device-derive")
...     telemetry.record_tick("decode_steps")
>>> t.snapshot()["sources"]["device-derive"], t.snapshot()["sources"]["host-build"]
(2, 1)
>>> t.snapshot()["build_seconds"]["host-build"]
0.25
>>> t.host_free(warmup=1)   # after the 1-record warmup, no host builds
True
>>> delta = t.since(snap)
>>> delta["host-build"], delta["decode_steps"]
(0, 1)
>>> t.decode_host_free(snap)   # >=1 decode tick, 0 host builds since snap
True
"""
from __future__ import annotations

import contextlib
import threading

__all__ = ["PLAN_SOURCES", "TICK_KINDS", "PlanTelemetry", "stats", "record",
           "record_tick", "isolated"]

# Ordered from cheapest to most expensive way of obtaining a plan.
PLAN_SOURCES = ("memory-hit", "disk-hit", "bucket-reuse", "device-derive",
                "host-build")

# Sources that never touch the host O(nnz) preparation step after warmup.
HOT_PATH_SOURCES = ("memory-hit", "disk-hit", "bucket-reuse",
                    "device-derive")

# Serving-loop tick counters (repro.serve): one bump per jitted decode
# tick / per prefill chunk — the denominator for "zero host builds while
# the loop was actually decoding".
TICK_KINDS = ("decode_steps", "prefill_chunks")


class PlanTelemetry:
    """Per-exchange plan-source counters + accumulated build latency."""

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        with getattr(self, "_lock", threading.Lock()):
            self.sources = {s: 0 for s in PLAN_SOURCES}
            self.build_seconds = {s: 0.0 for s in PLAN_SOURCES}
            self.ticks = {k: 0 for k in TICK_KINDS}
            self.events: list[str] = []   # sources in record order

    def record(self, source: str, seconds: float = 0.0) -> None:
        if source not in PLAN_SOURCES:
            raise ValueError(
                f"unknown plan source {source!r}; expected one of "
                f"{PLAN_SOURCES}")
        with self._lock:
            self.sources[source] += 1
            self.build_seconds[source] += float(seconds)
            self.events.append(source)

    def record_tick(self, kind: str, n: int = 1) -> None:
        """Bump a serving-loop counter (a ``TICK_KINDS`` name) by ``n``."""
        if kind not in TICK_KINDS:
            raise ValueError(
                f"unknown tick kind {kind!r}; expected one of {TICK_KINDS}")
        with self._lock:
            self.ticks[kind] += int(n)

    @property
    def total(self) -> int:
        return sum(self.sources.values())

    def snapshot(self) -> dict:
        """A deep, detached copy — safe to compare across later records."""
        with self._lock:
            return {
                "sources": dict(self.sources),
                "build_seconds": dict(self.build_seconds),
                "ticks": dict(self.ticks),
                "total": sum(self.sources.values()),
            }

    def since(self, snap: dict) -> dict:
        """Per-source (and per-tick-kind) deltas between ``snap`` (a
        ``snapshot()``) and now.  Pre-tick snapshots are accepted — missing
        keys count from 0."""
        cur = self.snapshot()
        out = {s: cur["sources"][s] - snap["sources"].get(s, 0)
               for s in PLAN_SOURCES}
        prev_ticks = snap.get("ticks", {})
        out.update({k: cur["ticks"][k] - prev_ticks.get(k, 0)
                    for k in TICK_KINDS})
        return out

    def decode_host_free(self, snap: dict) -> bool:
        """The serving acceptance criterion: since ``snap``, at least one
        decode tick ran and NO plan came from the host O(nnz) build."""
        delta = self.since(snap)
        return delta["decode_steps"] > 0 and delta["host-build"] == 0

    def host_free(self, warmup: int = 0) -> bool:
        """True when every record after the first ``warmup`` events came
        from a hot-path source (never ``host-build``) — the dynamic-MoE
        acceptance criterion."""
        with self._lock:
            tail = self.events[warmup:]
        return all(s in HOT_PATH_SOURCES for s in tail)


# Module-global telemetry; swap it out with ``isolated()`` in tests.
stats = PlanTelemetry()


def record(source: str, seconds: float = 0.0) -> None:
    """Record one plan acquisition on the active telemetry object."""
    stats.record(source, seconds)


def record_tick(kind: str, n: int = 1) -> None:
    """Bump a serving-loop tick counter on the active telemetry object."""
    stats.record_tick(kind, n)


@contextlib.contextmanager
def isolated():
    """Capture-safe scope: a fresh ``PlanTelemetry`` becomes the module
    global for the duration, the previous one is restored after — tests
    never mutate (or race on) the process-wide counters."""
    global stats
    prev = stats
    stats = PlanTelemetry()
    try:
        yield stats
    finally:
        stats = prev
