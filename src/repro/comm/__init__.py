"""repro.comm — the paper's irregular-communication runtime, workload-agnostic.

The optimization unit is an ``AccessPattern`` (which global elements of a
``SharedVector`` does each accessor touch), not any one workload — and not
any one *direction*: ``IrregularGather`` (pull — accessors read their
elements) and ``IrregularScatter`` (push — accessors contribute to their
elements, duplicates combining under ``reduce="add"|"set"|"max"``) are the
two front doors over one shared exchange core.  Each plans once (§4.3.1,
persistently cached; the scatter plan is the gather plan with send/recv
tables swapped, ``CommPlan.transpose()``), picks a ladder rung (§4) by hand
or by the §5 models (``strategy="auto"``, ``blocksize="auto"`` — put-model
pricing for scatters), and exposes both a standalone call and
``shard_map``-local functions — including the handle-based
start/compute/finish protocol that generalizes the own/foreign split.

A ``Destination`` descriptor names *where* gathered values land (halo
strips, EllPack slots, expert-capacity rows): with one attached, every
strategy's ``finish`` scatters the landed recv buffer straight into the
consumer's named slots — O(slots + recv) work — instead of assembling the
O(n) full-length private copy (still available via
``finish(materialize="full")``).

The third, higher-level front door is the ``Schedule`` builder /
``ExchangeSchedule``: a declared *chain* of exchanges
(gather → compute → scatter, any length) compiled into one ``shard_map``
whose stages share a single exchange-core context (one hw-calibration
memo hit, one base-plan probe per pattern, transpose-derived scatter
plans reused from sibling gathers) and pipeline through the handle
protocol — priced as one consolidated window by
``perfmodel.predict_schedule``.

Consumers: ``repro.core.spmv`` (the paper's workload, plus its transposed
product ``transpose=True`` via scatter-accumulate), ``repro.core.heat2d``
(§8 stencil halos), ``repro.models.moe`` (token→expert dispatch gather and
its inverse, the weighted expert→token combine scatter).  See
``docs/comm_api.md`` for the API walkthrough and ``docs/perf_model.md`` for
the paper-formula-to-code map.
"""
from repro.comm.pattern import AccessPattern, Destination
from repro.comm.shared import SharedVector
from repro.comm.plan import (CommPlan, GatherCounts, ScatterPlan, Topology,
                             attach_destination, build_comm_plan,
                             blockwise_block_counts, derive_scatter_plan)
from repro.comm.plan_cache import (get_comm_plan, get_envelope_plan,
                                   get_scatter_plan)
from repro.comm.dynamic import (DynamicPattern, derive_gather_tables,
                                derive_scatter_tables, envelope_s_max)
from repro.comm.strategies import SCATTER_REDUCES, STRATEGIES
from repro.comm.exchange import IrregularExchange
from repro.comm.gather import IrregularGather, OverlapHandle
from repro.comm.scatter import IrregularScatter, ScatterHandle
from repro.comm.schedule import ExchangeSchedule, Schedule, StageRef
from repro.comm import plan, plan_cache, pattern, shared, strategies, select
from repro.comm import dynamic, exchange, gather, scatter, schedule
from repro.comm import telemetry

__all__ = [
    "AccessPattern", "Destination", "SharedVector", "IrregularExchange",
    "IrregularGather", "IrregularScatter", "OverlapHandle", "ScatterHandle",
    "ExchangeSchedule", "Schedule", "StageRef",
    "CommPlan", "GatherCounts", "ScatterPlan", "Topology", "DynamicPattern",
    "attach_destination", "build_comm_plan", "blockwise_block_counts",
    "derive_scatter_plan", "get_comm_plan", "get_scatter_plan",
    "get_envelope_plan", "derive_gather_tables", "derive_scatter_tables",
    "envelope_s_max", "STRATEGIES", "SCATTER_REDUCES",
    "plan", "plan_cache", "pattern", "shared", "strategies", "select",
    "dynamic", "exchange", "gather", "scatter", "schedule", "telemetry",
]
