"""repro.comm — the paper's irregular-communication runtime, workload-agnostic.

The optimization unit is an ``AccessPattern`` (which global elements of a
``SharedVector`` does each accessor touch), not any one workload.
``IrregularGather`` is the single front door: it plans once (§4.3.1,
persistently cached), picks a ladder rung (§4) by hand or by the §5 models
(``strategy="auto"``, ``blocksize="auto"``), and exposes both a standalone
gather and ``shard_map``-local functions — including the ``OverlapHandle``
start/compute/finish protocol that generalizes the own/foreign split.

A ``Destination`` descriptor names *where* gathered values land (halo
strips, EllPack slots, expert-capacity rows): with one attached, every
strategy's ``finish`` scatters the landed recv buffer straight into the
consumer's named slots — O(slots + recv) work — instead of assembling the
O(n) full-length private copy (still available via
``finish(materialize="full")``).

Consumers: ``repro.core.spmv`` (the paper's workload), ``repro.core.heat2d``
(§8 stencil halos), ``repro.models.moe`` (token→expert dispatch).  See
``docs/comm_api.md`` for the API walkthrough and ``docs/perf_model.md`` for
the paper-formula-to-code map.
"""
from repro.comm.pattern import AccessPattern, Destination
from repro.comm.shared import SharedVector
from repro.comm.plan import (CommPlan, GatherCounts, Topology,
                             attach_destination, build_comm_plan,
                             blockwise_block_counts)
from repro.comm.plan_cache import get_comm_plan
from repro.comm.strategies import STRATEGIES
from repro.comm.gather import IrregularGather, OverlapHandle
from repro.comm import plan, plan_cache, pattern, shared, strategies, select
from repro.comm import gather

__all__ = [
    "AccessPattern", "Destination", "SharedVector", "IrregularGather",
    "OverlapHandle", "CommPlan", "GatherCounts", "Topology",
    "attach_destination", "build_comm_plan", "blockwise_block_counts",
    "get_comm_plan", "STRATEGIES",
    "plan", "plan_cache", "pattern", "shared", "strategies", "select",
    "gather",
]
