"""Shims over jax version skew (container jax 0.4.x vs current APIs).

The code targets the modern spellings (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh(..., axis_types=...)``); on older jax these fall back to
``jax.experimental.shard_map.shard_map`` (whose ``check_rep`` is the old name
of ``check_vma``) and to ``make_mesh`` without axis types (older meshes are
implicitly fully Auto, so dropping the argument is semantics-preserving).
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "auto_axis_types", "axis_size",
           "optimization_barrier"]


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


def auto_axis_types(num_axes: int):
    """(AxisType.Auto,) * num_axes on jax that has explicit axis types."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * num_axes


def make_mesh(axis_shapes, axis_names, axis_types=None):
    """``jax.make_mesh`` accepting (and discarding, pre-AxisType) axis_types."""
    if axis_types is not None and hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    return jax.make_mesh(axis_shapes, axis_names)


def axis_size(axis_name):
    """``jax.lax.axis_size``; on older jax, ``psum(1)`` over the axis."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


_barrier_differentiable: bool | None = None


def optimization_barrier(x):
    """``jax.lax.optimization_barrier``, degraded to identity on jax
    versions whose barrier has no differentiation rule.

    The barrier is purely a scheduling hint, so dropping it is
    semantics-preserving.  Probed lazily (abstract trace only) so merely
    importing this module never touches jax device state.
    """
    global _barrier_differentiable
    if _barrier_differentiable is None:
        try:
            jax.make_jaxpr(jax.grad(
                lambda a: jax.lax.optimization_barrier(a * 1.0)))(0.0)
            _barrier_differentiable = True
        except Exception:
            _barrier_differentiable = False
    if _barrier_differentiable:
        return jax.lax.optimization_barrier(x)
    return x
