"""Continuous-batching inference server (docs/serving.md).

The serving loop is the decode-regime consumer of ``repro.comm``: a
request queue with arrival-time admission (``queue``), slot-based KV-cache
bookkeeping (``slots``), and the prefill/decode interleave engine
(``engine``) that packs ready prompts into free cache lanes, runs chunked
fused prefill (``Model.prefill``) and steps every active lane through one
jitted decode step per tick — with the MoE block optionally routed through
the per-batch ``models.moe.DynamicMoELayer`` comm schedule (§5-priced,
zero host plan builds after warmup, telemetry-asserted).
"""
from repro.serve.engine import (ServeEngine, ServeReport, generate_batch_loop,
                                moe_decode_hook)
from repro.serve.queue import Request, RequestQueue
from repro.serve.slots import Slot, SlotManager

__all__ = ["Request", "RequestQueue", "Slot", "SlotManager", "ServeEngine",
           "ServeReport", "generate_batch_loop", "moe_decode_hook"]
