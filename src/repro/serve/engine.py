"""Prefill/decode interleave engine (continuous batching).

The MaxText offline-inference shape: ``num_slots`` lanes of one batched
per-slot KV cache.  Every tick the engine

1. **admits** — pops arrived requests off the ``RequestQueue`` while free
   lanes exist: each prompt runs chunked fused prefill (``Model.prefill``)
   into a private 1-lane cache, which one jitted insert copies into the
   free lane (slot index and first token are traced, so admission never
   recompiles);
2. **decodes** — one jitted ``Model.decode_step`` over ALL lanes (free
   lanes compute garbage that is simply never read);
3. **bookkeeps** — appends each active lane's greedy token host-side,
   releases lanes whose request hit ``max_new_tokens`` / ``eos_id`` so the
   next tick's admission can refill them.

With ``moe_layer`` set (a ``models.moe.DynamicMoELayer`` built for
``num_tokens == num_slots``), the transformer's MoE FFN is routed through
the §5-priced comm schedule via ``RunCtx.moe_step``: per-tick routing,
in-jit plan derivation, zero host plan builds after the first-tick trace —
asserted through ``comm.telemetry`` (``decode_host_free``).

The engine's clock is the tick counter, so ``Request.arrival_time`` in
tick units makes admission order fully deterministic for tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import telemetry
from repro.models.transformer import Model
from repro.serve.queue import Request, RequestQueue
from repro.serve.slots import SlotManager

__all__ = ["ServeEngine", "ServeReport", "generate_batch_loop",
           "moe_decode_hook"]


def moe_decode_hook(cfg, layer):
    """``RunCtx.moe_step`` adapter: route one decode tick's (B, 1, D)
    hidden batch through a ``DynamicMoELayer``.

    The routing math is ``moe_fwd``'s verbatim (same einsum, f32 softmax,
    ``lax.top_k``, renormalize); the dispatch→expert→combine then runs in
    the layer's fused shard_map window with THIS layer's traced weights —
    one ``DynamicMoELayer`` instance (template shapes) serves every
    scanned transformer layer via ``DynamicMoELayer.apply``.
    """
    k = cfg.experts_per_token

    def moe_step(p_moe, h):
        b, _, d = h.shape
        xg = h.reshape(1, b, d)
        logits = jnp.einsum(
            "gtd,de->gte", xg, p_moe["router"]["w"].astype(h.dtype)
        ).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / top_p.sum(-1, keepdims=True)
        wx = [p_moe["w1"], p_moe["w2"]]
        if cfg.act == "swiglu":
            wx.append(p_moe["w3"])
        y = layer.apply(h.reshape(b, d), top_e[0], top_p[0], *wx)
        return y.reshape(b, 1, d).astype(h.dtype)

    return moe_step


def _with_moe_hook(model: Model, moe_layer) -> Model:
    if moe_layer is None:
        return model
    if model.cfg.family != "moe":
        raise ValueError(
            f"moe_layer needs a MoE model, got family {model.cfg.family!r}")
    ctx = dataclasses.replace(
        model.ctx, moe_step=moe_decode_hook(model.cfg, moe_layer))
    return Model(model.cfg, ctx)


def _insert(cache, prefix, slot, token, tokens):
    """Copy a B=1 per-slot prefix cache into lane ``slot`` of the batched
    cache and seed the lane's next input token.  Layer arrays carry a
    leading stacked-L dim, so every leaf maps as (L, 1, ...) -> lane of
    (L, B, ...)."""

    def put(dst, src):
        return dst.at[:, slot].set(src[:, 0])

    layers = jax.tree.map(put, cache["layers"], prefix["layers"])
    pos = cache["pos"].at[slot].set(prefix["pos"][0])
    toks = tokens.at[slot, 0].set(token)
    return {"pos": pos, "layers": layers}, toks


def _percentile(xs, q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    i = min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1))))
    return float(s[i])


@dataclasses.dataclass
class ServeReport:
    """What a ``ServeEngine.run`` produced, with the latency accounting
    ``benchmarks.tables.table_serve`` reports."""

    outputs: dict[Any, list[int]]       # request id -> greedy tokens
    completed: list[Any]                # completion order
    slot_of: dict[Any, int]             # request id -> lane it ran in
    ticks: int
    tick_seconds: list[float]           # wall time of each decode tick
    token_seconds: list[float]          # per generated token (its tick's dt)
    ttft_seconds: dict[Any, float]      # request id -> prefill wall time
    telemetry: dict                     # comm.telemetry deltas for the run

    @property
    def total_tokens(self) -> int:
        return sum(len(v) for v in self.outputs.values())

    @property
    def tokens_per_s(self) -> float:
        """Decode throughput: tokens emitted by decode ticks over decode
        wall time (prefill tokens/time excluded on both sides)."""
        t = sum(self.tick_seconds)
        return len(self.token_seconds) / t if t > 0 else 0.0

    def p50_us(self) -> float:
        return _percentile(self.token_seconds, 50.0) * 1e6

    def p99_us(self) -> float:
        return _percentile(self.token_seconds, 99.0) * 1e6


class ServeEngine:
    """Continuous-batching serving loop over a per-slot decode cache."""

    def __init__(self, model: Model, params, *, num_slots: int,
                 cache_len: int, prefill_chunk: int | None = None,
                 moe_layer=None, cache_dtype=None):
        if moe_layer is not None and moe_layer.num_tokens != num_slots:
            raise ValueError(
                f"moe_layer routes {moe_layer.num_tokens} tokens per step "
                f"but the engine decodes {num_slots} lanes; build the "
                f"DynamicMoELayer with num_tokens={num_slots}")
        self.model = _with_moe_hook(model, moe_layer)
        self.params = params
        self.prefill_chunk = prefill_chunk
        self.cache_dtype = cache_dtype or self.model.ctx.act_dtype
        # one traced derivation per MoE layer executes every decode tick
        self._derives_per_tick = (
            model.cfg.num_layers if moe_layer is not None else 0)

        self.cache = self.model.init_cache(
            num_slots, cache_len, per_slot=True, dtype=self.cache_dtype)
        self.cache_len = int(self.cache["layers"]["k"].shape[2])
        self.slots = SlotManager(num_slots)
        self.queue = RequestQueue()
        self._tokens = jnp.zeros((num_slots, 1), jnp.int32)

        self._decode_fn = jax.jit(self.model.decode_step)
        self._prefill_fn = jax.jit(self.model.prefill)
        self._insert_fn = jax.jit(_insert)

        self.now = 0.0            # tick clock (admission compares against it)
        self.ticks = 0
        self._outputs: dict[Any, list[int]] = {}
        self._completed: list[Any] = []
        self._slot_of: dict[Any, int] = {}
        self._tick_seconds: list[float] = []
        self._token_seconds: list[float] = []
        self._ttft: dict[Any, float] = {}
        self._snap0 = telemetry.stats.snapshot()

    # ---- request intake ----
    def submit(self, request: Request) -> None:
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        plen = len(np.asarray(request.prompt).reshape(-1))
        if plen < 1 or plen > self.cache_len:
            raise ValueError(
                f"prompt length {plen} must be in [1, {self.cache_len}] "
                "(the decode cache ring)")
        self.queue.submit(request)

    # ---- one tick ----
    def step(self) -> int:
        """Admit → decode → bookkeep.  Returns the number of lanes still
        active after the tick."""
        while self.slots.num_free and len(self.queue):
            req = self.queue.pop_ready(self.now)
            if req is None:
                break
            self._admit(req)

        active = self.slots.active()
        if active:
            t0 = time.perf_counter()
            logits, self.cache = self._decode_fn(
                self.params, self.cache, self._tokens)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            self._tokens = nxt[:, None]
            nxt_host = np.asarray(nxt)          # blocks: tick boundary
            dt = time.perf_counter() - t0
            telemetry.record_tick("decode_steps")
            for _ in range(self._derives_per_tick):
                telemetry.record("device-derive")
            self._tick_seconds.append(dt)
            for s in active:
                self._token_seconds.append(dt)
                self._emit(s, int(nxt_host[s.index]))

        self.now += 1.0
        self.ticks += 1
        return len(self.slots.active())

    def _admit(self, req: Request) -> None:
        t0 = time.perf_counter()
        prompt = np.asarray(req.prompt, np.int32).reshape(1, -1)
        plen = prompt.shape[1]
        prefix = self.model.init_cache(
            1, self.cache_len, per_slot=True, dtype=self.cache_dtype)
        chunk = self.prefill_chunk or plen
        logits = None
        for lo in range(0, plen, chunk):
            piece = jnp.asarray(prompt[:, lo:lo + chunk])
            logits, prefix = self._prefill_fn(self.params, prefix, piece)
            telemetry.record_tick("prefill_chunks")
        first = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
        slot = self.slots.allocate(req.id, max_new_tokens=req.max_new_tokens,
                                   eos_id=req.eos_id)
        self.cache, self._tokens = self._insert_fn(
            self.cache, prefix, jnp.asarray(slot, jnp.int32), first,
            self._tokens)
        self._outputs[req.id] = []
        self._slot_of[req.id] = slot
        self._ttft[req.id] = time.perf_counter() - t0
        # the prefill's last-position logits yield generated token #1
        self._emit(self.slots[slot], int(first))

    def _emit(self, s, tok: int) -> None:
        rid = s.request_id
        self._outputs[rid].append(tok)
        s.generated += 1
        if s.generated >= s.max_new_tokens or (
                s.eos_id is not None and tok == s.eos_id):
            self._completed.append(rid)
            self.slots.release(s.index)

    # ---- drive to completion ----
    def run(self, *, max_ticks: int = 100_000) -> ServeReport:
        """Tick until the queue drains and every lane completes."""
        while len(self.queue) or self.slots.active():
            if not self.slots.active():
                nxt = self.queue.next_arrival()
                if nxt is not None and nxt > self.now:
                    self.now = float(nxt)       # idle: jump to next arrival
            self.step()
            if self.ticks >= max_ticks:
                raise RuntimeError(f"serve loop exceeded {max_ticks} ticks")
        return self.report()

    def report(self) -> ServeReport:
        return ServeReport(
            outputs={k: list(v) for k, v in self._outputs.items()},
            completed=list(self._completed),
            slot_of=dict(self._slot_of),
            ticks=self.ticks,
            tick_seconds=list(self._tick_seconds),
            token_seconds=list(self._token_seconds),
            ttft_seconds=dict(self._ttft),
            telemetry=telemetry.stats.since(self._snap0),
        )

    # ---- steady-state invariant ----
    def snapshot(self) -> dict:
        """Telemetry snapshot for a later ``assert_steady_state``."""
        return telemetry.stats.snapshot()

    def assert_steady_state(self, snap: dict) -> dict:
        """Assert ZERO host plan builds happened across the decode ticks
        since ``snap`` (the §5 T_plan tax must not recur once warm).
        Returns the telemetry delta."""
        delta = telemetry.stats.since(snap)
        if not telemetry.stats.decode_host_free(snap):
            raise AssertionError(
                f"host plan builds during steady-state decode: {delta}")
        return delta


def generate_batch_loop(model: Model, params, requests, *, cache_len: int,
                        prefill_chunk: int | None = None, moe_layer=None,
                        cache_dtype=None) -> dict[Any, list[int]]:
    """The naive batch-loop baseline the engine must match token-for-token.

    Every request gets a dedicated lane up front (batch = len(requests):
    no queue, no admission, no slot reuse), prompts prefill per-request
    into their lanes through the same fused path, then one decode step per
    tick until the longest request finishes — the pre-serve ``launch.serve``
    demo loop.  Tokens stop accumulating per request at its
    ``max_new_tokens`` / ``eos_id``, so outputs compare directly against
    ``ServeReport.outputs``.
    """
    model = _with_moe_hook(model, moe_layer)
    dtype = cache_dtype or model.ctx.act_dtype
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    insert = jax.jit(_insert)

    b = len(requests)
    cache = model.init_cache(b, cache_len, per_slot=True, dtype=dtype)
    clen = int(cache["layers"]["k"].shape[2])
    tokens = jnp.zeros((b, 1), jnp.int32)
    outs: dict[Any, list[int]] = {}

    for i, r in enumerate(requests):
        prompt = np.asarray(r.prompt, np.int32).reshape(1, -1)
        prefix = model.init_cache(1, clen, per_slot=True, dtype=dtype)
        chunk = prefill_chunk or prompt.shape[1]
        logits = None
        for lo in range(0, prompt.shape[1], chunk):
            piece = jnp.asarray(prompt[:, lo:lo + chunk])
            logits, prefix = prefill(params, prefix, piece)
        first = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
        cache, tokens = insert(cache, prefix, jnp.asarray(i, jnp.int32),
                               first, tokens)
        outs[r.id] = [int(first)]

    def done(r):
        o = outs[r.id]
        return len(o) >= r.max_new_tokens or (
            r.eos_id is not None and o and o[-1] == r.eos_id)

    while not all(done(r) for r in requests):
        logits, cache = decode(params, cache, tokens)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        tokens = nxt[:, None]
        nh = np.asarray(nxt)
        for i, r in enumerate(requests):
            if not done(r):
                outs[r.id].append(int(nh[i]))
    return outs
