"""Request queue with arrival-time admission (FIFO).

Pure host-side bookkeeping — no jax.  Requests become *ready* once the
engine's clock passes their ``arrival_time``; among ready requests,
admission is strictly first-come-first-served (arrival time, then
submission order), so a late-arriving short prompt can never starve an
earlier long one.  The clock unit is the caller's: ``ServeEngine`` counts
decode ticks (deterministic for tests), a real gateway would pass wall
seconds — the queue only ever compares ``arrival_time <= now``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any

__all__ = ["Request", "RequestQueue"]


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int token sequence (list / tuple / ndarray);
    ``max_new_tokens`` counts every generated token, including the one the
    prefill's last-position logits yield; ``eos_id`` stops generation
    early when the greedy token hits it.
    """

    id: Any
    prompt: Any
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_id: int | None = None


class RequestQueue:
    """FIFO admission gated on arrival time.

    >>> q = RequestQueue()
    >>> q.submit(Request(id="late", prompt=[1], max_new_tokens=4,
    ...                  arrival_time=2.0))
    >>> q.submit(Request(id="early", prompt=[2], max_new_tokens=4))
    >>> [r.id for r in q.ready(now=0.0)]     # peek: only arrived requests
    ['early']
    >>> q.pop_ready(now=0.0).id
    'early'
    >>> q.pop_ready(now=0.0) is None         # "late" hasn't arrived yet
    True
    >>> q.next_arrival()                     # when to wake an idle engine
    2.0
    >>> q.pop_ready(now=5.0).id
    'late'
    >>> len(q)
    0
    """

    def __init__(self):
        self._heap: list[tuple[float, int, Request]] = []
        self._seq = 0

    def submit(self, request: Request) -> None:
        heapq.heappush(self._heap,
                       (float(request.arrival_time), self._seq, request))
        self._seq += 1

    def pop_ready(self, now: float) -> Request | None:
        """The earliest-arrived ready request, or None if none has
        arrived by ``now``."""
        if self._heap and self._heap[0][0] <= now:
            return heapq.heappop(self._heap)[2]
        return None

    def ready(self, now: float) -> list[Request]:
        """Arrived-but-unadmitted requests in admission order (peek)."""
        return [r for (t, _, r) in sorted(self._heap) if t <= now]

    def next_arrival(self) -> float | None:
        """Earliest pending arrival time (None when empty) — lets an idle
        engine jump its clock instead of spinning empty ticks."""
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
