"""Slot-based KV-cache bookkeeping.

A *slot* is one lane of the engine's batched decode cache (``per_slot``
caches in ``models.transformer``).  The device side never moves — a
request is admitted by overwriting a free lane's K/V prefix in place and
released by plain host bookkeeping (the lane's ``slot_pos`` rows are reset
lazily at the next insert).  This mirrors MaxText's offline-inference slot
scheme: allocate the lowest free lane, decode all lanes every tick, free a
lane the moment its request completes so the queue can refill it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["Slot", "SlotManager"]


@dataclasses.dataclass
class Slot:
    """Host-side state of one cache lane."""

    index: int
    request_id: Any = None
    generated: int = 0          # tokens emitted so far (prefill token incl.)
    max_new_tokens: int = 0
    eos_id: int | None = None

    @property
    def free(self) -> bool:
        return self.request_id is None


class SlotManager:
    """Fixed pool of cache lanes with allocate / free / reset.

    >>> sm = SlotManager(2)
    >>> sm.allocate("r1", max_new_tokens=4)
    0
    >>> sm.allocate("r2", max_new_tokens=4)
    1
    >>> sm.allocate("r3", max_new_tokens=1) is None   # pool exhausted
    True
    >>> sm.release(0)
    >>> sm.allocate("r3", max_new_tokens=1)           # lowest free lane wins
    0
    >>> [s.request_id for s in sm.active()]
    ['r3', 'r2']
    >>> sm.num_free
    0
    """

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.slots = [Slot(i) for i in range(num_slots)]

    def allocate(self, request_id: Any, *, max_new_tokens: int = 0,
                 eos_id: int | None = None) -> int | None:
        """Claim the lowest free lane for ``request_id``; None if full."""
        for s in self.slots:
            if s.free:
                s.request_id = request_id
                s.generated = 0
                s.max_new_tokens = int(max_new_tokens)
                s.eos_id = eos_id
                return s.index
        return None

    def release(self, index: int) -> None:
        """Free a lane (request finished or evicted)."""
        self.reset(index)
        self.slots[index].request_id = None

    def reset(self, index: int) -> None:
        """Clear per-request counters; keeps the lane's assignment."""
        s = self.slots[index]
        s.generated = 0

    def active(self) -> list[Slot]:
        return [s for s in self.slots if not s.free]

    def free_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.free]

    @property
    def num_free(self) -> int:
        return len(self.free_slots())

    def __getitem__(self, index: int) -> Slot:
        return self.slots[index]

    def __len__(self) -> int:
        return len(self.slots)
