"""Int8 error-feedback gradient compression for data-parallel reductions.

Beyond-paper but squarely in the paper's spirit: reduce the *volume* of the
dominant collective.  Each data-parallel rank quantizes its local gradient to
int8 with a per-tensor scale, all-reduces the int8 payload (4x fewer bytes on
the wire than f32), dequantizes, and keeps the quantization residual locally,
adding it back before the next step's quantization (error feedback makes the
scheme unbiased over time).

Used by the train driver in pure-DP mode (params replicated over dp), where
the gradient all-reduce is explicit and ours to compress; under FSDP the
reduction is fused into backward by XLA and is not interceptable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum"]


def quantize_int8(x):
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, residual, axis_name):
    """Error-feedback int8 psum of one tensor over ``axis_name``.

    Returns (reduced_f32_mean, new_residual).
    """
    gf = g.astype(jnp.float32) + residual
    # shared scale (pmax, one scalar on the wire) so the int8 payloads are
    # summable: sum_i q_i * s == s * sum_i q_i exactly
    local_scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    scale = jax.lax.pmax(local_scale, axis_name)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    # int8 summed in int32 to avoid overflow; wire cost is the 1B payload
    # (ICI supports int8 reductions; the perf model charges 1 B/elem)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    from repro import compat
    n = compat.axis_size(axis_name)
    return summed.astype(jnp.float32) * scale / n, new_residual
