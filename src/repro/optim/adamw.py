"""AdamW + schedules + global-norm clipping, from scratch (no optax here).

Pure-functional: ``init`` builds the state pytree (safe under eval_shape),
``apply`` returns updated (params, state).  Learning-rate schedules are plain
callables step->lr evaluated inside jit (lax-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "cosine_schedule", "linear_warmup", "global_norm",
           "clip_by_global_norm"]


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


def linear_warmup(base_lr: float, warmup_steps: int):
    def lr(step):
        return base_lr * jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
    return lr


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1):
    def lr(step):
        warm = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        t = jnp.clip((step - warmup_steps) /
                     max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * warm * cos
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    """AdamW with optional true mixed precision.

    ``mixed_precision=True``: the params passed through the train step are
    the bf16 COMPUTE copy (so every FSDP weight all-gather moves 2-byte
    payloads); the f32 master weights live inside the optimizer state and
    are the ones actually updated — the bf16 params are re-derived from the
    master each step (Megatron-style)."""

    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    mixed_precision: bool = False

    def init(self, params) -> dict[str, Any]:
        zeros = lambda t: jax.tree.map(  # noqa: E731
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        state = {"m": zeros(params), "v": zeros(params),
                 "step": jnp.zeros((), jnp.int32)}
        if self.mixed_precision:
            state["master"] = jax.tree.map(
                lambda x: x.astype(jnp.float32), params)
        return state

    def cast_params(self, params, dtype=jnp.bfloat16):
        """f32 master tree -> compute tree (used at init/restore time)."""
        return jax.tree.map(
            lambda x: x.astype(dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

    def apply(self, params, grads, state):
        step = state["step"] + 1
        if self.clip_norm:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v, master):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + self.eps)
            ref = master if master is not None else p.astype(jnp.float32)
            if self.weight_decay:
                u = u + self.weight_decay * ref
            new_master = ref - lr * u
            return new_master.astype(p.dtype), m2, v2, new_master

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_ma = (tdef.flatten_up_to(state["master"])
                   if self.mixed_precision else [None] * len(flat_p))
        out = [upd(p, g, m, v, ma) for p, g, m, v, ma
               in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        new_state = {"m": new_m, "v": new_v, "step": step}
        if self.mixed_precision:
            new_state["master"] = tdef.unflatten([o[3] for o in out])
        return new_p, new_state, gnorm
