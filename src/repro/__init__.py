"""repro — fine-grained irregular communication, optimized and modeled.

JAX/TPU reproduction of Lagraviere et al., "Performance optimization and
modeling of fine-grained irregular communication in UPC" (2019), scaled to
a multi-pod training/serving framework.  See README.md and DESIGN.md.
"""

__version__ = "1.0.0"
