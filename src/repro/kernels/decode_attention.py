"""Pallas TPU kernel for single-token (decode) GQA attention.

Flash-decoding schedule: the sequential TPU grid walks KV-cache chunks for
one query token, carrying running (max, sum, accumulator) in VMEM scratch —
the KV cache streams HBM→VMEM exactly once, and the softmax never
materializes (the decode-step hot-spot: decode_32k cells are KV-read-bound,
see EXPERIMENTS.md §Roofline).

Grid: (B, n_kv_chunks); the chunk axis is innermost (sequential on TPU), so
scratch persists across chunks of the same batch element.  Validity of cache
slots is passed as a per-batch length (scalar prefetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention"]


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, kv_chunk: int, nchunks: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (Hkv, G, D)
    k = k_ref[0].astype(jnp.float32)          # (C, Hkv, D)
    v = v_ref[0].astype(jnp.float32)          # (C, Hkv, D)

    logits = jnp.einsum("hgd,chd->hgc", q, k) * scale    # (Hkv, G, C)
    pos = j * kv_chunk + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 2)
    valid = pos < len_ref[b]
    logits = jnp.where(valid, logits, -1e30)

    m_prev = m_scr[...]                        # (Hkv, G)
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    p = jnp.exp(logits - m_new[..., None])     # (Hkv, G, C)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[..., None] + jnp.einsum(
        "hgc,chd->hgd", p, v)
    m_scr[...] = m_new

    @pl.when(j == nchunks - 1)
    def _finish():
        norm = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / norm).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,        # (B, H, D) single query token
    k: jax.Array,        # (B, S, Hkv, D) KV cache
    v: jax.Array,        # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) int32 valid cache length per batch elem
    *,
    kv_chunk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    kv_chunk = min(kv_chunk, s)
    assert s % kv_chunk == 0
    nchunks = s // kv_chunk
    qg = q.reshape(b, hkv, g, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nchunks),
        in_specs=[
            pl.BlockSpec((1, hkv, g, d), lambda i, j, L: (i, 0, 0, 0)),
            pl.BlockSpec((1, kv_chunk, hkv, d), lambda i, j, L: (i, j, 0, 0)),
            pl.BlockSpec((1, kv_chunk, hkv, d), lambda i, j, L: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, g, d), lambda i, j, L: (i, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g, d), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, kv_chunk=kv_chunk, nchunks=nchunks,
                             scale=d ** -0.5)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(b, h, d)
