"""Pallas TPU kernel for modified-EllPack SpMV — the paper's compute hot-spot.

TPU adaptation of the paper's insight (DESIGN.md §2): the GPU/CPU version of
this kernel gathers ``x[J[i,j]]`` straight from main memory.  On TPU we apply
the paper's *blockwise* idea one level down the memory hierarchy — at the
HBM→VMEM boundary:

  * rows are processed in blocks of ``rows_per_block``;
  * for each row block, the one-time plan computes the (quantized) column
    *window* that covers every index the block touches (meshes reordered for
    locality make this window small — paper §3.1/§6.1);
  * the window is DMA'd into VMEM as two adjacent BlockSpec tiles selected by
    a scalar-prefetched per-block window index (``win_blk``), so the irregular
    gather happens VMEM-locally on relative indices.

This is exactly "message condensing at VMEM granularity": bulk, planned,
latency-amortizing transfers instead of fine-grained irregular access.

Grid: ``(n_row_blocks,)``.  VMEM per step: window 2·W·4B + row tiles.
The in-VMEM gather (``jnp.take``) lowers to Mosaic dynamic-gather; validated
with ``interpret=True`` on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ellpack_spmv_windowed"]


def _kernel_simple(win_blk_ref, diag_ref, vals_ref, cols_ref, own_rel_ref,
                   x_lo_ref, x_hi_ref, y_ref):
    """Row-block kernel; ``own_rel`` carries the row's own x index relative to
    the window (so the diagonal term is also a window gather)."""
    xw = jnp.concatenate([x_lo_ref[...], x_hi_ref[...]])   # (2W,)
    gathered = jnp.take(xw, cols_ref[...], axis=0)         # (R, r_nz)
    own = jnp.take(xw, own_rel_ref[...], axis=0)           # (R,)
    acc = (vals_ref[...].astype(jnp.float32)
           * gathered.astype(jnp.float32)).sum(axis=1)
    y = diag_ref[...].astype(jnp.float32) * own.astype(jnp.float32) + acc
    y_ref[...] = y.astype(y_ref.dtype)


def ellpack_spmv_windowed(
    diag: jax.Array,       # (n,)
    vals: jax.Array,       # (n, r_nz)
    cols_rel: jax.Array,   # (n, r_nz) int32, relative to win_blk*window
    own_rel: jax.Array,    # (n,)      int32, row's own x idx relative to window
    win_blk: jax.Array,    # (n_blocks,) int32 scalar-prefetch window indices
    x: jax.Array,          # (>= (max(win_blk)+2)*window,) padded vector
    *,
    window: int,
    rows_per_block: int,
    interpret: bool = True,
) -> jax.Array:
    """y of shape (n,).  All blocking/padding is prepared by kernels.ops."""
    n, r_nz = vals.shape
    assert n % rows_per_block == 0
    n_blocks = n // rows_per_block
    assert x.shape[0] % window == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((rows_per_block,), lambda i, w: (i,)),
            pl.BlockSpec((rows_per_block, r_nz), lambda i, w: (i, 0)),
            pl.BlockSpec((rows_per_block, r_nz), lambda i, w: (i, 0)),
            pl.BlockSpec((rows_per_block,), lambda i, w: (i,)),
            pl.BlockSpec((window,), lambda i, w: (w[i],)),
            pl.BlockSpec((window,), lambda i, w: (w[i] + 1,)),
        ],
        out_specs=pl.BlockSpec((rows_per_block,), lambda i, w: (i,)),
    )
    return pl.pallas_call(
        _kernel_simple,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n,), diag.dtype),
        interpret=interpret,
    )(win_blk, diag, vals, cols_rel, own_rel, x, x)
