"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth; kernels are validated against
these in interpret mode across shape/dtype sweeps (tests/test_kernels_*.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ellpack_spmv_ref", "pack_gather_ref", "unpack_dest_ref",
           "unpack_scatter_set_ref", "accumulate_segments_ref",
           "accumulate_into_ref", "stencil2d_ref",
           "decode_attention_ref", "selective_scan_ref"]


def _reduce_identity(dtype, reduce):
    if reduce == "max":
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.array(-jnp.inf, dtype)
        return jnp.array(jnp.iinfo(dtype).min, dtype)
    return jnp.array(0, dtype)


def _combine(acc, idx, vals, reduce):
    if reduce == "max":
        return acc.at[idx].max(vals)
    return acc.at[idx].add(vals)


def ellpack_spmv_ref(diag, vals, cols, x):
    """y = diag*x[:n] + sum_j vals[:, j] * x[cols[:, j]] (paper Listing 1).

    ``x`` may be longer than n (private-copy dump slots); rows use global
    indices, diag pairs with x[0:n].
    """
    n = diag.shape[0]
    return diag * x[:n] + (vals * x[cols]).sum(axis=-1)


def pack_gather_ref(x, idx):
    """Message packing (paper Listing 5 pack loop): out[k] = x[idx[k]]."""
    return x[idx]


def unpack_dest_ref(recv_flat, x_local, src_idx, own_idx, own_mask,
                    rem_mask):
    """Destination-targeted unpack (strategies.dest_gather_local): each of
    the L consumer slots reads the landed recv buffer (foreign), the owned
    shard, or 0.0 (both masks zero)."""
    nf = x_local.ndim - 1
    dtype = x_local.dtype

    def bmask(m):
        return m.reshape(m.shape + (1,) * nf).astype(dtype)

    return (recv_flat[src_idx] * bmask(rem_mask)
            + x_local[own_idx] * bmask(own_mask))


def unpack_scatter_set_ref(recv, idx, x_own, offset, *, out_len,
                           copy_own=True):
    """Full-materialization unpack: zeros((out_len,)+rest), scatter-set the
    landed messages, then memcpy the owned rows in at ``offset``."""
    rest = x_own.shape[1:]
    x_copy = jnp.zeros((out_len,) + rest, x_own.dtype)
    x_copy = x_copy.at[idx].set(recv)
    if copy_own:
        x_copy = jax.lax.dynamic_update_slice(
            x_copy, x_own, (offset,) + (0,) * len(rest))
    return x_copy


def accumulate_segments_ref(vals, idx, *, out_len, reduce="add"):
    """acc = full((out_len,)+rest, identity); combine vals at idx (the put
    direction's segment-combine under add/set/max semantics; ``set`` is
    add-after-winner-masking, exactly like the strategy path)."""
    rest = vals.shape[1:]
    acc = jnp.full((out_len,) + rest,
                   _reduce_identity(vals.dtype, reduce), vals.dtype)
    return _combine(acc, idx, vals, reduce)


def accumulate_into_ref(init, vals, idx, *, reduce="add"):
    """Combine vals into an existing accumulator (landed-foreign half of the
    push-side split)."""
    return _combine(init, idx, vals, reduce)


def stencil2d_ref(x, coef):
    """One 5-point Jacobi step on the interior; boundary rows/cols copied.

    x: (M, N).  y[i,j] = x[i,j] + coef*(x[i-1,j]+x[i+1,j]+x[i,j-1]+x[i,j+1]
    - 4 x[i,j]) for 1<=i<M-1, 1<=j<N-1  (paper Listing 8).
    """
    up = x[:-2, 1:-1]
    down = x[2:, 1:-1]
    left = x[1:-1, :-2]
    right = x[1:-1, 2:]
    mid = x[1:-1, 1:-1]
    interior = mid + coef * (up + down + left + right - 4.0 * mid)
    return x.at[1:-1, 1:-1].set(interior)


def decode_attention_ref(q, k, v, *, scale=None):
    """Single-token GQA attention: q (B, H, D), k/v (B, S, Hkv, D)
    (the framework's cache layout).

    H must be a multiple of Hkv (grouped queries share a KV head).
    Returns (B, H, D).
    """
    b, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    if scale is None:
        scale = d ** -0.5
    qg = q.reshape(b, hkv, group, d)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def selective_scan_ref(x, dt, bmat, cmat, a):
    """Sequential mamba-1 recurrence oracle: x/dt (B, L, di),
    bmat/cmat (B, L, st), a (di, st) -> y (B, L, di)."""
    bshape, l, di = x.shape[0], x.shape[1], x.shape[2]
    st = bmat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, t):
        da = jnp.exp(dtf[:, t, :, None] * af[None])          # (B, di, st)
        h = da * h + (dtf[:, t] * xf[:, t])[..., None] * bf[:, t, None, :]
        y = jnp.einsum("bds,bs->bd", h, cf[:, t])
        return h, y

    h0 = jnp.zeros((bshape, di, st), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(l))
    return ys.swapaxes(0, 1).astype(x.dtype)
