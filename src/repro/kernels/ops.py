"""Jit'd public wrappers around the Pallas kernels.

Each wrapper owns the blocking/padding/window planning its kernel needs and
falls back to the jnp reference where the kernel's preconditions cannot be
met (e.g. shard too large for whole-VMEM residence).  ``interpret`` defaults
to True off-TPU so the whole framework runs (and is tested) on CPU; on TPU
backends the same call sites compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import pack_gather as _pg
from repro.kernels import ref as kref
from repro.kernels.ellpack_spmv import ellpack_spmv_windowed
from repro.kernels.stencil2d import stencil2d as _stencil2d_kernel

__all__ = [
    "on_tpu", "plan_spmv_windows", "ellpack_spmv", "make_spmv_on_copy_sharded",
    "make_spmv_overlap_sharded", "pack_gather", "unpack_dest",
    "unpack_scatter_set", "accumulate_segments", "accumulate_into",
    "stencil2d", "decode_attention",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default(interpret):
    return (not on_tpu()) if interpret is None else interpret


# --------------------------------------------------------------------------
# EllPack SpMV
# --------------------------------------------------------------------------

def plan_spmv_windows(
    cols: np.ndarray, *, rows_per_block: int = 256, lane: int = 128
):
    """Host-side one-time window planning (DESIGN.md: VMEM-level blockwise).

    Returns (window, win_blk, cols_rel, own_rel); ``window`` is the static
    tile width (multiple of ``lane``) covering every row block's column span.
    """
    n, _ = cols.shape
    assert n % rows_per_block == 0, "pad rows first"
    nblk = n // rows_per_block
    own = np.arange(n, dtype=np.int64)
    # own row index participates in the span (diagonal term gathers x[i])
    lo = np.minimum(
        cols.reshape(nblk, -1).min(axis=1),
        own.reshape(nblk, rows_per_block).min(axis=1),
    )
    hi = np.maximum(
        cols.reshape(nblk, -1).max(axis=1),
        own.reshape(nblk, rows_per_block).max(axis=1),
    )
    span = int((hi - lo + 1).max())
    window = max(lane, int(np.ceil(span / lane)) * lane)
    win_blk = (lo // window).astype(np.int32)           # (nblk,)
    base = (win_blk.astype(np.int64) * window)          # window start
    cols_rel = (
        cols - np.repeat(base, rows_per_block)[:, None]
    ).astype(np.int32)
    own_rel = (own - np.repeat(base, rows_per_block)).astype(np.int32)
    assert cols_rel.min() >= 0 and cols_rel.max() < 2 * window
    return window, win_blk, cols_rel, own_rel


@functools.partial(
    jax.jit, static_argnames=("window", "rows_per_block", "interpret")
)
def _spmv_call(diag, vals, cols_rel, own_rel, win_blk, x_padded, *, window,
               rows_per_block, interpret):
    return ellpack_spmv_windowed(
        diag, vals, cols_rel, own_rel, win_blk, x_padded,
        window=window, rows_per_block=rows_per_block, interpret=interpret,
    )


def ellpack_spmv(
    diag, vals, cols, x, *, rows_per_block: int = 256, interpret=None,
    plan=None,
):
    """y = diag*x + EllPack(vals, cols) @ x via the windowed Pallas kernel.

    ``plan``: optional precomputed ``plan_spmv_windows`` output (amortize the
    one-time prep, exactly like the paper's preparation step).
    """
    interpret = _interpret_default(interpret)
    n, _ = np.shape(vals)
    if plan is None:
        plan = plan_spmv_windows(np.asarray(cols), rows_per_block=rows_per_block)
    window, win_blk, cols_rel, own_rel = plan
    need = (int(win_blk.max()) + 2) * window
    x_padded = jnp.pad(x, (0, max(0, need - x.shape[0])))
    return _spmv_call(
        diag, vals, jnp.asarray(cols_rel), jnp.asarray(own_rel),
        jnp.asarray(win_blk), x_padded,
        window=window, rows_per_block=rows_per_block, interpret=interpret,
    )


def make_spmv_on_copy_sharded(
    cols: np.ndarray, p: int, *, rows_per_block: int = 256, interpret=None
):
    """Per-shard window plans with one common static window, for use inside
    the DistributedSpMV shard_map (each device computes its own rows against
    its private x_copy).

    Returns (local_fn, plan_args) where ``plan_args`` are host arrays shaped
    (P, ...) to be passed through shard_map with in_specs P(axis) and
    ``local_fn(diag_l, vals_l, x_copy, win_blk_l, cols_rel_l, own_rel_l)``.
    """
    interpret = _interpret_default(interpret)
    n, r_nz = cols.shape
    shard = n // p
    rows_per_block = min(rows_per_block, shard)
    # plan per shard, then unify the static window across shards
    plans = [
        plan_spmv_windows(cols[q * shard:(q + 1) * shard],
                          rows_per_block=rows_per_block)
        for q in range(p)
    ]
    window = max(pl[0] for pl in plans)
    nblk = shard // rows_per_block
    win_blk = np.zeros((p, nblk), np.int32)
    cols_rel = np.zeros((p, shard, r_nz), np.int32)
    own_rel = np.zeros((p, shard), np.int32)
    for q in range(p):
        sub = cols[q * shard:(q + 1) * shard]
        own = np.arange(q * shard, (q + 1) * shard, dtype=np.int64)
        lo = np.minimum(
            sub.reshape(nblk, -1).min(axis=1),
            own.reshape(nblk, rows_per_block).min(axis=1),
        )
        wb = (lo // window).astype(np.int32)
        base = np.repeat(wb.astype(np.int64) * window, rows_per_block)
        win_blk[q] = wb
        cols_rel[q] = (sub - base[:, None]).astype(np.int32)
        own_rel[q] = (own - base).astype(np.int32)
        assert cols_rel[q].min() >= 0 and cols_rel[q].max() < 2 * window
    need_global = (int(win_blk.max()) + 2) * window

    def local_fn(diag_l, vals_l, x_copy, win_blk_l, cols_rel_l, own_rel_l):
        ln = x_copy.shape[0]
        if ln < need_global:
            xp = jnp.pad(x_copy, (0, need_global - ln))
        else:
            xp = x_copy[:need_global]
        return _spmv_call(
            diag_l, vals_l, cols_rel_l[0], own_rel_l[0], win_blk_l[0], xp,
            window=window, rows_per_block=rows_per_block, interpret=interpret,
        )

    return local_fn, (win_blk, cols_rel, own_rel)


def make_spmv_overlap_sharded(plan, vals: np.ndarray, *,
                              rows_per_block: int = 256, interpret=None):
    """Split-kernel on-copy variant of the ``overlap`` rung.

    The overlap strategy splits the local SpMV into an own-shard partial
    (reads only ``x_local``, runs while the condensed all_to_all is in
    flight) and a foreign partial (reads the landed ``x_copy``).  This
    builds BOTH partials as windowed Pallas kernels from the plan's
    own/foreign column split:

      * own kernel: columns are the plan's shard-local ``loc_cols`` (padding
        -> the zero slot at ``shard_size``), x is ``x_local`` + 1 pad slot;
      * foreign kernel: columns are ``rem_cols`` with padding redirected to
        an in-window fallback whose value is zeroed out of ``vals`` (the
        jnp path instead relies on x_copy's zero slot at n+1, which would
        blow the kernel's window up to the whole vector), diag = 0.

    Returns ``(own_fn, rem_fn, kargs)``: ``kargs`` are 7 host arrays shaped
    (P, ...) to pass through shard_map with in_specs P(axis);
    ``own_fn(diag_l, x_ext, *kargs[:3])`` and ``rem_fn(x_copy, *kargs[3:])``
    are the two shard-local partials.
    """
    interpret = _interpret_default(interpret)
    p, n, shard = plan.p, plan.n, plan.shard_size
    rows_per_block = min(rows_per_block, shard)
    assert shard % rows_per_block == 0
    nblk_rows = shard // rows_per_block
    lane = 128

    # ---- own half: local indices in [0, shard]; one static window covers
    # the whole extended shard, so win_blk is identically zero ----
    loc_vals = np.take_along_axis(vals, plan.loc_src, axis=1)
    window_own = max(lane, int(np.ceil((shard + 1) / lane)) * lane)
    loc_vals_s = loc_vals.reshape(p, shard, -1)
    loc_cols_s = plan.loc_cols.reshape(p, shard, -1)
    own_win = np.zeros((p, nblk_rows), np.int32)
    own_rel_const = np.arange(shard, dtype=np.int32)

    # ---- foreign half: global indices; padding (n + 1) must not join the
    # window span, so redirect padded slots to the block's lowest valid
    # column and zero their vals ----
    rem_vals = np.take_along_axis(vals, plan.rem_src, axis=1)
    valid = plan.rem_cols != (n + 1)
    rem_vals = np.where(valid, rem_vals, 0).astype(vals.dtype)
    r_rem = plan.rem_cols.shape[1]
    cols_v = np.where(valid, plan.rem_cols, np.iinfo(np.int32).max)
    cols_blk = cols_v.reshape(p, nblk_rows, rows_per_block * r_rem)
    lo = cols_blk.min(axis=2)
    lo = np.where(lo == np.iinfo(np.int32).max, 0, lo)      # all-pad block
    hi_blk = np.where(valid, plan.rem_cols, 0).reshape(
        p, nblk_rows, rows_per_block * r_rem)
    hi = np.maximum(hi_blk.max(axis=2), lo)
    span = int((hi - lo + 1).max())
    window_rem = max(lane, int(np.ceil(span / lane)) * lane)
    rem_win = (lo // window_rem).astype(np.int32)            # (P, nblk)
    base = np.repeat(rem_win.astype(np.int64) * window_rem,
                     rows_per_block, axis=1)                 # (P, shard)
    lo_rows = np.repeat(lo.astype(np.int64), rows_per_block, axis=1)
    rem_cols_rel = (
        np.where(valid.reshape(p, shard, r_rem),
                 plan.rem_cols.reshape(p, shard, r_rem),
                 lo_rows[:, :, None]) - base[:, :, None]
    ).astype(np.int32)
    rem_own_rel = (lo_rows - base).astype(np.int32)          # diag=0: any
    assert rem_cols_rel.min() >= 0 and rem_cols_rel.max() < 2 * window_rem
    need_rem = (int(rem_win.max()) + 2) * window_rem

    def own_fn(diag_l, x_ext, loc_vals_l, loc_cols_l, own_win_l):
        xp = jnp.pad(x_ext, (0, 2 * window_own - x_ext.shape[0]))
        return _spmv_call(
            diag_l, loc_vals_l[0], loc_cols_l[0],
            jnp.asarray(own_rel_const), own_win_l[0], xp,
            window=window_own, rows_per_block=rows_per_block,
            interpret=interpret,
        )

    def rem_fn(x_copy, rem_vals_l, rem_cols_l, rem_own_l, rem_win_l):
        ln = x_copy.shape[0]
        if ln < need_rem:
            xp = jnp.pad(x_copy, (0, need_rem - ln))
        else:
            xp = x_copy[:need_rem]
        zero_diag = jnp.zeros((shard,), x_copy.dtype)
        return _spmv_call(
            zero_diag, rem_vals_l[0], rem_cols_l[0], rem_own_l[0],
            rem_win_l[0], xp,
            window=window_rem, rows_per_block=rows_per_block,
            interpret=interpret,
        )

    kargs = (loc_vals_s, loc_cols_s, own_win,
             rem_vals.reshape(p, shard, r_rem), rem_cols_rel,
             rem_own_rel.reshape(p, shard), rem_win)
    return own_fn, rem_fn, kargs


# --------------------------------------------------------------------------
# Exchange fast path: pack / unpack / segment-accumulate
# --------------------------------------------------------------------------

_VMEM_SHARD_LIMIT = 8 * 1024 * 1024  # bytes; half of v5e VMEM


def _fits_vmem(*arrays) -> bool:
    return all(a.size * a.dtype.itemsize <= _VMEM_SHARD_LIMIT
               for a in arrays)


def pack_gather(x, idx, *, block: int | None = None, interpret=None):
    """out[k] = x[idx[k]] with the shard VMEM-resident; ref fallback if the
    shard exceeds the VMEM budget.  Handles trailing feature dims and any
    message count (padding is internal to the kernel)."""
    interpret = _interpret_default(interpret)
    if not _fits_vmem(x):
        return kref.pack_gather_ref(x, idx)
    return _pg.pack_gather(x, idx, block=block, interpret=interpret)


def unpack_dest(recv_flat, x_local, src_idx, own_idx, own_mask, rem_mask,
                *, block: int | None = None, interpret=None):
    """Fused Destination-targeted unpack: recv buffer + owned shard straight
    into the L consumer slots (see kernels/pack_gather.py)."""
    interpret = _interpret_default(interpret)
    if not _fits_vmem(recv_flat, x_local):
        return kref.unpack_dest_ref(recv_flat, x_local, src_idx, own_idx,
                                    own_mask, rem_mask)
    return _pg.unpack_dest(recv_flat, x_local, src_idx, own_idx, own_mask,
                           rem_mask, block=block, interpret=interpret)


def unpack_scatter_set(recv, idx, x_own, offset, *, out_len: int,
                       copy_own: bool = True, interpret=None):
    """Fused full-materialization unpack (eq.-15 scatter + eq.-14 own
    memcpy); ref fallback when the assembled copy exceeds the VMEM budget."""
    interpret = _interpret_default(interpret)
    rest_elems = int(np.prod(x_own.shape[1:], dtype=np.int64)) or 1
    out_bytes = out_len * rest_elems * x_own.dtype.itemsize
    if out_bytes > _VMEM_SHARD_LIMIT or not _fits_vmem(recv, x_own):
        return kref.unpack_scatter_set_ref(recv, idx, x_own, offset,
                                           out_len=out_len,
                                           copy_own=copy_own)
    return _pg.unpack_scatter_set(recv, idx, x_own, offset, out_len=out_len,
                                  copy_own=copy_own, interpret=interpret)


def accumulate_segments(vals, idx, *, out_len: int, reduce: str = "add",
                        interpret=None):
    """Segment-combine from the reduce identity (put-direction pack and
    own-target accumulate); ref fallback past the VMEM budget."""
    interpret = _interpret_default(interpret)
    rest_elems = int(np.prod(vals.shape[1:], dtype=np.int64)) or 1
    out_bytes = out_len * rest_elems * vals.dtype.itemsize
    if out_bytes > _VMEM_SHARD_LIMIT or not _fits_vmem(vals):
        return kref.accumulate_segments_ref(vals, idx, out_len=out_len,
                                            reduce=reduce)
    return _pg.accumulate_segments(vals, idx, out_len=out_len, reduce=reduce,
                                   interpret=interpret)


def accumulate_into(init, vals, idx, *, reduce: str = "add", interpret=None):
    """Combine landed contributions into a prior accumulator (the second
    half of the push-side split); ref fallback past the VMEM budget."""
    interpret = _interpret_default(interpret)
    if not _fits_vmem(init, vals):
        return kref.accumulate_into_ref(init, vals, idx, reduce=reduce)
    return _pg.accumulate_into(init, vals, idx, reduce=reduce,
                               interpret=interpret)


# --------------------------------------------------------------------------
# 2D stencil
# --------------------------------------------------------------------------

def stencil2d(x, *, coef: float, tile_rows: int = 8, interpret=None):
    """One Jacobi step; pads rows to a tile multiple and slices back."""
    interpret = _interpret_default(interpret)
    m, n = x.shape
    mp = int(np.ceil(m / tile_rows)) * tile_rows
    if mp != m:
        x_p = jnp.pad(x, ((0, mp - m), (0, 0)), mode="edge")
    else:
        x_p = x
    # padded rows replicate the last row; masking keys on the *unpadded*
    # boundary, so run the kernel with total_rows = m semantics by slicing.
    out = _stencil2d_kernel(x_p, coef=coef, tile_rows=tile_rows,
                            interpret=interpret)
    if mp != m:
        # rows >= m are padding; recompute the last true row as boundary copy
        out = out[:m, :]
        out = out.at[m - 1, :].set(x[m - 1, :])
    return out


# --------------------------------------------------------------------------
# Decode attention (flash-decoding)
# --------------------------------------------------------------------------

def decode_attention(q, k, v, lengths, *, kv_chunk: int = 512,
                     interpret=None):
    """Single-token GQA attention over a KV cache; see
    kernels/decode_attention.py."""
    from repro.kernels.decode_attention import decode_attention as _da
    interpret = _interpret_default(interpret)
    return _da(q, k, v, lengths, kv_chunk=kv_chunk, interpret=interpret)


# --------------------------------------------------------------------------
# Fused selective scan (mamba-1 recurrence)
# --------------------------------------------------------------------------

def selective_scan(x, dt, bmat, cmat, a, *, tile_di: int = 128,
                   chunk_l: int = 256, interpret=None):
    """HBM-minimal SSM recurrence; see kernels/selective_scan.py."""
    from repro.kernels.selective_scan import selective_scan as _ss
    interpret = _interpret_default(interpret)
    return _ss(x, dt, bmat, cmat, a, tile_di=tile_di, chunk_l=chunk_l,
               interpret=interpret)
