"""Pallas kernels for the exchange fast path (paper Listing 5, both loops,
both directions).

The paper's whole point is that once messages are condensed, what remains
of the communication cost is the local pack/unpack around one exchange.
These kernels make that remainder touch HBM once per element:

* ``pack_gather``        — ``out[k] = x[idx[k]]``: extract the condensed
  message values from the owned shard into a contiguous send buffer.  The
  shard lives whole in VMEM (shards on the comm axis are small: n/P
  elements); the irregular gather is VMEM-local, which is the entire point
  of the pack/unpack design — irregularity never touches the slow memory
  level.  Handles trailing feature dims and pads the message count to a
  block multiple internally.
* ``unpack_dest``        — the Destination-targeted unpack: deliver the
  landed recv buffer straight into the consumer's named slots, fusing the
  foreign gather, the owned gather and the mask combine of
  ``strategies.dest_gather_local`` into one pass over the L slots.
* ``unpack_scatter_set`` — the full-materialization unpack: scatter the
  landed messages into a fresh x_copy and (optionally) memcpy the owned
  shard in, in one kernel — the gather direction's eq.-14/15 fused.
* ``accumulate_segments`` / ``accumulate_into`` — the put direction's
  segment-combine: fold contributions into an accumulator under
  ``reduce="add"|"set"|"max"`` semantics.  ``accumulate_segments`` starts
  from the reduce identity (the pack-side message combine and the
  own-target accumulate); ``accumulate_into`` continues from a prior
  accumulator (the landed-foreign combine of the push-side split — the
  own-accumulate kernel runs while the all_to_all is in flight, then this
  kernel folds the landed messages into its result).

Bit-identity contract: every kernel body executes the *same jnp op
sequence* as the pure-jnp strategy path (``repro.comm.strategies``), and
the accumulate kernels run on a single-program grid so the scatter-combine
order is identical too.  In interpret mode (the default off-TPU) the body
lowers to the very same XLA ops — kernel and jnp rungs agree bit for bit,
which the blocking test tier asserts across rungs × reduces × dtypes.

Gather-style kernels (``pack_gather``, ``unpack_dest``) are
order-independent, so they block over the message/slot axis; the
accumulate kernels keep ``grid=(1,)`` semantics (whole-array blocks) so
duplicate-index combines stay deterministic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "pack_gather", "unpack_dest", "unpack_scatter_set",
    "accumulate_segments", "accumulate_into", "reduce_identity",
]


def _interpret_default(interpret):
    # interpret only off-TPU: on a TPU backend the same call sites compile
    # to Mosaic; everywhere else the kernels run (and are tested) via the
    # interpreter, which lowers the body to plain XLA ops
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def reduce_identity(dtype, reduce: str):
    """The reduce identity padded lanes carry (mirrors
    ``strategies._reduce_identity`` — duplicated so the kernel layer never
    imports comm machinery)."""
    if reduce == "max":
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.array(-jnp.inf, dtype)
        return jnp.array(jnp.iinfo(dtype).min, dtype)
    return jnp.array(0, dtype)


def _combine(acc: jax.Array, idx: jax.Array, vals: jax.Array,
             reduce: str) -> jax.Array:
    if reduce == "max":
        return acc.at[idx].max(vals)
    return acc.at[idx].add(vals)


# --------------------------------------------------------------------------
# Pack (paper Listing 5 pack loop)
# --------------------------------------------------------------------------

def _pack_kernel(x_ref, idx_ref, out_ref):
    out_ref[...] = jnp.take(x_ref[...], idx_ref[...], axis=0)


def pack_gather(
    x: jax.Array,          # (shard, feat...) owned values, VMEM-resident
    idx: jax.Array,        # (m,) int32 local indices
    *,
    block: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """out[k] = x[idx[k]], blocked over the message axis.

    ``m`` need not divide ``block``: the index buffer is padded internally
    (padding gathers row 0, whose values are sliced off) and the result is
    sliced back to ``m`` — callers never crash on odd message counts.
    ``block=None`` picks 1024 compiled and the whole axis in interpret
    mode (a grid buys nothing off-TPU: each extra step is just another
    round of XLA slice ops).
    """
    interpret = _interpret_default(interpret)
    m = idx.shape[0]
    feat = x.shape[1:]
    nf = len(feat)
    if m == 0:
        return jnp.zeros((0,) + feat, x.dtype)
    if block is None:
        block = m if interpret else 1024
    block = min(block, m)
    padded = -(-m // block) * block
    idx_p = jnp.pad(idx, (0, padded - m)) if padded != m else idx
    out = pl.pallas_call(
        _pack_kernel,
        grid=(padded // block,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0,) * (1 + nf)),  # whole shard
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,) + feat,
                               lambda i: (i,) + (0,) * nf),
        out_shape=jax.ShapeDtypeStruct((padded,) + feat, x.dtype),
        interpret=interpret,
    )(x, idx_p)
    return out[:m] if padded != m else out


# --------------------------------------------------------------------------
# Destination-targeted unpack (fused strategies.dest_gather_local)
# --------------------------------------------------------------------------

def _dest_kernel(recv_ref, x_ref, src_ref, own_ref, own_m_ref, rem_m_ref,
                 out_ref):
    nf = len(x_ref.shape) - 1
    dtype = x_ref.dtype
    mshape = src_ref.shape + (1,) * nf
    rem = jnp.take(recv_ref[...], src_ref[...], axis=0)
    own = jnp.take(x_ref[...], own_ref[...], axis=0)
    out_ref[...] = (rem * rem_m_ref[...].reshape(mshape).astype(dtype)
                    + own * own_m_ref[...].reshape(mshape).astype(dtype))


def unpack_dest(
    recv_flat: jax.Array,   # (R, feat...) flattened landed recv buffer
    x_local: jax.Array,     # (shard, feat...)
    src_idx: jax.Array,     # (L,) recv_flat position of each foreign slot
    own_idx: jax.Array,     # (L,) x_local position of each owned slot
    own_mask: jax.Array,    # (L,) int8: 1 where the slot is owned
    rem_mask: jax.Array,    # (L,) int8: 1 where the slot is foreign
    *,
    block: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Deliver landed values straight into the L named consumer slots.

    One fused pass: each slot reads either the recv buffer (foreign), the
    owned shard, or exactly 0.0 (both masks 0) — the full-length x_copy is
    never built.  Recv buffer and shard are whole in VMEM; the slot axis
    blocks (slots are written once each, so blocking is order-safe);
    ``block=None`` picks 1024 compiled and the whole axis in interpret
    mode, like ``pack_gather``.
    """
    interpret = _interpret_default(interpret)
    L = src_idx.shape[0]
    feat = x_local.shape[1:]
    nf = len(feat)
    if L == 0:
        return jnp.zeros((0,) + feat, x_local.dtype)
    if block is None:
        block = L if interpret else 1024
    block = min(block, L)
    padded = -(-L // block) * block
    if padded != L:
        pad = (0, padded - L)
        src_idx = jnp.pad(src_idx, pad)
        own_idx = jnp.pad(own_idx, pad)
        own_mask = jnp.pad(own_mask, pad)     # pad slots read exactly 0.0
        rem_mask = jnp.pad(rem_mask, pad)
    out = pl.pallas_call(
        _dest_kernel,
        grid=(padded // block,),
        in_specs=[
            pl.BlockSpec(recv_flat.shape, lambda i: (0,) * (1 + nf)),
            pl.BlockSpec(x_local.shape, lambda i: (0,) * (1 + nf)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,) + feat,
                               lambda i: (i,) + (0,) * nf),
        out_shape=jax.ShapeDtypeStruct((padded,) + feat, x_local.dtype),
        interpret=interpret,
    )(recv_flat, x_local, src_idx, own_idx, own_mask, rem_mask)
    return out[:L] if padded != L else out


# --------------------------------------------------------------------------
# Full-materialization unpack (fused eq. 14 own-copy + eq. 15 scatter)
# --------------------------------------------------------------------------

def _unpack_set_kernel(recv_ref, x_ref, idx_ref, off_ref, out_ref, *,
                       copy_own: bool):
    nrest = len(x_ref.shape) - 1
    x_copy = jnp.zeros(out_ref.shape, x_ref.dtype)
    x_copy = x_copy.at[idx_ref[...]].set(recv_ref[...])
    if copy_own:
        x_copy = jax.lax.dynamic_update_slice(
            x_copy, x_ref[...], (off_ref[0],) + (0,) * nrest)
    out_ref[...] = x_copy


def unpack_scatter_set(
    recv: jax.Array,      # (R, rest...) landed messages (flattened pairs)
    idx: jax.Array,       # (R,) destination row of each landed message
    x_own: jax.Array,     # (rows_own, rest...) the owned values to memcpy in
    offset: jax.Array,    # scalar int32: own-copy start row (me * rows_own)
    *,
    out_len: int,
    copy_own: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """x_copy = zeros((out_len,) + rest); x_copy[idx] = recv; then the
    eq.-14 own-shard memcpy at ``offset`` — the condensed/blockwise full
    unpack as ONE kernel (rows are whole virtual blocks for blockwise).

    Single-program grid: the scatter-set and the own memcpy execute in the
    same order as the jnp path, so duplicate dump-row writes and the
    own/recv overlap resolve identically.
    """
    interpret = _interpret_default(interpret)
    rest = x_own.shape[1:]
    off = jnp.asarray(offset, jnp.int32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_unpack_set_kernel, copy_own=copy_own),
        out_shape=jax.ShapeDtypeStruct((out_len,) + rest, x_own.dtype),
        interpret=interpret,
    )(recv, x_own, idx, off)


# --------------------------------------------------------------------------
# Segment accumulate (put direction: pack-combine and accumulate-unpack)
# --------------------------------------------------------------------------

def _segsum_kernel(vals_ref, idx_ref, out_ref, *, reduce: str):
    vals = vals_ref[...]
    acc = jnp.full(out_ref.shape, reduce_identity(vals.dtype, reduce),
                   vals.dtype)
    out_ref[...] = _combine(acc, idx_ref[...], vals, reduce)


def accumulate_segments(
    vals: jax.Array,      # (K, rest...) contributions
    idx: jax.Array,       # (K,) destination row of each contribution
    *,
    out_len: int,
    reduce: str = "add",
    interpret: bool | None = None,
) -> jax.Array:
    """acc = full((out_len,) + rest, identity); combine vals at idx.

    The put direction's segment-combine: the sender-side message pack
    (12ᵀ), the own-target accumulate (the half of 15ᵀ that needs no landed
    data — issue it while the all_to_all flies), and the blockwise block
    combine are all this kernel at different ``out_len``.  ``reduce`` set
    semantics are realized by the caller pre-masking (the plan's winner
    mask), exactly like the jnp path.
    """
    interpret = _interpret_default(interpret)
    rest = vals.shape[1:]
    return pl.pallas_call(
        functools.partial(_segsum_kernel, reduce=reduce),
        out_shape=jax.ShapeDtypeStruct((out_len,) + rest, vals.dtype),
        interpret=interpret,
    )(vals, idx)


def _accinto_kernel(init_ref, vals_ref, idx_ref, out_ref, *, reduce: str):
    out_ref[...] = _combine(init_ref[...], idx_ref[...], vals_ref[...],
                            reduce)


def accumulate_into(
    init: jax.Array,      # (out_len, rest...) prior accumulator
    vals: jax.Array,      # (K, rest...) landed contributions
    idx: jax.Array,       # (K,) destination row of each contribution
    *,
    reduce: str = "add",
    interpret: bool | None = None,
) -> jax.Array:
    """Combine ``vals`` into an existing accumulator (the landed-foreign
    half of the push-side split: takes the own-accumulate kernel's output,
    which the scheduler computed while the collective was in flight)."""
    interpret = _interpret_default(interpret)
    return pl.pallas_call(
        functools.partial(_accinto_kernel, reduce=reduce),
        out_shape=jax.ShapeDtypeStruct(init.shape, init.dtype),
        interpret=interpret,
    )(init, vals, idx)
