"""Pallas TPU kernel for message packing (paper Listing 5, the pack loop).

``out[k] = x[idx[k]]`` — extracting the condensed message values from the
owned shard into a contiguous send buffer.  The shard lives whole in VMEM
(shards on the comm axis are small: n/P elements); the irregular gather is
VMEM-local, which is the entire point of the paper's pack/unpack design —
irregularity never touches the slow memory level.

Grid: (n_msg_blocks,) over the flattened padded message buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pack_gather"]


def _kernel(x_ref, idx_ref, out_ref):
    out_ref[...] = jnp.take(x_ref[...], idx_ref[...], axis=0)


def pack_gather(
    x: jax.Array,          # (shard,) owned values, fully VMEM-resident
    idx: jax.Array,        # (m,) int32 local indices, padded
    *,
    block: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    m = idx.shape[0]
    assert m % block == 0, "pad the message buffer to a block multiple"
    grid = (m // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0,)),          # whole shard
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=interpret,
    )(x, idx)
