"""Pallas kernel layer: exchange fast-path kernels plus the compute
hot-spots the paper's consumers use.

The canonical entry points are the jit-friendly wrappers in
``repro.kernels.ops`` (blocking/padding/VMEM-fallback policy lives there);
they are re-exported here so consumers stop reaching into submodules.
This package never imports ``repro.comm`` — the comm layer depends on it,
not the other way around.
"""
from repro.kernels.ops import (
    accumulate_into,
    accumulate_segments,
    decode_attention,
    ellpack_spmv,
    make_spmv_on_copy_sharded,
    make_spmv_overlap_sharded,
    on_tpu,
    pack_gather,
    plan_spmv_windows,
    selective_scan,
    stencil2d,
    unpack_dest,
    unpack_scatter_set,
)

__all__ = [
    "on_tpu", "plan_spmv_windows", "ellpack_spmv",
    "make_spmv_on_copy_sharded", "make_spmv_overlap_sharded",
    "pack_gather", "unpack_dest", "unpack_scatter_set",
    "accumulate_segments", "accumulate_into",
    "stencil2d", "decode_attention", "selective_scan",
]
