"""Pallas TPU kernel for the fused mamba-1 selective scan.

The pure-JAX chunked scan (models/ssm.py) materializes the (B, C, d_inner,
state) decay/update tensors in HBM every chunk — the dominant memory-roofline
term for SSM architectures at long sequence (EXPERIMENTS.md §Perf cell C).
This kernel keeps the recurrence state in VMEM across the whole sequence:
HBM traffic drops to the inputs (x, dt, B, C) and output y only —
O(L·(d_inner + 2·state)) instead of O(L·d_inner·state).

Grid: (batch, d_inner tiles, seq chunks), seq innermost (sequential on TPU)
so the (tile, state) VMEM scratch carries h across chunks.

Same per-(channel,state) recurrence as the oracle:
    h[t] = exp(dt[t]·A) ⊙ h[t-1] + (dt[t]·x[t]) ⊗ B[t]
    y[t] = h[t] · C[t]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["selective_scan"]


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_scr,
            *, chunk_l: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)               # (tile, st)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)       # (tile,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)     # (tile,)
        bt = b_ref[0, t, :].astype(jnp.float32)       # (st,)
        ct = c_ref[0, t, :].astype(jnp.float32)       # (st,)
        da = jnp.exp(dtt[:, None] * a)                # (tile, st)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y_ref[0, t, :] = (h @ ct).astype(y_ref.dtype)  # (tile,)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk_l, step, h_scr[...])


def selective_scan(
    x: jax.Array,    # (B, L, di)
    dt: jax.Array,   # (B, L, di)  (already softplus'd)
    bmat: jax.Array, # (B, L, st)
    cmat: jax.Array, # (B, L, st)
    a: jax.Array,    # (di, st)    (negative decay rates)
    *,
    tile_di: int = 128,
    chunk_l: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Returns y (B, L, di) = the recurrence output (no gate/skip)."""
    b, l, di = x.shape
    st = bmat.shape[-1]
    tile_di = min(tile_di, di)
    chunk_l = min(chunk_l, l)
    assert di % tile_di == 0 and l % chunk_l == 0
    grid = (b, di // tile_di, l // chunk_l)

    return pl.pallas_call(
        functools.partial(_kernel, chunk_l=chunk_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk_l, tile_di), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk_l, tile_di), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk_l, st), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1, chunk_l, st), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((tile_di, st), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk_l, tile_di),
                               lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((b, l, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((tile_di, st), jnp.float32)],
        interpret=interpret,
    )(x, dt, bmat, cmat, a)
