"""Pallas TPU kernel for the 5-point Jacobi stencil (paper §8, Listing 8).

Row-band decomposition: the grid walks row tiles of height ``tile_rows``; the
kernel reads three bands (previous / current / next, selected by clamped
index maps — BlockSpecs cannot overlap, so halo rows come from the adjacent
bands) and writes one band of the updated field.  Column halos are handled
in-register by shifting; the global boundary is preserved via masking with
the band's global row offset.

VMEM per step: 4 bands × tile_rows × N × 4 B.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stencil2d"]


def _kernel(prev_ref, cur_ref, next_ref, out_ref, *, coef: float,
            tile_rows: int, total_rows: int):
    i = pl.program_id(0)
    cur = cur_ref[...].astype(jnp.float32)                  # (T, N)
    prev_last = prev_ref[tile_rows - 1:tile_rows, :].astype(jnp.float32)
    next_first = next_ref[0:1, :].astype(jnp.float32)
    up = jnp.concatenate([prev_last, cur[:-1, :]], axis=0)
    down = jnp.concatenate([cur[1:, :], next_first], axis=0)
    left = jnp.concatenate([cur[:, :1], cur[:, :-1]], axis=1)
    right = jnp.concatenate([cur[:, 1:], cur[:, -1:]], axis=1)

    lap = up + down + left + right - 4.0 * cur
    updated = cur + jnp.float32(coef) * lap

    t, n = cur.shape
    grow = i * tile_rows + jax.lax.broadcasted_iota(jnp.int32, (t, n), 0)
    gcol = jax.lax.broadcasted_iota(jnp.int32, (t, n), 1)
    interior = (
        (grow > 0) & (grow < total_rows - 1) & (gcol > 0) & (gcol < n - 1)
    )
    out_ref[...] = jnp.where(interior, updated, cur).astype(out_ref.dtype)


def stencil2d(
    x: jax.Array,          # (M, N) local field including halo/boundary rows
    *,
    coef: float,
    tile_rows: int = 8,
    interpret: bool = True,
) -> jax.Array:
    m, n = x.shape
    assert m % tile_rows == 0, "pad rows to a tile multiple"
    nblk = m // tile_rows
    kern = functools.partial(
        _kernel, coef=coef, tile_rows=tile_rows, total_rows=m
    )
    spec = lambda f: pl.BlockSpec((tile_rows, n), f)  # noqa: E731
    return pl.pallas_call(
        kern,
        grid=(nblk,),
        in_specs=[
            spec(lambda i: (jnp.maximum(i - 1, 0), 0)),
            spec(lambda i: (i, 0)),
            spec(lambda i: (jnp.minimum(i + 1, nblk - 1), 0)),
        ],
        out_specs=spec(lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, x, x)
