"""The model zoo: one scan-over-layers transformer covering all 10 assigned
architectures (dense / MoE / SSM / hybrid / enc-dec / VLM).

Everything is pure-functional: ``Model.init_params`` builds a nested-dict
pytree (safe under ``jax.eval_shape`` for the dry-run), ``Model.forward``
is the training forward, ``Model.init_cache``/``prefill``/``decode_step``
serve inference.  Sharding is injected from outside via the ``RunCtx``
constraint callbacks (runtime/sharding.py), keeping model code
mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

__all__ = ["RunCtx", "Model", "lm_loss"]


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Runtime context: grouping for MoE dispatch, remat policy, and
    sharding-constraint hooks (None = single-device smoke)."""

    moe_groups: int = 1
    remat: str = "full"          # none | full | dots
    constrain: Callable[[jax.Array, str], jax.Array] | None = None
    act_dtype: Any = jnp.bfloat16
    vocab_shards: int = 1        # model-axis size (embed strategy divisibility)
    scan_barrier: bool = True    # optimization_barrier on the layer-scan
    # carry: stops XLA hoisting the residual-stack bf16->f32 convert out of
    # the backward loop (a whole-stack f32 copy; see EXPERIMENTS.md §Perf)
    remat_groups: int = 1        # >1: nested (sqrt) remat — outer scan over
    # groups of layers is checkpointed, so only G boundary residuals are
    # saved instead of L (peak activations / L*(1/G + G/L); one extra fwd)
    cast_params_once: bool = False  # cast layer stack f32->act_dtype before
    # the scan: FSDP all-gathers then move bf16 instead of f32 master params
    # (2x weight-collective cut; see EXPERIMENTS.md §Perf)
    ssm_scan_dtype: Any = jnp.float32  # bf16 halves SSM recurrence traffic
    # Serving hook: when set, the MoE FFN of a SINGLE-TOKEN decode step is
    # routed through fn(moe_params, h) -> out (both (B, 1, D)) instead of
    # the in-jit moe_fwd dispatch — repro.serve wires the per-batch-routed
    # DynamicMoELayer comm schedule in here (docs/serving.md).  Prefill and
    # training (s_len > 1) keep the moe_fwd path.
    moe_step: Callable[[Any, jax.Array], jax.Array] | None = None

    def c(self, x, tag):
        return self.constrain(x, tag) if self.constrain is not None else x


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _stack_init(key, n, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _init_block(key, cfg, dtype, *, kind: str):
    """kind: dense | moe | ssm | hybrid | encdec_dec | enc | cross"""
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"ln1": L.init_norm(ks[0], cfg.d_model, kind=cfg.norm)}
    if kind == "ssm":
        p["ssm"] = S.init_ssm(ks[1], cfg, dtype=dtype)
        return p
    if kind == "cross":
        p["attn"] = L.init_attention(ks[1], cfg, dtype=dtype)
        p["ln2"] = L.init_norm(ks[2], cfg.d_model, kind=cfg.norm)
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, act=cfg.act,
                              dtype=dtype)
        return p
    if kind in ("dense", "enc", "encdec_dec", "hybrid", "moe"):
        p["attn"] = L.init_attention(ks[1], cfg, dtype=dtype)
        p["ln2"] = L.init_norm(ks[2], cfg.d_model, kind=cfg.norm)
        if kind == "hybrid":
            p["ssm"] = S.init_ssm(ks[4], cfg, dtype=dtype)
            p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, act=cfg.act,
                                  dtype=dtype)
        elif kind == "moe":
            p["moe"] = M.init_moe(ks[3], cfg, dtype=dtype)
            if cfg.dense_residual:
                p["res_mlp"] = L.init_mlp(
                    ks[5], cfg.d_model, cfg.residual_d_ff, act=cfg.act,
                    dtype=dtype)
        else:
            p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, act=cfg.act,
                                  dtype=dtype)
        if kind == "encdec_dec":
            p["ln_cross"] = L.init_norm(ks[6], cfg.d_model, kind=cfg.norm)
            p["cross"] = L.init_attention(ks[7], cfg, dtype=dtype)
        return p
    raise ValueError(kind)


def _mixer_fwd(p, h, cfg, ctx, *, kind, kv_ctx=None):
    """The token-mixing half of a block (h already normed)."""
    if kind == "ssm":
        return S.ssm_fwd(p["ssm"], h, cfg, scan_dtype=ctx.ssm_scan_dtype)
    if kind == "hybrid":
        a = L.attention_fwd(p["attn"], h, cfg, causal=True,
                            window=cfg.swa_window)
        s = S.ssm_fwd(p["ssm"], h, cfg, scan_dtype=ctx.ssm_scan_dtype)
        return 0.5 * (a + s)
    if kind == "cross":
        return L.attention_fwd(p["attn"], h, cfg, kv_x=kv_ctx, causal=False,
                               use_rope=False)
    causal = kind != "enc"
    return L.attention_fwd(p["attn"], h, cfg, causal=causal,
                           window=cfg.swa_window,
                           use_rope=kind != "enc")


def _ffn_fwd(p, x, cfg, ctx, *, kind):
    h = L.norm_apply(p["ln2"], x, kind=cfg.norm)
    if kind == "moe":
        b, s_len, d = h.shape
        if ctx.moe_step is not None and s_len == 1:
            # serving decode: the comm-scheduled per-step MoE exchange
            out = ctx.moe_step(p["moe"], h)
        else:
            g = min(ctx.moe_groups, b)
            hg = h.reshape(g, (b // g) * s_len, d)
            aux: dict = {}
            out = M.moe_fwd(p["moe"], hg, cfg, constrain=ctx.constrain,
                            aux=aux)
            out = out.reshape(b, s_len, d)
        if cfg.dense_residual:
            out = out + L.mlp_fwd(p["res_mlp"], h, act=cfg.act)
        return out
    return L.mlp_fwd(p["mlp"], h, act=cfg.act)


def _block_fwd(p, x, cfg, ctx, *, kind, kv_ctx=None):
    h = L.norm_apply(p["ln1"], x, kind=cfg.norm)
    x = x + ctx.c(_mixer_fwd(p, h, cfg, ctx, kind=kind, kv_ctx=kv_ctx), "act")
    if kind == "encdec_dec":
        hc = L.norm_apply(p["ln_cross"], x, kind=cfg.norm)
        x = x + L.attention_fwd(p["cross"], hc, cfg, kv_x=kv_ctx,
                                causal=False, use_rope=False)
    if kind != "ssm":
        x = x + ctx.c(_ffn_fwd(p, x, cfg, ctx, kind=kind), "act")
    return x


# ---------------------------------------------------------------------------
# decode-path blocks (single token, cache)
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg, batch, cache_len, dtype, *, kind, cross_len=0,
                      per_slot=False):
    c: dict[str, Any] = {}
    if kind in ("dense", "moe", "hybrid", "encdec_dec", "cross"):
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        if kind != "cross":
            c["k"] = jnp.zeros((batch, cache_len, hkv, hd), dtype)
            c["v"] = jnp.zeros((batch, cache_len, hkv, hd), dtype)
            # per_slot: each batch lane advances independently (continuous
            # batching), so positions are tracked per lane too
            spos_shape = (batch, cache_len) if per_slot else (cache_len,)
            c["slot_pos"] = jnp.full(spos_shape, -1, jnp.int32)
        if kind in ("encdec_dec", "cross"):
            c["cross_k"] = jnp.zeros((batch, cross_len, hkv, hd), dtype)
            c["cross_v"] = jnp.zeros((batch, cross_len, hkv, hd), dtype)
    if kind in ("ssm", "hybrid"):
        c["ssm"] = S.init_ssm_cache(batch, cfg, dtype=dtype)
    return c


def _attn_decode(p, x, cfg, cache, pos, *, window=0):
    """x: (B, 1, D); ring-buffer KV cache with per-slot positions.

    ``pos`` scalar: every batch lane sits at the same position (the batch
    demo / the oracle scan) and ``slot_pos`` is shared ``(cache_len,)``.
    ``pos`` (B,): continuous-batching lanes at independent positions with
    per-lane ``slot_pos`` ``(B, cache_len)`` (``init_cache(per_slot=True)``).
    """
    b = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cache_len = cache["k"].shape[1]
    q = L.linear(p["wq"], x).reshape(b, 1, h, hd)
    k = L.linear(p["wk"], x).reshape(b, 1, hkv, hd)
    v = L.linear(p["wv"], x).reshape(b, 1, hkv, hd)
    positions = pos[None, None] if jnp.ndim(pos) == 0 else pos[:, None]
    q = L.rope(q, positions, theta=cfg.rope_theta)
    k = L.rope(k, positions, theta=cfg.rope_theta)

    slot = pos % cache_len  # ring slot (== pos when cache_len >= seq)
    if jnp.ndim(pos) == 0:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        spos = jax.lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
        valid = (spos >= 0) & (spos <= pos)
        if window:
            valid &= spos > pos - window
        valid = valid[None, None, None, :]
    else:
        lane = jnp.arange(b)
        ck = cache["k"].at[lane, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[lane, slot].set(v[:, 0].astype(cache["v"].dtype))
        spos = cache["slot_pos"].at[lane, slot].set(pos.astype(jnp.int32))
        valid = (spos >= 0) & (spos <= pos[:, None])       # (B, cache_len)
        if window:
            valid &= spos > (pos - window)[:, None]
        valid = valid[:, None, None, :]
    d = hd
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * (d ** -0.5)
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, cv.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    y = L.linear(p["wo"], out)
    return y, {"k": ck, "v": cv, "slot_pos": spos}


def _attn_prefill(p, x, cfg, cache, pos, *, window=0):
    """x: (B, S, D) prompt chunk; writes positions [pos, pos+S) into the
    ring cache and attends causally over everything valid — the fused
    counterpart of S successive ``_attn_decode`` calls (same f32 einsum,
    same -1e30 masking, same softmax length over the full cache), so the
    two paths agree bit-for-bit as long as the chunk fits the ring
    (S <= cache_len: no slot is written twice within one call).

    ``pos`` scalar for a shared-position cache, (B,) for a per-slot cache
    (each lane prefills from its own start — the continuous-batching
    insert path).
    """
    b, s_len = x.shape[:2]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cache_len = cache["k"].shape[1]
    q = L.linear(p["wq"], x).reshape(b, s_len, h, hd)
    k = L.linear(p["wk"], x).reshape(b, s_len, hkv, hd)
    v = L.linear(p["wv"], x).reshape(b, s_len, hkv, hd)
    offs = jnp.arange(s_len)
    per_slot = jnp.ndim(pos) == 1
    qpos = pos[:, None] + offs[None] if per_slot else pos + offs
    positions = qpos if per_slot else qpos[None]       # (B, S) | (1, S)
    q = L.rope(q, positions, theta=cfg.rope_theta)
    k = L.rope(k, positions, theta=cfg.rope_theta)

    slots = qpos % cache_len
    if per_slot:
        lane = jnp.arange(b)[:, None]
        ck = cache["k"].at[lane, slots].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[lane, slots].set(v.astype(cache["v"].dtype))
        spos = cache["slot_pos"].at[lane, slots].set(qpos.astype(jnp.int32))
        sp = spos                                      # (B, cache_len)
    else:
        ck = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
        spos = cache["slot_pos"].at[slots].set(qpos.astype(jnp.int32))
        sp = spos[None]                                # (1, cache_len)
    qp = qpos if per_slot else qpos[None]              # (B, S) | (1, S)
    valid = (sp[:, None, :] >= 0) & (sp[:, None, :] <= qp[..., None])
    if window:
        valid &= sp[:, None, :] > qp[..., None] - window
    g = h // hkv
    qg = q.reshape(b, s_len, hkv, g, hd)
    logits = jnp.einsum("bshgd,blhd->bhgsl", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * (hd ** -0.5)
    logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgsl,blhd->bshgd", w, cv.astype(jnp.float32))
    out = out.reshape(b, s_len, h * hd).astype(x.dtype)
    y = L.linear(p["wo"], out)
    return y, {"k": ck, "v": cv, "slot_pos": spos}


def _block_prefill(p, x, cfg, ctx, cache, pos, *, kind):
    """Prefill twin of ``_block_decode`` for attention stacks: identical
    residual/norm/FFN math (no training-path sharding constraints), S
    positions at once."""
    h = L.norm_apply(p["ln1"], x, kind=cfg.norm)
    a, kvc = _attn_prefill(p["attn"], h, cfg, cache, pos,
                           window=cfg.swa_window)
    new_cache = dict(cache)
    new_cache.update(kvc)
    x = x + a
    x = x + _ffn_fwd(p, x, cfg, ctx, kind=kind)
    return x, new_cache


def _cross_decode(p, x, cfg, cache):
    """Cross-attention against precomputed (cached) encoder/image KV."""
    b = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = L.linear(p["wq"], x).reshape(b, 1, h, hd)
    out = L.attention(q, cache["cross_k"], cache["cross_v"], causal=False)
    return L.linear(p["wo"], out.reshape(b, 1, h * hd))


def _block_decode(p, x, cfg, ctx, cache, pos, *, kind):
    h = L.norm_apply(p["ln1"], x, kind=cfg.norm)
    new_cache = dict(cache)
    if kind == "ssm":
        y, new_cache["ssm"] = S.ssm_decode_step(p["ssm"], h, cache["ssm"], cfg)
        return x + y, new_cache
    if kind == "hybrid":
        a, kvc = _attn_decode(p["attn"], h, cfg, cache, pos,
                              window=cfg.swa_window)
        s_out, new_cache["ssm"] = S.ssm_decode_step(
            p["ssm"], h, cache["ssm"], cfg)
        new_cache.update(kvc)
        x = x + 0.5 * (a + s_out)
    elif kind == "cross":
        x = x + _cross_decode(p["attn"], h, cfg, cache)
    else:
        a, kvc = _attn_decode(p["attn"], h, cfg, cache, pos,
                              window=cfg.swa_window)
        new_cache.update(kvc)
        x = x + a
        if kind == "encdec_dec":
            hc = L.norm_apply(p["ln_cross"], x, kind=cfg.norm)
            x = x + _cross_decode(p["cross"], hc, cfg, cache)
    if kind != "ssm":
        x = x + _ffn_fwd(p, x, cfg, ctx, kind=kind)
    return x, new_cache


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed_fwd(p, tokens, cfg, ctx):
    w = p["w"]
    sharded = cfg.vocab_size % ctx.vocab_shards == 0 and ctx.vocab_shards > 1
    if cfg.embed_gather == "replicate" or not sharded:
        # naive: gather from a (conceptually) replicated table — also the
        # fallback when the vocab does not divide the model axis
        x = w.astype(ctx.act_dtype)[tokens]
        return x
    # onehot_psum: vocab-sharded table; the contraction over V turns the
    # irregular gather into a planned reduction (the condensed analogue).
    # Chunked over S under remat so the one-hot never materializes whole.
    b, s = tokens.shape
    chunk = min(512, s)
    if s % chunk:
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=ctx.act_dtype)
        return oh @ w.astype(ctx.act_dtype)
    nc = s // chunk
    ts = tokens.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(_, tc):
        oh = jax.nn.one_hot(tc, cfg.vocab_size, dtype=ctx.act_dtype)
        return None, oh @ w.astype(ctx.act_dtype)

    _, xs = jax.lax.scan(body, None, ts)                 # (nc, B, C, D)
    return xs.swapaxes(0, 1).reshape(b, s, -1)


def lm_loss(logits, labels, mask=None):
    """Cross-entropy with vocab-sharded logits (one-hot contraction keeps
    the sharded dim out of gather ops)."""
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    oh = jax.nn.one_hot(labels, v, dtype=jnp.float32)
    ll = (oh * lf).sum(-1)
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def fused_ce_loss(x, head, labels, *, chunk=512, constrain=None):
    """Memory-fused cross-entropy: the (B, S, V) logits tensor is never
    materialized — the head matmul + log-softmax run per sequence chunk
    under remat (the same "plan bulk movement, keep irregularity local"
    principle applied to the loss).  x: (B, S, D) post-norm hidden."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xs = x.reshape(b, nc, chunk, d).swapaxes(0, 1)       # (nc, B, C, D)
    ls = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, args):
        xc, lc = args
        logits = xc @ head.astype(xc.dtype)              # (B, C, V)
        if constrain is not None:
            logits = constrain(logits, "logits")
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        oh = jax.nn.one_hot(lc, lf.shape[-1], dtype=jnp.float32)
        ll = (oh * lf).sum(-1)
        return acc + (lse - ll).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (b * s)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    """Family-dispatching model wrapper around the pure functions above."""

    def __init__(self, cfg, ctx: RunCtx | None = None):
        self.cfg = cfg
        self.ctx = ctx or RunCtx()
        self.kind = {
            "dense": "dense", "moe": "moe", "ssm": "ssm", "hybrid": "hybrid",
            "encdec": "encdec_dec", "vlm": "dense",
        }[cfg.family]

    # ---- init ----
    def init_params(self, key):
        cfg = self.cfg
        dtype = jnp.float32  # master params; cast to act_dtype in forward
        ks = jax.random.split(key, 8)
        p: dict[str, Any] = {
            "embed": {"w": jax.random.normal(
                ks[0], (cfg.vocab_size, cfg.d_model), dtype) * 0.02},
            "final_norm": L.init_norm(ks[1], cfg.d_model, kind=cfg.norm),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = {"w": jax.random.normal(
                ks[2], (cfg.d_model, cfg.vocab_size), dtype)
                * cfg.d_model ** -0.5}

        if cfg.is_vlm and cfg.cross_attn_period:
            per = cfg.cross_attn_period
            groups = cfg.num_layers // per
            p["groups"] = {
                "self": _stack_init(
                    ks[3], groups,
                    lambda k: _stack_init(
                        k, per - 1,
                        lambda k2: _init_block(k2, cfg, dtype, kind="dense"))),
                "cross": _stack_init(
                    ks[4], groups,
                    lambda k: _init_block(k, cfg, dtype, kind="cross")),
            }
        else:
            p["layers"] = _stack_init(
                ks[3], cfg.num_layers,
                lambda k: _init_block(k, cfg, dtype, kind=self.kind))
        if cfg.is_encdec:
            p["encoder"] = {
                "layers": _stack_init(
                    ks[5], cfg.encoder_layers,
                    lambda k: _init_block(k, cfg, dtype, kind="enc")),
                "norm": L.init_norm(ks[6], cfg.d_model, kind=cfg.norm),
            }
        return p

    # ---- training forward ----
    def hidden(self, params, tokens, *, extra=None):
        """Post-final-norm hidden states (B, S, D)."""
        cfg, ctx = self.cfg, self.ctx
        x = ctx.c(_embed_fwd(params["embed"], tokens, cfg, ctx), "act")

        kv_ctx = None
        if cfg.is_encdec:
            kv_ctx = self._encode(params["encoder"], extra["frames"])
        if cfg.is_vlm:
            kv_ctx = extra["image_embeds"].astype(ctx.act_dtype)

        if ctx.cast_params_once and "layers" in params:
            params = dict(params)
            params["layers"] = jax.tree.map(
                lambda a: a.astype(ctx.act_dtype)
                if jnp.issubdtype(a.dtype, jnp.floating) else a,
                params["layers"])

        if cfg.is_vlm and cfg.cross_attn_period:
            x = self._vlm_stack(params["groups"], x, kv_ctx)
        elif ctx.remat_groups > 1 and cfg.num_layers % ctx.remat_groups == 0:
            g = ctx.remat_groups
            per = cfg.num_layers // g
            grouped = jax.tree.map(
                lambda a: a.reshape(g, per, *a.shape[1:]), params["layers"])

            def group_body(x, gp):
                def inner(x2, lp):
                    return self._scan_body(x2, lp, kv_ctx=kv_ctx)
                x, _ = jax.lax.scan(inner, x, gp)
                return x, None

            x, _ = jax.lax.scan(_remat(group_body, ctx.remat), x, grouped)
        else:
            body = _remat(
                functools.partial(self._scan_body, kv_ctx=kv_ctx), ctx.remat)
            x, _ = jax.lax.scan(body, x, params["layers"])

        return L.norm_apply(params["final_norm"], x, kind=cfg.norm)

    def head_weight(self, params):
        return (params["embed"]["w"].T if self.cfg.tie_embeddings
                else params["lm_head"]["w"])

    def forward(self, params, tokens, *, extra=None, last_only=False):
        """tokens: (B, S) int32.  extra: {"frames"|"image_embeds": (B,T,D)}.
        Returns logits (B, S, V) — or (B, 1, V) when ``last_only`` (prefill:
        the head matmul runs on the final position only)."""
        ctx = self.ctx
        x = self.hidden(params, tokens, extra=extra)
        if last_only:
            x = x[:, -1:, :]
        logits = x @ self.head_weight(params).astype(x.dtype)
        return ctx.c(logits, "logits")

    def loss(self, params, tokens, labels, *, extra=None, chunk=512):
        """Fused chunked cross-entropy (never materializes full logits)."""
        x = self.hidden(params, tokens, extra=extra)
        return fused_ce_loss(x, self.head_weight(params), labels,
                             chunk=chunk, constrain=self.ctx.constrain)

    def _scan_body(self, x, layer_p, *, kv_ctx=None):
        if self.ctx.scan_barrier:
            from repro import compat
            x = compat.optimization_barrier(x)
        return _block_fwd(layer_p, x, self.cfg, self.ctx, kind=self.kind,
                          kv_ctx=kv_ctx), None

    def _vlm_stack(self, groups_p, x, kv_ctx):
        cfg, ctx = self.cfg, self.ctx

        def group_body(x, gp):
            def self_body(x2, lp):
                return _block_fwd(lp, x2, cfg, ctx, kind="dense"), None
            x, _ = jax.lax.scan(_remat(self_body, ctx.remat), x, gp["self"])
            x = _remat(
                lambda x3: _block_fwd(gp["cross"], x3, cfg, ctx,
                                      kind="cross", kv_ctx=kv_ctx),
                ctx.remat)(x)
            return x, None

        x, _ = jax.lax.scan(group_body, x, groups_p)
        return x

    def _encode(self, enc_p, frames):
        cfg, ctx = self.cfg, self.ctx
        x = frames.astype(ctx.act_dtype)

        def body(x, lp):
            return _block_fwd(lp, x, cfg, ctx, kind="enc"), None

        x, _ = jax.lax.scan(_remat(body, ctx.remat), x, enc_p["layers"])
        return L.norm_apply(enc_p["norm"], x, kind=cfg.norm)

    # ---- serving ----
    def init_cache(self, batch, cache_len, *, cross_len=0, dtype=jnp.bfloat16,
                   per_slot=False):
        """``per_slot=True`` builds a continuous-batching cache: ``pos``
        becomes (B,) and ``slot_pos`` (B, cache_len), so every batch lane
        (a serving *slot*) tracks its own sequence independently —
        ``decode_step`` / ``prefill`` dispatch on the pos rank.  Needs an
        attention-only stack (SSM recurrences carry no per-lane position)."""
        cfg = self.cfg
        if cfg.swa_window:
            cache_len = min(cache_len, cfg.swa_window)
        if per_slot and self.kind not in ("dense", "moe"):
            raise NotImplementedError(
                "per-slot caches (continuous batching) need an "
                f"attention-only stack, got family {cfg.family!r}")

        def one(_):
            return _init_layer_cache(cfg, batch, cache_len, dtype,
                                     kind=self.kind, cross_len=cross_len,
                                     per_slot=per_slot)

        if cfg.is_vlm and cfg.cross_attn_period:
            per = cfg.cross_attn_period
            groups = cfg.num_layers // per
            layers = {
                "self": jax.vmap(lambda i: jax.vmap(one)(
                    jnp.arange(per - 1)))(jnp.arange(groups)),
                "cross": jax.vmap(
                    lambda i: _init_layer_cache(
                        cfg, batch, cache_len, dtype, kind="cross",
                        cross_len=cross_len))(jnp.arange(groups)),
            }
        else:
            layers = jax.vmap(one)(jnp.arange(cfg.num_layers))
        pos0 = (jnp.zeros((batch,), jnp.int32) if per_slot
                else jnp.zeros((), jnp.int32))
        return {"pos": pos0, "layers": layers}

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1). Returns (logits (B, 1, V), new_cache)."""
        cfg, ctx = self.cfg, self.ctx
        x = ctx.c(_embed_fwd(params["embed"], tokens, cfg, ctx), "act")
        pos = cache["pos"]

        if cfg.is_vlm and cfg.cross_attn_period:
            def group_body(x, args):
                gp, gc = args

                def self_body(x2, a2):
                    lp, lc = a2
                    y, nc = _block_decode(lp, x2, cfg, ctx, lc, pos,
                                          kind="dense")
                    return y, nc
                x, nself = jax.lax.scan(
                    self_body, x, (gp["self"], gc["self"]))
                x, ncross = _block_decode(gp["cross"], x, cfg, ctx,
                                          gc["cross"], pos, kind="cross")
                return x, {"self": nself, "cross": ncross}

            x, new_layers = jax.lax.scan(
                group_body, x, (params["groups"], cache["layers"]))
        else:
            def body(x, args):
                lp, lc = args
                y, nc = _block_decode(lp, x, cfg, ctx, lc, pos,
                                      kind=self.kind)
                return y, nc

            x, new_layers = jax.lax.scan(
                body, x, (params["layers"], cache["layers"]))

        x = L.norm_apply(params["final_norm"], x, kind=cfg.norm)
        head = (params["embed"]["w"].T if cfg.tie_embeddings
                else params["lm_head"]["w"])
        logits = ctx.c(x @ head.astype(x.dtype), "logits")
        return logits, {"pos": pos + 1, "layers": new_layers}

    def prefill(self, params, cache, tokens):
        """Fused prompt prefill: one forward over ``tokens`` (B, S) that
        ALSO writes the prompt's K/V into the decode cache at positions
        [pos, pos+S) — the production path ``runtime.steps.build_prefill
        (fill_cache=True)`` wraps, replacing the sequential decode_step
        scan (kept as the oracle in ``launch.serve.prefill_into_cache``).

        Returns ``(last_logits (B, 1, V), new_cache)``; chunked prefill is
        consecutive calls, each advancing ``cache["pos"]`` by its chunk
        length.  Works on shared-position and per-slot caches; needs an
        attention-only stack (dense/moe) — other families prefill through
        the decode_step scan.  ``S <= cache_len`` (one ring lap per call).
        """
        cfg, ctx = self.cfg, self.ctx
        if self.kind not in ("dense", "moe"):
            raise NotImplementedError(
                "fused prefill supports attention-only stacks (dense/moe); "
                f"family {cfg.family!r} prefills via the decode_step scan")
        cache_len = cache["layers"]["k"].shape[2]
        if tokens.shape[1] > cache_len:
            raise ValueError(
                f"prefill chunk ({tokens.shape[1]} tokens) exceeds the ring "
                f"cache ({cache_len} slots); chunk the prompt")
        x = ctx.c(_embed_fwd(params["embed"], tokens, cfg, ctx), "act")
        pos = cache["pos"]

        def body(x, args):
            lp, lc = args
            y, nc = _block_prefill(lp, x, cfg, ctx, lc, pos, kind=self.kind)
            return y, nc

        x, new_layers = jax.lax.scan(
            body, x, (params["layers"], cache["layers"]))
        x = L.norm_apply(params["final_norm"], x, kind=cfg.norm)
        x = x[:, -1:, :]
        logits = ctx.c(x @ self.head_weight(params).astype(x.dtype), "logits")
        return logits, {"pos": pos + tokens.shape[1], "layers": new_layers}

    def prefill_cross(self, params, cache, context):
        """Fill cross-attention KV from encoder output / image embeds."""
        cfg = self.cfg
        if cfg.is_encdec:
            enc = self._encode(params["encoder"], context)

            def fill(lp, lc):
                b = enc.shape[0]
                hkv, hd = cfg.num_kv_heads, cfg.head_dim
                k = L.linear(lp["cross"]["wk"], enc).reshape(b, -1, hkv, hd)
                v = L.linear(lp["cross"]["wv"], enc).reshape(b, -1, hkv, hd)
                lc = dict(lc)
                lc["cross_k"] = k.astype(lc["cross_k"].dtype)
                lc["cross_v"] = v.astype(lc["cross_v"].dtype)
                return lc

            new_layers = jax.vmap(fill)(params["layers"], cache["layers"])
            return {**cache, "layers": new_layers}
        if cfg.is_vlm:
            ctx_e = context.astype(self.ctx.act_dtype)

            def fill(gp, gc):
                b = ctx_e.shape[0]
                hkv, hd = cfg.num_kv_heads, cfg.head_dim
                k = L.linear(gp["cross"]["attn"]["wk"], ctx_e).reshape(
                    b, -1, hkv, hd)
                v = L.linear(gp["cross"]["attn"]["wv"], ctx_e).reshape(
                    b, -1, hkv, hd)
                gc = dict(gc)
                cc = dict(gc["cross"])
                cc["cross_k"] = k.astype(cc["cross_k"].dtype)
                cc["cross_v"] = v.astype(cc["cross_v"].dtype)
                gc["cross"] = cc
                return gc

            new_layers = jax.vmap(fill)(params["groups"], cache["layers"])
            return {**cache, "layers": new_layers}
        return cache
