"""GNN-style neighbor aggregation on the comm API (4th Schedule consumer).

Message passing over a fixed graph is the canonical irregular-exchange
chain: every node *gathers* its neighbors' features, *combines* them into
per-edge messages, and *scatter-adds* the messages back onto the nodes that
name it as a neighbor — gather → combine → scatter-update, one declarative
``Schedule`` compiled into a single ``shard_map`` window.  The scatter
stage shares the gather stage's ``AccessPattern``, so its executor tables
are a transpose-derived delta of the same base plan (never a second
O(edges) build) and the §5 window composition prices both directions in
one consolidated window.

The graph lives in ELL form — ``nbrs`` is ``(n, r)`` int32, row i naming
node i's r neighbors, rows with fewer neighbors padded with i itself (an
owned, zero-cost access whose message is identically zero).  That is the
same index-set shape as SpMV's EllPack ``cols``, which is the point: the
planner, the strategy ladder and the performance models are reused
unchanged on a workload the paper never ran.
"""
from __future__ import annotations

import numpy as np

from repro.comm.pattern import AccessPattern
from repro.comm.schedule import Schedule

__all__ = ["GNNNeighborAggregate", "gnn_ref_np", "random_neighbors"]


def random_neighbors(n: int, r: int, *, alpha: float = 0.0,
                     seed: int = 0) -> np.ndarray:
    """An ELL neighbor list, optionally with Zipf(``alpha``) hub nodes.

    ``alpha=0`` draws neighbors uniformly; larger ``alpha`` concentrates
    in-degree on a few hub nodes (``repro.data.skewed`` popularity law),
    the regime where the scatter direction's per-shard accumulate loads
    become badly imbalanced.  Self-edges are kept: they are owned accesses
    and their messages vanish in the combine.
    """
    rng = np.random.default_rng(seed)
    if alpha > 0.0:
        from repro.data.skewed import zipf_column_weights
        cdf = np.cumsum(zipf_column_weights(n, alpha, seed=seed + 1))
        cdf[-1] = 1.0
        nbrs = np.searchsorted(cdf, rng.random((n, r)), side="right")
    else:
        nbrs = rng.integers(0, n, size=(n, r))
    return np.ascontiguousarray(nbrs, dtype=np.int32)


def gnn_ref_np(h: np.ndarray, nbrs: np.ndarray,
               weight: float = 0.5) -> np.ndarray:
    """Ground-truth aggregation step in numpy.

    ``msg[i, s] = weight * (h[nbrs[i, s]] - h[i])`` and every message is
    pushed onto its *neighbor*: ``out[j] = h[j] + sum over {(i, s):
    nbrs[i, s] == j} msg[i, s]`` — a graph-Laplacian-flavored smoothing
    update (self-edges contribute exactly zero).
    """
    gathered = h[nbrs]                              # (n, r, d)
    msg = weight * (gathered - h[:, None, :])
    out = h.copy()
    np.add.at(out, nbrs.ravel(), msg.reshape(-1, h.shape[-1]))
    return out


class GNNNeighborAggregate:
    """One aggregation step compiled as a fused gather→combine→scatter
    window over row-sharded node features.

    ``nbrs`` — (n, r) int32 ELL neighbor list (global node ids, self-id
    padding); features are (n, d) and sharded over the mesh axis like
    every other consumer.  ``strategy``/``blocksize``/``hw`` etc. forward
    to ``Schedule.resolve`` — ``strategy="auto"`` ranks the ladder on the
    §5 models exactly as SpMV does, and ``.predicted_window`` carries the
    fused two-exchange composition prediction.
    """

    def __init__(self, nbrs: np.ndarray, n: int, mesh, *,
                 weight: float = 0.5, axis_name="data",
                 strategy: str = "auto", blocksize=None,
                 topology=None, shards_per_node: int | None = None,
                 hw=None, use_plan_cache: bool = True):
        nbrs = np.ascontiguousarray(np.asarray(nbrs), dtype=np.int32)
        assert nbrs.ndim == 2 and nbrs.shape[0] == n, (
            f"nbrs must be (n, r) with n={n}, got {nbrs.shape}")
        self.nbrs = nbrs
        self.n = n
        self.weight = float(weight)
        pattern = AccessPattern.from_indices(nbrs, n=n)

        sched = Schedule()
        h = sched.input("h")
        rows = sched.constant(nbrs, name="nbrs")
        g = sched.gather(pattern, src=h, name="gather_nbrs")
        w = self.weight
        # Messages accumulate in float32 regardless of the feature dtype:
        # under a skewed in-degree law a hub node sums thousands of
        # same-sign contributions, which low-precision accumulation drifts
        # on unboundedly.  Mixed-precision accumulate is the standard fix;
        # for float32 features both casts are no-ops.
        msg = sched.compute(
            lambda xc, rl, hl: (w * (xc[rl] - hl[:, None, :]))
            .astype("float32"),
            g, rows, h, name="combine")
        agg = sched.scatter(pattern, msg, reduce="add", name="scatter_upd")
        sched.compute(lambda s, hl: hl + s.astype(hl.dtype), agg, h,
                      name="update")
        self.schedule = sched.compile(
            mesh, axis_name=axis_name, strategy=strategy,
            blocksize=blocksize, topology=topology,
            shards_per_node=shards_per_node, hw=hw,
            use_plan_cache=use_plan_cache)

    # the resolved rungs / §5 predictions, straight off the schedule
    @property
    def strategies(self) -> dict:
        return self.schedule.strategies

    @property
    def predicted_times(self) -> dict:
        return self.schedule.predicted_times

    @property
    def predicted_window(self):
        return self.schedule.predicted_window

    def shard_features(self, h):
        return self.schedule.shard_input(np.asarray(h))

    def __call__(self, h):
        return self.schedule(h)
