"""Mamba-1 selective SSM block (falcon-mamba-7b; hymba's SSM heads).

Training path uses a chunked scan: an outer ``lax.scan`` over sequence chunks
carries the (B, d_inner, state) hidden state; within a chunk the linear
recurrence runs as an associative scan.  This bounds the materialized state
tensor to one chunk (the TPU-memory analogue of the paper's "plan bulk
transfers instead of fine-grained access": state stays VMEM/HBM-local per
chunk instead of materializing (B, L, d_inner, state)).

Decode path is the exact single-step recurrence with a rolling conv window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear

__all__ = ["init_ssm", "ssm_fwd", "ssm_decode_step", "init_ssm_cache"]


def init_ssm(key, cfg, *, d_model=None, d_inner=None, dtype=jnp.float32):
    d = d_model or cfg.d_model
    di = d_inner or cfg.d_inner
    st, dr, dc = cfg.ssm_state, cfg.ssm_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": init_linear(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (dc, di), dtype) * (dc ** -0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_linear(ks[2], di, dr + 2 * st, dtype=dtype),
        "dt_proj": init_linear(ks[3], dr, di, bias=True, dtype=dtype),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": init_linear(ks[4], di, d, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, L, di); w: (K, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _selective_scan_chunk(h0, da, dbx, c):
    """Linear recurrence h_t = da_t * h_{t-1} + dbx_t within one chunk via
    associative scan; returns per-step h and final h.

    da, dbx: (B, C, di, st); c: (B, C, st); h0: (B, di, st).
    """
    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    da0 = jnp.concatenate([jnp.ones_like(da[:, :1]), da[:, 1:]], axis=1)
    # fold h0 into the first step: h_1 = da_1*h0 + dbx_1
    dbx = dbx.at[:, 0].add(da[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (da0, dbx), axis=1)
    return h, h[:, -1]


def ssm_fwd(p, u, cfg, *, d_inner=None, chunk=256, scan_dtype=jnp.float32):
    """u: (B, L, d). Returns (B, L, d).

    ``scan_dtype=jnp.bfloat16`` halves the HBM traffic of the chunked
    recurrence (the dominant term at long sequence; EXPERIMENTS.md §Perf
    cell C).  The decay exponent and boundary states stay f32; only the
    within-chunk scan payload is reduced — validated against the f32 path
    in tests/test_moe_ssm.py.
    """
    di = d_inner or cfg.d_inner
    st, dr = cfg.ssm_state, cfg.ssm_dt_rank
    b, l, _ = u.shape
    xz = linear(p["in_proj"], u)
    x, z = jnp.split(xz, 2, axis=-1)                      # (B, L, di)
    x = _causal_conv(x, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    x = jax.nn.silu(x)

    dbc = linear(p["x_proj"], x)
    dt, bmat, cmat = jnp.split(dbc, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt)).astype(jnp.float32)  # (B,L,di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (di, st)

    chunk = min(chunk, l)
    assert l % chunk == 0
    nchunks = l // chunk
    xs = x.astype(jnp.float32).reshape(b, nchunks, chunk, di)
    dts = dt.reshape(b, nchunks, chunk, di)
    bs = bmat.astype(jnp.float32).reshape(b, nchunks, chunk, st)
    cs = cmat.astype(jnp.float32).reshape(b, nchunks, chunk, st)

    def body(h, args):
        xc, dtc, bc, cc = args                           # (B, C, ...)
        # decay computed in f32, scan payload in scan_dtype
        da = jnp.exp(dtc[..., None] * a).astype(scan_dtype)
        dbx = ((dtc * xc)[..., None] * bc[:, :, None, :]).astype(scan_dtype)
        hs, h_last = _selective_scan_chunk(
            h.astype(scan_dtype), da, dbx, cc)
        yc = jnp.einsum("bcds,bcs->bcd", hs.astype(jnp.float32), cc)
        return h_last.astype(jnp.float32), yc

    h0 = jnp.zeros((b, di, st), jnp.float32)
    _, ys = jax.lax.scan(
        body, h0,
        (xs.swapaxes(0, 1), dts.swapaxes(0, 1), bs.swapaxes(0, 1),
         cs.swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1).reshape(b, l, di)
    y = y + xs.reshape(b, l, di) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return linear(p["out_proj"], y.astype(u.dtype))


def init_ssm_cache(batch, cfg, *, d_inner=None, dtype=jnp.float32):
    di = d_inner or cfg.d_inner
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
    }


def ssm_decode_step(p, u, cache, cfg, *, d_inner=None):
    """u: (B, 1, d). Exact single-step recurrence. Returns (y, new_cache)."""
    di = d_inner or cfg.d_inner
    st, dr = cfg.ssm_state, cfg.ssm_dt_rank
    b = u.shape[0]
    xz = linear(p["in_proj"], u)                          # (B, 1, 2di)
    x, z = jnp.split(xz, 2, axis=-1)
    conv_in = jnp.concatenate([cache["conv"], x], axis=1)  # (B, K, di)
    w = p["conv_w"].astype(x.dtype)
    xc = (conv_in * w[None]).sum(axis=1, keepdims=True) \
        + p["conv_b"].astype(x.dtype)[None, None]
    xc = jax.nn.silu(xc)

    dbc = linear(p["x_proj"], xc)
    dt, bmat, cmat = jnp.split(dbc, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt)).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt[..., None] * a)[:, 0]                  # (B, di, st)
    dbx = (dt * xc.astype(jnp.float32))[..., None][:, 0] \
        * bmat.astype(jnp.float32)[:, 0, None, :]
    h = da * cache["h"] + dbx
    y = jnp.einsum("bds,bs->bd", h, cmat.astype(jnp.float32)[:, 0])[:, None]
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = linear(p["out_proj"], y.astype(u.dtype))
    return out, {"h": h, "conv": conv_in[:, 1:]}
