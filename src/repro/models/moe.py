"""Mixture-of-Experts block with the paper's communication-strategy ladder.

Token->expert routing is the LM-scale instance of the paper's fine-grained
irregular communication: each token (array element) must reach the shard
owning its expert (owner thread).  Following DESIGN.md §4:

* ``tp_local``  — experts are *weight-sharded* over the model axis (tensor
  parallel); tokens never move.  The analogue of the paper's single-node
  case where no remote transfers exist (natural for few-expert models:
  mixtral's 8 experts < 16-way model axis).
* ``ep_a2a``    — experts are sharded over the model axis (expert parallel);
  tokens are *sort-packed* into per-expert capacity-bounded buffers —
  message condensing (only selected tokens move) and consolidation (one
  buffer per expert) with a static capacity bound standing in for the
  paper's one-time plan, as XLA's static shapes require.  The resharding of
  the packed buffer is where GSPMD materializes the all-to-all.

Dispatch is computed per data-parallel group (the ``G`` leading dim) so no
collective sort is ever needed — the paper's per-thread preparation step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_linear

__all__ = ["init_moe", "moe_fwd", "moe_capacity", "random_router",
           "moe_dispatch_pattern", "moe_dispatch_ref", "MoEDispatchGather",
           "moe_combine_weights", "moe_combine_ref", "MoECombineScatter",
           "moe_expert_local", "MoELayer", "DynamicMoELayer"]


def random_router(key, num_tokens: int, num_experts: int, top_e: int = 2):
    """Seeded zipf-skewed routing, the shared stand-in for a trained router.

    Expert popularity follows the paper-style skew real routers exhibit
    (weights ∝ 1/rank): every benchmark and test that needs a routing draws
    it here so the load imbalance — the thing the ladder optimizes — is the
    same everywhere.  Per token the ``top_e`` experts are drawn *without
    replacement* (Gumbel top-k over the skewed logits) and the routing
    weights are normalized to sum to 1.

    Returns ``(top_e_idx (T, k) int32, top_w (T, k) float32)``.
    """
    rng = np.random.default_rng(key)
    weights = 1.0 / np.arange(1, num_experts + 1)
    weights /= weights.sum()
    # Gumbel top-k: k distinct experts per token with P(expert) ∝ weights
    g = rng.gumbel(size=(num_tokens, num_experts)) + np.log(weights)
    idx = np.argsort(-g, axis=1)[:, :top_e].astype(np.int32)
    raw = rng.random((num_tokens, top_e)).astype(np.float32) + 0.1
    top_w = raw / raw.sum(axis=1, keepdims=True)
    return idx, top_w.astype(np.float32)


def init_moe(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "router": init_linear(ks[0], d, e, dtype=dtype),
        "w1": jax.random.normal(ks[1], (e, d, f), dtype) * scale,
        "w2": jax.random.normal(ks[2], (e, f, d), dtype) * (f ** -0.5),
    }
    if cfg.act == "swiglu":
        p["w3"] = jax.random.normal(ks[3], (e, d, f), dtype) * scale
    return p


def moe_capacity(tokens_per_group: int, cfg) -> int:
    c = math.ceil(
        tokens_per_group * cfg.experts_per_token / cfg.num_experts
        * cfg.capacity_factor
    )
    return max(8, -(-c // 8) * 8)  # round up to 8


def _expert_mlp(p, buf, act):
    """buf: (G, E, C, D) -> (G, E, C, D)."""
    w1 = p["w1"].astype(buf.dtype)
    w2 = p["w2"].astype(buf.dtype)
    h = jnp.einsum("gecd,edf->gecf", buf, w1)
    if act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum(
            "gecd,edf->gecf", buf, p["w3"].astype(buf.dtype))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("gecf,efd->gecd", h, w2)


def moe_fwd(p, x, cfg, *, constrain=None, aux=None):
    """x: (G, T, D) tokens grouped by data-parallel rank.

    ``constrain``: optional fn(array, stage) -> array applying sharding
    constraints; stage in {"dispatch", "expert"} (runtime/sharding.py).
    ``aux``: optional dict populated with the load-balancing loss.
    """
    g, t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = moe_capacity(t, cfg)

    logits = jnp.einsum(
        "gtd,de->gte", x, p["router"]["w"].astype(x.dtype)
    ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)               # (G, T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                # (G, T, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    if aux is not None:
        # Switch-style load-balance loss: E * mean(frac_tokens * frac_prob)
        me = probs.mean(axis=1)                           # (G, E)
        ce = jax.nn.one_hot(top_e[..., 0], e).mean(axis=1)
        aux["moe_loss"] = (e * (me * ce).sum(-1)).mean()

    # ---- condensed dispatch: sort tokens by expert, pack to capacity ----
    flat_e = top_e.reshape(g, t * k)
    flat_w = top_p.reshape(g, t * k)
    sort_idx = jnp.argsort(flat_e, axis=-1)               # (G, T*k) stable
    se = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    counts = jax.nn.one_hot(flat_e, e, dtype=jnp.int32).sum(axis=1)  # (G, E)
    seg_start = jnp.cumsum(counts, axis=-1) - counts      # exclusive
    pos = jnp.arange(t * k)[None] - jnp.take_along_axis(seg_start, se, axis=-1)
    keep = pos < c
    dest = jnp.where(keep, se * c + pos, e * c)           # dump slot
    tok = sort_idx // k

    gather_tok = jnp.take_along_axis(x, tok[..., None], axis=1)  # (G,T*k,D)

    def scatter_one(vals, dst):
        buf = jnp.zeros((e * c + 1, d), vals.dtype)
        return buf.at[dst].set(vals)[: e * c]

    buf = jax.vmap(scatter_one)(gather_tok, dest).reshape(g, e, c, d)
    if constrain is not None:
        buf = constrain(buf, "expert")                    # -> a2a under EP

    out_buf = _expert_mlp(p, buf, cfg.act)                # (G, E, C, D)
    if constrain is not None:
        out_buf = constrain(out_buf, "dispatch")          # -> back to dp

    flat_out = jnp.concatenate(
        [out_buf.reshape(g, e * c, d),
         jnp.zeros((g, 1, d), out_buf.dtype)], axis=1)
    y_sorted = jnp.take_along_axis(flat_out, dest[..., None], axis=1)
    w_sorted = jnp.take_along_axis(flat_w, sort_idx, axis=-1)
    y_sorted = y_sorted * (w_sorted * keep)[..., None].astype(y_sorted.dtype)

    def combine_one(ys, tk):
        return jnp.zeros((t, d), ys.dtype).at[tk].add(ys)

    return jax.vmap(combine_one)(y_sorted, tok)           # (G, T, D)


# ---------------------------------------------------------------------------
# MoE dispatch as the paper's irregular gather (repro.comm consumer)
# ---------------------------------------------------------------------------
#
# The dispatch above rides inside one jitted forward where XLA/GSPMD places
# the all-to-all.  At *serving* scale the routing of a decoded batch is a
# static fact between steps: tokens live sharded over devices, experts live
# sharded over (possibly other) devices, and each expert shard must gather
# exactly the token vectors routed to it — a fine-grained irregular gather
# with expert-capacity slots as accessor rows and tokens as the shared
# vector.  ``MoEDispatchGather`` runs that gather through the same
# ``CommPlan`` / strategy ladder / §5 models as SpMV and Heat2D.


def _pack_slots(top_e, num_tokens: int, num_experts: int, capacity: int):
    """Shared slot packing: sort (token, choice) pairs by expert, truncate
    at capacity.  Returns (slot_expert, slot_pos, src_flat, keep) over the
    flattened (num_tokens * k) routing choices, token-major within each
    expert — the same tokens ``moe_fwd`` keeps."""
    top_e = np.asarray(top_e)
    k = top_e.shape[1]
    e_flat = top_e.ravel()
    order = np.argsort(e_flat, kind="stable")     # (e, then token-major)
    se = e_flat[order]
    counts = np.bincount(e_flat, minlength=num_experts)
    seg_start = np.cumsum(counts) - counts
    pos = np.arange(num_tokens * k) - seg_start[se]
    keep = pos < capacity
    return se, pos, order, keep


def moe_dispatch_pattern(top_e, num_tokens: int, num_experts: int,
                         capacity: int, p: int, *, packed=None):
    """Token→expert assignment as an access-pattern index table.

    ``top_e``: (num_tokens, k) expert choices per token.  Accessor row
    ``e*capacity + c`` reads the c-th token routed to expert e (token-major
    order, truncated at capacity — the same tokens ``moe_fwd`` keeps).
    Returns ``(idx (E*C,) int32, valid (E*C,) bool)``; empty slots pad with
    a token *owned by the expert's shard* so padding costs no communication.
    ``packed`` accepts a precomputed ``_pack_slots`` result so a caller
    that also builds the combine weights runs the sort pipeline once.
    """
    top_e = np.asarray(top_e)
    assert num_tokens % p == 0 and num_experts % p == 0
    t_loc, e_loc = num_tokens // p, num_experts // p
    k = top_e.shape[1]
    se, pos, order, keep = packed if packed is not None else _pack_slots(
        top_e, num_tokens, num_experts, capacity)
    st = np.repeat(np.arange(num_tokens, dtype=np.int64), k)[order]

    idx = np.zeros((num_experts, capacity), np.int64)
    valid = np.zeros((num_experts, capacity), bool)
    idx[se[keep], pos[keep]] = st[keep]
    valid[se[keep], pos[keep]] = True
    # pad empty slots with an owned token id (zero-cost access)
    own_token = np.repeat(np.arange(p) * t_loc, e_loc * capacity).reshape(
        num_experts, capacity)
    idx = np.where(valid, idx, own_token)
    return idx.reshape(-1).astype(np.int32), valid.reshape(-1)


def moe_combine_weights(top_e, top_w, num_tokens: int, num_experts: int,
                        capacity: int, *, packed=None):
    """Per-slot combine weight for the expert→token return path.

    ``top_w``: (num_tokens, k) routing weights aligned with ``top_e``.
    Slot ``e*capacity + c`` gets the weight of the token occupying it under
    ``moe_dispatch_pattern``'s packing; empty (over-capacity) slots get 0,
    so their contribution vanishes exactly.  Returns (E*C,) float32.
    ``packed`` accepts a precomputed ``_pack_slots`` result, as in
    ``moe_dispatch_pattern``.
    """
    top_w = np.asarray(top_w)
    se, pos, order, keep = packed if packed is not None else _pack_slots(
        top_e, num_tokens, num_experts, capacity)
    sw = top_w.ravel()[order]
    w = np.zeros((num_experts, capacity), np.float32)
    w[se[keep], pos[keep]] = sw[keep]
    return w.reshape(-1)


def moe_dispatch_ref(x, idx, valid, num_experts: int, capacity: int):
    """NumPy ground truth: buf[e, c] = x[idx[e*C+c]] (0 where invalid)."""
    x = np.asarray(x)
    out = x[idx] * valid.reshape(-1, *([1] * (x.ndim - 1)))
    return out.reshape((num_experts, capacity) + x.shape[1:])


class MoEDispatchGather:
    """Expert-capacity-slot gather over sharded tokens via ``repro.comm``.

    Tokens (the shared vector, length ``num_tokens``, optional feature dims)
    and experts (``num_experts``, ``capacity`` slots each) are both sharded
    contiguously over ``axis_name``.  Any ladder rung or ``"auto"`` applies.

    ``materialize="dest"`` (default) registers the expert-capacity slots as
    a ``Destination``: each exchange lands token vectors directly in
    ``(expert, capacity-slot)`` order — O(slots + recv) work per dispatch,
    empty slots read exactly 0.0, and no length-``num_tokens`` private copy
    is ever assembled.  ``materialize="full"`` keeps the classic
    assemble-then-index path (bit-identical output); there the ``overlap``
    rung fills owned-token slots from ``x_local`` while the condensed
    exchange is in flight (the plan's own/foreign split with r = 1).
    """

    def __init__(self, top_e, num_tokens: int, num_experts: int,
                 capacity: int, mesh, *, axis_name: str = "data",
                 strategy: str = "auto", blocksize=None,
                 shards_per_node=None, materialize: str = "dest",
                 hw=None, use_plan_cache: bool = True):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.comm.gather import IrregularGather
        from repro.comm.pattern import AccessPattern, Destination
        from repro.comm.plan import Topology

        p = int(mesh.shape[axis_name])
        self.p = p
        self.num_tokens = num_tokens
        self.num_experts = num_experts
        self.capacity = capacity
        assert materialize in ("dest", "full"), materialize
        self.materialize = materialize
        idx, valid = moe_dispatch_pattern(
            top_e, num_tokens, num_experts, capacity, p)
        self.idx, self.valid = idx, valid
        pattern = AccessPattern.from_indices(idx, n=num_tokens)
        destination = None
        if materialize == "dest":
            # capacity slots ARE the consumer buffer: empty slots (whose
            # pattern entry is an owned zero-cost pad token) deliver 0.0
            slot_idx = np.where(valid, idx.astype(np.int64),
                                Destination.ZERO)
            destination = Destination.from_slots(
                slots=slot_idx.reshape(p, -1))
        self.gather = IrregularGather(
            pattern, mesh, axis_name=axis_name, strategy=strategy,
            blocksize=blocksize, destination=destination,
            topology=Topology(p, shards_per_node or p), hw=hw,
            use_plan_cache=use_plan_cache,
        )
        self.strategy = self.gather.strategy
        self.requested_strategy = strategy
        self.predicted_times = self.gather.predicted_times
        self.plan = self.gather.plan
        gather = self.gather

        shard = NamedSharding(mesh, P(axis_name))
        n = num_tokens
        if materialize == "dest":
            extra = ()
        elif self.strategy == "overlap":
            plan = self.plan
            extra = (plan.loc_cols[:, 0], plan.rem_cols[:, 0],
                     valid.astype(np.float32))
        else:
            extra = (idx, valid.astype(np.float32))
        self._extra_args = tuple(jax.device_put(a, shard) for a in extra)

        def step_local(x_local, *args):
            gargs = args[:len(gather.plan_args)]
            rest = args[len(gather.plan_args):]
            feat = x_local.shape[1:]
            e_loc = num_experts // p
            if materialize == "dest":
                # one targeted delivery: owned tokens from x_local, foreign
                # tokens from the landed recv buffer, empty slots exactly 0
                vals = gather.local(x_local, *gargs)["slots"]
                return vals.reshape((e_loc, capacity) + feat)
            if self.strategy == "overlap":
                loc_l, rem_l, valid_l = rest
                handle = gather.start_local(x_local, *gargs)
                # own-token slots resolve from x_local while the exchange
                # flies; padding points at the zero slot appended here
                x_ext = jnp.concatenate(
                    [x_local, jnp.zeros((1,) + feat, x_local.dtype)])
                own = x_ext[loc_l]
                x_copy = handle.finish(extra_slots=1, copy_own=False)
                vals = own + x_copy[rem_l]   # each slot is own xor foreign
            else:
                idx_l, valid_l = rest
                x_copy = gather.local(x_local, *gargs)
                vals = x_copy[idx_l]
            mask = valid_l.reshape(valid_l.shape + (1,) * len(feat))
            buf = vals * mask.astype(vals.dtype)
            return buf.reshape((e_loc, capacity) + feat)

        in_specs = ((P(axis_name),) + gather.in_specs
                    + (P(axis_name),) * len(extra))
        mapped = compat.shard_map(
            step_local, mesh=mesh, in_specs=in_specs,
            out_specs=P(axis_name), check_vma=False)

        @jax.jit
        def dispatch(x):
            return mapped(x, *gather.plan_args, *self._extra_args)

        self._dispatch = dispatch

    @property
    def counts(self):
        return self.plan.counts

    def shard_tokens(self, x) -> jax.Array:
        return self.gather.shard_vector(x)

    def __call__(self, x: jax.Array) -> jax.Array:
        """x: (num_tokens, ...) sharded -> (num_experts, capacity, ...)
        expert input buffers, sharded over the expert dim."""
        return self._dispatch(x)


def moe_combine_ref(buf, idx, valid, w_slot, num_tokens: int):
    """NumPy ground truth for the combine: y[t] = Σ_slots→t w_slot * buf.

    ``buf``: (num_experts, capacity, ...) expert outputs; ``idx``/``valid``
    from ``moe_dispatch_pattern``; ``w_slot`` from ``moe_combine_weights``.
    """
    buf = np.asarray(buf)
    feat = buf.shape[2:]
    flat = buf.reshape((-1,) + feat)
    wshape = (-1,) + (1,) * len(feat)
    contrib = flat * (np.asarray(w_slot) * valid).reshape(wshape)
    y = np.zeros((num_tokens,) + feat, buf.dtype)
    np.add.at(y, np.asarray(idx), contrib.astype(buf.dtype))
    return y


class MoECombineScatter:
    """Weighted expert→token combine via ``repro.comm`` — the true inverse
    of ``MoEDispatchGather``.

    After the experts run, each (expert, capacity-slot) row holds the
    processed vector of the token that occupied it; the combine pushes
    ``w_slot * buf[e, c]`` back to that token and sums across a token's
    experts (``reduce="add"``) — what ``moe_fwd``'s ``combine_one`` vmap
    does *locally* inside one jitted forward.  On the cross-device serving
    path (experts sharded over ``axis_name``, tokens sharded over the same
    axis) this class replaces that local-only combine: the same
    ``AccessPattern`` that planned the dispatch gather plans the combine
    scatter — ``CommPlan.transpose()`` reuses the cached base plan, so the
    pair costs one O(nnz) preparation step total — and any ladder rung (or
    ``"auto"`` via the §5 put models) moves exactly the selected tokens'
    vectors back.

    Over-capacity (invalid) slots carry weight 0, so they contribute
    exactly nothing, matching ``moe_fwd``'s capacity-drop semantics.
    """

    def __init__(self, top_e, top_w, num_tokens: int, num_experts: int,
                 capacity: int, mesh, *, axis_name: str = "data",
                 strategy: str = "auto", blocksize=None,
                 shards_per_node=None, hw=None, use_plan_cache: bool = True):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.comm.pattern import AccessPattern
        from repro.comm.plan import Topology
        from repro.comm.scatter import IrregularScatter

        p = int(mesh.shape[axis_name])
        self.p = p
        self.num_tokens = num_tokens
        self.num_experts = num_experts
        self.capacity = capacity
        packed = _pack_slots(top_e, num_tokens, num_experts, capacity)
        idx, valid = moe_dispatch_pattern(
            top_e, num_tokens, num_experts, capacity, p, packed=packed)
        w_slot = moe_combine_weights(
            top_e, top_w, num_tokens, num_experts, capacity, packed=packed)
        self.idx, self.valid, self.w_slot = idx, valid, w_slot
        # same pattern as the dispatch gather: slot (e, c) touches its
        # token — pulled on dispatch, pushed on combine
        pattern = AccessPattern.from_indices(idx, n=num_tokens)
        self.scatter = IrregularScatter(
            pattern, mesh, axis_name=axis_name, strategy=strategy,
            blocksize=blocksize, reduce="add",
            topology=Topology(p, shards_per_node or p), hw=hw,
            use_plan_cache=use_plan_cache,
        )
        self.strategy = self.scatter.strategy
        self.requested_strategy = strategy
        self.predicted_times = self.scatter.predicted_times
        self.plan = self.scatter.plan
        self.splan = self.scatter.splan
        scatter = self.scatter

        shard = NamedSharding(mesh, P(axis_name))
        # invalid slots: weight 0 -> contribution exactly 0
        w_masked = (w_slot * valid).astype(np.float32)[:, None]
        self._w = jax.device_put(w_masked, shard)

        @jax.jit
        def combine(buf):
            flat = buf.reshape((num_experts * capacity, 1) + buf.shape[2:])
            w = self._w.reshape((num_experts * capacity, 1)
                                + (1,) * (buf.ndim - 2))
            return scatter(flat * w.astype(buf.dtype))

        self._combine = combine

    @property
    def counts(self):
        """Put-direction §5 volume counts of the combine exchange."""
        return self.splan.counts

    def shard_expert_buf(self, buf) -> jax.Array:
        """Place a host (num_experts, capacity, ...) buffer on the mesh,
        sharded over the expert dim."""
        return self.scatter.shard_vector(buf)

    def __call__(self, buf: jax.Array) -> jax.Array:
        """buf: (num_experts, capacity, ...) expert outputs sharded over
        the expert dim -> (num_tokens, ...) combined tokens, sharded."""
        return self._combine(buf)


# ---------------------------------------------------------------------------
# The fused serving-path layer: dispatch → expert → combine through ONE
# ExchangeSchedule (repro.comm.schedule) — one shard_map, one planned window
# ---------------------------------------------------------------------------


def moe_expert_local(buf, w1, w2, w3=None, act="gelu"):
    """Per-shard expert MLP: ``buf`` (E_loc, C, D) with this shard's expert
    weights ``w1`` (E_loc, D, F) / ``w2`` (E_loc, F, D) (and ``w3`` under
    swiglu).  Shared by ``MoELayer``'s compute stage and any composed
    baseline so the two paths run the identical local math."""
    w1 = w1.astype(buf.dtype)
    w2 = w2.astype(buf.dtype)
    h = jnp.einsum("ecd,edf->ecf", buf, w1)
    if act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf,
                                        w3.astype(buf.dtype))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w2)


class MoELayer:
    """Fused dispatch → expert MLP → combine via one ``ExchangeSchedule``.

    The composed serving path pays three windows: the
    ``MoEDispatchGather`` jit, the expert-MLP jit, the
    ``MoECombineScatter`` jit — each with its own dispatch overhead, and
    the middle one re-reading the landed expert buffers from HBM.
    ``MoELayer`` declares the whole chain as one ``Schedule``:

    * one gather stage (the token→expert ``Destination`` delivery of
      ``MoEDispatchGather``), one compute stage (``moe_expert_local`` +
      the combine-weight multiply), one scatter stage (the
      ``reduce="add"`` push of ``MoECombineScatter``);
    * both exchange stages share one base ``CommPlan`` (the combine's
      executor tables are the transpose-derived delta) and one
      hw-calibration memo hit;
    * ``compile`` emits a **single** ``shard_map``: the expert compute and
      the combine's own-shard accumulate run inside the scatter's
      collective window, and the fused window is priced by
      ``perfmodel.predict_schedule`` (``.predicted_window``).

    Bit-identical to the composed
    ``MoEDispatchGather → moe_expert_local → MoECombineScatter`` path on
    every ladder rung (tested in ``tests/test_schedule.py``).

    ``params``: ``{"w1": (E, D, F), "w2": (E, F, D)[, "w3": (E, D, F)]}``
    (the ``init_moe`` layout), sharded over the expert dim at compile.
    """

    def __init__(self, params, top_e, top_w, num_tokens: int,
                 num_experts: int, capacity: int, mesh, *,
                 axis_name: str = "data", act: str = "gelu",
                 strategy: str = "auto", blocksize=None,
                 shards_per_node=None, hw=None, use_plan_cache: bool = True):
        from repro.comm import AccessPattern, Destination, Schedule
        from repro.comm.plan import Topology

        p = int(mesh.shape[axis_name])
        assert num_experts % p == 0 and num_tokens % p == 0
        self.p = p
        self.num_tokens = num_tokens
        self.num_experts = num_experts
        self.capacity = capacity
        e_loc = num_experts // p
        d = params["w1"].shape[1]

        # one sort pipeline builds the dispatch pattern AND the combine
        # weights (the pair shares the packing, like the two front doors)
        packed = _pack_slots(top_e, num_tokens, num_experts, capacity)
        idx, valid = moe_dispatch_pattern(
            top_e, num_tokens, num_experts, capacity, p, packed=packed)
        w_slot = moe_combine_weights(
            top_e, top_w, num_tokens, num_experts, capacity, packed=packed)
        self.idx, self.valid, self.w_slot = idx, valid, w_slot
        pattern = AccessPattern.from_indices(idx, n=num_tokens)
        slot_idx = np.where(valid, idx.astype(np.int64), Destination.ZERO)
        destination = Destination.from_slots(slots=slot_idx.reshape(p, -1))
        # invalid (over-capacity) slots: weight 0 -> contribution exactly 0
        w_masked = (w_slot * valid).astype(np.float32)[:, None]

        sched = Schedule()
        x_ref = sched.input("tokens")
        w1 = sched.constant(np.asarray(params["w1"]), "w1")
        w2 = sched.constant(np.asarray(params["w2"]), "w2")
        wexperts = (w1, w2)
        if act == "swiglu":
            wexperts += (sched.constant(np.asarray(params["w3"]), "w3"),)
        wc = sched.constant(w_masked, "combine_w")
        g = sched.gather(pattern, src=x_ref, destination=destination,
                         name="dispatch")

        def expert_fn(delivered, *weights):
            *wx, wc_l = weights
            w3_l = wx[2] if len(wx) == 3 else None
            # tokens land in (expert, capacity) order; empty slots are
            # exactly 0 and carry combine weight 0
            buf = delivered["slots"].reshape(e_loc, capacity, d)
            out = moe_expert_local(buf, wx[0], wx[1], w3_l, act)
            flat = out.reshape(e_loc * capacity, 1, d)
            return flat * wc_l.reshape(
                e_loc * capacity, 1, 1).astype(flat.dtype)

        y = sched.compute(expert_fn, g, *wexperts, wc, name="expert")
        out = sched.scatter(pattern, y, reduce="add", name="combine")
        self.schedule = sched.compile(
            mesh, axis_name=axis_name, strategy=strategy,
            blocksize=blocksize, topology=Topology(p, shards_per_node or p),
            hw=hw, use_plan_cache=use_plan_cache, output=out)
        self.gather = sched.exchange_of(g)
        self.scatter = sched.exchange_of(out)
        self.requested_strategy = strategy
        self.strategies = self.schedule.strategies
        self.predicted_times = self.schedule.predicted_times
        self.predicted_window = self.schedule.predicted_window

    def shard_tokens(self, x) -> jax.Array:
        return self.schedule.shard_input(x)

    def __call__(self, x: jax.Array) -> jax.Array:
        """x: (num_tokens, d) sharded -> (num_tokens, d) combined expert
        outputs, sharded — the full dispatch→expert→combine step in one
        fused window."""
        return self.schedule(x)


# ---------------------------------------------------------------------------
# Per-batch routing: the DynamicPattern consumer (repro.comm.dynamic)
# ---------------------------------------------------------------------------


class DynamicMoELayer:
    """Per-batch routed dispatch → expert MLP → combine with ZERO host plan
    builds after warmup.

    ``MoELayer`` bakes one routing into its compiled window: a new routing
    means a new host ``CommPlan`` build, a new trace, a new compile — the
    §5 ``T_plan`` tax every batch.  ``DynamicMoELayer`` instead wraps one
    representative routing in a ``DynamicPattern``: the plan cache serves a
    capacity-bounded *envelope* plan (bucket-reused across compatible
    routings, ``plan_cache.get_envelope_plan``), and each batch's executor
    tables are re-derived **in-jit** from that batch's ``(top_e, top_w)``
    (``repro.comm.dynamic``) — one derivation pass feeds BOTH directions,
    the ``CommPlan.transpose()`` economy on device.  One jit serves every
    routing of the same shape; after the first call the only per-batch plan
    work is the traced derivation (telemetry source ``"device-derive"``).

    The per-call cost the auto ranking pays for this is
    ``perfmodel.plan_build_time(..., source="device-derive")``, threaded
    through ``select.rank_strategies(plan_cost=...)`` — exposed as
    ``.plan_time`` so consumers can ask ``replan_break_even_steps`` whether
    rebuilding a static ``MoELayer`` would ever pay off.

    Bit-identical to a freshly host-planned
    ``MoEDispatchGather(materialize="full") → moe_expert_local →
    MoECombineScatter`` per routing (tests/test_dynamic_pattern.py).

    ``params``: the ``init_moe`` layout (``w1``/``w2``[/``w3``]), sharded
    over the expert dim at construction.  ``top_e`` is a *template*
    routing (T, k) — only its shape and load envelope matter.
    """

    def __init__(self, params, top_e, num_tokens: int, num_experts: int,
                 capacity: int, mesh, *, axis_name: str = "data",
                 act: str = "gelu", strategy: str = "auto", blocksize=None,
                 shards_per_node=None, hw=None, use_plan_cache: bool = True,
                 s_max: int | None = None, decode: bool = False):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.comm import dynamic as dyn
        from repro.comm.exchange import measure_hw
        from repro.comm.gather import IrregularGather
        from repro.comm.pattern import AccessPattern
        from repro.comm.plan import Topology
        from repro.comm.scatter import IrregularScatter
        from repro.core import perfmodel

        p = int(mesh.shape[axis_name])
        assert num_experts % p == 0 and num_tokens % p == 0
        self.p = p
        self.num_tokens = num_tokens
        self.num_experts = num_experts
        self.capacity = capacity
        t_loc, e_loc = num_tokens // p, num_experts // p
        d = params["w1"].shape[1]
        k = np.asarray(top_e).shape[1]
        self.k = k
        m = num_experts * capacity

        # the template routing founds the envelope plan; every later batch
        # reuses it (memory/bucket tier) and re-derives tables on device
        idx, _ = moe_dispatch_pattern(
            top_e, num_tokens, num_experts, capacity, p)
        template = AccessPattern.from_indices(idx, n=num_tokens)
        self.pattern = dyn.DynamicPattern.from_template(
            template, p, s_max=s_max)

        if hw is None:
            hw = measure_hw(mesh, axis_name)
        # the per-batch T_plan this layer actually pays: the traced
        # derivation sort, not a host build
        self.plan_time = perfmodel.plan_build_time(
            m, 1, hw, source="device-derive")
        topo = Topology(p, shards_per_node or p)
        gather = IrregularGather(
            self.pattern, mesh, axis_name=axis_name, strategy=strategy,
            blocksize=blocksize, topology=topo, hw=hw,
            use_plan_cache=use_plan_cache, plan_cost=self.plan_time,
            decode=decode)
        scatter = IrregularScatter(
            self.pattern, mesh, axis_name=axis_name, strategy=strategy,
            reduce="add", blocksize=blocksize, topology=topo, hw=hw,
            use_plan_cache=use_plan_cache, plan_cost=self.plan_time,
            decode=decode)
        self.gather, self.scatter = gather, scatter
        self.decode = decode
        self.strategies = {"dispatch": gather.strategy,
                           "combine": scatter.strategy}
        self.predicted_times = {"dispatch": gather.predicted_times,
                                "combine": scatter.predicted_times}
        self.requested_strategy = strategy

        shard = NamedSharding(mesh, P(axis_name))
        wlist = [np.asarray(params["w1"]), np.asarray(params["w2"])]
        if act == "swiglu":
            wlist.append(np.asarray(params["w3"]))
        self._weights = tuple(jax.device_put(w, shard) for w in wlist)
        # empty-slot pad: an owned token id per expert shard (zero-cost)
        own_token = jnp.asarray(np.repeat(
            np.arange(p, dtype=np.int32) * t_loc, e_loc * capacity))

        n, e, c, t = num_tokens, num_experts, capacity, num_tokens
        s_max_r = self.pattern.s_max

        def pack(top_e_d, top_w_d):
            # the in-jit twin of _pack_slots + moe_dispatch_pattern +
            # moe_combine_weights: same stable sort, same capacity drop,
            # same owned-token padding — bit-identical slot tables
            flat_e = top_e_d.reshape(t * k).astype(jnp.int32)
            flat_w = top_w_d.reshape(t * k)
            sort_idx = jnp.argsort(flat_e)                    # stable
            se = flat_e[sort_idx]
            counts = jax.nn.one_hot(flat_e, e, dtype=jnp.int32).sum(axis=0)
            seg_start = jnp.cumsum(counts) - counts
            pos = jnp.arange(t * k) - seg_start[se]
            keep = pos < c
            dest = jnp.where(keep, se * c + pos, e * c)       # dump slot
            tok = (sort_idx // k).astype(jnp.int32)
            sw = flat_w[sort_idx].astype(jnp.float32)
            valid = jnp.zeros((e * c + 1,), bool).at[dest].set(True)[:e * c]
            slot_tok = jnp.zeros((e * c + 1,),
                                 jnp.int32).at[dest].set(tok)[:e * c]
            w_slot = jnp.zeros((e * c + 1,),
                               jnp.float32).at[dest].set(sw)[:e * c]
            cols = jnp.where(valid, slot_tok, own_token)
            return cols, w_slot           # w_slot is 0 at invalid slots

        ng, ns = len(gather.in_specs), len(scatter.in_specs)

        def step_local(x_local, *args):
            gargs = args[:ng]
            sargs = args[ng:ng + ns]
            cols_l, w_l = args[ng + ns], args[ng + ns + 1]
            wx = args[ng + ns + 2:]
            x_copy = gather.local(x_local, *gargs)
            buf = x_copy[cols_l].reshape(e_loc, capacity, d)
            w3_l = wx[2] if len(wx) == 3 else None
            out = moe_expert_local(buf, wx[0], wx[1], w3_l, act)
            flat = out.reshape(e_loc * capacity, 1, d)
            contrib = flat * w_l.reshape(
                e_loc * capacity, 1, 1).astype(flat.dtype)
            return scatter.local(contrib, *sargs)

        in_specs = ((P(axis_name),) + gather.in_specs + scatter.in_specs
                    + (P(axis_name), P(axis_name))
                    + (P(axis_name),) * len(self._weights))
        mapped = compat.shard_map(
            step_local, mesh=mesh, in_specs=in_specs,
            out_specs=P(axis_name), check_vma=False)
        weights_dev = self._weights

        def routed_step(x, top_e_d, top_w_d, wx):
            cols, w_slot = pack(top_e_d, top_w_d)
            cols2 = cols.reshape(-1, 1)
            # ONE derivation pass serves both directions (the transpose
            # economy, in-jit): the gather tables seed the scatter derive
            g = dyn.derive_gather_tables(cols2, n, p, s_max_r)
            gargs = (g.send_local_idx, g.recv_global_idx)
            sargs = scatter.derive_plan_args(cols2, gather_tables=g)
            return mapped(x, *gargs, *sargs, cols, w_slot, *wx)

        self._routed_step = routed_step

        @jax.jit
        def fwd(x, top_e_d, top_w_d):
            return routed_step(x, top_e_d, top_w_d, weights_dev)

        self._fwd = fwd

    def shard_tokens(self, x) -> jax.Array:
        return self.gather.shard_vector(x)

    def apply(self, x: jax.Array, top_e, top_w, *weights) -> jax.Array:
        """One routed step with the expert weights passed PER CALL (traced)
        instead of baked at construction — the embeddable twin of
        ``__call__`` for consumers that already sit inside a jit, e.g. the
        transformer decode step scanning over its layer stack: one layer
        instance (template shapes) serves every scanned layer, each
        supplying its own traced ``w1, w2[, w3]`` slices.

        Same shard_map window, same in-jit derivation, same math as
        ``__call__``.  No telemetry is recorded here (this runs under the
        caller's trace); the caller records one ``"device-derive"`` per
        *executed* step host-side — ``repro.serve.engine`` does this per
        decode tick."""
        if len(weights) != len(self._weights):
            raise ValueError(
                f"expected {len(self._weights)} expert weight arrays "
                f"(w1, w2{', w3' if len(self._weights) == 3 else ''}), "
                f"got {len(weights)}")
        return self._routed_step(x, jnp.asarray(top_e), jnp.asarray(top_w),
                                 tuple(weights))

    def __call__(self, x: jax.Array, top_e, top_w) -> jax.Array:
        """One routed step: x (num_tokens, d) sharded + THIS batch's
        routing (T, k) -> (num_tokens, d) combined expert outputs.

        No host plan work happens here — the tables come from the traced
        derivation (recorded per call as ``"device-derive"``; the trace
        itself compiles once for all routings of this shape)."""
        from repro.comm import telemetry
        telemetry.record("device-derive")
        return self._fwd(x, jnp.asarray(top_e), jnp.asarray(top_w))
