"""Mixture-of-Experts block with the paper's communication-strategy ladder.

Token->expert routing is the LM-scale instance of the paper's fine-grained
irregular communication: each token (array element) must reach the shard
owning its expert (owner thread).  Following DESIGN.md §4:

* ``tp_local``  — experts are *weight-sharded* over the model axis (tensor
  parallel); tokens never move.  The analogue of the paper's single-node
  case where no remote transfers exist (natural for few-expert models:
  mixtral's 8 experts < 16-way model axis).
* ``ep_a2a``    — experts are sharded over the model axis (expert parallel);
  tokens are *sort-packed* into per-expert capacity-bounded buffers —
  message condensing (only selected tokens move) and consolidation (one
  buffer per expert) with a static capacity bound standing in for the
  paper's one-time plan, as XLA's static shapes require.  The resharding of
  the packed buffer is where GSPMD materializes the all-to-all.

Dispatch is computed per data-parallel group (the ``G`` leading dim) so no
collective sort is ever needed — the paper's per-thread preparation step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear

__all__ = ["init_moe", "moe_fwd", "moe_capacity"]


def init_moe(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "router": init_linear(ks[0], d, e, dtype=dtype),
        "w1": jax.random.normal(ks[1], (e, d, f), dtype) * scale,
        "w2": jax.random.normal(ks[2], (e, f, d), dtype) * (f ** -0.5),
    }
    if cfg.act == "swiglu":
        p["w3"] = jax.random.normal(ks[3], (e, d, f), dtype) * scale
    return p


def moe_capacity(tokens_per_group: int, cfg) -> int:
    c = math.ceil(
        tokens_per_group * cfg.experts_per_token / cfg.num_experts
        * cfg.capacity_factor
    )
    return max(8, -(-c // 8) * 8)  # round up to 8


def _expert_mlp(p, buf, act):
    """buf: (G, E, C, D) -> (G, E, C, D)."""
    w1 = p["w1"].astype(buf.dtype)
    w2 = p["w2"].astype(buf.dtype)
    h = jnp.einsum("gecd,edf->gecf", buf, w1)
    if act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum(
            "gecd,edf->gecf", buf, p["w3"].astype(buf.dtype))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("gecf,efd->gecd", h, w2)


def moe_fwd(p, x, cfg, *, constrain=None, aux=None):
    """x: (G, T, D) tokens grouped by data-parallel rank.

    ``constrain``: optional fn(array, stage) -> array applying sharding
    constraints; stage in {"dispatch", "expert"} (runtime/sharding.py).
    ``aux``: optional dict populated with the load-balancing loss.
    """
    g, t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = moe_capacity(t, cfg)

    logits = jnp.einsum(
        "gtd,de->gte", x, p["router"]["w"].astype(x.dtype)
    ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)               # (G, T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                # (G, T, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    if aux is not None:
        # Switch-style load-balance loss: E * mean(frac_tokens * frac_prob)
        me = probs.mean(axis=1)                           # (G, E)
        ce = jax.nn.one_hot(top_e[..., 0], e).mean(axis=1)
        aux["moe_loss"] = (e * (me * ce).sum(-1)).mean()

    # ---- condensed dispatch: sort tokens by expert, pack to capacity ----
    flat_e = top_e.reshape(g, t * k)
    flat_w = top_p.reshape(g, t * k)
    sort_idx = jnp.argsort(flat_e, axis=-1)               # (G, T*k) stable
    se = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    counts = jax.nn.one_hot(flat_e, e, dtype=jnp.int32).sum(axis=1)  # (G, E)
    seg_start = jnp.cumsum(counts, axis=-1) - counts      # exclusive
    pos = jnp.arange(t * k)[None] - jnp.take_along_axis(seg_start, se, axis=-1)
    keep = pos < c
    dest = jnp.where(keep, se * c + pos, e * c)           # dump slot
    tok = sort_idx // k

    gather_tok = jnp.take_along_axis(x, tok[..., None], axis=1)  # (G,T*k,D)

    def scatter_one(vals, dst):
        buf = jnp.zeros((e * c + 1, d), vals.dtype)
        return buf.at[dst].set(vals)[: e * c]

    buf = jax.vmap(scatter_one)(gather_tok, dest).reshape(g, e, c, d)
    if constrain is not None:
        buf = constrain(buf, "expert")                    # -> a2a under EP

    out_buf = _expert_mlp(p, buf, cfg.act)                # (G, E, C, D)
    if constrain is not None:
        out_buf = constrain(out_buf, "dispatch")          # -> back to dp

    flat_out = jnp.concatenate(
        [out_buf.reshape(g, e * c, d),
         jnp.zeros((g, 1, d), out_buf.dtype)], axis=1)
    y_sorted = jnp.take_along_axis(flat_out, dest[..., None], axis=1)
    w_sorted = jnp.take_along_axis(flat_w, sort_idx, axis=-1)
    y_sorted = y_sorted * (w_sorted * keep)[..., None].astype(y_sorted.dtype)

    def combine_one(ys, tk):
        return jnp.zeros((t, d), ys.dtype).at[tk].add(ys)

    return jax.vmap(combine_one)(y_sorted, tok)           # (G, T, D)
