"""Shared neural building blocks (pure-functional, params = nested dicts).

Everything is written against abstract shapes so the whole zoo can be
initialized under ``jax.eval_shape`` for the dry-run (no allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "init_linear", "linear", "init_norm", "norm_apply", "rope",
    "attention", "init_attention", "attention_fwd", "mlp_fwd", "init_mlp",
]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def init_linear(key, d_in, d_out, *, bias=False, dtype=jnp.float32, scale=None):
    if scale is None:
        scale = d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_norm(key, d, *, kind="rmsnorm", dtype=jnp.float32):
    del key
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, *, kind="rmsnorm", eps=1e-5):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, *, theta=1e4):
    """x: (..., S, H, D). positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]   # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _dense_attention(q, k, v, *, causal, window, q_pos0=0, kv_pos0=0,
                     kv_len=None):
    """q: (B, Sq, Hkv, G, D); k/v: (B, Skv, Hkv, D). f32 softmax."""
    d = q.shape[-1]
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d ** -0.5)
    sq, sk = q.shape[1], k.shape[1]
    qi = q_pos0 + jnp.arange(sq)[:, None]
    ki = kv_pos0 + jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= ki > qi - window
    if kv_len is not None:  # decode: only positions < kv_len are valid
        mask &= ki < kv_len
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _flash_attention(q, k, v, *, causal, window, q_chunk=1024, kv_chunk=1024):
    """Memory-bounded attention: scan over q chunks (outer) and kv chunks
    (inner) with running log-sum-exp — the flash algorithm in lax.scan form.

    Fully-masked kv chunks are skipped *statically is impossible* under scan;
    they are computed and masked (counted as waste in useful_flops_ratio; see
    EXPERIMENTS.md §Perf for the prefill optimization that removes it).
    """
    b, sq, hkv, g, d = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    assert sq % q_chunk == 0 and sk % kv_chunk == 0
    scale = d ** -0.5

    qs = q.reshape(b, nq, q_chunk, hkv, g, d).astype(jnp.float32)
    ks = k.reshape(b, nk, kv_chunk, hkv, d).astype(jnp.float32)
    vs = v.reshape(b, nk, kv_chunk, hkv, d).astype(jnp.float32)

    def q_body(_, qi_and_idx):
        qc, iq = qi_and_idx  # (b, qc, hkv, g, d)
        m0 = jnp.full((b, hkv, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        acc0 = jnp.zeros((b, q_chunk, hkv, g, d), jnp.float32)

        def kv_body(carry, kc_vc_idx):
            m, l, acc = carry
            kc, vc, ik = kc_vc_idx
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc) * scale
            qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
                "bhgqk,bkhd->bqhgd", p, vc
            )
            return (m_new, l_new, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, acc0),
            (ks.swapaxes(0, 1), vs.swapaxes(0, 1), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, out

    _, outs = jax.lax.scan(
        q_body, None, (qs.swapaxes(0, 1), jnp.arange(nq))
    )  # (nq, b, qc, hkv, g, d)
    out = outs.swapaxes(0, 1).reshape(b, sq, hkv, g, d)
    return out.astype(q.dtype)


def _swa_banded_attention(q, k, v, *, window, q_chunk=2048):
    """Sliding-window attention that only touches the diagonal band.

    Every q chunk attends a (q_chunk + window)-wide kv band sliced around
    the diagonal — the compute/memory-optimal schedule for SWA (the dense
    flash path wastes O(S/window) work on fully-masked chunks; see
    EXPERIMENTS.md §Perf cell C).  q: (B, S, Hkv, G, D); k/v: (B, S, Hkv, D).
    """
    b, sq, hkv, g, d = q.shape
    q_chunk = min(q_chunk, sq)
    band = min(q_chunk + window, sq)
    nq = sq // q_chunk
    scale = d ** -0.5

    def body(_, iq):
        qc = jax.lax.dynamic_slice_in_dim(
            q, iq * q_chunk, q_chunk, 1).astype(jnp.float32)
        start = jnp.clip(iq * q_chunk - window, 0, sq - band)
        kc = jax.lax.dynamic_slice_in_dim(k, start, band, 1).astype(
            jnp.float32)
        vc = jax.lax.dynamic_slice_in_dim(v, start, band, 1).astype(
            jnp.float32)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc) * scale
        qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None]
        kpos = start + jnp.arange(band)[None, :]
        mask = (kpos <= qpos) & (kpos > qpos - window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w, vc)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(body, None, jnp.arange(nq))
    return outs.swapaxes(0, 1).reshape(b, sq, hkv, g, d)


def attention(q, k, v, *, causal=True, window=0, q_pos0=0, kv_len=None,
              flash_threshold=4096):
    """GQA attention. q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    self_attn = sq == k.shape[1] and kv_len is None
    use_banded = (
        causal and window and self_attn and sq > 2 * window
        and sq % min(2048, sq) == 0
    )
    use_flash = (
        sq > 1 and (sq * k.shape[1] > flash_threshold * flash_threshold // 4)
        and sq % 512 == 0 and k.shape[1] % 512 == 0 and kv_len is None
    )
    if use_banded:
        out = _swa_banded_attention(qg, k, v, window=window,
                                    q_chunk=min(2048, sq))
    elif use_flash:
        out = _flash_attention(qg, k, v, causal=causal, window=window,
                               q_chunk=min(2048, sq), kv_chunk=min(1024, k.shape[1]))
    else:
        out = _dense_attention(qg, k, v, causal=causal, window=window,
                               q_pos0=q_pos0, kv_len=kv_len)
    return out.reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# attention block (params + forward)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, *, d_model=None, dtype=jnp.float32):
    d = d_model or cfg.d_model
    hd, h, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], h * hd, d, dtype=dtype),
    }


def attention_fwd(p, x, cfg, *, kv_x=None, positions=None, causal=True,
                  window=0, cache=None, cache_pos=None, use_rope=True):
    """Self- or cross-attention.  ``cache``: optional dict {k, v} with
    (B, Smax, Hkv, D) buffers for decode; ``cache_pos``: current length."""
    b, sq, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    q = linear(p["wq"], x).reshape(b, sq, h, hd)
    k = linear(p["wk"], src).reshape(b, src.shape[1], hkv, hd)
    v = linear(p["wv"], src).reshape(b, src.shape[1], hkv, hd)

    if positions is None:
        positions = jnp.arange(sq)[None, :]
    if use_rope and kv_x is None:
        q = rope(q, positions, theta=cfg.rope_theta)
        k = rope(k, positions, theta=cfg.rope_theta)

    if cache is not None:
        # decode: write new k/v at cache_pos, attend over the whole buffer
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        out = attention(q, ck, cv, causal=False, window=window,
                        q_pos0=cache_pos, kv_len=cache_pos + sq)
        new_cache = {"k": ck, "v": cv}
    else:
        out = attention(q, k, v, causal=causal and kv_x is None, window=window)
        new_cache = None

    y = linear(p["wo"], out.reshape(b, sq, h * hd))
    return (y, new_cache) if cache is not None else y


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d, f, *, act="swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w1": init_linear(ks[0], d, f, dtype=dtype),
         "w2": init_linear(ks[1], f, d, dtype=dtype)}
    if act == "swiglu":
        p["w3"] = init_linear(ks[2], d, f, dtype=dtype)
    return p


def mlp_fwd(p, x, *, act="swiglu"):
    h = linear(p["w1"], x)
    if act == "swiglu":
        h = jax.nn.silu(h) * linear(p["w3"], x)
    else:
        h = jax.nn.gelu(h)
    return linear(p["w2"], h)
