"""Sharding-aware checkpointing with async commit and elastic restore.

Layout per step:
    <dir>/step_<N>.tmp/   -> written first
        arrays.npz        -> flattened pytree ("path/to/leaf" -> ndarray)
        manifest.json     -> step, tree structure, data-pipeline state
    <dir>/step_<N>/       -> atomic rename on completion (commit point)

Fault-tolerance properties (DESIGN.md §7):
  * crash mid-write never corrupts the latest checkpoint (tmp + rename);
  * ``restore`` takes target shardings for the *current* mesh — restoring a
    checkpoint written on a different device count / mesh shape re-shards
    transparently (elastic restart);
  * ``save(..., blocking=False)`` snapshots to host then commits on a
    background thread, overlapping I/O with the next train steps.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_SEP = "||"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(jax.tree_util.keystr((k,))) for k in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None,
         blocking: bool = True) -> threading.Thread | None:
    """Snapshot ``tree`` (host copy) and commit atomically."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, _ = _flatten(tree)  # host snapshot happens HERE, synchronously
    manifest = {"step": int(step), "keys": sorted(arrays),
                "extra": extra or {}}

    def commit():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        commit()
        return None
    t = threading.Thread(target=commit, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, *, shardings=None):
    """Restore into the structure of ``target_tree``; if ``shardings`` (a
    matching pytree of jax.sharding.Sharding) is given, arrays are placed
    sharded — this is the elastic-restart path (any mesh, any device count).
    Returns (tree, extra)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    out = []
    for (p, leaf), sh in zip(flat, shard_flat):
        key = _SEP.join(str(jax.tree_util.keystr((k,))) for k in p)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"target {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out), manifest.get("extra", {})


class CheckpointManager:
    """keep_last-N manager with async commit and auto-resume."""

    def __init__(self, ckpt_dir: str, *, keep_last: int = 3,
                 save_every: int = 100):
        self.dir = ckpt_dir
        self.keep_last = keep_last
        self.save_every = save_every
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, tree, *, extra=None, force=False):
        if not force and (step == 0 or step % self.save_every):
            return False
        self.wait()
        self._pending = save(self.dir, step, tree, extra=extra,
                             blocking=False)
        self._gc(step)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self, newest: int):
        if not os.path.isdir(self.dir):
            return
        steps = {
            int(m.group(1)) for name in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)", name))}
        steps.add(newest)  # the async commit may not have landed yet
        for s in sorted(steps)[:-self.keep_last]:
            if s != newest:
                shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                              ignore_errors=True)

    def restore_latest(self, target_tree, *, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, None, None
        tree, extra = restore(self.dir, step, target_tree,
                              shardings=shardings)
        return step, tree, extra
