"""Train / serve step builders: the jit boundary of the framework.

``build_train_step`` returns (step_fn, state_specs) where step_fn is jittable
with donated state; ``build_decode_step`` / ``build_prefill`` cover serving.
All functions work both concrete (examples, tests) and abstract (dry-run via
ShapeDtypeStruct) — nothing here allocates.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import Model, RunCtx, lm_loss
from repro.optim.adamw import AdamW

__all__ = ["TrainState", "build_train_step", "build_decode_step",
           "build_prefill", "model_flops"]


def build_train_step(model: Model, opt: AdamW, *, accum_steps: int = 1,
                     grad_shardings=None):
    """(params, opt_state, batch, extra) -> (params, opt_state, metrics).

    ``batch`` = (tokens, labels) with shape (B, S); grad accumulation splits
    B into ``accum_steps`` microbatches scanned sequentially (overlaps the
    per-microbatch DP reduction with compute under XLA's scheduler).

    ``grad_shardings``: optional pytree of NamedSharding matching params —
    gradients are constrained to the param layout right out of backward,
    which keeps the (param-sized, f32) cotangents from materializing
    replicated (a 16x memory regression observed without it).
    """

    def loss_fn(params, tokens, labels, extra):
        return model.loss(params, tokens, labels, extra=extra)

    def constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def step(params, opt_state, batch, extra=None):
        tokens, labels = batch
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                params, tokens, labels, extra)
            grads = constrain_grads(grads)
        else:
            b = tokens.shape[0]
            mb = b // accum_steps
            tk = tokens.reshape(accum_steps, mb, -1)
            lb = labels.reshape(accum_steps, mb, -1)
            ex = (None if extra is None else jax.tree.map(
                lambda a: a.reshape(accum_steps, mb, *a.shape[1:]), extra))

            def body(carry, xs):
                acc, lsum = carry
                t, l, e = xs
                loss_i, g_i = jax.value_and_grad(loss_fn)(
                    params, t, l, e)
                g_i = constrain_grads(g_i)
                acc = jax.tree.map(jnp.add, acc, g_i)
                return (acc, lsum + loss_i), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), (tk, lb, ex))
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = lsum / accum_steps

        new_params, new_opt, gnorm = opt.apply(params, grads, opt_state)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": gnorm.astype(jnp.float32)}
        return new_params, new_opt, metrics

    return step


def build_decode_step(model: Model):
    def step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return step


def build_prefill(model: Model, *, fill_cache: bool = False):
    """Inference prefill: forward over the prompt; the head matmul runs on
    the last position only (next-token logits), as real serving does.

    Default (``fill_cache=False``): the benchmark-cell forward — measures
    the prompt-processing compute/comm, discards the KV.

    ``fill_cache=True``: the serving prefill — returns
    ``step(params, cache, tokens) -> (last_logits, new_cache)``, the fused
    ``Model.prefill`` that also writes the prompt's K/V into the decode
    cache (chunked prefill = consecutive calls).  This is the production
    replacement for the sequential decode_step scan
    (``launch.serve.prefill_into_cache``, kept as the test oracle)."""
    if fill_cache:
        def fill_step(params, cache, tokens):
            return model.prefill(params, cache, tokens)

        return fill_step

    def step(params, tokens, extra=None):
        return model.forward(params, tokens, extra=extra, last_only=True)

    return step


def model_flops(cfg, *, mode: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS per step: 6·N_active·tokens (train) / 2·N_active·tokens
    (inference); N excludes the embedding gather, and the head matmul is
    added for the positions whose logits are actually computed."""
    n = cfg.flops_param_count()
    tokens = batch * (seq if mode in ("train", "prefill") else 1)
    mult = 6.0 if mode == "train" else 2.0
    head = 2.0 * cfg.d_model * cfg.vocab_size
    head_tokens = tokens if mode == "train" else batch  # last-only otherwise
    return mult * n * tokens + (3.0 if mode == "train" else 1.0) \
        * head * head_tokens
