"""Sharding rules: param/activation/cache PartitionSpecs for every family.

Principles (DESIGN.md §6):
  * train: FSDP over the data axes (("pod","data") when multi-pod) + tensor
    parallel over "model"; every 2D weight is sharded on both of its dims.
  * serve: params sharded over "model"; additionally over the data axes
    (ZeRO-inference) when the per-device residency would not fit otherwise.
  * decode KV caches: batch over data axes, sequence over "model"
    (flash-decoding layout — the only layout divisible for GQA kv_heads <
    model-axis size).
  * every spec passes through ``fit_spec`` which *drops* axes that do not
    divide the dimension — replication instead of a compile error, and the
    drop is logged so the roofline table can attribute the cost.

The rules are name-pattern based on the param-tree path, with any number of
stacked leading scan dims (layers / vlm groups) automatically skipped.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "fit_spec", "param_shardings", "batch_sharding",
           "cache_shardings", "make_constrain"]


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def fit_spec(mesh: Mesh, shape, spec: P) -> P:
    """Drop spec axes that don't divide their dim (replicate instead)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, parts):
        if axes is not None and dim % _axes_size(mesh, axes) != 0:
            axes = None
        out.append(axes)
    return P(*out)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    fsdp_axes: Any            # e.g. ("pod","data") or "data" or None
    tp_axis: str = "model"
    ep_mode: bool = False     # expert-parallel MoE (experts on tp axis)
    seq_parallel: bool = False  # shard residual-stream seq dim over tp_axis
    # (Megatron-SP: activations at rest are 1/tp the size; XLA swaps the
    # block all-reduce for all-gather + reduce-scatter at equal bytes)
    opt_fsdp_axes: Any = None   # optional distinct FSDP axes for optimizer
    # state (master/m/v): e.g. params gather pod-locally over "data" while
    # the 4x-larger optimizer state spreads over ("pod","data") — per-layer
    # weight gathers then never cross the DCI (hierarchical ZeRO)
    ep_axes: Any = None         # expert-parallel axes (default: tp_axis);
    # e.g. ("pod","model") spreads 128 experts over 32 shards, halving the
    # per-device expert-weight gather volume

    @property
    def dp_axes(self):
        """axes that shard the batch — always the full data parallelism
        (pod+data when multi-pod), independent of how far the *weights*
        spread (fsdp_axes)."""
        return ("pod", "data") if "pod" in self.mesh.shape else "data"

    # ---- rule table: pattern over the LAST dims of the param ----
    def _rules(self):
        fs, tp = self.fsdp_axes, self.tp_axis
        ep = self.ep_axes if self.ep_axes is not None else tp
        # fsdp axes used on expert weights must not collide with ep axes
        ep_set = {ep} if isinstance(ep, str) else set(ep)
        fs_moe = fs
        if fs is not None and not isinstance(fs, str):
            fs_moe = tuple(a for a in fs if a not in ep_set) or None
        elif isinstance(fs, str) and fs in ep_set:
            fs_moe = None
        moe_w1 = (P(ep, fs_moe, None) if self.ep_mode else P(None, fs, tp))
        moe_w2 = (P(ep, None, fs_moe) if self.ep_mode else P(None, tp, fs))
        return [
            (r"embed.*\['w'\]", P(tp, fs)),           # (V, D) vocab-sharded
            (r"lm_head.*\['w'\]", P(fs, tp)),         # (D, V)
            (r"\['moe'\].*\['w1'\]", moe_w1),         # (E, D, F)
            (r"\['moe'\].*\['w3'\]", moe_w1),
            (r"\['moe'\].*\['w2'\]", moe_w2),         # (E, F, D)
            (r"\['router'\].*\['w'\]", P(fs, None)),  # (D, E)
            (r"\['(wq|wk|wv)'\].*\['w'\]", P(fs, tp)),
            (r"\['(wq|wk|wv)'\].*\['b'\]", P(tp)),
            (r"\['wo'\]\['w'\]", P(tp, fs)),
            (r"\['w1'\]\['w'\]", P(fs, tp)),          # mlp (D, F)
            (r"\['w3'\]\['w'\]", P(fs, tp)),
            (r"\['w2'\]\['w'\]", P(tp, fs)),          # mlp (F, D)
            (r"\['in_proj'\]\['w'\]", P(fs, tp)),     # ssm (D, 2di)
            (r"\['conv_w'\]", P(None, tp)),           # (K, di)
            (r"\['conv_b'\]", P(tp)),
            (r"\['x_proj'\]\['w'\]", P(tp, None)),    # (di, dr+2st)
            (r"\['dt_proj'\]\['w'\]", P(None, tp)),   # (dr, di)
            (r"\['dt_proj'\]\['b'\]", P(tp)),
            (r"\['a_log'\]", P(tp, None)),            # (di, st)
            (r"\['d_skip'\]", P(tp)),
            (r"\['out_proj'\]\['w'\]", P(tp, fs)),    # (di, D)
        ]

    def spec_for(self, path_str: str, shape) -> P:
        for pat, rule in self._rules():
            if re.search(pat, path_str):
                lead = len(shape) - len(rule)
                spec = P(*([None] * lead), *rule)
                return fit_spec(self.mesh, shape, spec)
        return P()  # norms, biases, scalars: replicate


def param_shardings(rules: ShardingRules, params_shapes):
    """Pytree of NamedSharding matching an eval_shape'd param tree.

    When ``opt_fsdp_axes`` is set, leaves under an optimizer-state subtree
    (path contains 'master'/'m'/'v') use those axes instead (hierarchical
    ZeRO: optimizer spreads wider than the compute copy)."""
    opt_rules = (dataclasses.replace(rules, fsdp_axes=rules.opt_fsdp_axes)
                 if rules.opt_fsdp_axes is not None else None)

    def one(path, leaf):
        pstr = "".join(str(jax.tree_util.keystr((k,))) for k in path)
        r = rules
        if opt_rules is not None and re.match(
                r"^\['(master|m|v)'\]", pstr):
            r = opt_rules
        spec = r.spec_for(pstr, leaf.shape)
        return NamedSharding(rules.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def batch_sharding(rules: ShardingRules, shape):
    spec = fit_spec(rules.mesh, shape, P(rules.dp_axes))
    return NamedSharding(rules.mesh, spec)


def cache_shardings(rules: ShardingRules, cache_shapes):
    """Decode caches: batch->dp, seq->tp (flash-decoding layout); SSM state
    channel dim -> tp."""
    mesh, dp, tp = rules.mesh, rules.dp_axes, rules.tp_axis

    def one(path, leaf):
        pstr = "".join(str(jax.tree_util.keystr((k,))) for k in path)
        nd = len(leaf.shape)
        if re.search(r"\['(k|v|cross_k|cross_v)'\]", pstr):
            # (..., B, S, H, hd)
            spec = P(*([None] * (nd - 4)), dp, tp, None, None)
        elif re.search(r"\['h'\]", pstr):       # ssm state (..., B, di, st)
            spec = P(*([None] * (nd - 3)), dp, tp, None)
        elif re.search(r"\['conv'\]", pstr):    # (..., B, K-1, di)
            spec = P(*([None] * (nd - 3)), dp, None, tp)
        elif re.search(r"\['slot_pos'\]", pstr):  # (..., S)
            spec = P(*([None] * (nd - 1)), tp)
        else:                                   # pos scalar etc.
            spec = P()
        return NamedSharding(mesh, fit_spec(mesh, leaf.shape, spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def make_constrain(rules: ShardingRules):
    """RunCtx constraint callback: tag -> with_sharding_constraint."""
    mesh, dp, tp = rules.mesh, rules.dp_axes, rules.tp_axis

    def constrain(x, tag):
        nd = x.ndim
        if tag == "act":          # (B, S, D)
            if rules.seq_parallel and nd >= 3:
                spec = P(dp, tp, *([None] * (nd - 2)))
            else:
                spec = P(dp, *([None] * (nd - 1)))
        elif tag == "logits":     # (B, S, V)
            spec = P(dp, *([None] * (nd - 2)), tp)
        elif tag == "expert":     # moe buffer (G, E, C, D)
            if rules.ep_mode:
                ep = rules.ep_axes if rules.ep_axes is not None else tp
                ep_set = {ep} if isinstance(ep, str) else set(ep)
                g_axes = tuple(a for a in
                               ((dp,) if isinstance(dp, str) else dp)
                               if a not in ep_set) or None
                spec = P(g_axes, ep, *([None] * (nd - 2)))
            else:
                spec = P(dp, *([None] * (nd - 1)))
        elif tag == "dispatch":   # moe buffer back on dp
            spec = P(dp, *([None] * (nd - 1)))
        else:
            return x
        spec = fit_spec(mesh, x.shape, spec)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain
