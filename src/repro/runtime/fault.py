"""Fault-tolerance utilities for the train/serve drivers (DESIGN.md §7).

The failure model at 1000+ nodes: (a) hard node loss -> process dies ->
relaunch resumes from the last committed checkpoint, possibly on a smaller
mesh (elastic); (b) transient step failure (preemption notice, flaky
collective) -> retry the step; (c) stragglers -> bulk-synchronous steps bound
blast radius to one collective; we detect persistent stragglers host-side
from step-time z-scores and surface them for re-slicing.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import numpy as np

log = logging.getLogger("repro.fault")

__all__ = ["StragglerWatch", "retrying", "StepTimer"]


@dataclasses.dataclass
class StragglerWatch:
    """Host-side per-step wall-time watchdog.

    A step slower than mean + z_thresh * std (over a sliding window) is
    flagged; ``persistent`` trips after ``patience`` consecutive flags — the
    driver's cue to checkpoint and re-slice away from the slow node.
    """

    window: int = 50
    z_thresh: float = 4.0
    patience: int = 3

    def __post_init__(self):
        self._times: list[float] = []
        self._consecutive = 0

    def observe(self, dt: float) -> bool:
        flagged = False
        hist = self._times[-self.window:]
        if len(hist) >= 10:
            mu, sd = float(np.mean(hist)), float(np.std(hist)) + 1e-9
            if dt > mu + self.z_thresh * sd:
                flagged = True
        self._times.append(dt)
        self._consecutive = self._consecutive + 1 if flagged else 0
        if flagged:
            log.warning("straggler: step took %.3fs (window mean %.3fs)",
                        dt, np.mean(hist))
        return flagged

    @property
    def persistent(self) -> bool:
        return self._consecutive >= self.patience


def retrying(fn: Callable, *, retries: int = 2, on_retry=None):
    """Wrap a step callable with bounded retry (transient failures)."""

    def wrapped(*a, **kw):
        for attempt in range(retries + 1):
            try:
                return fn(*a, **kw)
            except Exception as e:  # noqa: BLE001 - driver boundary
                if attempt == retries:
                    raise
                log.warning("step failed (%s); retry %d/%d",
                            e, attempt + 1, retries)
                if on_retry is not None:
                    on_retry(attempt, e)

    return wrapped


class StepTimer:
    def __init__(self):
        self._t0 = None

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.dt = time.perf_counter() - self._t0
        return False
