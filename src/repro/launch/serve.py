"""Serving driver: a continuous-batching engine over a request queue.

CPU example (8 forced host devices for the MoE comm path):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b \
      --reduced --requests 16 --slots 8 --prompt-len 24 --gen 16 --moe-comm

``repro.serve`` supplies the loop (queue → slots → engine, docs/serving.md);
this driver builds the model, fabricates a Poisson-ish arrival trace, and
prints the throughput/latency report.  Families without a per-slot cache
(ssm / hybrid / encdec / vlm) fall back to the original batched demo loop.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.train import make_mesh, preset_lm100m
from repro.models.transformer import Model, RunCtx

log = logging.getLogger("repro.serve")


def prefill_into_cache(model, params, cache, tokens):
    """Sequential prefill through decode_step — the bit-exactness ORACLE
    for the fused path (``Model.prefill`` via ``runtime.steps.build_prefill
    (fill_cache=True)``), which the engine uses in production.  Kept small
    and obviously-correct; tests/test_serve.py pins fused == this."""
    def body(cache, tok):
        logits, cache = model.decode_step(params, cache, tok[:, None])
        return cache, logits
    cache, logits = jax.lax.scan(body, cache, tokens.T)
    return cache, logits[-1]


def build_moe_layer(model, params, num_slots, mesh, *, axis_name="data",
                    strategy="auto"):
    """A ``DynamicMoELayer`` sized for the engine's decode batch: one
    instance (template shapes, layer-0 weight slices) serves every scanned
    layer via ``DynamicMoELayer.apply``."""
    from repro.models import moe as M

    cfg = model.cfg
    p = int(mesh.shape[axis_name])
    if cfg.num_experts % p or num_slots % p:
        raise ValueError(
            f"MoE comm path needs num_experts ({cfg.num_experts}) and "
            f"--slots ({num_slots}) divisible by the mesh axis ({p})")
    cap = M.moe_capacity(num_slots, cfg)
    tmpl_e, _ = M.random_router(0, num_slots, cfg.num_experts,
                                cfg.experts_per_token)
    moe_p = params["layers"]["moe"]
    weights = {"w1": np.asarray(moe_p["w1"][0]),
               "w2": np.asarray(moe_p["w2"][0])}
    if "w3" in moe_p:
        weights["w3"] = np.asarray(moe_p["w3"][0])
    return M.DynamicMoELayer(weights, tmpl_e, num_slots, cfg.num_experts,
                             cap, mesh, axis_name=axis_name, act=cfg.act,
                             strategy=strategy, decode=True)


def _serve_main(cfg, ctx, args):
    from repro.serve import Request, ServeEngine

    model = Model(cfg, ctx)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    cache_len = args.prompt_len + args.gen

    moe_layer = None
    if args.moe_comm:
        if cfg.family != "moe":
            raise SystemExit("--moe-comm needs a MoE architecture")
        mesh = (make_mesh(args.mesh) if args.mesh != "local"
                else make_local_mesh((len(jax.devices()),), ("data",)))
        moe_layer = build_moe_layer(model, params, args.slots, mesh)
        log.info("MoE decode comm: strategies=%s plan_time=%.2fus",
                 moe_layer.strategies, moe_layer.plan_time * 1e6)

    engine = ServeEngine(model, params, num_slots=args.slots,
                         cache_len=cache_len,
                         prefill_chunk=args.prefill_chunk,
                         moe_layer=moe_layer, cache_dtype=ctx.act_dtype)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2 + 1,
                                args.prompt_len + 1))
        engine.submit(Request(
            id=f"req{i}",
            prompt=rng.integers(0, cfg.vocab_size, (plen,)).tolist(),
            max_new_tokens=args.gen,
            # staggered arrivals in tick units: ~2 new requests per tick
            arrival_time=float(i // 2)))

    t0 = time.time()
    report = engine.run()
    wall = time.time() - t0
    log.info("%d requests, %d ticks, %.2fs wall", args.requests,
             report.ticks, wall)
    log.info("decode: %.1f tok/s, p50 %.0fus, p99 %.0fus per token",
             report.tokens_per_s, report.p50_us(), report.p99_us())
    log.info("telemetry: %s", report.telemetry)
    print("completed:", len(report.completed), "of", args.requests,
          "| total tokens:", report.total_tokens)
    return report


def _batch_demo_main(cfg, ctx, args):
    """Legacy batched demo for families without a per-slot cache."""
    model = Model(cfg, ctx)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    cache_len = args.prompt_len + args.gen
    cross_len = cfg.encoder_seq or cfg.num_image_tokens or 0
    cache = model.init_cache(args.batch, cache_len, cross_len=cross_len,
                             dtype=ctx.act_dtype)
    rng = np.random.default_rng(args.seed)
    if cross_len:
        context = jnp.asarray(rng.standard_normal(
            (args.batch, cross_len, cfg.d_model)), ctx.act_dtype)
        cache = model.prefill_cross(params, cache, context)

    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.time()
    cache, last_logits = jax.jit(
        lambda p, c, t: prefill_into_cache(model, p, c, t))(
            params, cache, prompt)
    last = jnp.argmax(last_logits[:, -1], axis=-1).astype(jnp.int32)  # (B,)
    jax.block_until_ready(last)
    t_prefill = time.time() - t0

    out_tokens = [last]
    t0 = time.time()
    for _ in range(args.gen):
        logits, cache = decode(params, cache, out_tokens[-1][:, None])
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out_tokens.append(nxt)
    jax.block_until_ready(out_tokens[-1])
    t_decode = time.time() - t0

    toks = args.gen * args.batch
    log.info("prefill %.3fs (%d tokens); decode %.3fs "
             "(%.1f tok/s aggregate)", t_prefill,
             args.batch * args.prompt_len, t_decode, toks / t_decode)
    seq = jnp.stack(out_tokens[1:], axis=1)
    print("generated shape:", seq.shape)
    return seq


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None, choices=[None, "lm100m"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)     # legacy demo path
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--prefill-chunk", type=int, default=None)
    ap.add_argument("--moe-comm", action="store_true",
                    help="route decode MoE through DynamicMoELayer")
    ap.add_argument("--experts", type=int, default=None,
                    help="override num_experts (e.g. to match the mesh)")
    ap.add_argument("--mesh", default="local")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = (preset_lm100m() if args.preset == "lm100m"
           else get_config(args.arch, reduced=args.reduced))
    if args.experts:
        cfg = dataclasses.replace(cfg, num_experts=args.experts)
    ctx = RunCtx(remat="none",
                 act_dtype=jnp.float32 if jax.default_backend() == "cpu"
                 else jnp.bfloat16)
    if cfg.family in ("dense", "moe"):
        return _serve_main(cfg, ctx, args)
    return _batch_demo_main(cfg, ctx, args)


if __name__ == "__main__":
    main()
