"""Batched serving driver: prefill a prompt batch, decode N tokens.

CPU example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.train import make_mesh, preset_lm100m
from repro.models.transformer import Model, RunCtx

log = logging.getLogger("repro.serve")


def prefill_into_cache(model, params, cache, tokens):
    """Sequential prefill through decode_step (simple reference path);
    production prefill is the fused forward (runtime.steps.build_prefill)."""
    def body(cache, tok):
        logits, cache = model.decode_step(params, cache, tok[:, None])
        return cache, logits
    cache, logits = jax.lax.scan(body, cache, tokens.T)
    return cache, logits[-1]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None, choices=[None, "lm100m"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="local")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = (preset_lm100m() if args.preset == "lm100m"
           else get_config(args.arch, reduced=args.reduced))
    ctx = RunCtx(remat="none",
                 act_dtype=jnp.float32 if jax.default_backend() == "cpu"
                 else jnp.bfloat16)
    model = Model(cfg, ctx)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    cache_len = args.prompt_len + args.gen
    cross_len = cfg.encoder_seq or cfg.num_image_tokens or 0
    cache = model.init_cache(args.batch, cache_len, cross_len=cross_len,
                             dtype=ctx.act_dtype)
    rng = np.random.default_rng(args.seed)
    if cross_len:
        context = jnp.asarray(rng.standard_normal(
            (args.batch, cross_len, cfg.d_model)), ctx.act_dtype)
        cache = model.prefill_cross(params, cache, context)

    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.time()
    cache, last_logits = jax.jit(
        lambda p, c, t: prefill_into_cache(model, p, c, t))(
            params, cache, prompt)
    last = jnp.argmax(last_logits[:, -1], axis=-1).astype(jnp.int32)  # (B,)
    jax.block_until_ready(last)
    t_prefill = time.time() - t0

    out_tokens = [last]
    t0 = time.time()
    for _ in range(args.gen):
        logits, cache = decode(params, cache, out_tokens[-1][:, None])
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out_tokens.append(nxt)
    jax.block_until_ready(out_tokens[-1])
    t_decode = time.time() - t0

    toks = args.gen * args.batch
    log.info("prefill %.3fs (%d tokens); decode %.3fs "
             "(%.1f tok/s aggregate)", t_prefill,
             args.batch * args.prompt_len, t_decode, toks / t_decode)
    seq = jnp.stack(out_tokens[1:], axis=1)
    print("generated shape:", seq.shape)
    return seq


if __name__ == "__main__":
    main()
