import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  * memory_analysis (proves per-device residency fits),
  * cost_analysis FLOPs/bytes,
  * the parsed collective schedule (per-op bytes, ICI vs DCI),
  * the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, skip_reason
from repro.core import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import Model, RunCtx
from repro.optim.adamw import AdamW
from repro.runtime import sharding as sh
from repro.runtime.steps import (build_decode_step, build_prefill,
                                 build_train_step, model_flops)

SERVE_RESIDENCY_LIMIT = 12e9  # bytes/device of weights before ZeRO-serving


def _sds(tree_shapes, shardings):
    return jax.tree.map(
        lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
        tree_shapes, shardings)


def _cast_tree(tree_shapes, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype),
        tree_shapes)


def make_rules(cfg, mesh, mode):
    multi = "pod" in mesh.shape
    ep = cfg.is_moe and cfg.num_experts >= mesh.shape["model"]
    if mode == "train":
        if multi:
            # hierarchical ZeRO (EXPERIMENTS.md §Perf cell B): bf16 compute
            # params gather pod-locally over "data"; the f32 optimizer state
            # spreads over ("pod","data") — weight gathers never cross DCI
            return sh.ShardingRules(
                mesh=mesh, fsdp_axes="data",
                opt_fsdp_axes=("pod", "data"), ep_mode=ep)
        return sh.ShardingRules(mesh=mesh, fsdp_axes="data", ep_mode=ep)
    # serve: weights over model axis only, unless they would not fit
    fsdp = ("pod", "data") if multi else "data"
    pshapes = jax.eval_shape(
        Model(cfg, RunCtx()).init_params, jax.random.PRNGKey(0))
    pbytes = sum(int(np.prod(s.shape)) * 2  # bf16 serving weights
                 for s in jax.tree.leaves(pshapes))
    if pbytes / mesh.shape["model"] <= SERVE_RESIDENCY_LIMIT:
        fsdp = None  # fits with pure TP: replicate over data for latency
    return sh.ShardingRules(mesh=mesh, fsdp_axes=fsdp, ep_mode=ep)


def input_specs(cfg, shape, mesh, *, mode: str, rules=None,
                remat_groups: int = 1):
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    rules = rules or make_rules(cfg, mesh, mode)
    dp = int(np.prod([mesh.shape[a] for a in
                      (("pod", "data") if "pod" in mesh.shape
                       else ("data",))]))
    ctx = RunCtx(moe_groups=max(1, min(dp, shape.global_batch)),
                 remat="full" if mode == "train" else "none",
                 constrain=sh.make_constrain(rules),
                 vocab_shards=mesh.shape["model"],
                 remat_groups=remat_groups if mode == "train" else 1)
    model = Model(cfg, ctx)

    pshapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    # compute params are bf16 in BOTH train and serve: train uses true mixed
    # precision (f32 masters live in the optimizer state), so every weight
    # collective moves 2-byte payloads
    pshapes = _cast_tree(pshapes, jnp.bfloat16)
    pspecs = _sds(pshapes, sh.param_shardings(rules, pshapes))

    b = shape.global_batch
    tok = lambda s: jax.ShapeDtypeStruct(  # noqa: E731
        (b, s), jnp.int32, sharding=sh.batch_sharding(rules, (b, s)))

    extra = None
    if cfg.is_encdec:
        eshape = (b, cfg.encoder_seq, cfg.d_model)
        extra = {"frames": jax.ShapeDtypeStruct(
            eshape, jnp.bfloat16, sharding=sh.batch_sharding(rules, eshape))}
    if cfg.is_vlm:
        ishape = (b, cfg.num_image_tokens, cfg.d_model)
        extra = {"image_embeds": jax.ShapeDtypeStruct(
            ishape, jnp.bfloat16, sharding=sh.batch_sharding(rules, ishape))}

    if mode == "train":
        opt = AdamW(lr=1e-4, mixed_precision=True)
        oshapes = jax.eval_shape(opt.init, pshapes)
        ospecs = _sds(oshapes, sh.param_shardings(rules, oshapes))
        return model, ctx, {
            "params": pspecs, "opt": ospecs,
            "batch": (tok(shape.seq_len), tok(shape.seq_len)),
            "extra": extra,
        }
    if mode == "prefill":
        return model, ctx, {"params": pspecs, "tokens": tok(shape.seq_len),
                            "extra": extra}
    # decode
    cross_len = cfg.encoder_seq or cfg.num_image_tokens or 0
    cshapes = jax.eval_shape(
        lambda: model.init_cache(b, shape.seq_len, cross_len=cross_len,
                                 dtype=jnp.bfloat16))
    cspecs = _sds(cshapes, sh.cache_shardings(rules, cshapes))
    return model, ctx, {"params": pspecs, "cache": cspecs,
                        "tokens": tok(1)}


DEFAULT_ACCUM = 8  # microbatched grad accumulation for train cells


def lower_cell(cfg, shape, mesh, *, mode: str, accum_steps: int | None = None,
               remat_groups: int = 1):
    if accum_steps is None:
        accum_steps = DEFAULT_ACCUM if mode == "train" else 1
    rules = make_rules(cfg, mesh, mode)
    model, ctx, specs = input_specs(cfg, shape, mesh, mode=mode, rules=rules,
                                    remat_groups=remat_groups)
    if mode == "train":
        opt = AdamW(lr=1e-4, mixed_precision=True)
        gshard = jax.tree.map(lambda s: s.sharding, specs["params"])
        step = build_train_step(model, opt, grad_shardings=gshard,
                                accum_steps=accum_steps)
        oshard = jax.tree.map(lambda s: s.sharding, specs["opt"])
        fn = jax.jit(step, donate_argnums=(0, 1),
                     out_shardings=(gshard, oshard, None))
        args = (specs["params"], specs["opt"], specs["batch"],
                specs["extra"])
    elif mode == "prefill":
        step = build_prefill(model)
        fn = jax.jit(step)
        args = (specs["params"], specs["tokens"], specs["extra"])
    else:
        step = build_decode_step(model)
        fn = jax.jit(step, donate_argnums=(1,))
        args = (specs["params"], specs["cache"], specs["tokens"])
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    return compiled


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_tag = "multipod_2x16x16" if multi_pod else "pod_16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}"

    reason = skip_reason(cfg, shape)
    if reason:
        art = {"name": name, "skipped": True, "reason": reason}
        _write(out_dir, name, art)
        if verbose:
            print(f"SKIP {name}: {reason}")
        return art

    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    compiled = lower_cell(cfg, shape, mesh, mode=shape.mode)
    compile_s = time.time() - t0

    report = rl.analyze_compiled(
        compiled, name=name, num_devices=ndev,
        devices_per_pod=256 if multi_pod else ndev,
        model_flops=model_flops(cfg, mode=shape.mode,
                                batch=shape.global_batch,
                                seq=shape.seq_len),
        bf16_program=True,  # models are authored bf16; see hlo_cost docs
    )
    ma = compiled.memory_analysis()
    art = report.to_json()
    art.update({
        "skipped": False,
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "compile_seconds": compile_s,
        "memory_analysis": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        },
    })
    _write(out_dir, name, art)
    if verbose:
        mb = art["memory_analysis"]["peak_bytes_per_device"] / 2**30
        print(f"OK {name}: compile={compile_s:.1f}s "
              f"peak={mb:.2f}GiB/dev dominant={art['dominant']} "
              f"terms(c/m/coll)=({art['compute_term_s']:.2e},"
              f"{art['memory_term_s']:.2e},{art['collective_term_s']:.2e})s "
              f"useful={art['useful_flops_ratio']:.2f}")
    return art


def _write(out_dir, name, art):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(art, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    run_cell(arch, shape, multi_pod=multi, out_dir=args.out)
                except Exception:
                    failures.append((arch, shape, multi))
                    print(f"FAIL {arch} {shape} multi={multi}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print("DRYRUN_ALL_OK")


if __name__ == "__main__":
    main()
