"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required for the
dry-run's forced host-device count to keep working.
"""
from __future__ import annotations

import jax

from repro import compat

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=compat.auto_axis_types(len(axes)))


def make_local_mesh(shape=None, axes=None):
    """Mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n,) if n == 1 else (2, n // 2)
    if axes is None:
        axes = ("data",) if len(shape) == 1 else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=compat.auto_axis_types(len(axes)))
