"""End-to-end training driver with checkpoint/restart, straggler watch and
elastic resume.

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.train --preset lm100m --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 20 --batch 8 --seq 128

On a pod, the same driver runs under the production mesh: --mesh 16x16.
XLA's latency-hiding scheduler overlaps the FSDP all-gathers with compute;
enable via:
  XLA_FLAGS="--xla_tpu_enable_latency_hiding_scheduler=true"  (TPU only)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataState, SyntheticLM
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.transformer import Model, RunCtx
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime import sharding as sh
from repro.runtime.fault import StepTimer, StragglerWatch, retrying
from repro.runtime.steps import build_train_step

log = logging.getLogger("repro.train")


def preset_lm100m() -> ArchConfig:
    """~100M-param dense LM for the end-to-end CPU example."""
    return ArchConfig(
        name="lm100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=32768,
        head_dim=64,
    )


def make_mesh(spec: str):
    if spec == "local":
        return make_local_mesh()
    if spec in ("16x16", "pod"):
        return make_production_mesh()
    if spec in ("2x16x16", "multipod"):
        return make_production_mesh(multi_pod=True)
    dims = tuple(int(d) for d in spec.split("x"))
    axes = ("data", "model")[: len(dims)]
    from repro import compat
    return compat.make_mesh(dims, axes,
                            axis_types=compat.auto_axis_types(len(dims)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None, choices=[None, "lm100m"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--mesh", default="local")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    if args.preset == "lm100m":
        cfg = preset_lm100m()
    elif args.arch:
        cfg = get_config(args.arch, reduced=args.reduced)
    else:
        raise SystemExit("pass --arch or --preset")

    mesh = make_mesh(args.mesh)
    has_model_axis = "model" in mesh.shape and mesh.shape["model"] > 1
    fsdp = "data" if mesh.shape.get("data", 1) > 1 else None
    rules = sh.ShardingRules(
        mesh=mesh, fsdp_axes=fsdp,
        ep_mode=cfg.is_moe and cfg.num_experts >= mesh.shape.get("model", 1),
    ) if (has_model_axis or fsdp) else None

    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    ctx = RunCtx(
        moe_groups=max(1, min(dp, args.batch)),
        remat="full",
        constrain=sh.make_constrain(rules) if rules else None,
        act_dtype=jnp.float32 if jax.default_backend() == "cpu"
        else jnp.bfloat16,
        vocab_shards=mesh.shape.get("model", 1),
    )
    model = Model(cfg, ctx)
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps),
                weight_decay=0.01)

    # ---- init or resume ----
    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    if rules is not None:
        pshard = sh.param_shardings(rules, jax.eval_shape(lambda: params))
        oshard = sh.param_shardings(rules, jax.eval_shape(lambda: opt_state))
        params = jax.tree.map(jax.device_put, params, pshard)
        opt_state = jax.tree.map(jax.device_put, opt_state, oshard)
    else:
        pshard = None

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    dstate = DataState()

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, save_every=args.save_every)
        got = mgr.restore_latest(
            {"params": params, "opt": opt_state},
            shardings={"params": pshard, "opt": oshard} if rules else None)
        if got[0] is not None:
            start_step, tree, extra_state = got
            params, opt_state = tree["params"], tree["opt"]
            dstate = DataState.from_json(extra_state.get("data", {"step": 0}))
            log.info("resumed from step %d", start_step)

    step_fn = jax.jit(
        build_train_step(
            model, opt, accum_steps=args.accum,
            grad_shardings=pshard),
        donate_argnums=(0, 1))

    watch = StragglerWatch()
    extra = None
    if cfg.is_encdec:
        extra = {"frames": jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), ctx.act_dtype)}
    if cfg.is_vlm:
        extra = {"image_embeds": jnp.zeros(
            (args.batch, cfg.num_image_tokens, cfg.d_model), ctx.act_dtype)}

    metrics_hist = []

    def one_step(params, opt_state, batch):
        return step_fn(params, opt_state, batch, extra)

    safe_step = retrying(one_step, retries=1)

    t_start = time.time()
    for step in range(start_step, args.steps):
        tokens, labels = data.batch_at(dstate.step)
        if rules is not None:
            bshard = sh.batch_sharding(rules, tokens.shape)
            tokens = jax.device_put(tokens, bshard)
            labels = jax.device_put(labels, bshard)
        with StepTimer() as t:
            params, opt_state, metrics = safe_step(
                params, opt_state, (jnp.asarray(tokens), jnp.asarray(labels)))
            loss = float(metrics["loss"])
        dstate.step += 1
        watch.observe(t.dt)
        if watch.persistent:
            log.warning("persistent straggler detected; checkpoint + "
                        "re-slice advised")
        if step % args.log_every == 0 or step == args.steps - 1:
            log.info("step %d loss %.4f gnorm %.3f %.2fs/step",
                     step, loss, float(metrics["grad_norm"]), t.dt)
        metrics_hist.append(
            {"step": step, "loss": loss, "sec": t.dt})
        if mgr is not None:
            mgr.maybe_save(step + 1, {"params": params, "opt": opt_state},
                           extra={"data": dstate.to_json()})

    if mgr is not None:
        mgr.maybe_save(args.steps, {"params": params, "opt": opt_state},
                       extra={"data": dstate.to_json()}, force=True)
        mgr.wait()
    wall = time.time() - t_start
    log.info("done: %d steps in %.1fs (%.2fs/step)",
             args.steps - start_step, wall,
             wall / max(1, args.steps - start_step))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_hist, f)
    return metrics_hist


if __name__ == "__main__":
    main()
