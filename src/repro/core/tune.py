"""Hardware calibration for the model-driven autotuner (§5.4 / §6.2).

``measure_hardware`` micro-benchmarks the paper's hardware characteristic
parameters ONCE PER MESH — a STREAM-like copy for ``w_private``, a large
ring ``ppermute`` for ``w_remote``, a tiny one for ``tau``, and a
random-gather probe for the effective non-contiguous access granularity
``cacheline`` (the per-element pack/unpack cost).  Results are memoized per
(devices, axis) for the life of the process.

The *selection* half of the autotuner (ranking strategies and sweeping
BLOCKSIZE through the §5 formulas) moved to ``repro.comm.select`` with the
rest of the communication machinery; ``rank_strategies`` /
``choose_strategy`` / ``choose_blocksize`` / ``workload_from_plan`` are
re-exported here for compatibility (``rank_strategies`` /
``choose_strategy`` now take ``direction="get"|"put"`` to price the push
rungs of ``IrregularScatter``).  The per-(mesh, axis) calibration memo used
by the exchange front doors — ``measure_hw`` / ``clear_hw_memo`` — lives in
``repro.comm.exchange`` and is re-exported here too.
"""
from __future__ import annotations

import time

import numpy as np

from repro.comm.exchange import (  # noqa: F401  (compat re-exports)
    clear_hw_memo, measure_hw,
)
from repro.comm.select import (  # noqa: F401  (compat re-exports)
    choose_blocksize, choose_strategy, rank_strategies, workload_from_plan,
)
from repro.core.perfmodel import HardwareParams

__all__ = [
    "measure_hardware", "rank_strategies", "choose_strategy",
    "choose_blocksize", "clear_hardware_cache", "workload_from_plan",
    "measure_hw", "clear_hw_memo",
]

_hw_cache: dict[tuple, HardwareParams] = {}


def clear_hardware_cache() -> None:
    _hw_cache.clear()


def _timeit(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def measure_hardware(
    mesh=None,
    axis_name: str | None = None,
    *,
    elem_bytes: int = 4,
    force: bool = False,
) -> HardwareParams:
    """Micro-benchmark the four §5.4 parameters on this process's devices.

    ``mesh``/``axis_name`` select the communication axis to probe; with no
    mesh every visible device joins a ring.  Memoized per (device set, axis,
    elem size) — pass ``force=True`` to re-measure.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat

    if mesh is not None:
        axis = axis_name or mesh.axis_names[0]
        devices = tuple(d.id for d in mesh.devices.flat)
        ndev = mesh.shape[axis]
    else:
        axis = axis_name or "data"
        devices = tuple(d.id for d in jax.devices())
        ndev = len(devices)
    key = (devices, axis, ndev, elem_bytes)
    if not force and key in _hw_cache:
        return _hw_cache[key]

    # -- w_private: STREAM-like copy (read + write) --
    n = 1 << 22
    x = jnp.arange(n, dtype=jnp.float32)
    copy = jax.jit(lambda a: a * 1.0000001)
    t_copy = _timeit(copy, x, iters=10)
    w_private = 2.0 * n * 4 / t_copy

    # -- cacheline: random-gather probe; the model charges every
    # non-contiguous local access one ``cacheline`` of traffic, so the
    # effective value is gather-time * w_private / accesses --
    g = 1 << 20
    idx = jnp.asarray(
        np.random.default_rng(0).integers(0, n, size=g, dtype=np.int32))
    gather = jax.jit(lambda a, i: a[i])
    t_gather = _timeit(gather, x, idx, iters=10)
    cacheline = int(np.clip(t_gather * w_private / g, 16, 4096))

    # -- w_remote and tau: ring ppermute, big minus tiny --
    if ndev > 1:
        ring_mesh = mesh
        if ring_mesh is None:
            ring_mesh = compat.make_mesh(
                (ndev,), (axis,), axis_types=compat.auto_axis_types(1))
        perm = [(i, (i + 1) % ndev) for i in range(ndev)]

        def ring(a):
            return compat.shard_map(
                lambda v: jax.lax.ppermute(v, axis, perm), mesh=ring_mesh,
                in_specs=P(axis), out_specs=P(axis))(a)

        sh = NamedSharding(ring_mesh, P(axis))
        big = jax.device_put(jnp.zeros((ndev * (1 << 20),), jnp.float32), sh)
        t_big = _timeit(jax.jit(ring), big, iters=5)
        tiny = jax.device_put(jnp.zeros((ndev * 8,), jnp.float32), sh)
        tau = _timeit(jax.jit(ring), tiny, iters=20)
        w_remote = (1 << 20) * 4 / max(t_big - tau, 1e-9)
    else:
        w_remote = w_private
        tau = _timeit(copy, jnp.zeros((8,), jnp.float32), iters=30)

    hw = HardwareParams(
        w_private=w_private, w_remote=w_remote, tau=tau,
        cacheline=cacheline, elem=elem_bytes, idx=4)
    _hw_cache[key] = hw
    return hw
