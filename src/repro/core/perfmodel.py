"""The paper's performance models (§5, §8) — eqs. (5)–(22), verbatim.

Philosophy (paper §5.4 / §9): represent the machine by only FOUR parameters
  * ``w_private``  — per-thread contiguous private-memory bandwidth [B/s]
  * ``w_remote``   — per-node interconnect bandwidth [B/s]
  * ``tau``        — latency of one individual remote access [s]
  * ``cacheline``  — granularity of a non-contiguous local access [B]
and predict run time from *exactly counted* communication volumes and
frequencies, per thread and per node (never aggregate averages).

The counts come from ``CommPlan.counts`` (one-time preparation step).  The
models are platform-independent: instantiate ``HardwareParams`` with the
paper's Abel cluster numbers to reproduce Table 4, with measured host numbers
to validate our own runs, or with TPU v5e numbers to predict pod-scale
behavior (the same constants the §Roofline analysis uses).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.comm.plan import GatherCounts, Topology

__all__ = [
    "HardwareParams", "ABEL", "TPU_V5E", "SpmvWorkload",
    "predict_v1", "predict_v2", "predict_v3", "predict_replicate",
    "predict_overlap", "predict_all", "STRATEGY_PREDICTORS",
    "put_components", "predict_put_v2", "predict_put_v3",
    "predict_put_overlap", "predict_put_replicate", "predict_put_all",
    "PUT_STRATEGY_PREDICTORS", "predict_schedule", "window_setup_time",
    "scan_loop_cost", "predict_scan_schedule",
    "PLAN_SOURCES", "plan_build_time", "replan_break_even_steps",
    "decode_floor", "predict_decode_exchange", "predict_decode_step",
    "predict_heat2d", "Heat2DWorkload", "full_assembly_tax",
    "heat2d_edge_ring_comp", "predict_heat2d_window",
    "predict_heat2d_scan",
    "model_error", "error_budget", "ERROR_BUDGET_DEFAULT",
]


@dataclasses.dataclass(frozen=True)
class HardwareParams:
    """The paper's four hardware characteristic parameters (§5.4)."""

    w_private: float   # B/s per thread/device (contiguous local)
    w_remote: float    # B/s per node (contiguous inter-node)
    tau: float         # s, individual remote access latency
    cacheline: int     # B, non-contiguous local access granularity
    elem: int = 8      # sizeof(one vector element); paper: double
    idx: int = 4       # sizeof(one column index);  paper: int

    def replace(self, **kw) -> "HardwareParams":
        return dataclasses.replace(self, **kw)


# Paper §6.2: Abel cluster, 16 UPC threads/node.
ABEL = HardwareParams(
    w_private=75e9 / 16, w_remote=6e9, tau=3.4e-6, cacheline=64,
)

# TPU v5e adaptation (DESIGN.md §2): device=chip, node=pod.
#   w_private -> HBM bw per chip; w_remote -> ICI egress per chip (~4 links);
#   tau -> per-collective hop latency; cacheline -> (8,128) f32 VREG tile row.
TPU_V5E = HardwareParams(
    w_private=819e9, w_remote=4 * 50e9, tau=1e-6, cacheline=512,
    elem=4, idx=4,
)


@dataclasses.dataclass(frozen=True)
class SpmvWorkload:
    """Static facts about one gather workload on one partitioning.

    ``m`` is the accessor-row count (the number of index rows in the access
    pattern); for SpMV every vector element is also an accessor, so ``m ==
    n`` — other consumers (e.g. expert-capacity slots reading tokens)
    decouple the two.
    """

    n: int
    r_nz: int
    p: int                 # number of threads/devices
    blocksize: int         # paper BLOCKSIZE (virtual block size)
    topology: Topology
    counts: GatherCounts
    m: int | None = None   # accessor rows; None -> n (SpMV-like)
    # Unpack-mode pricing (beyond paper; see docs/perf_model.md):
    #   None   — the paper's in-place unpack (eq. 15 as written; UPC reuses
    #            a persistent mythread_x_copy, so no assembly cost).
    #   "full" — our functional XLA unpack assembles a fresh length-n x_copy
    #            (zeros + scatter) every exchange: eq. 15 gains an O(n) term.
    #   "dest" — consumer-targeted unpack into ``dest_slots`` named slots:
    #            the eq.-14 own-copy vanishes and eq. 15 becomes O(slots).
    materialize: str | None = None
    dest_slots: int | None = None   # flattened Destination size L
    # Kernelized pack/unpack pricing (docs/perf_model.md kernel rows):
    # the fused Pallas kernels (repro.kernels) touch HBM once per element
    # on each side of the wire, so the compute terms of eqs. 14/15 (and
    # 14ᵀ/15ᵀ) shed their re-read and cacheline-grain charges.  Wire terms
    # are untouched — the collective is the same either way.
    use_kernel: bool = False

    @property
    def shard_size(self) -> int:
        return self.n // self.p

    @property
    def rows_per_shard(self) -> int:
        return (self.m if self.m is not None else self.n) // self.p


# --------------------------------------------------------------------------
# §5.1 computation time
# --------------------------------------------------------------------------

def _d_min_comp(hw: HardwareParams, r_nz: int) -> float:
    """Eq. (6): minimum main-memory traffic per y(i), assuming perfect reuse
    of x in the last-level cache."""
    return r_nz * (hw.elem + hw.idx) + 3 * hw.elem


def t_comp_per_thread(w: SpmvWorkload, hw: HardwareParams) -> np.ndarray:
    """Eq. (5)+(7): per-thread compute time, length-P array.

    Our partitioning is one contiguous shard per device (DESIGN.md §2 note 4),
    i.e. B_thread_comp * BLOCKSIZE == shard_size for every thread.  Compute
    scales with the *accessor rows* a thread evaluates (rows_per_shard ==
    shard_size for SpMV; expert-capacity slots etc. for m != n consumers).
    """
    elems = np.full(w.p, w.rows_per_shard, dtype=np.float64)
    return elems * _d_min_comp(hw, w.r_nz) / hw.w_private


# --------------------------------------------------------------------------
# §5.2.3 UPCv1 — fine-grained individual accesses, eq. (10) + eq. (16)
# --------------------------------------------------------------------------

def predict_v1(w: SpmvWorkload, hw: HardwareParams) -> float:
    c = w.counts
    t_comm = (
        c.c_local_indv * (hw.cacheline / hw.w_private)
        + c.c_remote_indv * hw.tau
    )
    return float(np.max(t_comp_per_thread(w, hw) + t_comm))


# --------------------------------------------------------------------------
# §5.2.4 UPCv2 — block-wise transfers, eq. (11) + eq. (17)
# --------------------------------------------------------------------------

def predict_v2(w: SpmvWorkload, hw: HardwareParams) -> float:
    c = w.counts
    bs_bytes = w.blocksize * hw.elem
    t_comp = t_comp_per_thread(w, hw)
    total = -np.inf
    for node in range(w.topology.num_nodes):
        th = _threads_of_node(w.topology, node)
        t_local = np.max(c.b_local[th] * 2.0 * bs_bytes / hw.w_private)
        t_remote = np.sum(c.b_remote[th] * (hw.tau + bs_bytes / hw.w_remote))
        total = max(total, np.max(t_comp[th]) + t_local + t_remote)
    # unpack-mode extension (docs/perf_model.md): the paper's UPCv2 reads
    # landed blocks in place; our functional paths pay a delivery tail
    # (halved / cacheline-free under the fused kernels' single HBM pass)
    if w.materialize == "full":
        tail = 2.0 * (w.n + w.blocksize) * hw.elem / hw.w_private
        total += 0.5 * tail if w.use_kernel else tail
    elif w.materialize == "dest":
        per_slot = hw.elem if w.use_kernel else hw.elem + hw.cacheline
        total += (w.dest_slots or 0) * per_slot / hw.w_private
    return float(total)


# --------------------------------------------------------------------------
# §5.2.5 UPCv3 — condensed + consolidated messages, eqs. (12)–(15) + (18)
# --------------------------------------------------------------------------

def v3_components(
    w: SpmvWorkload, hw: HardwareParams
) -> dict[str, np.ndarray]:
    """Per-thread pack/copy/unpack (and per-thread memput inputs), eqs. 12–15.

    The copy/unpack terms depend on ``w.materialize`` (the unpack-mode
    extension, eqs. 14′/15′ in docs/perf_model.md): ``None`` is the paper's
    in-place unpack; ``"full"`` adds the O(n) x_copy-assembly traffic the
    functional XLA scatter pays; ``"dest"`` replaces both with the
    consumer-targeted O(slots + recv) delivery (eq.-14 copy drops — owned
    slots are gathered from x_local inside the slot term).
    """
    c = w.counts
    s_out = c.s_local_out + c.s_remote_out
    s_in = c.s_local_in + c.s_remote_in
    if w.use_kernel:
        # fused pack kernel: each packed element is one VMEM-local gather
        # (value read + index read + contiguous write, no re-read)
        t_pack = s_out * (hw.elem + hw.idx) / hw.w_private          # (12ᵏ)
    else:
        t_pack = s_out * (2 * hw.elem + hw.idx) / hw.w_private       # (12)
    if w.materialize == "dest":
        slots = w.dest_slots or 0
        t_copy = np.zeros(w.p)                                      # no (14)
        if w.use_kernel:
            # fused dest-unpack kernel: recv buffer and shard stay VMEM-
            # resident, each slot is one masked gather + one write — the
            # landed index reads fold into the slot pass
            t_unpack = (s_in * hw.elem / hw.w_private
                        + slots * hw.elem / hw.w_private)           # (15ᵏ')
        else:
            # (15'): read each landed value + its index once out of the
            # small condensed recv buffer, then write the L slots
            # contiguously in consumer order (the delivery IS the
            # consumer's gather, so no extra cacheline charge per slot)
            t_unpack = (s_in * (hw.elem + hw.idx) / hw.w_private
                        + slots * hw.elem / hw.w_private)
    else:
        t_copy = np.full(
            w.p, 2.0 * w.shard_size * hw.elem / hw.w_private        # (14)
        )
        if w.use_kernel:
            # fused scatter-set kernel: landed values scatter at element
            # grain inside VMEM, no cacheline-grain HBM charge
            t_unpack = s_in * (hw.elem + hw.idx) / hw.w_private     # (15ᵏ)
            if w.materialize == "full":
                # zero-fill and final write happen in one kernel pass:
                # half the functional zeros+scatter assembly traffic
                t_unpack = t_unpack + 0.5 * full_assembly_tax(w.n, hw)
        else:
            t_unpack = s_in * (hw.elem + hw.idx
                               + hw.cacheline) / hw.w_private       # (15)
            if w.materialize == "full":
                t_unpack = t_unpack + full_assembly_tax(w.n, hw)
    return {"pack": t_pack, "copy": t_copy, "unpack": t_unpack}


def full_assembly_tax(n: int, hw: HardwareParams) -> float:
    """Eq. (15') full-mode term: our functional XLA unpack zero-fills and
    writes a fresh length-n copy every exchange (the paper's UPC code
    reuses a persistent buffer and never pays this)."""
    return 2.0 * (n + 1) * hw.elem / hw.w_private


def predict_v3(w: SpmvWorkload, hw: HardwareParams) -> float:
    c = w.counts
    comp = t_comp_per_thread(w, hw)
    parts = v3_components(w, hw)

    # eq. (13): per-node memput term
    comm = -np.inf
    for node in range(w.topology.num_nodes):
        th = _threads_of_node(w.topology, node)
        t_local = np.max(2.0 * c.s_local_out[th] * hw.elem / hw.w_private)
        t_remote = np.sum(
            c.c_remote_out[th] * hw.tau
            + c.s_remote_out[th] * hw.elem / hw.w_remote
        )
        comm = max(comm, np.max(parts["pack"][th]) + t_local + t_remote)

    # eq. (18): barrier between memput and unpack -> max-compose the stages
    tail = np.max(parts["copy"] + parts["unpack"] + comp)
    return float(comm + tail)


# --------------------------------------------------------------------------
# Naive replicate baseline (beyond paper: the TPU "access anything" analogue).
# Whole vector all-gathered: every device receives n - shard_size elements,
# inter-node portion bounded by node egress.
# --------------------------------------------------------------------------

def predict_replicate(w: SpmvWorkload, hw: HardwareParams) -> float:
    topo = w.topology
    per_node_shards = topo.shards_per_node
    local_vol = (per_node_shards - 1) * w.shard_size * hw.elem
    remote_vol = (w.n - per_node_shards * w.shard_size) * hw.elem
    t_comm = (
        2.0 * local_vol / hw.w_private
        + (hw.tau * max(0, topo.num_nodes - 1) + remote_vol / hw.w_remote)
    )
    # the all-gather output IS the full copy (no assembly tax in "full"
    # mode); targeted delivery still pays the O(slots) gather out of it
    # (element-grain when the fused dest-unpack kernel delivers the slots)
    if w.materialize == "dest":
        per_slot = hw.elem if w.use_kernel else hw.elem + hw.cacheline
        t_comm += (w.dest_slots or 0) * per_slot / hw.w_private
    return float(np.max(t_comp_per_thread(w, hw)) + t_comm)


# --------------------------------------------------------------------------
# Beyond paper: overlap — condensed exchange hidden behind own-shard compute.
# The local step is split: the own-shard partial SpMV (which needs only
# x_local) runs while the condensed all_to_all is in flight, then the foreign
# partial consumes the unpacked remote values.  Two consequences for the
# model: (a) the memput phase max-composes with the own compute instead of
# adding to it; (b) the own-shard memcpy into x_copy (eq. 14) disappears —
# the remote pass only ever reads exchanged values.
# --------------------------------------------------------------------------

def predict_overlap(w: SpmvWorkload, hw: HardwareParams) -> float:
    c = w.counts
    comp = t_comp_per_thread(w, hw)
    parts = v3_components(w, hw)

    # split compute by access counts: foreign occurrences vs all occurrences
    foreign = (c.c_local_indv + c.c_remote_indv).astype(np.float64)
    frac_foreign = foreign / float(max(1, w.rows_per_shard * w.r_nz))
    comp_own = comp * (1.0 - frac_foreign)
    comp_foreign = comp * frac_foreign

    # eq. (13) memput phase, overlapped with the own-shard partial compute
    comm = -np.inf
    for node in range(w.topology.num_nodes):
        th = _threads_of_node(w.topology, node)
        t_local = np.max(2.0 * c.s_local_out[th] * hw.elem / hw.w_private)
        t_remote = np.sum(
            c.c_remote_out[th] * hw.tau
            + c.s_remote_out[th] * hw.elem / hw.w_remote
        )
        t_memput = np.max(parts["pack"][th]) + t_local + t_remote
        comm = max(comm, max(t_memput, float(np.max(comp_own[th]))))

    # tail: unpack + foreign partial compute (no eq. 14 own-shard copy)
    tail = np.max(parts["unpack"] + comp_foreign)
    return float(comm + tail)


def predict_all(w: SpmvWorkload, hw: HardwareParams) -> dict[str, float]:
    return {
        "v1_finegrained": predict_v1(w, hw),
        "v2_blockwise": predict_v2(w, hw),
        "v3_condensed": predict_v3(w, hw),
        "overlap": predict_overlap(w, hw),
        "replicate": predict_replicate(w, hw),
    }


# runtime strategy name (strategies.STRATEGIES) -> §5 predictor
STRATEGY_PREDICTORS = {
    "replicate": predict_replicate,
    "blockwise": predict_v2,
    "condensed": predict_v3,
    "overlap": predict_overlap,
}


# --------------------------------------------------------------------------
# Put direction (scatter / push) — the §5 formulas with send and recv
# volumes swapped, plus the accumulate-unpack term (docs/perf_model.md,
# eqs. 12ᵀ–15ᵀ).  The workload's ``counts`` must already be put-direction
# counts (``ScatterPlan.counts`` / ``plan.transpose_counts``): per-shard
# ``s_*_out`` is the contribution volume *leaving* the accessor shard —
# which equals the gather direction's incoming volume for the same pattern.
# The models hinge only on volumes, so the structure of eqs. 12–15 carries
# over; what changes is where the scatter/gather-grain memory traffic lands:
# the pack side becomes a segment-combine (every contribution read once and
# folded into the per-pair message buffer) and the unpack side becomes a
# read-modify-write accumulate into the owned slice (one cacheline-grain
# access per landed element, like eq. 15's non-contiguous reads).
# --------------------------------------------------------------------------

def put_components(w: SpmvWorkload, hw: HardwareParams) -> dict[str, np.ndarray]:
    """Per-thread pack/init/accumulate terms for the condensed put.

    * ``pack`` (12ᵀ): read all ``rows_per_shard * r_nz`` contributions once
      and segment-combine them into the per-pair message buffer (one write
      + one re-read per unique outgoing element).
    * ``init`` (14ᵀ): zero-fill + final write of the owned accumulator —
      the put dual of the eq.-14 own-shard copy.
    * ``accumulate`` (15ᵀ): landed foreign contributions (volume
      ``s_in``) and own contributions each pay one cacheline-grain
      read-modify-write into the owned slice, plus the index read.
    """
    c = w.counts
    s_out = c.s_local_out + c.s_remote_out
    s_in = c.s_local_in + c.s_remote_in
    contribs = float(w.rows_per_shard * w.r_nz)
    if w.use_kernel:
        # fused segment-combine kernel: the message buffer stays VMEM-
        # resident, so the per-unique-element re-read drops
        t_pack = (contribs * (hw.elem + hw.idx)
                  + s_out * hw.elem) / hw.w_private                 # (12ᵀᵏ)
    else:
        t_pack = (contribs * (hw.elem + hw.idx)
                  + s_out * 2.0 * hw.elem) / hw.w_private           # (12ᵀ)
    t_init = np.full(
        w.p, 2.0 * w.shard_size * hw.elem / hw.w_private)           # (14ᵀ)
    foreign = (c.c_local_indv + c.c_remote_indv).astype(np.float64)
    own_occ = np.maximum(contribs - foreign, 0.0)
    if w.use_kernel:
        # accumulate kernels: element-grain combines inside VMEM, no
        # cacheline-grain HBM read-modify-write per contribution
        t_acc = (s_in * (hw.elem + hw.idx)
                 + own_occ * hw.elem) / hw.w_private                # (15ᵀᵏ)
    else:
        t_acc = (s_in * (hw.elem + hw.idx + hw.cacheline)
                 + own_occ * (hw.elem + hw.cacheline)) / hw.w_private  # (15ᵀ)
    return {"pack": t_pack, "init": t_init, "accumulate": t_acc,
            "own_occ": own_occ}


def predict_put_v3(w: SpmvWorkload, hw: HardwareParams) -> float:
    """Condensed put (UPCv3ᵀ): segment-combine pack, one consolidated
    message per pair (eq. 13 on the swapped volumes), accumulate-unpack."""
    c = w.counts
    comp = t_comp_per_thread(w, hw)
    parts = put_components(w, hw)

    comm = -np.inf
    for node in range(w.topology.num_nodes):
        th = _threads_of_node(w.topology, node)
        t_local = np.max(2.0 * c.s_local_out[th] * hw.elem / hw.w_private)
        t_remote = np.sum(
            c.c_remote_out[th] * hw.tau
            + c.s_remote_out[th] * hw.elem / hw.w_remote
        )
        comm = max(comm, np.max(parts["pack"][th]) + t_local + t_remote)

    tail = np.max(parts["init"] + parts["accumulate"] + comp)
    return float(comm + tail)


def predict_put_overlap(w: SpmvWorkload, hw: HardwareParams) -> float:
    """Condensed put with the own-accumulate (and the producing compute)
    hiding the exchange: the memput phase max-composes with the own-shard
    work instead of adding to it; the tail is the foreign accumulate only."""
    c = w.counts
    comp = t_comp_per_thread(w, hw)
    parts = put_components(w, hw)
    s_in = c.s_local_in + c.s_remote_in
    own_grain = hw.elem if w.use_kernel else hw.elem + hw.cacheline
    t_own = parts["own_occ"] * own_grain / hw.w_private + comp

    comm = -np.inf
    for node in range(w.topology.num_nodes):
        th = _threads_of_node(w.topology, node)
        t_local = np.max(2.0 * c.s_local_out[th] * hw.elem / hw.w_private)
        t_remote = np.sum(
            c.c_remote_out[th] * hw.tau
            + c.s_remote_out[th] * hw.elem / hw.w_remote
        )
        t_memput = np.max(parts["pack"][th]) + t_local + t_remote
        comm = max(comm, max(t_memput, float(np.max(t_own[th]))))

    foreign_grain = (hw.elem + hw.idx if w.use_kernel
                     else hw.elem + hw.idx + hw.cacheline)
    t_foreign = s_in * foreign_grain / hw.w_private
    tail = np.max(parts["init"] + t_foreign)
    return float(comm + tail)


def predict_put_v2(w: SpmvWorkload, hw: HardwareParams) -> float:
    """Blockwise put (UPCv2ᵀ): contributions combine into whole virtual
    blocks (one scatter-grain write each), only touched blocks travel
    (eq. 11 on the swapped block counts), landed blocks accumulate into
    the owned slice at block granularity."""
    c = w.counts
    bs_bytes = w.blocksize * hw.elem
    contribs = float(w.rows_per_shard * w.r_nz)
    pack_grain = (hw.elem + hw.idx if w.use_kernel
                  else hw.elem + hw.cacheline)
    t_pack = np.full(w.p, contribs * pack_grain / hw.w_private)
    t_comp = t_comp_per_thread(w, hw)
    total = -np.inf
    for node in range(w.topology.num_nodes):
        th = _threads_of_node(w.topology, node)
        t_local = np.max(c.b_local[th] * 2.0 * bs_bytes / hw.w_private)
        t_remote = np.sum(c.b_remote[th] * (hw.tau + bs_bytes / hw.w_remote))
        total = max(total,
                    np.max(t_comp[th] + t_pack[th]) + t_local + t_remote)
    # accumulate tail: every landed block position read-modify-written
    # (single-pass under the block-unit accumulate kernel)
    acc_factor = 1.0 if w.use_kernel else 2.0
    t_acc = np.max((c.b_local + c.b_remote) * w.blocksize
                   * acc_factor * hw.elem / hw.w_private)
    return float(total + t_acc)


def predict_put_replicate(w: SpmvWorkload, hw: HardwareParams) -> float:
    """Naive put: every device combines all its contributions into a
    private full-length vector, then a whole-vector all-reduce (double the
    replicate all-gather's volume: reduce-scatter + all-gather)."""
    topo = w.topology
    per_node_shards = topo.shards_per_node
    contribs = float(w.rows_per_shard * w.r_nz)
    acc_grain = (hw.elem + hw.idx if w.use_kernel
                 else hw.elem + hw.cacheline)
    t_acc = (contribs * acc_grain + 2.0 * w.n * hw.elem) / hw.w_private
    local_vol = (per_node_shards - 1) * w.shard_size * hw.elem
    remote_vol = (w.n - per_node_shards * w.shard_size) * hw.elem
    t_comm = 2.0 * (
        2.0 * local_vol / hw.w_private
        + (hw.tau * max(0, topo.num_nodes - 1) + remote_vol / hw.w_remote)
    )
    return float(np.max(t_comp_per_thread(w, hw)) + t_acc + t_comm)


def predict_put_all(w: SpmvWorkload, hw: HardwareParams) -> dict[str, float]:
    return {name: float(fn(w, hw))
            for name, fn in PUT_STRATEGY_PREDICTORS.items()}


# runtime strategy name (strategies.STRATEGIES) -> §5 put-direction predictor
PUT_STRATEGY_PREDICTORS = {
    "replicate": predict_put_replicate,
    "blockwise": predict_put_v2,
    "condensed": predict_put_v3,
    "overlap": predict_put_overlap,
}


# --------------------------------------------------------------------------
# Fused-window composition (eq. 23, docs/perf_model.md) — a chain of
# exchanges issued inside ONE planned communication window
# (``repro.comm.schedule.ExchangeSchedule``).  Each §5 predictor prices a
# *standalone* exchange: its total includes, once, the per-window setup —
# the cross-node synchronization every bulk-synchronous window pays before
# any payload moves (the paper's barrier bracketing, eq. 18; one tau per
# inter-node hop, serialized across the node count like eq. 13's per-node
# latency sum).  A schedule consolidates K exchanges into one prepared
# window: the collectives issue back-to-back inside one program, so the
# setup is paid once and the remaining K-1 are saved.  The variable terms
# (pack, payload, unpack, compute tails) are untouched — they are
# per-stage physics — and the window can never beat its slowest stage.
# --------------------------------------------------------------------------


def window_setup_time(topo: Topology, hw: HardwareParams) -> float:
    """Per-window setup: one tau per inter-node hop of the barrier that
    brackets a bulk-synchronous exchange window (0 on a single node)."""
    return hw.tau * max(0, topo.num_nodes - 1)


def predict_schedule(stages, hw: HardwareParams) -> dict:
    """Eq. 23: price a fused multi-exchange window.

    ``stages``: sequence of ``(name, direction, workload, strategy)`` with
    ``direction`` in ``{"get", "put"}`` and ``strategy`` a ladder rung or
    ``None`` (pick the direction's §5 argmin per stage — different rungs
    per stage, one shared consolidation point).  Returns::

        {"total":          fused-window seconds,
         "sum_standalone": back-to-back one-shot seconds (Σ per-stage),
         "setup_saved":    (K-1) × window_setup_time,
         "stages":         [(name, direction, strategy, seconds), ...]}

    with ``total = max(sum_standalone - setup_saved, max stage time)``.
    """
    per = []
    topo = None
    for name, direction, w, strategy in stages:
        if direction not in ("get", "put"):
            raise ValueError(f"direction must be 'get' or 'put': {direction}")
        predictors = (PUT_STRATEGY_PREDICTORS if direction == "put"
                      else STRATEGY_PREDICTORS)
        if strategy is None:
            strategy, t = min(
                ((s, float(fn(w, hw))) for s, fn in predictors.items()),
                key=lambda kv: kv[1])
        else:
            t = float(predictors[strategy](w, hw))
        per.append((name, direction, strategy, t))
        topo = topo if topo is not None else w.topology
    assert per, "predict_schedule needs at least one exchange stage"
    times = [t for (_, _, _, t) in per]
    saved = (len(per) - 1) * window_setup_time(topo, hw)
    total = max(sum(times) - saved, max(times))
    return {"total": float(total), "sum_standalone": float(sum(times)),
            "setup_saved": float(saved), "stages": per}


# --------------------------------------------------------------------------
# Eq.-23 steady-state extension: a fused window re-entered n times inside
# one persistent scan window (docs/perf_model.md "Steady-state loops").
# A per-step re-dispatched loop pays the full window cost every iteration;
# a ScanSchedule keeps the window open across the whole loop, so the setup
# term is paid once and each iteration pays only the variable terms.  A
# double-buffered stage additionally hides compute of the NEXT iteration
# inside the in-flight window — the cross-step analogue of the overlap
# rung — modeled as a flat per-iteration credit floored at the credit
# itself (the hidden compute still has to run).
# --------------------------------------------------------------------------


def scan_loop_cost(t_call: float, setup: float, n_steps: int, *,
                   overlap_credit: float = 0.0) -> float:
    """Steady-state cost of ``n_steps`` iterations of one exchange window
    inside a persistent scan window::

        T_loop = T_setup + n * max(T_call - T_setup - credit, credit)

    ``t_call`` is the one-shot window cost (setup included), so the
    per-iteration term strips the setup (paid once for the loop) and any
    cross-step ``overlap_credit``, floored at the credit — hiding compute
    inside the window never makes the compute itself free."""
    steady = max(float(t_call) - float(setup) - float(overlap_credit),
                 float(overlap_credit), 0.0)
    return float(setup) + int(n_steps) * steady


def predict_scan_schedule(stages, hw: HardwareParams, n_steps: int, *,
                          overlap_credit: float = 0.0) -> dict:
    """Eq.-23 steady-state extension: price ``n_steps`` iterations of a
    fused multi-exchange window kept open across a scan.

    ``stages`` is the ``predict_schedule`` stage-spec list.  Returns::

        {"total":          T_setup + n * per_iter,
         "per_iter":       max(per_call - setup - credit, credit),
         "per_call":       the eq.-23 one-shot fused-window cost,
         "setup":          window_setup_time (paid once for the loop),
         "sum_redispatch": n * per_call — the per-step re-dispatch
                           baseline (one fresh window per iteration),
         "n_steps", "overlap_credit",
         "stages":         the per-stage terms of the one-shot window}
    """
    win = predict_schedule(stages, hw)
    topo = stages[0][2].topology
    setup = window_setup_time(topo, hw)
    per_call = win["total"]
    per_iter = max(per_call - setup - overlap_credit, overlap_credit, 0.0)
    return {"total": float(setup + n_steps * per_iter),
            "per_iter": float(per_iter),
            "per_call": float(per_call),
            "setup": float(setup),
            "sum_redispatch": float(n_steps * per_call),
            "n_steps": int(n_steps),
            "overlap_credit": float(overlap_credit),
            "stages": win["stages"]}


# --------------------------------------------------------------------------
# T_plan: the plan-acquisition term (§5 extension for dynamic patterns).
# The paper's models price only the executor — its one-time preparation
# step (§4.3.1) amortizes to zero over ~1000 iterations of a static
# pattern.  A per-batch pattern re-pays plan acquisition every use, so the
# term re-enters the model: each tier of ``repro.comm.dynamic`` (the
# telemetry's plan *sources*) has a closed form over the pattern's nnz =
# m·r index entries, all streaming through private memory at w_private:
#
#   host-build    — the O(nnz) preparation: one read + one write of the
#                   index set around an O(nnz log nnz) grouping sort.
#   device-derive — the in-jit derivation: the same sort, but fused with
#                   the table writes (no separate materialized pass).
#   disk-hit      — decompress + copy the serialized tables: ~2 passes.
#   bucket-reuse / memory-hit — hand over a resident pointer: ~1 pass
#                   (the key hash still touches the quantized stats).
#
# Thread the result through ``select.rank_strategies(plan_cost=...)``:
# it is a flat per-use addend, applied after any scan-loop scaling,
# because a plan is acquired once per use — once per loop, not per step.
# --------------------------------------------------------------------------

# Ordered cheapest-first; mirrors ``repro.comm.telemetry.PLAN_SOURCES``.
PLAN_SOURCES = ("memory-hit", "disk-hit", "bucket-reuse", "device-derive",
                "host-build")


def plan_build_time(m: int, r: int, hw: HardwareParams, *,
                    source: str = "host-build") -> float:
    """T_plan: seconds to obtain executor tables for an (m, r) pattern
    via one plan ``source`` (a ``telemetry.PLAN_SOURCES`` name)."""
    nnz = max(1, int(m) * int(r))
    idx_bytes = nnz * hw.idx
    log_term = max(1.0, np.log2(nnz))
    if source == "host-build":
        passes = 2.0 + log_term
    elif source == "device-derive":
        passes = log_term
    elif source == "disk-hit":
        passes = 2.0
    elif source in ("bucket-reuse", "memory-hit"):
        passes = 1.0
    else:
        raise ValueError(
            f"unknown plan source {source!r}; expected one of {PLAN_SOURCES}")
    return float(passes * idx_bytes / hw.w_private)


def replan_break_even_steps(t_plan: float, t_stale: float,
                            t_fresh: float) -> float:
    """Steps over which a fresh plan pays back its T_plan.

    A drifted pattern served by a stale (envelope/bucket) plan costs
    ``t_stale`` per step; rebuilding costs ``t_plan`` once, then
    ``t_fresh`` per step.  Replanning wins after::

        n* = t_plan / (t_stale - t_fresh)

    steps; ``inf`` when the stale plan is no slower (``t_stale <=
    t_fresh`` — never replan).  This is the MD/neighbor-list regime:
    lists drift slowly, so rebuild every ~n* steps and ride the stale
    plan in between."""
    gain = float(t_stale) - float(t_fresh)
    if gain <= 0.0:
        return float("inf")
    return float(t_plan) / gain


def _threads_of_node(topo: Topology, node: int) -> np.ndarray:
    lo = node * topo.shards_per_node
    return np.arange(lo, lo + topo.shards_per_node)


# --------------------------------------------------------------------------
# Decode regime — tiny-m latency floors (eqs. 12δ–15δ, docs/perf_model.md).
# Every model above is a throughput model: the β (volume / bandwidth) terms
# dominate because a training or prefill exchange moves thousands of
# elements per shard.  Token-by-token decode inverts that: one routed
# token per slot per step, so the per-message α (latency) terms dominate
# and the volume terms of eqs. 12–15 price a transfer that is smaller than
# one cacheline.  The floor keeps the §5 structure — exactly counted
# per-thread volumes, per-node maxima — but charges what a tiny message
# actually costs:
#
#   * every touched element moves a full cacheline plus its index entry
#     through private memory (no streaming amortization at m ~ p):
#     T_touchδ = (s_out + s_in) · (cacheline + idx) / w_private;
#   * every message pays a full τ regardless of payload, plus one τ per
#     thread for the step's issue/poll (paid even by threads with nothing
#     to send — the bulk-synchronous window still crosses them):
#     T_wireδ = Σ_threads-of-node (msgs_i + 1) · τ;
#   * message counts per rung: replicate broadcasts to every other node,
#     blockwise sends one message per needed remote block, condensed /
#     overlap send the consolidated c_remote_out messages;
#   * plus the per-window setup the schedule models already price.
#
# A rung's decode prediction is max(β model, α floor) — the floor can only
# raise a prediction, so throughput-regime rankings are untouched.
# --------------------------------------------------------------------------


def decode_floor(w: SpmvWorkload, hw: HardwareParams, *,
                 strategy: str = "condensed", direction: str = "get") -> float:
    """α/latency floor of one decode-step exchange (tiny-m eqs. 12δ–15δ).

    ``w.counts`` must already match ``direction`` (a put workload is built
    from the transposed ``ScatterPlan`` counts, as everywhere else); the
    touch term is send/recv symmetric so only the message counts differ.
    """
    c = w.counts
    if strategy == "replicate":
        msgs = np.full(w.p, float(max(0, w.topology.num_nodes - 1)))
    elif strategy == "blockwise":
        msgs = np.asarray(c.b_remote, float)
    elif strategy in ("condensed", "overlap"):
        msgs = np.asarray(c.c_remote_out, float)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    touched = np.asarray(c.s_local_out + c.s_remote_out
                         + c.s_local_in + c.s_remote_in, float)
    touch = touched * (hw.cacheline + hw.idx) / hw.w_private
    worst = 0.0
    for node in range(w.topology.num_nodes):
        th = _threads_of_node(w.topology, node)
        wire = float(((msgs[th] + 1.0) * hw.tau).sum())
        worst = max(worst, float(touch[th].max()) + wire)
    return float(worst + window_setup_time(w.topology, hw))


def predict_decode_exchange(w: SpmvWorkload, hw: HardwareParams, *,
                            strategy: str = "condensed",
                            direction: str = "get") -> float:
    """Decode-step price of one exchange: max(β throughput model, α floor).

    The throughput predictors under-charge a tiny transfer (their latency
    terms assume messages big enough to amortize); the floor under-charges
    a bulk one (it ignores bandwidth).  The max is the crossover-correct
    composite — it degrades to the plain §5 prediction exactly when the
    volume terms dominate, so it is safe to apply at every batch size.
    """
    predictors = (PUT_STRATEGY_PREDICTORS if direction == "put"
                  else STRATEGY_PREDICTORS)
    base = float(predictors[strategy](w, hw))
    return float(max(base, decode_floor(w, hw, strategy=strategy,
                                        direction=direction)))


def predict_decode_step(stages, hw: HardwareParams) -> dict:
    """Eq. 23 composed over decode-priced stages: one serving decode tick.

    Same stage spec as ``predict_schedule`` (``(name, direction, workload,
    strategy-or-None)``); each stage is priced by
    ``predict_decode_exchange`` and the fused window consolidates the K-1
    redundant setups exactly as in the throughput model.  The extra
    ``latency_bound`` entry names the stages whose α floor exceeded their
    β model — at decode batch sizes {1..32} that should be all of them;
    if it ever comes back empty the workload left the decode regime and
    the plain ``predict_schedule`` applies.
    """
    per = []
    latency_bound = []
    topo = None
    for name, direction, w, strategy in stages:
        if direction not in ("get", "put"):
            raise ValueError(f"direction must be 'get' or 'put': {direction}")
        predictors = (PUT_STRATEGY_PREDICTORS if direction == "put"
                      else STRATEGY_PREDICTORS)
        if strategy is None:
            strategy, t = min(
                ((s, predict_decode_exchange(w, hw, strategy=s,
                                             direction=direction))
                 for s in predictors),
                key=lambda kv: kv[1])
        else:
            t = predict_decode_exchange(w, hw, strategy=strategy,
                                        direction=direction)
        if t > float(predictors[strategy](w, hw)):
            latency_bound.append(name)
        per.append((name, direction, strategy, float(t)))
        topo = topo if topo is not None else w.topology
    assert per, "predict_decode_step needs at least one exchange stage"
    times = [t for (_, _, _, t) in per]
    saved = (len(per) - 1) * window_setup_time(topo, hw)
    total = max(sum(times) - saved, max(times))
    return {"total": float(total), "sum_standalone": float(sum(times)),
            "setup_saved": float(saved), "stages": per,
            "latency_bound": tuple(latency_bound)}


# --------------------------------------------------------------------------
# §8 — 2D heat equation on a uniform mesh, eqs. (19)–(22)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Heat2DWorkload:
    """Global M×N mesh on an mprocs×nprocs process grid (paper §8.1)."""

    big_m: int
    big_n: int
    mprocs: int
    nprocs: int
    topology: Topology  # over mprocs*nprocs threads, row-major rank order

    @property
    def m(self) -> int:  # local rows incl. halo
        return self.big_m // self.mprocs + 2

    @property
    def n(self) -> int:  # local cols incl. halo
        return self.big_n // self.nprocs + 2


def _heat2d_volumes(w: Heat2DWorkload):
    """Per-thread halo volumes (elements), split horizontal / all, local /
    remote, plus remote message counts — exact counting, per thread."""
    p = w.mprocs * w.nprocs
    node = w.topology.node_of(np.arange(p))
    s_horiz = np.zeros(p)
    s_local = np.zeros(p)
    s_remote = np.zeros(p)
    c_remote = np.zeros(p)
    inner_m, inner_n = w.m - 2, w.n - 2
    for ip in range(w.mprocs):
        for kp in range(w.nprocs):
            r = ip * w.nprocs + kp
            nbrs = []
            if kp > 0:
                nbrs.append((ip * w.nprocs + kp - 1, inner_m, True))
            if kp < w.nprocs - 1:
                nbrs.append((ip * w.nprocs + kp + 1, inner_m, True))
            if ip > 0:
                nbrs.append(((ip - 1) * w.nprocs + kp, inner_n, False))
            if ip < w.mprocs - 1:
                nbrs.append(((ip + 1) * w.nprocs + kp, inner_n, False))
            for (nr, vol, horiz) in nbrs:
                if horiz:
                    s_horiz[r] += vol
                if node[nr] == node[r]:
                    s_local[r] += vol
                else:
                    s_remote[r] += vol
                    c_remote[r] += 1
    return s_horiz, s_local, s_remote, c_remote


def predict_heat2d(
    w: Heat2DWorkload, hw: HardwareParams, steps: int = 1,
    materialize: str | None = None,
) -> dict[str, float]:
    """Returns {"halo": T_2D_halo, "comp": T_2D_comp} for ``steps`` steps.

    ``materialize`` mirrors the SpMV models: ``None``/``"dest"`` is the
    paper's in-place O(halo) unpack (eqs. 19–21 as written — exactly what
    the strip-targeted ``Destination`` runs); ``"full"`` adds the eq.-(15')
    per-step tax of assembling the big_m*big_n ``mythread_x_copy``.
    """
    s_horiz, s_local, s_remote, c_remote = _heat2d_volumes(w)

    # eq. (19): pack == unpack (horizontal only; vertical is contiguous)
    t_pack = s_horiz * (hw.elem + hw.cacheline) / hw.w_private

    # eq. (20): per-node memget
    halo = -np.inf
    for nd in range(w.topology.num_nodes):
        th = _threads_of_node(w.topology, nd)
        t_loc = np.max(2.0 * s_local[th] * hw.elem / hw.w_private)
        t_rem = np.sum(
            c_remote[th] * hw.tau + s_remote[th] * hw.elem / hw.w_remote
        )
        halo = max(
            halo, np.max(t_pack[th]) + t_loc + t_rem + np.max(t_pack[th])
        )  # eq. (21): pack + memget + unpack, max-composed per node

    if materialize == "full":
        halo += full_assembly_tax(w.big_m * w.big_n, hw)

    # eq. (22): 3 * (m-2) * (n-2) * elem / w_private
    comp = 3.0 * (w.m - 2) * (w.n - 2) * hw.elem / hw.w_private
    return {"halo": steps * float(halo), "comp": steps * float(comp)}


def heat2d_edge_ring_comp(w: Heat2DWorkload, hw: HardwareParams) -> float:
    """Edge-ring compute cost of the Heat2D ``overlap`` split (per step).

    The split runs the tile interior while the halo exchange is in flight,
    then updates the one-cell edge ring from four thin strips of the padded
    tile.  Each strip is a full 3-wide stencil band (the kernel computes
    the whole band to extract its single ring row/column), so the ring
    pays eq.-22 traffic on 3 cells per ring cell — the overhead the plain
    eq. 19–22 window never sees, and the term that decides ``overlap`` vs
    ``condensed`` for skinny tiles where the ring *is* the tile.
    """
    mi, ni = w.m - 2, w.n - 2          # interior tile (paper m/n incl. halo)
    band_cells = 2 * 3 * (ni + 2) + 2 * 3 * (mi + 2)
    return 3.0 * band_cells * hw.elem / hw.w_private


def predict_heat2d_window(
    w: Heat2DWorkload, hw: HardwareParams, steps: int = 1,
    materialize: str | None = None,
) -> dict[str, float]:
    """Full per-step window cost of the two Heat2D execution shapes.

    * ``"condensed"`` — eqs. 19–22 sequentially: halo exchange, then the
      whole-tile update.
    * ``"overlap"`` — the interior update (no halo dependency) hides the
      exchange (max-composition), then the edge ring pays
      ``heat2d_edge_ring_comp`` — the ROADMAP refinement: without the ring
      term the model would call ``overlap`` free whenever compute covers
      the exchange, mispicking on small tiles where the four 3-wide strips
      recompute more than the whole tile costs.

    ``strategy="auto"`` on ``Heat2D`` re-prices these two rungs with this
    window cost (the generic §5 exchange models keep pricing the
    ``replicate``/``blockwise`` rungs).
    """
    base = predict_heat2d(w, hw, steps=1, materialize=materialize)
    mi, ni = w.m - 2, w.n - 2
    interior = 3.0 * max(mi - 2, 0) * max(ni - 2, 0) * hw.elem / hw.w_private
    ring = heat2d_edge_ring_comp(w, hw)
    cond = base["halo"] + base["comp"]
    ovl = max(base["halo"], interior) + ring
    return {"condensed": steps * float(cond), "overlap": steps * float(ovl)}


def predict_heat2d_scan(
    w: Heat2DWorkload, hw: HardwareParams, steps: int,
    materialize: str | None = None,
) -> dict:
    """Steady-state Heat2D loop cost under ONE persistent scan window
    (``Heat2D.run`` on a ``ScanSchedule``) — the eq. 19–22 analogue of
    ``predict_scan_schedule``.

    * ``"condensed"`` — the whole-tile update repeats inside the window:
      the per-window setup is paid once, each iteration pays the variable
      halo terms plus eq.-22 compute (floored at the compute — the update
      always runs).
    * ``"overlap"`` — the double-buffered split: step k+1's halo exchange
      is issued right after step k's edge ring lands in the half-updated
      field, so the ENTIRE next interior update hides inside the in-flight
      window; each iteration pays ring + max(halo - setup, interior).

    Returns ``{"condensed", "overlap"}`` loop totals plus ``"per_iter"``
    (both per-iteration terms), ``"setup"``, and ``"redispatch"`` — the
    per-step re-dispatch baseline (``predict_heat2d_window × steps``) that
    ``table5`` compares the scan path against.
    """
    base = predict_heat2d(w, hw, steps=1, materialize=materialize)
    win = predict_heat2d_window(w, hw, steps=1, materialize=materialize)
    setup = window_setup_time(w.topology, hw)
    mi, ni = w.m - 2, w.n - 2
    interior = 3.0 * max(mi - 2, 0) * max(ni - 2, 0) * hw.elem / hw.w_private
    ring = heat2d_edge_ring_comp(w, hw)
    per_cond = max(win["condensed"] - setup, base["comp"])
    per_ovl = ring + max(base["halo"] - setup, interior)
    return {"condensed": float(setup + steps * per_cond),
            "overlap": float(setup + steps * per_ovl),
            "per_iter": {"condensed": float(per_cond),
                         "overlap": float(per_ovl)},
            "setup": float(setup),
            "redispatch": {"condensed": float(steps * win["condensed"]),
                           "overlap": float(steps * win["overlap"])}}


# ---------------------------------------------------------------------------
# Model-error budgets — the standing predicted-vs-measured regression gate
# (benchmarks/matrix.py fails the smoke job when any cell drifts past its
# budget; tests/helpers/model_error.py asserts the same tolerances in-suite)
# ---------------------------------------------------------------------------

def model_error(measured: float, predicted: float) -> float:
    """Symmetric relative drift between a measured and a predicted time.

    Defined as ``max(a, b) / min(a, b) - 1`` — the dual of the benchmark
    tables' ``accuracy = min/max`` column (``error == 1/accuracy - 1``), so
    a model that is 2x off in EITHER direction scores 1.0.  Symmetric on
    purpose: an over-prediction mis-ranks the ladder exactly as badly as an
    under-prediction.

    >>> round(model_error(2.0, 1.0), 3)   # 2x off, either direction
    1.0
    >>> round(model_error(1.0, 2.0), 3)
    1.0
    >>> model_error(1.5, 1.5)
    0.0
    """
    a, b = float(measured), float(predicted)
    if a < 0.0 or b < 0.0:
        raise ValueError(f"times must be non-negative, got ({a}, {b})")
    if a == 0.0 and b == 0.0:
        return 0.0
    lo, hi = min(a, b), max(a, b)
    if lo == 0.0:
        return float("inf")
    return hi / lo - 1.0


# The gate bounds GROSS drift, not noise: host-device smoke runs measure
# XLA collectives on timeshared CPU cores, where a fixed per-call dispatch
# floor (~hundreds of us) dwarfs the us-scale §5 comm terms at CI sizes —
# the seed table3 rows sit between accuracy 0.95 and 0.03 depending on
# rung, i.e. model_error up to ~30 even when the formulas are right.  The
# budgets below encode that observed envelope with headroom ~2-3x, so a
# broken formula (wrong volume term, dropped tau factor — typically >=10x
# further drift) trips the gate while routine scheduler jitter does not.
# On a real accelerator these budgets should be tightened per-platform.
ERROR_BUDGET_DEFAULT = 120.0

# per-rung base tolerance on model_error(measured, predicted)
ERROR_BUDGET_RUNGS = {
    "replicate": 60.0,   # bcast pressure is timeshare-sensitive
    "blockwise": 150.0,  # whole-block volume tax swamps host noise worst
    "condensed": 120.0,  # smallest predicted times -> dispatch floor bites
    "overlap": 140.0,    # hiding credit assumes async progress CPUs lack
    "auto": 120.0,       # priced by whichever rung it resolves to
}

# per-workload multiplier: feature-wide payloads (elem folded into hw.elem)
# and skew-concentrated patterns predict less tightly on host devices
ERROR_BUDGET_WORKLOADS = {
    "spmv": 1.0,
    "spmv_skewed": 1.5,
    "moe_dispatch": 2.0,
    "gnn": 2.0,
    # kernelized pack/unpack: interpret-mode pallas_call adds per-call
    # dispatch overhead on CPU hosts that the kernel terms (priced for a
    # real accelerator) deliberately do not carry
    "spmv_kernel": 1.5,
    # decode-step exchanges (predict_decode_exchange): per-step volumes are
    # a handful of cachelines, so the measured time is almost entirely the
    # host's fixed dispatch floor — the widest envelope in the matrix
    "moe_decode": 3.0,
}

# per-dtype multiplier: sub-f32 arithmetic is emulated on CPU hosts, so
# the compute terms mis-price by an extra platform factor
ERROR_BUDGET_DTYPES = {
    "float32": 1.0,
    "bfloat16": 2.0,
}

# multi-axis meshes route the collective over a product axis tuple; the
# per-hop tau calibration only sees the flat product
ERROR_BUDGET_MESH_MULTIDIM = 1.5


def error_budget(cell) -> float:
    """Model-error tolerance for one benchmark-matrix cell.

    ``cell`` is any mapping with (all optional) keys ``rung`` (ladder
    strategy name; ``strategy`` accepted as an alias), ``workload``,
    ``dtype``, and ``mesh`` (axis-shape sequence).  Unknown values fall
    back to the neutral factor so new axis entries are never silently
    un-gated — they get the conservative default instead.

    >>> error_budget({"rung": "condensed", "workload": "spmv",
    ...               "dtype": "float32", "mesh": [8]})
    120.0
    >>> error_budget({}) == ERROR_BUDGET_DEFAULT
    True
    >>> error_budget({"rung": "condensed", "workload": "gnn",
    ...               "dtype": "bfloat16", "mesh": [2, 4]})
    720.0
    """
    rung = cell.get("rung") or cell.get("strategy") or ""
    base = ERROR_BUDGET_RUNGS.get(rung, ERROR_BUDGET_DEFAULT)
    scale = ERROR_BUDGET_WORKLOADS.get(cell.get("workload"), 1.0)
    scale *= ERROR_BUDGET_DTYPES.get(cell.get("dtype"), 1.0)
    mesh = cell.get("mesh") or ()
    try:
        multidim = len(tuple(mesh)) > 1
    except TypeError:
        multidim = False
    if multidim:
        scale *= ERROR_BUDGET_MESH_MULTIDIM
    return float(base * scale)
