"""Synthetic EllPack sparse matrices with unstructured-mesh-like structure.

The paper's test problems are finite-volume discretizations over tetrahedral
meshes: every row has a fixed number of off-diagonal nonzeros (r_nz = 16) whose
column indices are irregular but — after mesh reordering — mostly *local*
(close to the diagonal), with occasional long-range couplings.  We reproduce
that structure synthetically and deterministically so that communication plans,
performance models and benchmarks are exactly repeatable.

Storage follows the paper's *modified EllPack* format (Section 3.1):
  M = D + A,  D the main diagonal (length n),
  A the off-diagonal nonzeros: ``vals`` (n, r_nz) and column indices
  ``cols`` (n, r_nz).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["EllpackMatrix", "make_mesh_like_matrix", "spmv_ref_np",
           "spmv_t_ref_np"]


@dataclasses.dataclass(frozen=True)
class EllpackMatrix:
    """Modified EllPack storage: M = diag(D) + A."""

    n: int
    r_nz: int
    diag: np.ndarray  # (n,)        float
    vals: np.ndarray  # (n, r_nz)   float
    cols: np.ndarray  # (n, r_nz)   int32, in [0, n)

    def __post_init__(self):
        assert self.diag.shape == (self.n,)
        assert self.vals.shape == (self.n, self.r_nz)
        assert self.cols.shape == (self.n, self.r_nz)
        assert self.cols.dtype == np.int32

    @property
    def nnz(self) -> int:
        return self.n * (self.r_nz + 1)

    def max_window_span(self, rows_per_block: int) -> int:
        """Max column span (hi-lo+1) over row blocks — sizes the kernel's
        VMEM x-window (see kernels/ellpack_spmv.py)."""
        n_blocks = self.n // rows_per_block
        cols = self.cols[: n_blocks * rows_per_block].reshape(
            n_blocks, rows_per_block * self.r_nz
        )
        span = cols.max(axis=1) - cols.min(axis=1) + 1
        return int(span.max())


def make_mesh_like_matrix(
    n: int,
    r_nz: int = 16,
    *,
    locality_window: int | None = None,
    long_range_frac: float = 0.0,
    seed: int = 0,
    dtype=np.float32,
) -> EllpackMatrix:
    """Build a synthetic matrix mimicking a reordered tetrahedral mesh.

    Off-diagonal columns for row ``i`` are drawn from a band
    ``[i - w, i + w]`` (w = ``locality_window``, default ``max(64, n // 256)``),
    with an optional ``long_range_frac`` fraction re-drawn uniformly over
    ``[0, n)`` to exercise non-neighbor communication.  Deterministic in
    ``seed``.
    """
    rng = np.random.default_rng(seed)
    if locality_window is None:
        locality_window = max(64, n // 256)
    w = int(locality_window)

    offsets = rng.integers(-w, w + 1, size=(n, r_nz), dtype=np.int64)
    # avoid offset 0 (the diagonal is stored separately)
    offsets[offsets == 0] = 1
    rows = np.arange(n, dtype=np.int64)[:, None]
    cols = np.clip(rows + offsets, 0, n - 1)

    if long_range_frac > 0.0:
        mask = rng.random(size=cols.shape) < long_range_frac
        cols[mask] = rng.integers(0, n, size=int(mask.sum()), dtype=np.int64)

    vals = rng.standard_normal((n, r_nz)).astype(dtype) / r_nz
    # diagonally dominant, as diffusion matrices are
    diag = (np.abs(vals).sum(axis=1) + 1.0).astype(dtype)
    return EllpackMatrix(
        n=n, r_nz=r_nz, diag=diag, vals=vals, cols=cols.astype(np.int32)
    )


def spmv_ref_np(m: EllpackMatrix, x: np.ndarray) -> np.ndarray:
    """Ground-truth SpMV in numpy (paper Listing 1)."""
    return m.diag * x + np.einsum("ij,ij->i", m.vals, x[m.cols])


def spmv_t_ref_np(m: EllpackMatrix, x: np.ndarray) -> np.ndarray:
    """Ground-truth transposed SpMV: y = (D + A)ᵀ x.

    Row i's off-diagonal entry (vals[i, j] at column cols[i, j]) becomes a
    *contribution* vals[i, j] * x[i] to y[cols[i, j]] — the push-direction
    dual of the gather-based forward product.
    """
    y = (m.diag * x).astype(x.dtype)
    np.add.at(y, m.cols.ravel(), (m.vals * x[:, None]).ravel())
    return y
