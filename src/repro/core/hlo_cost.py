"""Trip-count-aware cost analysis of optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` visits a while-loop body ONCE,
so any scanned program (scan-over-layers, flash-attention chunk scans, fused
losses — i.e. everything in this framework) is undercounted by the trip
count.  This module walks the HLO call graph instead:

    cost(entry) = Σ own instructions
                + Σ cost(called computation) × multiplier
      multiplier = known_trip_count for ``while`` (from backend_config),
                   1 for fusions / calls / branches.

Counted quantities per computation:
  * FLOPs: ``dot`` (2 × numel(result) × contracted-dims) and ``convolution``
    (2 × numel(result) × kernel reduction size); elementwise ops are ignored
    (dots dominate transformer arithmetic by orders of magnitude).
  * HBM bytes: Σ output bytes of materialized top-level instructions
    (post-fusion roots) + operand bytes for dot/convolution (matmuls stream
    their operands from HBM).  Control flow (while/conditional/call own
    tuples), GTEs, bitcasts, parameters and constants are free — their
    interiors/consumers are charged directly.  A post-fusion
    materialization-traffic model: what a TPU actually writes to and reads
    from HBM, assuming XLA's fusion decisions carry over.
  * Collective bytes: per-op, ring-model bytes (see core.roofline), with
    while-body collectives correctly multiplied.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done",
}


def _shape_numel_bytes(typestr: str):
    total_b = 0
    total_n = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total_n += numel
        total_b += numel * _DTYPE_BYTES[dt]
    return total_n, total_b


def _shape_dims(typestr: str):
    m = _SHAPE_RE.search(typestr)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: list  # (name, typestr, op, rest)
    shapes: dict  # instr name -> typestr


def _parse_instr(line: str):
    """'%name = TYPE op(rest' with TYPE possibly a nested tuple."""
    m = _INSTR_HEAD_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":       # tuple type: scan balanced parens
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        typestr = line[i:j + 1]
        i = j + 1
    else:                               # scalar/array type: up to whitespace
        j = line.find(" ", i)
        if j < 0:
            return None
        typestr = line[i:j]
        i = j
    rest = line[i:].lstrip()
    mo = re.match(r"([\w\-]+)\(", rest)
    if not mo:
        return None
    return name, typestr, mo.group(1), rest[mo.end():]


def _parse_computations(text: str) -> tuple[dict, str]:
    comps: dict[str, _Comp] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        s = line.strip()
        if cur is None:
            m = _COMP_START_RE.match(s)
            if m and s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                cur = _Comp(m.group(1), [], {})
                if s.startswith("ENTRY"):
                    entry = cur.name
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_instr(line)
        if parsed:
            name, typestr, op, rest = parsed
            cur.instrs.append((name, typestr, op, rest))
            cur.shapes[name] = typestr
    return comps, entry


def _collective_bytes(op: str, typestr: str, rest: str, num_devices: int,
                      devices_per_pod: int, bf16_program: bool = False):
    from repro.core.roofline import _parse_groups  # reuse group parser
    _, out_bytes = _shape_numel_bytes(typestr)
    if out_bytes == 0:
        return 0.0, 0.0, None
    # XLA:CPU float-normalization legalizes bf16 arithmetic to f32, so
    # collectives fused with dots carry f32 payloads on the dry-run host.
    # On TPU the same program communicates bf16.  When the model is
    # authored bf16 (bf16_program), charge large f32 payloads at 2 B/elem;
    # small f32 collectives (softmax/norm stats, which are genuinely f32)
    # are left uncorrected.
    if bf16_program and "f32[" in typestr and out_bytes >= (1 << 20):
        out_bytes //= 2
    groups = _parse_groups(rest, num_devices)
    if groups:
        g = max(len(grp) for grp in groups)
        crosses = any(
            (np.asarray(grp) // devices_per_pod).min()
            != (np.asarray(grp) // devices_per_pod).max()
            for grp in groups)
    else:
        g = num_devices
        crosses = devices_per_pod < num_devices
    g = max(g, 2)
    kind = op.replace("-start", "")
    if kind == "all-gather":
        moved = out_bytes * (g - 1) / g
    elif kind == "reduce-scatter":
        moved = out_bytes * (g - 1)
    elif kind == "all-reduce":
        moved = 2.0 * out_bytes * (g - 1) / g
    elif kind == "all-to-all":
        moved = out_bytes * (g - 1) / g
    elif kind == "collective-permute":
        moved = float(out_bytes)
    else:
        return 0.0, 0.0, None
    return (0.0, moved, kind) if crosses else (moved, 0.0, kind)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_ici_bytes: float = 0.0
    coll_dci_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_count: float = 0.0

    def __add__(self, o):
        kinds = dict(self.coll_by_kind)
        for k, v in o.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return HloCost(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes,
                       self.coll_ici_bytes + o.coll_ici_bytes,
                       self.coll_dci_bytes + o.coll_dci_bytes, kinds,
                       self.coll_count + o.coll_count)

    def __mul__(self, k: float):
        return HloCost(self.flops * k, self.hbm_bytes * k,
                       self.coll_ici_bytes * k, self.coll_dci_bytes * k,
                       {kk: v * k for kk, v in self.coll_by_kind.items()},
                       self.coll_count * k)


_COLLECTIVE_OPS = {
    "all-gather", "all-gather-start", "all-reduce", "all-reduce-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start",
}


def analyze_hlo(text: str, *, num_devices: int = 1,
                devices_per_pod: int | None = None,
                bf16_program: bool = False) -> HloCost:
    devices_per_pod = devices_per_pod or num_devices
    comps, entry = _parse_computations(text)
    memo: dict[str, HloCost] = {}

    def operand_bytes(comp, rest):
        """Bytes of materialized same-computation operands (first paren
        group of ``rest`` holds the operand list)."""
        depth, j = 1, 0
        while j < len(rest) and depth:
            if rest[j] == "(":
                depth += 1
            elif rest[j] == ")":
                depth -= 1
            j += 1
        total = 0
        for name in _OPERAND_RE.findall(rest[:j]):
            ts = comp.shapes.get(name)
            if ts is not None:
                _, b = _shape_numel_bytes(ts)
                total += b
        return total

    def cost_of(cname: str) -> HloCost:
        if cname in memo:
            return memo[cname]
        comp = comps.get(cname)
        if comp is None:
            return HloCost()
        memo[cname] = HloCost()  # cycle guard
        total = HloCost()
        for (iname, typestr, op, rest) in comp.instrs:
            own = HloCost()
            if op in ("dot", "dot-general"):
                n_out, _ = _shape_numel_bytes(typestr)
                k = 1
                mc = _CONTRACT_RE.search(rest)
                ops = _OPERAND_RE.findall(rest)
                if mc and ops:
                    lhs_shape = comp.shapes.get(ops[0])
                    if lhs_shape:
                        dims = _shape_dims(lhs_shape)
                        for ci in (mc.group(1).split(",")
                                   if mc.group(1) else []):
                            ci = int(ci)
                            if ci < len(dims):
                                k *= dims[ci]
                own.flops = 2.0 * n_out * k
                _, ob = _shape_numel_bytes(typestr)
                own.hbm_bytes = float(ob + operand_bytes(comp, rest))
            elif op == "convolution":
                n_out, ob = _shape_numel_bytes(typestr)
                # reduction size: input feature * kernel spatial (approx from
                # rhs operand numel / output features)
                ops = _OPERAND_RE.findall(rest)
                red = 1
                if len(ops) >= 2 and ops[1] in comp.shapes:
                    rn, _ = _shape_numel_bytes(comp.shapes[ops[1]])
                    dims = _shape_dims(typestr)
                    feat = dims[-1] if dims else 1
                    red = max(1, rn // max(feat, 1))
                own.flops = 2.0 * n_out * red
                own.hbm_bytes = float(ob + operand_bytes(comp, rest))
            elif op in _COLLECTIVE_OPS:
                ici, dci, kind = _collective_bytes(
                    op, typestr, rest, num_devices, devices_per_pod,
                    bf16_program=bf16_program)
                if kind:
                    own.coll_ici_bytes = ici
                    own.coll_dci_bytes = dci
                    own.coll_by_kind = {kind: ici + dci}
                    own.coll_count = 1.0
                _, ob = _shape_numel_bytes(typestr)
                own.hbm_bytes = float(ob)
            elif op in _FREE_OPS or op in ("while", "conditional", "call",
                                           "optimization-barrier"):
                pass  # control flow: interiors are charged directly
            else:
                _, ob = _shape_numel_bytes(typestr)
                own.hbm_bytes = float(ob)

            total = total + own

            # sub-computations
            if op == "while":
                mb = _WHILE_RE.search(rest)
                trip = 1
                mt = _TRIP_RE.search(rest)
                if mt:
                    trip = int(mt.group(1))
                if mb:
                    total = total + cost_of(mb.group(1)) * trip
            elif op == "conditional":
                mbr = _BRANCH_RE.search(rest)
                if mbr:
                    branches = _OPERAND_RE.findall(mbr.group(1))
                    if branches:
                        sub = [cost_of(b) for b in branches]
                        # charge the max-cost branch
                        total = total + max(
                            sub, key=lambda c: (c.flops, c.hbm_bytes))
            else:
                mc2 = _CALLS_RE.search(rest)
                if mc2:
                    callee = mc2.group(1)
                    sub = cost_of(callee)
                    if op == "fusion":
                        # fused interiors are not materialized: keep flops
                        # (a dot may hide inside), drop interior bytes
                        sub = HloCost(sub.flops, 0.0, sub.coll_ici_bytes,
                                      sub.coll_dci_bytes, sub.coll_by_kind,
                                      sub.coll_count)
                    total = total + sub

        memo[cname] = total
        return total

    return cost_of(entry) if entry else HloCost()
