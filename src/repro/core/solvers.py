"""Iterative solvers on persistent exchange windows (``ScanSchedule``).

The paper's irregular-communication machinery was built for one exchange;
real consumers run *time loops* — and a Krylov solver is the sharpest
version of that shape: every iteration needs one fine-grained irregular
product plus a handful of scalar reductions, thousands of times.  Dispatch
the product per iteration and the loop pays a plan-cache probe, a hardware
memo hit and a host round trip per step; declared as ONE ``Schedule.scan``
the whole solve is a single ``shard_map`` window wrapped around a
``lax.scan`` — plans resolve once, and every iteration is collective +
local compute with zero host involvement.

``ConjugateGradient`` is CGNR on the normal equations: it reuses the exact
``z = MᵀM p`` stage graph of ``normal_equations_step``
(``spmv.normal_equations_stages`` — forward gather-product chained into the
transposed scatter-product in one fused window) and adds the CG recurrence
as cheap compute stages around it: the two global dot products are
``psum``-reduced scalars, and the vector updates are O(n/p) local AXPYs.
Since MᵀM is symmetric positive definite whenever M is nonsingular, CGNR
converges for any of the paper's mesh-like test matrices — solving
``M x = b`` in the least-squares sense via ``(MᵀM) x = Mᵀ b``.

Usage (solve (MᵀM) x = b):

    cg = ConjugateGradient(matrix, mesh, strategy="auto")
    x = cg.solve(b, n_steps=50)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.plan import Topology
from repro.comm.schedule import Schedule
from repro.core.matrix import EllpackMatrix
from repro.core.spmv import normal_equations_stages

__all__ = ["ConjugateGradient", "cg_solve"]


def _safe_div(a, b):
    """a / b with 0/0 -> 0 (a converged CG has rs == pz == 0: the iterate
    must then stay fixed instead of going NaN inside the scan)."""
    nz = b != 0
    return jnp.where(nz, a / jnp.where(nz, b, 1.0), 0.0)


class ConjugateGradient:
    """CGNR: iterate x -> x + α p on ``(MᵀM) x = b``, each iteration one
    fused exchange window inside a persistent ``ScanSchedule``.

    The scan body carries ``(x, r, p)``; the ``z = MᵀM p`` product is the
    ``normal_equations_stages`` graph (gather + scatter in one window) and
    the recurrence stages are scalar ``psum`` dots plus local AXPYs:

        α  = (r·r) / (p·z)        x' = x + α p      r' = r − α z
        β  = (r'·r') / (r·r)      p' = r' + β p

    ``strategy`` accepts any rung or ``"auto"``; with ``n_steps_hint`` the
    auto ranking prices the rungs on the n-step steady-state loop cost
    (``perfmodel.scan_loop_cost``) instead of one dispatch.
    """

    def __init__(self, matrix: EllpackMatrix, mesh, *,
                 axis_name: str = "data", strategy: str = "auto",
                 blocksize: int | str | None = None,
                 shards_per_node: int | None = None, hw=None,
                 use_plan_cache: bool = True,
                 n_steps_hint: int | None = None):
        p = int(mesh.shape[axis_name]) if not isinstance(axis_name, tuple) \
            else int(np.prod([mesh.shape[a] for a in axis_name]))
        self.matrix = matrix
        self.mesh = mesh
        self.axis_name = axis_name

        sched = Schedule()
        x = sched.input("x")
        r = sched.input("r")
        pv = sched.input("p")
        z = normal_equations_stages(sched, matrix, p, pv)

        def gdot(a, b):
            return jax.lax.psum(jnp.sum(a * b), axis_name)

        # both dots in one stage: the (r·r, p·z) pair rides a single tiny
        # psum right after the product's window closes
        dots = sched.compute(
            lambda r_l, p_l, z_l: jnp.stack([gdot(r_l, r_l),
                                             gdot(p_l, z_l)]),
            r, pv, z, name="dots")
        x2 = sched.compute(
            lambda x_l, p_l, d: x_l + _safe_div(d[0], d[1]) * p_l,
            x, pv, dots, name="x'")
        r2 = sched.compute(
            lambda r_l, z_l, d: r_l - _safe_div(d[0], d[1]) * z_l,
            r, z, dots, name="r'")
        p2 = sched.compute(
            lambda r2_l, p_l, d: r2_l
            + _safe_div(gdot(r2_l, r2_l), d[0]) * p_l,
            r2, pv, dots, name="p'")

        self.schedule = sched.scan(
            mesh, carry=(x, r, pv), output=(x2, r2, p2),
            axis_name=axis_name, strategy=strategy, blocksize=blocksize,
            topology=Topology(p, shards_per_node or p), hw=hw,
            use_plan_cache=use_plan_cache, n_steps_hint=n_steps_hint)

    @property
    def strategies(self):
        """Resolved strategy per exchange stage (gather_x / scatter_t)."""
        return self.schedule.strategies

    def predicted_loop(self, n_steps: int, *, overlap_credit: float = 0.0):
        """Eq.-23 steady-state pricing of an n-iteration solve (None
        without hardware parameters)."""
        return self.schedule.predicted_loop(n_steps,
                                            overlap_credit=overlap_credit)

    def carries(self, b):
        """The sharded (x0, r0, p0) start state for right-hand side ``b``:
        x0 = 0, r0 = p0 = b (the CG start at zero initial guess)."""
        b = np.asarray(b)
        x0 = self.schedule.shard_input(np.zeros_like(b), 0)
        r0 = self.schedule.shard_input(b, 1)
        p0 = self.schedule.shard_input(b, 2)
        return x0, r0, p0

    def solve(self, b, n_steps: int):
        """Run ``n_steps`` CG iterations on ``(MᵀM) x = b`` from x0 = 0.

        Returns the sharded iterate x_n (use ``np.asarray`` to gather).
        The whole solve is one device program: no per-iteration host
        dispatch, plans and calibration resolved once at build time.
        """
        x0, r0, p0 = self.carries(b)
        x_n, _, _ = self.schedule(x0, r0, p0, n_steps=n_steps)
        return x_n


def cg_solve(matrix: EllpackMatrix, b, mesh, *, n_steps: int = 50,
             **kwargs) -> np.ndarray:
    """One-call convenience: build ``ConjugateGradient`` and solve
    ``(MᵀM) x = b``, returning a host array."""
    cg = ConjugateGradient(matrix, mesh, n_steps_hint=n_steps, **kwargs)
    return np.asarray(cg.solve(b, n_steps))
