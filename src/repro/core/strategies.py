"""Deprecation shim — the strategy ladder moved to ``repro.comm.strategies``.

New code should go through ``repro.comm.IrregularGather`` instead of calling
the local gather functions directly.
"""
from repro.comm.strategies import (  # noqa: F401
    STRATEGIES,
    replicate_gather_local,
    blockwise_gather_local,
    condensed_gather_local,
    plan_device_args,
    gather_in_specs,
    make_gather_local,
    make_start_local,
)

__all__ = [
    "STRATEGIES",
    "replicate_gather_local",
    "blockwise_gather_local",
    "condensed_gather_local",
    "plan_device_args",
    "gather_in_specs",
]
