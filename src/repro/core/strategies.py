"""The paper's communication-strategy ladder, as shard_map-local gathers.

Each strategy turns a sharded vector ``x`` (one contiguous shard per device on
the communication mesh axis) into a device-private copy ``x_copy`` — the
paper's ``mythread_x_copy`` — that the local computation then indexes with
*global* indices (the paper stresses that retaining global indices is what
keeps UPCv3 easier than MPI; we retain them too).

All functions here are *local* functions: they must be called inside a
``shard_map`` over ``axis_name``.  They return an array of length >= n whose
first n entries are valid; entries at index >= n are a padding dump.

Strategies (paper §4):
  * ``replicate`` — naive: all-gather the whole vector (volume n per device).
  * ``blockwise`` — UPCv2: move whole virtual blocks that contain >=1 needed
    element, via a padded block all_to_all (volume = needed blocks × BS).
  * ``condensed`` — UPCv3: pack exactly the unique needed values, one padded
    message per pair, single all_to_all, scatter-unpack (volume = Σ unique).
  * ``overlap``   — beyond paper: same condensed exchange, but the consumer
    splits its compute so the own-shard partial runs while the all_to_all is
    in flight (see ``spmv.DistributedSpMV``); as a pure gather it is
    identical to ``condensed``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.plan import CommPlan

__all__ = [
    "STRATEGIES",
    "replicate_gather_local",
    "blockwise_gather_local",
    "condensed_gather_local",
    "plan_device_args",
    "gather_in_specs",
]


def replicate_gather_local(x_local: jax.Array, *, axis_name: str) -> jax.Array:
    """Naive strategy: materialize the entire shared vector on every device."""
    return jax.lax.all_gather(x_local, axis_name, tiled=True)


def condensed_gather_local(
    x_local: jax.Array,
    send_local_idx: jax.Array,   # (1, P, s_max) local slice of plan array
    recv_global_idx: jax.Array,  # (1, P, s_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
) -> jax.Array:
    """UPCv3: pack -> one consolidated message per pair -> unpack.

    The pack loop (paper Listing 5) is the gather ``x_local[send_idx]``; the
    ``upc_memput`` + ``upc_barrier`` pair is the bulk-synchronous
    ``all_to_all``; the unpack loop is the scatter into ``x_copy``.  Padding
    lands in the dump slot at index n.
    """
    buf = x_local[send_local_idx[0]]                      # (P, s_max) pack
    recv = jax.lax.all_to_all(                            # memput + barrier
        buf, axis_name, split_axis=0, concat_axis=0, tiled=True
    )
    x_copy = jnp.zeros((n + 1,), x_local.dtype)
    x_copy = x_copy.at[recv_global_idx[0].ravel()].set(recv.ravel())  # unpack
    me = jax.lax.axis_index(axis_name)
    # copy own shard (paper: memcpy of own blocks into mythread_x_copy)
    x_copy = jax.lax.dynamic_update_slice(x_copy, x_local, (me * shard_size,))
    return x_copy


def blockwise_gather_local(
    x_local: jax.Array,
    send_local_blk: jax.Array,   # (1, P, b_max)
    recv_global_blk: jax.Array,  # (1, P, b_max)
    *,
    axis_name: str,
    n: int,
    shard_size: int,
    blocksize: int,
) -> jax.Array:
    """UPCv2: move whole needed virtual blocks (upc_memget analogue).

    Every needed block travels in its entirety regardless of how many of its
    elements are actually used — exactly the paper's trade-off: fewer, larger,
    latency-amortizing transfers at the price of extra volume.
    """
    blocks_per_shard = shard_size // blocksize
    nblks = n // blocksize
    xb = x_local.reshape(blocks_per_shard, blocksize)
    buf = xb[send_local_blk[0]]                            # (P, b_max, BS)
    recv = jax.lax.all_to_all(
        buf, axis_name, split_axis=0, concat_axis=0, tiled=True
    )
    x_blocks = jnp.zeros((nblks + 1, blocksize), x_local.dtype)
    x_blocks = x_blocks.at[recv_global_blk[0].ravel()].set(
        recv.reshape(-1, blocksize)
    )
    x_copy = x_blocks.reshape(-1)                          # (n + BS,)
    me = jax.lax.axis_index(axis_name)
    x_copy = jax.lax.dynamic_update_slice(x_copy, x_local, (me * shard_size,))
    return x_copy


def plan_device_args(plan: CommPlan, strategy: str) -> tuple[Any, ...]:
    """Host (numpy) plan arrays each strategy needs, to be passed through
    shard_map with ``gather_in_specs`` so every device holds only its slice."""
    if strategy == "replicate":
        return ()
    if strategy in ("condensed", "overlap"):
        return (plan.send_local_idx, plan.recv_global_idx)
    if strategy == "blockwise":
        return (plan.send_local_blk, plan.recv_global_blk)
    raise ValueError(f"unknown strategy {strategy!r}")


def gather_in_specs(strategy: str, axis_name: str):
    """PartitionSpecs matching ``plan_device_args`` (sharded on dim 0)."""
    p = jax.sharding.PartitionSpec
    if strategy == "replicate":
        return ()
    return (p(axis_name), p(axis_name))


def make_gather_local(plan: CommPlan, strategy: str, axis_name: str):
    """Returns local_fn(x_local, *plan_args) -> x_copy (len >= n)."""
    if strategy == "replicate":
        return functools.partial(replicate_gather_local, axis_name=axis_name)
    if strategy in ("condensed", "overlap"):
        return functools.partial(
            condensed_gather_local,
            axis_name=axis_name,
            n=plan.n,
            shard_size=plan.shard_size,
        )
    if strategy == "blockwise":
        return functools.partial(
            blockwise_gather_local,
            axis_name=axis_name,
            n=plan.n,
            shard_size=plan.shard_size,
            blocksize=plan.blocksize,
        )
    raise ValueError(f"unknown strategy {strategy!r}")


STRATEGIES = ("replicate", "blockwise", "condensed", "overlap")
