"""Deprecation shim — the strategy ladder moved to ``repro.comm.strategies``.

New code should go through ``repro.comm.IrregularGather`` /
``repro.comm.IrregularScatter`` instead of calling the local gather/scatter
functions directly.
"""
from repro.comm.strategies import (  # noqa: F401
    STRATEGIES,
    SCATTER_REDUCES,
    replicate_gather_local,
    blockwise_gather_local,
    condensed_gather_local,
    replicate_scatter_local,
    blockwise_scatter_local,
    condensed_scatter_local,
    plan_device_args,
    gather_in_specs,
    make_gather_local,
    make_start_local,
    scatter_plan_device_args,
    scatter_in_specs,
    make_scatter_start_local,
)

__all__ = [
    "STRATEGIES",
    "SCATTER_REDUCES",
    "replicate_gather_local",
    "blockwise_gather_local",
    "condensed_gather_local",
    "replicate_scatter_local",
    "blockwise_scatter_local",
    "condensed_scatter_local",
    "plan_device_args",
    "gather_in_specs",
    "scatter_plan_device_args",
    "scatter_in_specs",
    "make_scatter_start_local",
]
