"""Deprecation shim — the plan cache moved to ``repro.comm.plan_cache``.

Re-exported module-level state (``stats``, the memory LRU, env knobs) is the
same object as ``repro.comm.plan_cache``'s, so existing monitoring keeps
seeing every hit/miss — including the v4 scatter-delta derivations
(``get_scatter_plan`` / ``stats.derives``).  New code should import from
``repro.comm``.
"""
from repro.comm.plan_cache import (  # noqa: F401
    CacheStats, StalePlanCacheError, cache_dir, clear_memory_cache,
    envelope_plan_key, get_comm_plan, get_envelope_plan, get_scatter_plan,
    plan_key, stats, _disk_path, _key_for_version, _memory,
)

__all__ = ["plan_key", "get_comm_plan", "get_scatter_plan",
           "envelope_plan_key", "get_envelope_plan",
           "clear_memory_cache", "stats", "CacheStats",
           "StalePlanCacheError", "cache_dir"]
