"""Deprecation shim — the planner moved to ``repro.comm.plan``.

The communication planning machinery is workload-agnostic and now lives in
the ``repro.comm`` package (``AccessPattern`` / ``IrregularGather`` front
door).  This module re-exports the old names so existing imports keep
working; new code should import from ``repro.comm``.
"""
from repro.comm.plan import (  # noqa: F401
    CommPlan, GatherCounts, Topology, build_comm_plan,
    blockwise_block_counts,
)

__all__ = ["Topology", "GatherCounts", "CommPlan", "build_comm_plan"]
