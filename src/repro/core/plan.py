"""Deprecation shim — the planner moved to ``repro.comm.plan``.

The communication planning machinery is workload-agnostic and now lives in
the ``repro.comm`` package (``AccessPattern`` / ``IrregularGather`` /
``IrregularScatter`` front doors).  This module re-exports the old names —
plus the direction-agnostic additions (``ScatterPlan``,
``CommPlan.transpose()`` helpers) — so existing imports keep working; new
code should import from ``repro.comm``.
"""
from repro.comm.plan import (  # noqa: F401
    CommPlan, GatherCounts, ScatterPlan, Topology, build_comm_plan,
    blockwise_block_counts, derive_scatter_plan, pattern_cols,
    transpose_counts,
)

__all__ = ["Topology", "GatherCounts", "CommPlan", "ScatterPlan",
           "build_comm_plan", "derive_scatter_plan", "pattern_cols",
           "transpose_counts"]
