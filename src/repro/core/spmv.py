"""Distributed SpMV engine — the paper's workload on the repro.comm runtime.

``DistributedSpMV`` is now a *consumer* of ``repro.comm``: it derives an
``AccessPattern`` from the EllPack column table, hands it to
``IrregularGather`` (which owns the cached ``CommPlan``, the strategy
resolution, and the device-resident plan arrays), and fuses the gather with
the local EllPack compute inside one jitted ``shard_map``.  The local
compute can run through the Pallas kernels (``use_kernel=True``) or the
pure-jnp reference.

``strategy`` may be any rung of the ladder (``replicate`` / ``blockwise`` /
``condensed`` / ``overlap``) or ``"auto"``, which micro-benchmarks the
hardware parameters once per mesh and lets the §5 performance models pick.
``blocksize`` may likewise be ``"auto"`` (eq.-11-minimizing BLOCKSIZE).  The
resolved choices are available as ``engine.strategy`` / ``engine.blocksize``;
the request is kept in ``engine.requested_strategy``.

``materialize`` picks the unpack: ``"dest"`` (default on the jnp paths)
registers the EllPack slot table as a ``Destination`` so each exchange
lands directly in gather-slot order — O(slots + recv) per step, no
full-length ``x_copy`` ever assembled; ``"full"`` keeps the paper's UPCv3
layout (assemble ``mythread_x_copy``, then index it), bit-identical
results.  With ``use_kernel=True`` the default is ``"full"`` (the split
SpMV compute kernels consume the assembled copy, itself built by the
fused unpack kernel); an explicit ``materialize="dest"`` instead routes
the exchange through the kernelized dest-unpack (``kernels.unpack_dest``
delivers the recv buffer straight into the EllPack slots) with the slot
compute in jnp.  ``transpose=True`` with ``use_kernel=True`` runs the
push-side split kernels: the own-target accumulate overlaps the in-flight
collective, then the landed contributions fold in
(``kernels.accumulate_segments`` / ``accumulate_into``).

The ``overlap`` strategy uses the ``OverlapHandle`` protocol: issue the
condensed ``all_to_all``, run the own-shard partial SpMV (which depends only
on ``x_local``) while the exchange is in flight, then finish with the
foreign partial on the unpacked remote values — XLA's latency-hiding
scheduler can hide the collective behind the first partial.  With
``use_kernel=True`` both partials run through the windowed Pallas kernel
(the split-kernel on-copy variant).

Usage:
    mesh = jax.make_mesh((8,), ("data",))
    m = make_mesh_like_matrix(1 << 16, 16)
    engine = DistributedSpMV(m, mesh, strategy="auto")
    x = engine.shard_vector(x_host)
    y = engine(x)              # y = (D + A) x, sharded like x
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm.gather import IrregularGather
from repro.comm.pattern import AccessPattern, Destination
from repro.comm.plan import CommPlan, Topology
from repro.comm.scatter import IrregularScatter
from repro.core.matrix import EllpackMatrix

__all__ = ["DistributedSpMV", "normal_equations_step",
           "normal_equations_stages"]


def _spmv_local(x_copy, diag_l, vals_l, cols_l, *, shard_size, axis_name):
    """Local EllPack compute on the device-private x_copy (global indices)."""
    me = jax.lax.axis_index(axis_name)
    offset = me * shard_size
    own = jax.lax.dynamic_slice(x_copy, (offset,), (shard_size,))
    gathered = x_copy[cols_l]                       # (shard, r_nz)
    return diag_l * own + (vals_l * gathered).sum(axis=-1)


class DistributedSpMV:
    """y = (D + A) x with x, y, D, A, J sharded over ``axis_name``.

    ``transpose=True`` computes y = (D + A)ᵀ x instead — the push-direction
    workload: row i's off-diagonal entries become *contributions*
    ``vals[i, j] * x[i]`` to ``y[cols[i, j]]``, scatter-accumulated through
    ``IrregularScatter`` (``reduce="add"``) over the transpose-derived plan,
    so forward and transposed products share one cached base ``CommPlan``.
    """

    def __init__(
        self,
        matrix: EllpackMatrix,
        mesh: jax.sharding.Mesh,
        *,
        axis_name: str = "data",
        strategy: str = "condensed",
        blocksize: int | str | None = None,
        shards_per_node: int | None = None,
        use_kernel: bool = False,
        materialize: str | None = None,
        transpose: bool = False,
        hw=None,
        use_plan_cache: bool = True,
    ):
        self.matrix = matrix
        self.mesh = mesh
        self.axis_name = axis_name
        p = int(np.prod([mesh.shape[axis_name]]))
        self.p = p
        n = matrix.n
        assert n % p == 0, "pad the matrix so n divides the mesh axis"
        topology = Topology(p, shards_per_node or p)
        self.transpose = transpose
        if transpose:
            assert materialize is None, (
                "materialize= is a gather-unpack knob; the transposed "
                "product always accumulates straight into the owned slice")
            self._init_transpose(matrix, mesh, axis_name=axis_name,
                                 strategy=strategy, blocksize=blocksize,
                                 topology=topology, hw=hw,
                                 use_kernel=use_kernel,
                                 use_plan_cache=use_plan_cache)
            return

        if materialize is None:
            # the split SpMV compute kernels consume the assembled copy, so
            # the kernel default is "full"; an explicit materialize="dest"
            # with use_kernel=True routes the exchange through the fused
            # dest-unpack kernel instead (slot compute stays jnp)
            materialize = "full" if use_kernel else "dest"
        assert materialize in ("dest", "full"), materialize
        self.materialize = materialize
        rows_per_shard = matrix.cols.shape[0] // p

        destination = None
        if materialize == "dest":
            # land every gathered value in EllPack slot order: accessor row
            # i's slot j reads x[J[i, j]] — delivered without ever building
            # the length-n private copy.  The overlap rung resolves owned
            # slots from x_local inside the own partial, so there the
            # destination targets the plan's foreign (rem) slots only;
            # resolved per strategy, after "auto" picks (no throwaway plan
            # entry gets cached).
            def destination(resolved, base_plan):
                if resolved == "overlap":
                    rem = np.where(base_plan.rem_cols >= n,
                                   Destination.ZERO, base_plan.rem_cols)
                    return Destination.from_slots(
                        foreign=rem.reshape(p, rows_per_shard, -1))
                return Destination.from_slots(
                    ellpack=matrix.cols.reshape(p, rows_per_shard, -1))
        self.gather = IrregularGather(
            AccessPattern.from_ellpack(matrix), mesh,
            axis_name=axis_name, strategy=strategy, blocksize=blocksize,
            topology=topology, destination=destination,
            dest_slots=rows_per_shard * matrix.cols.shape[1],
            hw=hw, use_kernel=use_kernel, use_plan_cache=use_plan_cache,
        )
        self.plan: CommPlan = self.gather.plan
        self.requested_strategy = strategy
        self.predicted_times = self.gather.predicted_times
        strategy = self.gather.strategy
        self.strategy = strategy
        self.blocksize = self.plan.blocksize

        shard = NamedSharding(mesh, P(axis_name))
        shard2 = NamedSharding(mesh, P(axis_name, None))
        self._diag = jax.device_put(matrix.diag, shard)
        if strategy == "overlap":
            # the overlap step never reads the unsplit matrix; keeping
            # vals/cols resident would double the device footprint
            self._vals = self._cols = None
        elif materialize == "dest":
            # targeted delivery arrives already in EllPack slot order — the
            # runtime column table is baked into the plan, not an operand
            self._vals = jax.device_put(matrix.vals, shard2)
            self._cols = None
        else:
            self._vals = jax.device_put(matrix.vals, shard2)
            self._cols = jax.device_put(matrix.cols, shard2)
        self._gather_args = self.gather.plan_args
        self._plan_args = self._gather_args

        gather = self.gather
        shard_size = self.plan.shard_size

        if strategy == "overlap" and use_kernel and materialize == "full":
            from repro.kernels import ops as kops
            plan = self.plan
            own_fn, rem_fn, kargs = kops.make_spmv_overlap_sharded(
                plan, matrix.vals)
            self._plan_args = self._gather_args + tuple(
                jax.device_put(a, shard) for a in kargs)
            n_kargs = len(kargs)

            def step_local(x_local, diag_l, send_idx, recv_idx, *args):
                assert len(args) == n_kargs
                handle = gather.start_local(x_local, send_idx, recv_idx)
                # own-shard partial through the kernel on x_local (+ its
                # one zero pad slot), overlapping the in-flight exchange
                x_ext = jnp.concatenate(
                    [x_local, jnp.zeros((1,), x_local.dtype)])
                y_own = own_fn(diag_l, x_ext, *args[:3])
                x_copy = handle.finish(extra_slots=1, copy_own=False)
                y_rem = rem_fn(x_copy, *args[3:])
                return y_own + y_rem

            kernel_specs = (P(axis_name),) * n_kargs
        elif strategy == "overlap" and materialize == "dest":
            plan = self.plan
            # split vals the same way the plan split cols; padded slots are
            # guaranteed-zero deliveries, so their vals are never observed
            loc_vals = np.take_along_axis(matrix.vals, plan.loc_src, axis=1)
            rem_vals = np.take_along_axis(matrix.vals, plan.rem_src, axis=1)
            self._plan_args = self._gather_args + tuple(
                jax.device_put(a, shard2)
                for a in (plan.loc_cols, loc_vals, rem_vals)
            )
            n_gargs = len(self._gather_args)

            def step_local(x_local, diag_l, *args):
                loc_cols_l, loc_vals_l, rem_vals_l = args[n_gargs:]
                # 1. issue the condensed exchange (paper Listing 5 pack)
                handle = gather.start_local(x_local, *args[:n_gargs])
                # 2. own-shard partial: no dependency on the landed messages,
                # so the scheduler can run it while the collective is in
                # flight
                x_ext = jnp.concatenate(
                    [x_local, jnp.zeros((1,), x_local.dtype)])
                y_own = diag_l * x_local + (
                    loc_vals_l * x_ext[loc_cols_l]).sum(axis=-1)
                # 3. foreign partial straight off the targeted delivery:
                # the landed messages arrive in (row, rem-slot) order
                foreign = handle.finish()["foreign"]
                y_rem = (rem_vals_l * foreign).sum(axis=-1)
                return y_own + y_rem

            kernel_specs = (P(axis_name, None),) * 3
        elif strategy == "overlap":
            plan = self.plan
            # split vals the same way the plan split cols; padded slots point
            # at a guaranteed-zero x slot, so their vals are never observed
            loc_vals = np.take_along_axis(matrix.vals, plan.loc_src, axis=1)
            rem_vals = np.take_along_axis(matrix.vals, plan.rem_src, axis=1)
            self._plan_args = self._gather_args + tuple(
                jax.device_put(a, shard2)
                for a in (plan.loc_cols, loc_vals, plan.rem_cols, rem_vals)
            )

            def step_local(x_local, diag_l, send_idx,
                           recv_idx, loc_cols_l, loc_vals_l, rem_cols_l,
                           rem_vals_l):
                # 1. issue the condensed exchange (paper Listing 5 pack)
                handle = gather.start_local(x_local, send_idx, recv_idx)
                # 2. own-shard partial: no dependency on the landed messages,
                # so the scheduler can run it while the collective is in
                # flight
                x_ext = jnp.concatenate(
                    [x_local, jnp.zeros((1,), x_local.dtype)])
                y_own = diag_l * x_local + (
                    loc_vals_l * x_ext[loc_cols_l]).sum(axis=-1)
                # 3. foreign partial on the landed remote values; slot n is
                # the recv padding dump, slot n+1 the compute padding (zero)
                x_copy = handle.finish(extra_slots=1, copy_own=False)
                y_rem = (rem_vals_l * x_copy[rem_cols_l]).sum(axis=-1)
                return y_own + y_rem

            kernel_specs = (P(axis_name, None),) * 4
        elif use_kernel and materialize == "full":
            from repro.kernels import ops as kops
            kernel_local, kplan = kops.make_spmv_on_copy_sharded(
                matrix.cols, p
            )
            kplan_args = tuple(
                jax.device_put(a, NamedSharding(mesh, P(axis_name)))
                for a in kplan
            )
            self._plan_args = self._plan_args + kplan_args
            n_gather_args = len(self._gather_args)

            def step_local(x_local, diag_l, vals_l, cols_l, *args):
                x_copy = gather.local(x_local, *args[:n_gather_args])
                return kernel_local(diag_l, vals_l, x_copy,
                                    *args[n_gather_args:])

            kernel_specs = (P(axis_name, None), P(axis_name, None, None),
                            P(axis_name, None))
        elif materialize == "dest":
            def step_local(x_local, diag_l, vals_l, *plan_args):
                # landed values arrive already in EllPack slot order; owned
                # slots were gathered from x_local by the same delivery
                gathered = gather.local(x_local, *plan_args)["ellpack"]
                return diag_l * x_local + (vals_l * gathered).sum(axis=-1)

            kernel_specs = ()
        else:
            def step_local(x_local, diag_l, vals_l, cols_l, *plan_args):
                x_copy = gather.local(x_local, *plan_args)
                return _spmv_local(
                    x_copy, diag_l, vals_l, cols_l,
                    shard_size=shard_size, axis_name=axis_name,
                )

            kernel_specs = ()

        if strategy == "overlap":
            base_args = (self._diag,)
            base_specs = (P(axis_name), P(axis_name))
        elif materialize == "dest":
            base_args = (self._diag, self._vals)
            base_specs = (P(axis_name), P(axis_name), P(axis_name, None))
        else:
            base_args = (self._diag, self._vals, self._cols)
            base_specs = (P(axis_name), P(axis_name), P(axis_name, None),
                          P(axis_name, None))
        in_specs = (base_specs
                    + self.gather.in_specs
                    + kernel_specs)
        mapped = compat.shard_map(
            step_local, mesh=mesh, in_specs=in_specs, out_specs=P(axis_name),
            check_vma=False,  # pallas_call inside shard_map needs this
        )

        @jax.jit
        def step(x):
            return mapped(x, *base_args, *self._plan_args)

        self._step = step

    def _init_transpose(self, matrix, mesh, *, axis_name, strategy,
                        blocksize, topology, hw, use_kernel,
                        use_plan_cache):
        """y = (D + A)ᵀ x via scatter-accumulate of partial products.

        Each shard forms its contributions ``vals * x_local[:, None]`` (its
        rows' partial products) and pushes them to the column owners; the
        diagonal term is purely local (Dᵀ = D).  The ``ScatterHandle``
        protocol issues the exchange first, so the diagonal product and the
        own-column accumulate run while the collective is in flight — the
        ``overlap`` rung's window, available on every rung.  With
        ``use_kernel=True`` the pack-accumulate, the own-target accumulate
        and the landed-contribution fold each run as one fused Pallas pass
        (push-side split kernels), bit-identical to the jnp path.
        """
        scatter = IrregularScatter(
            AccessPattern.from_ellpack(matrix), mesh,
            axis_name=axis_name, strategy=strategy, blocksize=blocksize,
            topology=topology, reduce="add", hw=hw,
            use_kernel=use_kernel, use_plan_cache=use_plan_cache,
        )
        self.scatter = scatter
        self.gather = None
        self.plan: CommPlan = scatter.plan
        self.splan = scatter.splan
        self.requested_strategy = strategy
        self.predicted_times = scatter.predicted_times
        self.strategy = scatter.strategy
        self.blocksize = self.plan.blocksize
        self.materialize = None

        shard = NamedSharding(mesh, P(axis_name))
        shard2 = NamedSharding(mesh, P(axis_name, None))
        self._diag = jax.device_put(matrix.diag, shard)
        self._vals = jax.device_put(matrix.vals, shard2)
        self._cols = None
        self._plan_args = scatter.plan_args

        def step_local(x_local, diag_l, vals_l, *plan_args):
            contrib = vals_l * x_local[:, None]
            handle = scatter.start_local(contrib, *plan_args)
            y_diag = diag_l * x_local
            return y_diag + handle.finish()

        mapped = compat.shard_map(
            step_local, mesh=mesh,
            in_specs=(P(axis_name), P(axis_name), P(axis_name, None))
            + scatter.in_specs,
            out_specs=P(axis_name), check_vma=False,
        )

        @jax.jit
        def step(x):
            return mapped(x, self._diag, self._vals, *self._plan_args)

        self._step = step

    # ---- public API ----
    def shard_vector(self, x: np.ndarray) -> jax.Array:
        if self.transpose:
            return self.scatter.shard_vector(x)
        return self.gather.shard_vector(x)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self._step(x)

    def gather_x_copy(self, x: jax.Array) -> jax.Array:
        """(P, >=n) array: row q is device q's private x_copy (testing)."""
        assert not self.transpose, "the transposed product never gathers"
        return self.gather(x)

    @property
    def counts(self):
        """Exact per-shard §5 volume counts — put-direction counts when
        ``transpose=True`` (the direction the step actually runs)."""
        if self.transpose:
            return self.splan.counts
        return self.plan.counts

    def iterate(self, x: jax.Array, steps: int) -> jax.Array:
        """Paper §6.1 time loop: x <- M x, ``steps`` times (power iteration).

        Normalizes each step to keep values finite over 1000 iterations.
        """
        @jax.jit
        def body(x, _):
            y = self._step(x)
            y = y / jnp.max(jnp.abs(y))
            return y, None

        out, _ = jax.lax.scan(body, x, None, length=steps)
        return out


def normal_equations_stages(sched, matrix: EllpackMatrix, p: int, x_ref):
    """Declare the z = MᵀM x stage graph on an existing ``Schedule``.

    ``x_ref`` is the (already declared) input/stage whose value is the
    length-n operand; the return value is the ``z`` stage ref.  Shared by
    ``normal_equations_step`` (one-shot window) and the iterative solvers
    (``repro.core.solvers``), which embed the same graph inside a
    ``ScanSchedule`` body next to their own recurrence stages.

    The graph chains the two SpMV directions in one window: gather-product
    ``y = M x`` (EllPack-slot ``Destination``), push-product ``z = Mᵀ y``
    whose scatter stage derives its executor tables from the gather stage's
    base plan, and the diagonal product ``D·y`` scheduled after the scatter
    so it runs inside the push collective's window.
    """
    n = matrix.n
    assert n % p == 0, "pad the matrix so n divides the mesh axis"
    rows_per_shard = matrix.cols.shape[0] // p
    pattern = AccessPattern.from_ellpack(matrix)
    # forward product lands gathered x in EllPack slot order (the same
    # Destination the forward engine registers on the jnp path)
    destination = Destination.from_slots(
        ellpack=matrix.cols.reshape(p, rows_per_shard, -1))

    diag = sched.constant(matrix.diag, "diag")
    vals = sched.constant(matrix.vals, "vals")
    g = sched.gather(pattern, src=x_ref, destination=destination,
                     name="gather_x")

    def forward(x_l, d_l, v_l, delivered):
        return d_l * x_l + (v_l * delivered["ellpack"]).sum(axis=-1)

    y = sched.compute(forward, x_ref, diag, vals, g, name="y=Mx")
    contrib = sched.compute(lambda y_l, v_l: v_l * y_l[:, None], y, vals,
                            name="partials")
    s = sched.scatter(pattern, contrib, reduce="add", name="scatter_t")
    # scheduled after the scatter stage: D·y runs inside the push window
    y_diag = sched.compute(lambda y_l, d_l: d_l * y_l, y, diag,
                           name="diag_t")
    return sched.compute(lambda a, b: a + b, s, y_diag, name="z=Mty")


def normal_equations_step(
    matrix: EllpackMatrix,
    mesh: jax.sharding.Mesh,
    *,
    axis_name: str = "data",
    strategy: str = "auto",
    blocksize: int | str | None = None,
    shards_per_node: int | None = None,
    hw=None,
    use_plan_cache: bool = True,
):
    """z = MᵀM x with M = (D + A), as ONE fused ``ExchangeSchedule``.

    The normal-equations step (the CGNR/least-squares inner product) chains
    the two SpMV directions: the forward gather-product ``y = M x`` and the
    transposed scatter-product ``z = Mᵀ y``.  Run through two
    ``DistributedSpMV`` engines it pays two plan resolutions, two windows
    and an intermediate round trip; declared as one ``Schedule`` it shares
    everything — the scatter stage derives its executor tables from the
    gather stage's base plan (one O(nnz) preparation step total, exactly
    like the forward/transpose engine pair), one hw-calibration memo hit
    prices both stages, and the diagonal product ``D·y`` is scheduled
    *after* the scatter stage so it runs inside the push collective's
    window.

    Returns the compiled ``ExchangeSchedule``: ``step(x_sharded) -> z``
    (use ``step.shard_vector`` for placement; ``step.predicted_window``
    holds the §5 fused-window pricing).
    """
    from repro.comm.schedule import Schedule

    p = int(mesh.shape[axis_name]) if not isinstance(axis_name, tuple) \
        else int(np.prod([mesh.shape[a] for a in axis_name]))
    sched = Schedule()
    x_ref = sched.input("x")
    z = normal_equations_stages(sched, matrix, p, x_ref)
    return sched.compile(
        mesh, axis_name=axis_name, strategy=strategy, blocksize=blocksize,
        topology=Topology(p, shards_per_node or p), hw=hw,
        use_plan_cache=use_plan_cache, output=z)
