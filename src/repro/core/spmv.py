"""Distributed SpMV engine — the paper's workload as a composable JAX module.

``DistributedSpMV`` owns: the row partitioning, the one-time ``CommPlan``
(paper §4.3.1, persistently cached through ``plan_cache``), the sharded
matrix residency, and a jitted ``shard_map`` step that fuses gather
(strategy-pluggable) + local EllPack compute.  The local compute can run
through the Pallas kernel (``use_kernel=True``) or the pure-jnp reference.

``strategy`` may be any rung of the ladder (``replicate`` / ``blockwise`` /
``condensed`` / ``overlap``) or ``"auto"``, which micro-benchmarks the
hardware parameters once per mesh and lets the §5 performance models pick
(``core.tune``).  The resolved choice is available as ``engine.strategy``;
the request is kept in ``engine.requested_strategy``.

The ``overlap`` strategy issues the condensed ``all_to_all`` first, runs the
own-shard partial SpMV (which depends only on ``x_local``) while the exchange
is in flight, then finishes with the foreign partial on the unpacked remote
values — XLA's latency-hiding scheduler can hide the collective behind the
first partial.  It also skips the eq.-14 own-shard copy into ``x_copy``.

Usage:
    mesh = jax.make_mesh((8,), ("data",))
    m = make_mesh_like_matrix(1 << 16, 16)
    engine = DistributedSpMV(m, mesh, strategy="auto")
    x = engine.shard_vector(x_host)
    y = engine(x)              # y = (D + A) x, sharded like x
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.matrix import EllpackMatrix
from repro.core.plan import CommPlan, Topology
from repro.core import plan_cache
from repro.core import strategies as strat

__all__ = ["DistributedSpMV"]


def _spmv_local(x_copy, diag_l, vals_l, cols_l, *, shard_size, axis_name):
    """Local EllPack compute on the device-private x_copy (global indices)."""
    me = jax.lax.axis_index(axis_name)
    offset = me * shard_size
    own = jax.lax.dynamic_slice(x_copy, (offset,), (shard_size,))
    gathered = x_copy[cols_l]                       # (shard, r_nz)
    return diag_l * own + (vals_l * gathered).sum(axis=-1)


class DistributedSpMV:
    """y = (D + A) x with x, y, D, A, J sharded over ``axis_name``."""

    def __init__(
        self,
        matrix: EllpackMatrix,
        mesh: jax.sharding.Mesh,
        *,
        axis_name: str = "data",
        strategy: str = "condensed",
        blocksize: int | None = None,
        shards_per_node: int | None = None,
        use_kernel: bool = False,
        hw=None,
        use_plan_cache: bool = True,
    ):
        valid = strat.STRATEGIES + ("auto",)
        if strategy not in valid:
            raise ValueError(f"strategy must be one of {valid}")
        self.matrix = matrix
        self.mesh = mesh
        self.axis_name = axis_name
        p = int(np.prod([mesh.shape[axis_name]]))
        self.p = p
        n = matrix.n
        assert n % p == 0, "pad the matrix so n divides the mesh axis"
        topology = Topology(p, shards_per_node or p)
        self.plan: CommPlan = plan_cache.get_comm_plan(
            matrix.cols, n, p, blocksize=blocksize, topology=topology,
            cache=use_plan_cache,
        )

        self.requested_strategy = strategy
        self.predicted_times: dict[str, float] | None = None
        if strategy == "auto":
            from repro.core import tune
            if hw is None:
                hw = tune.measure_hardware(mesh, axis_name)
            candidates = None
            if use_kernel:  # kernel path consumes a full x_copy
                candidates = tuple(s for s in strat.STRATEGIES
                                   if s != "overlap")
            ranked = tune.rank_strategies(self.plan, matrix.r_nz, hw,
                                          candidates=candidates)
            self.predicted_times = dict(ranked)
            strategy = ranked[0][0]
        self.strategy = strategy
        if use_kernel and strategy == "overlap":
            raise ValueError(
                "overlap splits the local compute and bypasses x_copy; "
                "it does not compose with use_kernel yet")

        shard = NamedSharding(mesh, P(axis_name))
        shard2 = NamedSharding(mesh, P(axis_name, None))
        self._diag = jax.device_put(matrix.diag, shard)
        if strategy == "overlap":
            # the overlap step never reads the unsplit matrix; keeping
            # vals/cols resident would double the device footprint
            self._vals = self._cols = None
        else:
            self._vals = jax.device_put(matrix.vals, shard2)
            self._cols = jax.device_put(matrix.cols, shard2)
        self._gather_args = tuple(
            jax.device_put(a, NamedSharding(mesh, P(axis_name)))
            for a in strat.plan_device_args(self.plan, strategy)
        )
        self._plan_args = self._gather_args

        gather_local = strat.make_gather_local(self.plan, strategy, axis_name)
        shard_size = self.plan.shard_size

        if strategy == "overlap":
            plan = self.plan
            # split vals the same way the plan split cols; padded slots point
            # at a guaranteed-zero x slot, so their vals are never observed
            loc_vals = np.take_along_axis(matrix.vals, plan.loc_src, axis=1)
            rem_vals = np.take_along_axis(matrix.vals, plan.rem_src, axis=1)
            self._plan_args = self._gather_args + tuple(
                jax.device_put(a, shard2)
                for a in (plan.loc_cols, loc_vals, plan.rem_cols, rem_vals)
            )

            def step_local(x_local, diag_l, send_idx,
                           recv_idx, loc_cols_l, loc_vals_l, rem_cols_l,
                           rem_vals_l):
                # 1. issue the condensed exchange (paper Listing 5 pack)
                buf = x_local[send_idx[0]]
                recv = jax.lax.all_to_all(
                    buf, axis_name, split_axis=0, concat_axis=0, tiled=True)
                # 2. own-shard partial: no dependency on `recv`, so the
                # scheduler can run it while the collective is in flight
                x_ext = jnp.concatenate(
                    [x_local, jnp.zeros((1,), x_local.dtype)])
                y_own = diag_l * x_local + (
                    loc_vals_l * x_ext[loc_cols_l]).sum(axis=-1)
                # 3. foreign partial on the landed remote values; slot n is
                # the recv padding dump, slot n+1 the compute padding (zero)
                x_copy = jnp.zeros((n + 2,), x_local.dtype)
                x_copy = x_copy.at[recv_idx[0].ravel()].set(recv.ravel())
                y_rem = (rem_vals_l * x_copy[rem_cols_l]).sum(axis=-1)
                return y_own + y_rem

            kernel_specs = (P(axis_name, None),) * 4
        elif use_kernel:
            from repro.kernels import ops as kops
            kernel_local, kplan = kops.make_spmv_on_copy_sharded(
                matrix.cols, p
            )
            kplan_args = tuple(
                jax.device_put(a, NamedSharding(mesh, P(axis_name)))
                for a in kplan
            )
            self._plan_args = self._plan_args + kplan_args
            n_gather_args = len(strat.plan_device_args(self.plan, strategy))

            def step_local(x_local, diag_l, vals_l, cols_l, *args):
                x_copy = gather_local(x_local, *args[:n_gather_args])
                return kernel_local(diag_l, vals_l, x_copy,
                                    *args[n_gather_args:])

            kernel_specs = (P(axis_name, None), P(axis_name, None, None),
                            P(axis_name, None))
        else:
            def step_local(x_local, diag_l, vals_l, cols_l, *plan_args):
                x_copy = gather_local(x_local, *plan_args)
                return _spmv_local(
                    x_copy, diag_l, vals_l, cols_l,
                    shard_size=shard_size, axis_name=axis_name,
                )

            kernel_specs = ()

        if strategy == "overlap":
            base_args = (self._diag,)
            base_specs = (P(axis_name), P(axis_name))
        else:
            base_args = (self._diag, self._vals, self._cols)
            base_specs = (P(axis_name), P(axis_name), P(axis_name, None),
                          P(axis_name, None))
        in_specs = (base_specs
                    + strat.gather_in_specs(strategy, axis_name)
                    + kernel_specs)
        mapped = compat.shard_map(
            step_local, mesh=mesh, in_specs=in_specs, out_specs=P(axis_name),
            check_vma=False,  # pallas_call inside shard_map needs this
        )

        @jax.jit
        def step(x):
            return mapped(x, *base_args, *self._plan_args)

        self._step = step

        def gather_only_local(x_local, *plan_args):
            return gather_local(x_local, *plan_args)[None]

        self._gather_only = jax.jit(compat.shard_map(
            gather_only_local,
            mesh=mesh,
            in_specs=(P(axis_name),) + strat.gather_in_specs(strategy, axis_name),
            out_specs=P(axis_name),
            check_vma=False,
        ))
        self._gather_only_args = self._gather_args

    # ---- public API ----
    def shard_vector(self, x: np.ndarray) -> jax.Array:
        return jax.device_put(
            x, NamedSharding(self.mesh, P(self.axis_name)))

    def __call__(self, x: jax.Array) -> jax.Array:
        return self._step(x)

    def gather_x_copy(self, x: jax.Array) -> jax.Array:
        """(P, >=n) array: row q is device q's private x_copy (testing)."""
        return self._gather_only(x, *self._gather_only_args)

    @property
    def counts(self):
        return self.plan.counts

    def iterate(self, x: jax.Array, steps: int) -> jax.Array:
        """Paper §6.1 time loop: x <- M x, ``steps`` times (power iteration).

        Normalizes each step to keep values finite over 1000 iterations.
        """
        @jax.jit
        def body(x, _):
            y = self._step(x)
            y = y / jnp.max(jnp.abs(y))
            return y, None

        out, _ = jax.lax.scan(body, x, None, length=steps)
        return out
