"""Distributed SpMV engine — the paper's workload as a composable JAX module.

``DistributedSpMV`` owns: the row partitioning, the one-time ``CommPlan``
(paper §4.3.1), the sharded matrix residency, and a jitted
``shard_map`` step that fuses gather (strategy-pluggable) + local EllPack
compute.  The local compute can run through the Pallas kernel
(``use_kernel=True``) or the pure-jnp reference.

Usage:
    mesh = jax.make_mesh((8,), ("data",))
    m = make_mesh_like_matrix(1 << 16, 16)
    engine = DistributedSpMV(m, mesh, strategy="condensed")
    x = engine.shard_vector(x_host)
    y = engine(x)              # y = (D + A) x, sharded like x
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.matrix import EllpackMatrix
from repro.core.plan import CommPlan, Topology, build_comm_plan
from repro.core import strategies as strat

__all__ = ["DistributedSpMV"]


def _spmv_local(x_copy, diag_l, vals_l, cols_l, *, shard_size, axis_name):
    """Local EllPack compute on the device-private x_copy (global indices)."""
    me = jax.lax.axis_index(axis_name)
    offset = me * shard_size
    own = jax.lax.dynamic_slice(x_copy, (offset,), (shard_size,))
    gathered = x_copy[cols_l]                       # (shard, r_nz)
    return diag_l * own + (vals_l * gathered).sum(axis=-1)


class DistributedSpMV:
    """y = (D + A) x with x, y, D, A, J sharded over ``axis_name``."""

    def __init__(
        self,
        matrix: EllpackMatrix,
        mesh: jax.sharding.Mesh,
        *,
        axis_name: str = "data",
        strategy: str = "condensed",
        blocksize: int | None = None,
        shards_per_node: int | None = None,
        use_kernel: bool = False,
    ):
        if strategy not in strat.STRATEGIES:
            raise ValueError(f"strategy must be one of {strat.STRATEGIES}")
        self.matrix = matrix
        self.mesh = mesh
        self.axis_name = axis_name
        self.strategy = strategy
        p = int(np.prod([mesh.shape[axis_name]]))
        self.p = p
        n = matrix.n
        assert n % p == 0, "pad the matrix so n divides the mesh axis"
        topology = Topology(p, shards_per_node or p)
        self.plan: CommPlan = build_comm_plan(
            matrix.cols, n, p, blocksize=blocksize, topology=topology
        )

        shard = NamedSharding(mesh, P(axis_name))
        shard2 = NamedSharding(mesh, P(axis_name, None))
        self._diag = jax.device_put(matrix.diag, shard)
        self._vals = jax.device_put(matrix.vals, shard2)
        self._cols = jax.device_put(matrix.cols, shard2)
        self._gather_args = tuple(
            jax.device_put(a, NamedSharding(mesh, P(axis_name)))
            for a in strat.plan_device_args(self.plan, strategy)
        )
        self._plan_args = self._gather_args

        gather_local = strat.make_gather_local(self.plan, strategy, axis_name)
        shard_size = self.plan.shard_size

        if use_kernel:
            from repro.kernels import ops as kops
            kernel_local, kplan = kops.make_spmv_on_copy_sharded(
                matrix.cols, p
            )
            kplan_args = tuple(
                jax.device_put(a, NamedSharding(mesh, P(axis_name)))
                for a in kplan
            )
            self._plan_args = self._plan_args + kplan_args
            n_gather_args = len(strat.plan_device_args(self.plan, strategy))

            def step_local(x_local, diag_l, vals_l, cols_l, *args):
                x_copy = gather_local(x_local, *args[:n_gather_args])
                return kernel_local(diag_l, vals_l, x_copy,
                                    *args[n_gather_args:])

            kernel_specs = (P(axis_name, None), P(axis_name, None, None),
                            P(axis_name, None))
        else:
            def step_local(x_local, diag_l, vals_l, cols_l, *plan_args):
                x_copy = gather_local(x_local, *plan_args)
                return _spmv_local(
                    x_copy, diag_l, vals_l, cols_l,
                    shard_size=shard_size, axis_name=axis_name,
                )

            kernel_specs = ()

        in_specs = (
            P(axis_name), P(axis_name), P(axis_name, None), P(axis_name, None),
        ) + strat.gather_in_specs(strategy, axis_name) + kernel_specs
        mapped = jax.shard_map(
            step_local, mesh=mesh, in_specs=in_specs, out_specs=P(axis_name),
            check_vma=False,  # pallas_call inside shard_map needs this
        )

        @jax.jit
        def step(x):
            return mapped(x, self._diag, self._vals, self._cols,
                          *self._plan_args)

        self._step = step

        def gather_only_local(x_local, *plan_args):
            return gather_local(x_local, *plan_args)[None]

        self._gather_only = jax.jit(jax.shard_map(
            gather_only_local,
            mesh=mesh,
            in_specs=(P(axis_name),) + strat.gather_in_specs(strategy, axis_name),
            out_specs=P(axis_name),
            check_vma=False,
        ))
        self._gather_only_args = self._gather_args

    # ---- public API ----
    def shard_vector(self, x: np.ndarray) -> jax.Array:
        return jax.device_put(
            x, NamedSharding(self.mesh, P(self.axis_name)))

    def __call__(self, x: jax.Array) -> jax.Array:
        return self._step(x)

    def gather_x_copy(self, x: jax.Array) -> jax.Array:
        """(P, >=n) array: row q is device q's private x_copy (testing)."""
        return self._gather_only(x, *self._gather_only_args)

    @property
    def counts(self):
        return self.plan.counts

    def iterate(self, x: jax.Array, steps: int) -> jax.Array:
        """Paper §6.1 time loop: x <- M x, ``steps`` times (power iteration).

        Normalizes each step to keep values finite over 1000 iterations.
        """
        @jax.jit
        def body(x, _):
            y = self._step(x)
            y = y / jnp.max(jnp.abs(y))
            return y, None

        out, _ = jax.lax.scan(body, x, None, length=steps)
        return out
