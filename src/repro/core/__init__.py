"""The paper's contribution: planning, strategies, models — and workloads.

The communication machinery itself (planner, strategy ladder, plan cache,
strategy/BLOCKSIZE selection) lives in ``repro.comm`` behind the
``AccessPattern`` / ``SharedVector`` / ``IrregularGather`` API; this package
keeps the paper-specific pieces (§5 performance models, workloads, cost
analysis) plus thin deprecation re-exports of the moved names.
"""
from repro.core.matrix import EllpackMatrix, make_mesh_like_matrix, spmv_ref_np
from repro.core.plan import CommPlan, GatherCounts, Topology, build_comm_plan
from repro.core.plan_cache import get_comm_plan
from repro.core.spmv import DistributedSpMV
from repro.core.heat2d import Heat2D
from repro.core.solvers import ConjugateGradient, cg_solve
from repro.core import (perfmodel, plan_cache, roofline, hlo_cost, strategies,
                        tune)

__all__ = [
    "EllpackMatrix", "make_mesh_like_matrix", "spmv_ref_np",
    "CommPlan", "GatherCounts", "Topology", "build_comm_plan",
    "get_comm_plan", "DistributedSpMV", "Heat2D",
    "ConjugateGradient", "cg_solve",
    "perfmodel", "plan_cache", "roofline", "hlo_cost", "strategies", "tune",
]
