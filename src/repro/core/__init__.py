"""The paper's contribution: communication planning, strategies, models."""
from repro.core.matrix import EllpackMatrix, make_mesh_like_matrix, spmv_ref_np
from repro.core.plan import CommPlan, GatherCounts, Topology, build_comm_plan
from repro.core.plan_cache import get_comm_plan
from repro.core.spmv import DistributedSpMV
from repro.core.heat2d import Heat2D
from repro.core import (perfmodel, plan_cache, roofline, hlo_cost, strategies,
                        tune)

__all__ = [
    "EllpackMatrix", "make_mesh_like_matrix", "spmv_ref_np",
    "CommPlan", "GatherCounts", "Topology", "build_comm_plan",
    "get_comm_plan", "DistributedSpMV", "Heat2D",
    "perfmodel", "plan_cache", "roofline", "hlo_cost", "strategies", "tune",
]
